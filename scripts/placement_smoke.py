#!/usr/bin/env python
"""CI placement smoke: the slow timescale's two load-bearing promises.

    PYTHONPATH=src python scripts/placement_smoke.py

Gates two contracts on short streaming runs (`make placement-smoke`):

1. **Off means off.** ``placement=None`` and ``PlacementSpec.none()`` must
   produce *identical* summaries on the fused, sharded, and serving
   backends — placement rewrites the host carry between windows, so an
   inactive spec changes no compiled program and no result.
2. **Placement acts.** An active demand-following policy (lfu) on a
   Zipf-skewed multi-model cell sees the exact arrival stream of the
   placement-free run (`tasks_injected` parity), issues decisions every
   seam, and pre-warms gangs (prefetches > 0); on the serving backend the
   real-weight prefetch/evict ledger accrues off the timed path.
"""
from __future__ import annotations

import re
import sys

_MEASURED = re.compile(
    r"(_latency_(p\d+|mean)_s$|_decisions$|^decision_latency_n$"
    r"|measured_busy|^wall_s$)")


def _det(summary):
    return {k: v for k, v in summary.items()
            if isinstance(v, (int, float, bool)) and not _MEASURED.search(k)}


def main() -> int:
    import jax

    from repro.api import ExecSpec, PolicySpec, Simulator, WorkloadSpec
    from repro.core import env as EV
    from repro.core.scenarios import Scenario, zipf_probs
    from repro.core.workload import TraceConfig
    from repro.placement import PlacementSpec

    ecfg = EV.EnvConfig(num_servers=4, max_tasks=8, num_models=3)
    cell = Scenario(
        name="placement-smoke-cell", ecfg=ecfg,
        tcfg=TraceConfig(num_tasks=8, arrival_rate=2.0, max_servers=4,
                         num_models=3, model_probs=zipf_probs(3)))
    key = jax.random.PRNGKey(0)

    def run(backend, placement, **es_kw):
        wl = WorkloadSpec.streaming(
            cell, streams=1 if backend == "serving" else 4,
            num_windows=3, window_tasks=8)
        sim = Simulator(wl, ExecSpec(backend=backend, placement=placement,
                                     **es_kw))
        return sim.run(PolicySpec("greedy"), key)

    # 1. inactive spec == no spec, byte for byte, on every backend --------
    for backend, kw in (("fused", {}), ("sharded", {}),
                        ("serving", {"serving_execute": False})):
        print(f"[placement-smoke] placement=None == PlacementSpec.none() "
              f"({backend})")
        a = run(backend, None, **kw)
        b = run(backend, PlacementSpec.none(), **kw)
        da, db = _det(a.summary), _det(b.summary)
        assert da == db, (
            f"{backend}: PlacementSpec.none() changed results: "
            f"{ {k: (da[k], db[k]) for k in da if da[k] != db[k]} }")
        assert a.raw.placement_counters == b.raw.placement_counters == {}
        print("  bitwise-identical summaries")

    # 2. an active policy acts without perturbing arrivals ----------------
    print("[placement-smoke] lfu placement on the fused backend")
    base = run("fused", None)
    lfu = run("fused", PlacementSpec(policy="lfu"))
    assert lfu.summary["tasks_injected"] == base.summary["tasks_injected"], \
        "placement perturbed the arrival stream"
    pc = lfu.raw.placement_counters
    assert pc["placement_decisions"] == 3, pc
    assert pc["placement_gangs_planned"] > 0, pc
    print(f"  decisions={pc['placement_decisions']} "
          f"planned={pc['placement_gangs_planned']} "
          f"prefetches={pc['placement_prefetches']}")

    print("[placement-smoke] lfu placement on the serving backend")
    slfu = run("serving", PlacementSpec(policy="lfu"),
               serving_execute=False)
    spc = slfu.raw.placement_counters
    assert spc["placement_decisions"] == 3, spc
    assert "placement_weight_prefetches" in slfu.summary
    print(f"  weight_prefetches={slfu.summary['placement_weight_prefetches']} "
          f"weight_evictions={slfu.summary['placement_weight_evictions']}")
    print("[placement-smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
