#!/usr/bin/env python
"""CI actor smoke: the compiled-inference layer's load-bearing promises.

    PYTHONPATH=src python scripts/actor_smoke.py

Gates three contracts on tiny runs (`make actor-smoke`):

1. **No regression.** The default actor (``sampler="ddpm"``) resolved
   through the registry/ActorProgram layer is BITWISE-identical to the
   pre-refactor door (`core.sac.actor_policy` fed straight into
   `batch_rollout`) on the fused backend, and the fused / sharded /
   serving backends agree on the same run.
2. **The chain kernel is exact.** The Pallas whole-chain denoiser kernel
   (interpret mode on CPU) matches the jnp chain oracle bitwise.
3. **Fast samplers hold deterministic parity.** ``ddim:K`` and
   ``distilled`` produce identical deterministic decision processes on
   the fused and serving backends (virtual time, mirror mode) — the
   contract that lets serving swap samplers without a parity suite rerun.
"""
from __future__ import annotations

import re
import sys
import warnings

_MEASURED = re.compile(
    r"(_latency_(p\d+|mean)_s$|_decisions$|^decision_latency_n$"
    r"|measured_busy|^wall_s$|^wall_clock$"
    r"|^model_loads$|^model_reuses$|^tasks_executed$)")


def _det(summary):
    return {k: v for k, v in summary.items()
            if isinstance(v, (int, float, bool)) and not _MEASURED.search(k)}


def _assert_same(da, db, what):
    """Every deterministic key of `da` matches `db` (the serving backend
    adds ledger-only extras — weight prefetch/evict counters — on top)."""
    diff = {k: (v, db.get(k)) for k, v in da.items() if db.get(k) != v}
    assert not diff, f"{what} diverged: {diff}"


def main() -> int:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import actors as ACT
    from repro.api import (ExecSpec, PolicySpec, Simulator, WorkloadSpec,
                           UntrainedPolicyWarning)
    from repro.core import agent as AG
    from repro.core import diffusion as DF
    from repro.core import rollout as RO
    from repro.core import sac as SAC
    from repro.core.env import EnvConfig
    from repro.core.scenarios import Scenario
    from repro.core.workload import TraceConfig, make_trace
    from repro.kernels.denoiser import ops as KOPS

    warnings.simplefilter("ignore", UntrainedPolicyWarning)

    ecfg = EnvConfig(num_servers=4, max_tasks=8, queue_window=4,
                     max_steps=24)
    tcfg = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)
    cell = Scenario(name="actor-smoke-cell", ecfg=ecfg, tcfg=tcfg)
    acfg = AG.AgentConfig(variant="eat-a", T=4, hidden=32)
    params = AG.init_actor(jax.random.PRNGKey(0), ecfg, acfg)
    key = jax.random.PRNGKey(42)

    # 1a. registry door == pre-refactor door, bitwise, on shared traces ---
    print("[actor-smoke] ddpm no-regression: registry vs core.sac door")
    B = 4
    traces = jax.vmap(lambda k: make_trace(k, tcfg))(
        jax.random.split(jax.random.PRNGKey(7), B))
    keys = jax.random.split(jax.random.PRNGKey(8), B)
    old = RO.batch_rollout(ecfg, traces,
                           SAC.actor_policy(ecfg, acfg, deterministic=True),
                           params, keys)
    spec = PolicySpec("eat", params=params,
                      options={"acfg": acfg, "deterministic": True})
    rp = Simulator(WorkloadSpec.episodic(cell, batch=B), ExecSpec()) \
        .resolve(spec)
    assert rp.meta["sampler"] == "ddpm"
    assert rp.program.sampler == "ddpm"
    new = RO.batch_rollout(ecfg, traces, rp.policy, rp.params, keys)
    for name in old.metrics:
        a, b = np.asarray(old.metrics[name]), np.asarray(new.metrics[name])
        np.testing.assert_array_equal(a, b, err_msg=name)
    print(f"  {len(old.metrics)} metric arrays bitwise-identical")

    # 1b. fused == sharded == serving on the registry path ----------------
    def run(backend, spec, **es_kw):
        wl = WorkloadSpec.streaming(
            cell, streams=1, num_windows=2, window_tasks=8,
            max_steps_per_window=16)
        return Simulator(wl, ExecSpec(backend=backend, **es_kw)) \
            .run(spec, key)

    base = run("fused", spec)
    for backend, kw in (("sharded", {}),
                        ("serving", {"serving_execute": False})):
        print(f"[actor-smoke] ddpm parity: fused vs {backend}")
        other = run(backend, spec, **kw)
        _assert_same(_det(base.summary), _det(other.summary),
                     f"{backend} vs fused")
        print("  bitwise-identical summaries")

    # 2. whole-chain kernel vs oracle, bitwise ----------------------------
    print("[actor-smoke] chain kernel (interpret) vs jnp oracle")
    A, F, K = 3, ecfg.obs_shape[1], 5
    ks = jax.random.split(jax.random.PRNGKey(3), 7)
    p = DF.init_denoiser(ks[0], A, F, hidden=24)
    x = jax.random.normal(ks[1], (9, A))
    noises = jax.random.normal(ks[2], (K, 9, A))
    f_s = jax.random.normal(ks[3], (9, F))
    tembs = DF.timestep_embedding(jnp.arange(K) + 1, 16)
    cx = 1.0 + 0.1 * jax.random.normal(ks[4], (K,))
    ce = 0.1 * jax.random.normal(ks[5], (K,))
    cn = 0.1 * jax.random.uniform(ks[6], (K,))
    ref = KOPS.denoise_chain(p, x, noises, f_s, tembs, cx, ce, cn,
                             impl="ref")
    ker = KOPS.denoise_chain(p, x, noises, f_s, tembs, cx, ce, cn,
                             impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))
    print("  bitwise")

    # 3. fast samplers: deterministic fused == serving --------------------
    for sampler in ("ddim:2", "distilled"):
        print(f"[actor-smoke] {sampler} deterministic parity: "
              "fused vs serving")
        fspec = PolicySpec("eat", sampler=sampler,
                           options={"acfg": acfg, "deterministic": True})
        rf = run("fused", fspec)
        rs = run("serving", fspec, serving_execute=False)
        assert rf.summary["sampler"] == rs.summary["sampler"] == sampler
        _assert_same(_det(rf.summary), _det(rs.summary),
                     f"{sampler} serving vs fused")
        print("  bitwise-identical summaries")

    print("[actor-smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
