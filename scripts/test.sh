#!/usr/bin/env bash
# Tier-1 verify: fast test suite (slow-marked trainings are deselected by
# pyproject.toml). Extra pytest args pass through, e.g. scripts/test.sh -m "".
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
