#!/usr/bin/env python
"""Summarise a telemetry trace file: per-span wall-time breakdown.

    PYTHONPATH=src python scripts/trace_summary.py trace.json [--validate]

Reads a Chrome trace-event JSON (or its JSONL sidecar) emitted by
`repro.telemetry` and prints one row per span name — count, total, mean,
and self time (total minus directly nested spans) — sorted by self time,
plus the final value of every counter track. Spans that carry a
``sampler`` attribute (serving ``decision`` spans, stream ``window``
spans — the diffusion actor's sampler label) split into per-sampler rows
(``decision[ddim:5]``), so the self-time table attributes inference cost
to the sampler that paid it. `--validate` additionally schema-checks the
file (strict span names) and exits non-zero on problems.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_events(path: str):
    if path.endswith(".jsonl"):
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]
    with open(path) as f:
        return json.load(f)["traceEvents"]


def split_by_sampler(events):
    """Rename complete spans carrying a `sampler` attr to `name[sampler]`
    so `span_durations` aggregates them per sampler. Non-span events and
    unlabelled spans pass through untouched."""
    out = []
    for e in events:
        s = (e.get("args") or {}).get("sampler") if e.get("ph") == "X" \
            else None
        out.append({**e, "name": f"{e['name']}[{s}]"} if s else e)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace.json or trace.json.jsonl")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the file (strict span names) first")
    args = ap.parse_args(argv)

    from repro.telemetry.schema import span_durations, validate_trace

    if args.validate:
        errors = validate_trace(args.trace, strict_names=True)
        if errors:
            print(f"INVALID trace {args.trace}:", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
            return 1
        print(f"trace OK: {args.trace}")

    events = load_events(args.trace)
    rows = span_durations(split_by_sampler(events))
    if rows:
        wall = max(r["total_s"] for r in rows.values())
        w = max(18, max(len(n) for n in rows))
        print(f"{'span':<{w}s} {'count':>7s} {'total_s':>10s} "
              f"{'mean_s':>10s} {'self_s':>10s} {'self%':>6s}")
        for name, r in sorted(rows.items(),
                              key=lambda kv: -kv[1]["self_total_s"]):
            print(f"{name:<{w}s} {r['count']:7d} {r['total_s']:10.4f} "
                  f"{r['mean_s']:10.6f} {r['self_total_s']:10.4f} "
                  f"{100 * r['self_total_s'] / max(wall, 1e-12):5.1f}%")
    counters = {}
    for e in events:
        if e.get("ph") == "C":
            counters[e["name"]] = e["args"].get("value")
    if counters:
        print("\ncounters (final value):")
        for k, v in sorted(counters.items()):
            print(f"  {k} = {v}")
    print(f"\n{sum(1 for e in events if e.get('ph') == 'X')} spans, "
          f"{len(events)} events")
    return 0


if __name__ == "__main__":
    sys.exit(main())
