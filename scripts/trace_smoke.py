#!/usr/bin/env python
"""CI trace smoke: traced streaming windows on the fused AND serving
backends must (a) emit schema-valid traces and (b) leave every result
number bitwise-identical to an untraced run.

    PYTHONPATH=src python scripts/trace_smoke.py [--outdir DIR]

Runs a short streaming workload per backend twice — tracing off, then on
(with a metrics snapshot) — and fails loudly on any schema violation or
any summary difference. This is the observability contract `make
trace-smoke` gates in CI.
"""
from __future__ import annotations

import argparse
import re
import sys
import tempfile
import warnings

# host wall-clock measurements (decision-profile percentiles, measured
# executor seconds) differ between ANY two runs, traced or not — the
# bitwise contract covers the *result* numbers (QoS, rewards, ledgers)
_MEASURED = re.compile(
    r"(_latency_(p\d+|mean)_s$|_decisions$|^decision_latency_n$"
    r"|measured_busy|^wall_s$)")


def run_backend(backend: str, outdir: str) -> str:
    import jax
    import numpy as np

    from repro import api
    from repro.core import env as EV
    from repro.core.scenarios import Scenario
    from repro.core.workload import TraceConfig as WTraceConfig
    from repro.telemetry import (TraceConfig, default_registry,
                                 reset_tracers, validate_trace)

    ecfg = EV.EnvConfig(num_servers=4, max_tasks=8)
    cell = Scenario(name="trace-smoke", ecfg=ecfg,
                    tcfg=WTraceConfig(num_tasks=8, arrival_rate=2.0,
                                      max_servers=4))
    streams = 1 if backend == "serving" else 2
    wl = api.WorkloadSpec.streaming(cell, streams=streams, num_windows=2,
                                    window_tasks=8, max_steps_per_window=16)
    extra = ({"serving_archs": ("tinyllama-1.1b",),
              "serving_prompt_len": 8, "serving_max_new_tokens": 8}
             if backend == "serving" else {})

    def run(trace_cfg):
        reset_tracers()
        default_registry().clear()
        sim = api.Simulator(wl, api.ExecSpec(backend=backend,
                                             trace=trace_cfg, **extra))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", api.UntrainedPolicyWarning)
            return sim.run("fifo", jax.random.PRNGKey(0))

    r_off = run(TraceConfig())
    path = f"{outdir}/trace_{backend}.json"
    r_on = run(TraceConfig(enabled=True, path=path,
                           metrics_path=f"{outdir}/metrics_{backend}.prom"))

    errors = validate_trace(path, strict_names=True)
    errors += validate_trace(path + ".jsonl", strict_names=True)
    if errors:
        return f"[{backend}] schema violations:\n  " + "\n  ".join(errors)

    if set(r_off.summary) != set(r_on.summary):
        return (f"[{backend}] summary keys differ: "
                f"{set(r_off.summary) ^ set(r_on.summary)}")
    n_cmp = 0
    for k, v in r_off.summary.items():
        if _MEASURED.search(k):
            continue
        w = r_on.summary[k]
        same = (v == w) or (isinstance(v, float) and isinstance(w, float)
                            and np.isnan(v) and np.isnan(w))
        if not same:
            return (f"[{backend}] summary[{k!r}] differs with tracing on: "
                    f"{v!r} vs {w!r}")
        n_cmp += 1
    n_spans = sum(1 for line in open(path + ".jsonl"))
    print(f"[{backend}] OK: {n_spans} events, summaries bitwise-identical "
          f"on vs off ({n_cmp} result keys compared)")
    return ""


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--outdir", default=None,
                    help="where trace/metrics files land (default: tmp)")
    args = ap.parse_args(argv)
    outdir = args.outdir or tempfile.mkdtemp(prefix="trace_smoke_")

    failures = [msg for backend in ("fused", "serving")
                for msg in [run_backend(backend, outdir)] if msg]
    for msg in failures:
        print(msg, file=sys.stderr)
    if not failures:
        print(f"trace smoke PASSED (files in {outdir})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
