#!/usr/bin/env python
"""CI chaos smoke: the fault-injection stack under an aggressive FaultSpec.

    PYTHONPATH=src python scripts/chaos_smoke.py

Gates three contracts on short streaming runs (`make chaos-smoke`):

1. **No silent loss.** On the fused backend under heavy crashes/stragglers
   the stream ledger must balance exactly:
   ``injected == scheduled + dropped + failed_pending_retry + leftover``
   (dropped = backlog-shed + retry-exhausted), and the run must be
   bit-for-bit repeatable (same FaultSpec + key => same summary).
2. **Fault-free identity.** ``faults=None`` and ``FaultSpec.none()`` must
   produce *identical* summaries — the fault branch compiles away.
3. **Serving tolerance.** The serving backend under the same FaultSpec must
   skip crashed gangs (mirror says the server dies mid-run), retry injected
   executor errors, degrade the final attempt, and keep its own ledger
   consistent with the stream's.
"""
from __future__ import annotations

import re
import sys

_MEASURED = re.compile(
    r"(_latency_(p\d+|mean)_s$|_decisions$|^decision_latency_n$"
    r"|measured_busy|^wall_s$)")

CHAOS = dict(seed=2, mtbf=60.0, mttr=15.0, straggler_prob=0.3,
             straggler_factor=3.0, max_retries=3, backoff_base=2.0,
             backoff_cap=20.0, retry_deadline=600.0)


def _det(summary):
    return {k: v for k, v in summary.items()
            if isinstance(v, (int, float, bool)) and not _MEASURED.search(k)}


def _assert_ledger(s, ctx):
    lhs = s["tasks_injected"]
    rhs = (s["tasks_scheduled"] + s["tasks_dropped"]
           + s["tasks_failed_pending_retry"] + s["tasks_leftover"])
    assert lhs == rhs, f"{ctx}: ledger leak — {lhs} != {rhs} ({s})"
    assert s["tasks_dropped"] == (s["tasks_dropped_shed"]
                                  + s["tasks_dropped_retry_exhausted"]), ctx
    print(f"  {ctx}: ledger balances ({lhs} == {rhs}), "
          f"failed={s['tasks_failed']} retried={s['tasks_retried']}")


def main() -> int:
    import jax

    from repro.api import ExecSpec, PolicySpec, Simulator, WorkloadSpec
    from repro.core.scenarios import poisson_scenario
    from repro.faults import FaultSpec

    sc = poisson_scenario(num_servers=4, rate=2.0)
    key = jax.random.PRNGKey(0)

    def run(backend, faults, **es_kw):
        wl = WorkloadSpec.streaming(
            sc, streams=1 if backend == "serving" else 4,
            num_windows=3, window_tasks=8)
        sim = Simulator(wl, ExecSpec(backend=backend, faults=faults,
                                     **es_kw))
        res = sim.run(PolicySpec("greedy"), key)
        fc = (sim._rollout.fault_counters()
              if hasattr(sim._rollout, "fault_counters") else {})
        return res, fc

    chaos = FaultSpec(**CHAOS)

    # 1. fused chaos: conservation + determinism + visible faults ---------
    print("[chaos-smoke] fused backend under chaos")
    r1, _ = run("fused", chaos)
    _assert_ledger(r1.summary, "fused chaos")
    assert r1.summary["tasks_failed"] > 0, "chaos produced zero crashes"
    r2, _ = run("fused", chaos)
    d1, d2 = _det(r1.summary), _det(r2.summary)
    assert d1 == d2, ("fused chaos not deterministic: "
                      f"{ {k: (d1[k], d2[k]) for k in d1 if d1[k] != d2[k]} }")
    print("  deterministic: identical summary on repeat")

    # 2. fault-free identity ---------------------------------------------
    print("[chaos-smoke] faults=None == FaultSpec.none() (fused)")
    b1, _ = run("fused", None)
    b2, _ = run("fused", FaultSpec.none())
    db1, db2 = _det(b1.summary), _det(b2.summary)
    assert db1 == db2, ("FaultSpec.none() changed results: "
                        f"{ {k: (db1[k], db2[k]) for k in db1 if db1[k] != db2[k]} }")
    assert b1.summary["tasks_failed"] == 0
    print("  bitwise-identical summaries")

    # 3. serving under chaos + injected executor errors -------------------
    print("[chaos-smoke] serving backend under chaos + executor faults")
    schaos = FaultSpec(**{**CHAOS, "exec_error_prob": 0.5,
                          "exec_max_attempts": 2})
    s1, fc1 = run("serving", schaos)
    _assert_ledger(s1.summary, "serving chaos")
    print(f"  serving fault counters: {fc1}")
    assert fc1.get("crashed_tasks", 0) + s1.summary["tasks_failed"] > 0
    s2, fc2 = run("serving", schaos)
    assert fc1 == fc2, f"serving fault ledger not deterministic: {fc1} {fc2}"
    assert _det(s1.summary) == _det(s2.summary), "serving chaos summary drift"
    print("  deterministic: identical ledger + summary on repeat")

    sn1, _ = run("serving", None)
    sn2, _ = run("serving", FaultSpec.none())
    assert _det(sn1.summary) == _det(sn2.summary), \
        "serving FaultSpec.none() changed results"
    print("  serving fault-free identity holds")
    print("[chaos-smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
