PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-all test-sharded bench-rollout bench-traffic bench-env-step bench-sharded-rollout traffic-sweep

test-sharded:    ## api backend parity under 8 forced host devices
	XLA_FLAGS=--xla_force_host_platform_device_count=8 $(PY) -m pytest tests/test_api.py -q

test:            ## tier-1: fast suite (slow tests deselected by default)
	$(PY) -m pytest -x -q

test-all:        ## full suite including slow trainings
	$(PY) -m pytest -q -m ""

bench-rollout:   ## batched-rollout engine vs host-loop evaluator
	$(PY) benchmarks/bench_batch_rollout.py

bench-traffic:   ## streaming traffic engine throughput -> BENCH_traffic.json
	$(PY) benchmarks/bench_traffic.py

bench-env-step:  ## fused vs unfused env decision step -> BENCH_env_step.json
	$(PY) benchmarks/bench_env_step.py

bench-sharded-rollout:  ## sharded vs fused backend eps/s -> BENCH_sharded_rollout.json
	$(PY) benchmarks/bench_batch_rollout.py --sharded --devices 8

traffic-sweep:   ## >=100k-task streaming QoS sweep per policy
	$(PY) examples/traffic_sweep.py
