PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-all test-sharded train-stream-smoke serve-smoke trace-smoke chaos-smoke placement-smoke actor-smoke bench-rollout bench-traffic bench-env-step bench-sharded-rollout bench-stream-train bench-serving bench-decision-latency bench-faults bench-placement traffic-sweep

test-sharded:    ## api backend + stream-training parity under 8 forced host devices
	XLA_FLAGS=--xla_force_host_platform_device_count=8 $(PY) -m pytest tests/test_api.py tests/test_stream_train.py -q

test:            ## tier-1: fast suite (slow tests deselected by default)
	$(PY) -m pytest -x -q

test-all:        ## full suite including slow trainings
	$(PY) -m pytest -q -m ""

train-stream-smoke:  ## few-window streaming-training smoke (tiny nets), fused then sharded mesh
	$(PY) examples/train_stream.py --rounds 3 --streams 4 --window-tasks 8 \
	  --servers 4 --variant eat-da --diffusion-steps 2 --warmup-steps 32 \
	  --max-updates-per-round 2 --rate-scale 2.0
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	  $(PY) examples/train_stream.py --backend sharded --rounds 3 --streams 4 \
	  --window-tasks 8 --servers 4 --variant eat-da --diffusion-steps 2 \
	  --warmup-steps 32 --max-updates-per-round 2 --rate-scale 2.0

serve-smoke:     ## short Poisson stream on the real serving backend (tiny reduced model, virtual time)
	$(PY) examples/serve_stream.py --policy greedy --windows 2 \
	  --window-tasks 8 --servers 4 --archs tinyllama-1.1b

trace-smoke:     ## traced stream on fused + serving: schema-valid, bitwise-identical on vs off
	$(PY) scripts/trace_smoke.py

chaos-smoke:     ## fused + serving under an aggressive FaultSpec: ledger conserved, no silent loss, FaultSpec.none() bitwise-identical
	$(PY) scripts/chaos_smoke.py

placement-smoke: ## slow-timescale placement: PlacementSpec.none() bitwise-identical on fused/sharded/serving; lfu acts without perturbing arrivals
	$(PY) scripts/placement_smoke.py

actor-smoke:     ## compiled-inference layer: sampler="ddpm" bitwise vs the pre-refactor door on fused/sharded/serving; chain kernel bitwise vs oracle; ddim/distilled deterministic parity
	$(PY) scripts/actor_smoke.py

bench-decision-latency:  ## per-decision inference latency of every registry policy -> BENCH_decision_latency.json
	$(PY) benchmarks/bench_decision_latency.py

bench-stream-train:  ## stream-training throughput fused vs sharded -> BENCH_stream_train.json
	$(PY) benchmarks/bench_stream_train.py

bench-serving:   ## stream-trained EAT vs baselines on the real cluster -> BENCH_serving.json
	$(PY) benchmarks/bench_serving.py

bench-rollout:   ## batched-rollout engine vs host-loop evaluator
	$(PY) benchmarks/bench_batch_rollout.py

bench-traffic:   ## streaming traffic engine throughput -> BENCH_traffic.json
	$(PY) benchmarks/bench_traffic.py

bench-env-step:  ## fused vs unfused env decision step -> BENCH_env_step.json
	$(PY) benchmarks/bench_env_step.py

bench-faults:    ## QoS-vs-fault-rate frontier, retry+degrade vs naive drop -> BENCH_faults.json
	$(PY) benchmarks/bench_faults.py

bench-placement: ## placement policies vs reactive loading on skewed non-stationary cells -> BENCH_placement.json
	$(PY) benchmarks/bench_placement.py

bench-sharded-rollout:  ## sharded vs fused backend eps/s -> BENCH_sharded_rollout.json
	$(PY) benchmarks/bench_batch_rollout.py --sharded --devices 8

traffic-sweep:   ## >=100k-task streaming QoS sweep per policy
	$(PY) examples/traffic_sweep.py
