"""Whisper small — encoder-decoder audio backbone; mel+conv frontend is a
stub providing 1500 frame embeddings [arXiv:2212.04356]."""
from repro.common.config import ArchConfig, register


@register("whisper-small")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,                      # decoder layers
        encoder_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        head_dim=64,
        activation="gelu",
        cross_attention=True,
        layer_pattern="attn",
        frontend="audio",
        frontend_tokens=1500,               # 30 s of audio at 50 Hz
        frontend_dim=768,
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )
