"""Jamba v0.1 52B — hybrid Mamba+attention (1:7 interleave) with 16-expert
top-2 MoE [arXiv:2403.19887]."""
from repro.common.config import ArchConfig, MoEConfig, SSMConfig, register


@register("jamba-v0.1-52b")
def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        head_dim=128,
        activation="silu",
        layer_pattern="jamba",
        attn_period=8,                      # 1 attention layer per 8 (1:7)
        moe=MoEConfig(num_experts=16, experts_per_token=2, expert_d_ff=14336,
                      layer_period=2),
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
        source="arXiv:2403.19887",
    )
