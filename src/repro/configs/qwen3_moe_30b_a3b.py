"""Qwen3-30B-A3B — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B]."""
from repro.common.config import ArchConfig, MoEConfig, register


@register("qwen3-moe-30b-a3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=768,
        vocab_size=151936,
        head_dim=128,
        activation="silu",
        rope_theta=1000000.0,
        moe=MoEConfig(num_experts=128, experts_per_token=8, expert_d_ff=768,
                      layer_period=1),
        source="hf:Qwen/Qwen3-30B-A3B",
    )
