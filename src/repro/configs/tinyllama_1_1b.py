"""TinyLlama 1.1B — llama2-architecture small dense model [arXiv:2401.02385]."""
from repro.common.config import ArchConfig, register


@register("tinyllama-1.1b")
def config() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-1.1b",
        family="dense",
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=5632,
        vocab_size=32000,
        head_dim=64,
        activation="silu",
        rope_theta=10000.0,
        source="arXiv:2401.02385",
    )
