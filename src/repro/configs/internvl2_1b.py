"""InternVL2 1B — InternViT (stub) + InternLM2-like 0.5B LM backbone
[arXiv:2404.16821]. The ViT + projector is the modality stub: input_specs
provides 256 patch embeddings per image."""
from repro.common.config import ArchConfig, register


@register("internvl2-1b")
def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        head_dim=64,
        activation="silu",
        qkv_bias=True,
        rope_theta=1000000.0,
        frontend="vision",
        frontend_tokens=256,                # ViT patch tokens after projector
        frontend_dim=896,
        tie_embeddings=True,
        source="arXiv:2404.16821",
    )
