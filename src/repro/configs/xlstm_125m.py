"""xLSTM 125M — sLSTM + mLSTM recurrent blocks, no separate FFN (d_ff=0)
[arXiv:2405.04517]."""
from repro.common.config import ArchConfig, SSMConfig, register


@register("xlstm-125m")
def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        head_dim=192,
        activation="silu",
        layer_pattern="xlstm",
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2, mlstm_heads=4),
        tie_embeddings=True,
        source="arXiv:2405.04517",
    )
