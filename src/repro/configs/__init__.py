"""Architecture config registry: importing this package registers all configs."""
from repro.configs import (  # noqa: F401
    jamba_v01_52b,
    tinyllama_1_1b,
    whisper_small,
    gemma_7b,
    olmoe_1b_7b,
    llama3_2_3b,
    qwen2_1_5b,
    internvl2_1b,
    qwen3_moe_30b_a3b,
    xlstm_125m,
)
