"""OLMoE 1B-7B — 64-expert top-8 MoE, MoE in every layer [arXiv:2409.02060]."""
from repro.common.config import ArchConfig, MoEConfig, register


@register("olmoe-1b-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        head_dim=128,
        activation="silu",
        moe=MoEConfig(num_experts=64, experts_per_token=8, expert_d_ff=1024,
                      layer_period=1),
        source="arXiv:2409.02060",
    )
