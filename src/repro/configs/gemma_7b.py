"""Gemma 7B — dense GeGLU model, head_dim=256 [arXiv:2403.08295]."""
from repro.common.config import ArchConfig, register


@register("gemma-7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        d_ff=24576,
        vocab_size=256000,
        head_dim=256,
        activation="geglu",
        tie_embeddings=True,
        source="arXiv:2403.08295",
    )
