"""Qwen2 1.5B — dense GQA with QKV bias [arXiv:2407.10671]."""
from repro.common.config import ArchConfig, register


@register("qwen2-1.5b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-1.5b",
        family="dense",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        head_dim=128,
        activation="silu",
        qkv_bias=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
        source="arXiv:2407.10671",
    )
