"""Declarative specs for the unified simulation facade.

One simulation = (what schedules) x (what arrives) x (how it executes):

    PolicySpec   — a registered policy name plus weight provenance
                   (checkpoint dir / in-memory params / fresh seed) and
                   builder options. Resolved by `api.registry`.
    WorkloadSpec — an episodic trace grid or a streaming arrival process,
                   built from a `core.scenarios.Scenario` cell.
    ExecSpec     — which execution backend runs the batched rollout:
                   "reference" (legacy vmap-of-scans engine), "fused"
                   (fused env-step op, the default), "sharded" (the
                   fused program shard_map'd over a device mesh), or
                   "serving" (the real serving cluster: one physical
                   pool running actual model prefill/decode).

`Simulator(workload, exec_spec).run(policy_spec, key)` is the single door;
every spec is data, so a sweep is a list of specs, not a bespoke loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping, Optional

from repro.core.scenarios import Scenario
from repro.faults import FaultSpec
from repro.placement import PlacementSpec
from repro.telemetry.trace import TraceConfig

BACKENDS = ("reference", "fused", "sharded", "serving")
#: batch-parallel simulated backends — "serving" drives ONE physical
#: cluster (batch/streams must be 1), so sweeps over arbitrary batch
#: sizes should iterate these instead of BACKENDS.
SIM_BACKENDS = ("reference", "fused", "sharded")
MODES = ("episodic", "streaming")


@dataclass(frozen=True, eq=False)
class PolicySpec:
    """Name -> policy, with weight provenance made explicit.

    `params` short-circuits loading (already-trained in-memory weights);
    `checkpoint` restores the latest step via `api.checkpoints
    .restore_params`; neither means learned policies resolve to *fresh*
    weights and are flagged `trained=False` (with an `UntrainedPolicyWarning`)
    so sweep summaries cannot pass off an untrained agent as the paper's.
    `options` feeds the registry builder (e.g. ``{"acfg": AgentConfig(...)}``
    for "eat", ``{"seq_len": 512}`` for the offline meta-heuristics).

    `sampler` selects how a diffusion actor turns its denoiser into an
    action mean (``"ddpm"`` — the full T-step chain, the default —
    ``"ddim:K"`` strided deterministic sampling, or ``"distilled"`` — the
    one-call student head trained by `training.distill`; see
    `repro.actors`). Ignored by non-diffusion policies only in the sense
    that they reject anything but the default. ``None`` means "ddpm".
    """
    name: str
    checkpoint: Optional[str] = None
    params: Any = None
    seed: int = 0
    options: Mapping[str, Any] = field(default_factory=dict)
    sampler: Optional[str] = None


@dataclass(frozen=True, eq=False)
class WorkloadSpec:
    """What the simulator schedules: one scenario cell, episodic or streaming.

    * ``mode="episodic"``: `batch` fresh traces of the cell run to completion
      (`num_steps` caps the decision budget; `collect=True` returns stacked
      transitions for training consumers).
    * ``mode="streaming"``: `batch` parallel open-loop streams, `num_windows`
      windows of `window_tasks` tasks each (`window_tasks=None` keeps the
      cell's episodic `max_tasks`), with the cell's arrival process (Poisson
      at the cell rate when the scenario has none). `collect=True` is the
      streaming *training* mode: each window's stacked (B, T, ...)
      transitions come back on `SimResult.raw.transitions` for training
      consumers (`repro.training.stream_train` drives the window engine
      directly for bounded memory).
    """
    scenario: Scenario
    mode: str = "episodic"
    batch: int = 32
    num_steps: Optional[int] = None
    collect: bool = False
    # streaming-only knobs (mirror traffic.stream.StreamConfig)
    num_windows: int = 16
    window_tasks: Optional[int] = None
    max_steps_per_window: Optional[int] = None
    max_carry: Optional[int] = None
    resp_sla: float = 120.0
    chunk_size: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")

    @classmethod
    def episodic(cls, scenario: Scenario, *, batch: int = 32,
                 num_steps: Optional[int] = None,
                 collect: bool = False) -> "WorkloadSpec":
        return cls(scenario=scenario, mode="episodic", batch=batch,
                   num_steps=num_steps, collect=collect)

    @classmethod
    def streaming(cls, scenario: Scenario, *, streams: int = 32,
                  num_windows: int = 16, window_tasks: Optional[int] = None,
                  max_steps_per_window: Optional[int] = None,
                  max_carry: Optional[int] = None, resp_sla: float = 120.0,
                  chunk_size: int = 0, collect: bool = False) -> "WorkloadSpec":
        return cls(scenario=scenario, mode="streaming", batch=streams,
                   num_windows=num_windows, window_tasks=window_tasks,
                   max_steps_per_window=max_steps_per_window,
                   max_carry=max_carry, resp_sla=resp_sla,
                   chunk_size=chunk_size, collect=collect)


@dataclass(frozen=True)
class ExecSpec:
    """How the batched rollout executes. Hashable: it keys compiled-program
    caches (`api.backends`).

    * ``backend="fused"`` (default): the fused env-step engine
      (`batch_rollout(fused=True)`) — one fused decision op advances all B
      envs per step.
    * ``backend="reference"``: the legacy vmap-of-scans engine on the
      compositional `env.step` (bitwise-identical, slower; the oracle).
    * ``backend="sharded"``: the fused program `shard_map`'d over a 1-D
      device mesh (`launch.mesh.make_data_mesh`) — the batch/stream axis
      splits over `mesh_devices` devices (0 = all local devices; degraded
      to gcd(batch, devices) when the batch does not divide). Bitwise-
      identical to "fused" on the same inputs.
    * ``backend="serving"``: the real serving cluster
      (`repro.serving.backend.ServingRollout`) — ONE physical pool
      (batch/streams must be 1) running actual weight loads and
      patch-parallel prefill/decode per scheduled task. In virtual time
      (``serving_wall_clock=False``, default) the decision process is
      bitwise-identical to "fused"; with ``serving_wall_clock=True``
      measured execution seconds feed latencies, rewards, and
      observations (the sim-to-real loop).

    Serving knobs (`serving_*`) are ignored by the simulated backends.
    `serving_archs=()` resolves to `common.config.ASSIGNED_ARCHS`;
    `serving_execute=False` skips real model execution (pure-mirror mode
    for fast parity checks — pool economics still accrue).

    ``faults`` turns on deterministic fault injection
    (`repro.faults.FaultSpec`): seeded per-server crash/recovery windows,
    straggler slowdowns, and cold-restart cache wipes enter the decision
    step of every backend through extra trace columns, and the serving
    backend additionally arms its executor-level error/timeout injector
    with retry + degraded-fallback handling. ``None`` (the default) and
    ``FaultSpec.none()`` are bitwise-identical to a fault-free run — the
    fault branch is keyed off the trace columns, so the compiled program
    is exactly the pre-fault one.

    ``placement`` turns on the slow timescale (`repro.placement`):
    a `PlacementSpec` names a placement policy ("static" | "lfu" |
    "forecast" | registered) that decides at every stream-window seam
    which models stay resident on which idle servers, pre-forming
    complete gangs the fast scheduler reuses without a cold start (the
    serving backend additionally prefetches/evicts the real weights off
    the timed path). Streaming-only — it acts at window seams, so the
    Simulator rejects it in episodic mode. ``None`` (the default) and
    ``PlacementSpec.none()`` are bitwise-identical to a placement-free
    run on every backend: placement only rewrites host-side carry state
    between windows and never touches a compiled program.

    ``trace`` is the observability front door
    (`repro.telemetry.TraceConfig`): with ``enabled=True`` every layer a
    run touches — Simulator, StreamRunner, the streaming trainers, the
    serving backend — emits host-side spans into ONE trace file
    (Chrome trace-event JSON + JSONL), and `TraceConfig.profile_decisions`
    adds a per-decision policy-inference latency probe to the result
    summary. Disabled (the default) it is the shared no-op tracer: zero
    overhead, bitwise-identical results.
    """
    backend: str = "fused"
    fused_impl: str = "auto"       # fused/sharded: "auto" | "ref" | "pallas"
    mesh_devices: int = 0          # sharded: devices on the mesh (0 = all)
    mesh_axis: str = "data"        # sharded: mesh axis name
    serving_archs: tuple = ()      # serving: model zoo archs (by env model id)
    serving_reduced: bool = True   # serving: reduced-config real models
    serving_wall_clock: bool = False   # serving: measured latencies feed MDP
    serving_execute: bool = True   # serving: run real prefill/decode
    serving_prompt_len: int = 8    # serving: synthetic prompt tokens
    serving_max_new_tokens: int = 16   # serving: request decode budget
    serving_seed: int = 0          # serving: prompt/weight-init PRNG seed
    serving_warmup: Optional[bool] = None  # serving: pre-compile executor
    #                                  programs before timing tasks (None =
    #                                  on iff serving_wall_clock)
    faults: Optional[FaultSpec] = None  # deterministic fault injection
    placement: Optional[PlacementSpec] = None  # slow-timescale placement
    trace: TraceConfig = TraceConfig()  # telemetry front door (see above)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}")
