"""The one checkpoint-restore door for policy weights.

Every `PolicySpec(checkpoint=...)` restores through `restore_params`; the
legacy `traffic.policies._restore` and the ad-hoc example restore paths are
folded into it. Kept separate from `common.checkpoint` (the raw npz pytree
store) so the facade owns path/step resolution and error wording.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.common.checkpoint import latest_step, restore_checkpoint


def restore_params(directory: str, target: Any,
                   step: Optional[int] = None) -> Any:
    """Restore a weight pytree into the structure of `target`.

    `step=None` picks the latest step under `directory`. Raises
    FileNotFoundError when the directory holds no checkpoint — a PolicySpec
    that names a checkpoint must never fall back to fresh weights silently.
    """
    if step is None and latest_step(directory) is None:
        raise FileNotFoundError(
            f"no checkpoint steps under {directory!r}; a PolicySpec with "
            "checkpoint= must point at a saved run (or pass params= / omit "
            "both for fresh weights)")
    return restore_checkpoint(directory, target, step=step)
