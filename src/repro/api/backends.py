"""Pluggable execution backends for the batched rollout engine.

Every backend is one callable with the `batch_rollout` calling convention:

    fn(ecfg, traces, policy, params, keys, *,
       num_steps=None, collect=False, init_state=None) -> RolloutResult

so episodic evaluation, streaming windows (`run_stream(rollout_fn=...)`)
and training collection all swap engines through one seam:

* ``reference`` — the legacy vmap-of-scans engine on the compositional
  `env.step` (`batch_rollout(fused=False)`); the bitwise oracle.
* ``fused`` — the fused env-step op engine (`batch_rollout(fused=True)`,
  the repo default since PR 3).
* ``sharded`` — the fused program `shard_map`'d over a 1-D device mesh
  (`launch.mesh.make_data_mesh`): the batch/stream axis splits across
  devices, policy params are replicated, every output leaf comes back
  sharded on its leading axis. Each shard runs the *same* per-row program
  as the fused backend (the env's FMA/reciprocal bitwise armor makes the
  per-row math independent of the local batch size), so results are
  bitwise-identical to ``fused`` — CI asserts this under
  XLA_FLAGS=--xla_force_host_platform_device_count=8.
* ``serving`` — the real serving cluster (`repro.serving.backend`): one
  physical pool (batch must be 1) whose scheduler state is a mirror
  `EnvState` advanced by the shared decision step, with real weight loads
  and patch-parallel prefill/decode per scheduled task. Virtual time is
  bitwise-identical to ``fused``; wall-clock mode patches measured
  latencies back into rewards and observations. The returned callable is
  STATEFUL (the pool persists across calls — that is the point); build one
  per consumer via `rollout_fn_for` and `reset()` it between runs.

Compiled sharded programs are cached per (ecfg, policy, step budget, mesh)
— the streaming engine reuses one program across all its windows.
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import Optional

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.api.specs import BACKENDS, ExecSpec
from repro.core import env as EV
from repro.core import rollout as RO
from repro.launch import mesh as MX


def device_count() -> int:
    """Local devices visible to the sharded backend."""
    return jax.local_device_count()


def resolve_shards(batch: int, spec: ExecSpec) -> int:
    """Mesh size the sharded backend will actually use for a batch: the
    requested device count (0 = all local), degraded to gcd(batch, devices)
    when the batch axis does not divide evenly."""
    want = spec.mesh_devices or device_count()
    if want > device_count():
        raise ValueError(
            f"ExecSpec.mesh_devices={spec.mesh_devices} but only "
            f"{device_count()} local devices exist (on CPU, force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return math.gcd(int(batch), want)


@functools.lru_cache(maxsize=None)
def _sharded_program(ecfg: EV.EnvConfig, policy, num_steps: Optional[int],
                     collect: bool, fused_impl: str, ndev: int, axis: str,
                     has_init: bool):
    """jit(shard_map(batch_rollout)) over a 1-D `axis` mesh of `ndev`
    devices. traces/keys (and the carried init_state, when given) shard on
    their leading (batch) axis, params replicate, every result leaf comes
    back batch-sharded. Without a carried state the fresh reset is traced
    *inside* the program (each shard resets its local batch), matching the
    fused path's behaviour instead of materialising a host-side reset."""
    mesh = MX.make_data_mesh(ndev, axis=axis)

    def run(traces, params, keys, *init_state):
        return RO.batch_rollout(ecfg, traces, policy, params, keys,
                                num_steps=num_steps, collect=collect,
                                init_state=init_state[0] if has_init else None,
                                fused=True, fused_impl=fused_impl)

    in_specs = (P(axis), P(), P(axis)) + ((P(axis),) if has_init else ())
    f = shard_map(run, mesh=mesh, in_specs=in_specs,
                  out_specs=P(axis), check_rep=False)
    return jax.jit(f)


def rollout_fn_for(spec: ExecSpec = ExecSpec()):
    """Resolve an ExecSpec to a rollout callable (batch_rollout convention).

    The returned callable is safe to reuse across calls and batch sizes;
    program compilation is cached underneath (by `batch_rollout`'s jit for
    reference/fused, by `_sharded_program` for sharded).
    """
    if spec.backend not in BACKENDS:
        raise ValueError(
            f"backend must be one of {BACKENDS}, got {spec.backend!r}")

    if spec.backend == "serving":
        # lazy: the serving stack (model zoo, executor) is heavy and only
        # needed when actually serving. Fresh state per resolution — each
        # consumer owns its own pool, persistent across its windows/rounds.
        from repro.serving.backend import serving_rollout
        return serving_rollout(spec)

    if spec.backend in ("reference", "fused"):
        fused = spec.backend == "fused"

        def fn(ecfg, traces, policy, params, keys, *, num_steps=None,
               collect=False, init_state=None):
            return RO.batch_rollout(ecfg, traces, policy, params, keys,
                                    num_steps=num_steps, collect=collect,
                                    init_state=init_state, fused=fused,
                                    fused_impl=spec.fused_impl)
        fn.backend = spec.backend
        return fn

    def fn(ecfg, traces, policy, params, keys, *, num_steps=None,
           collect=False, init_state=None):
        B = keys.shape[0]
        ndev = resolve_shards(B, spec)
        want = spec.mesh_devices or device_count()
        if ndev < want:
            warnings.warn(
                f"sharded backend: batch {B} does not divide over {want} "
                f"devices; degrading to a {ndev}-device mesh", stacklevel=2)
        prog = _sharded_program(ecfg, policy, num_steps, collect,
                                spec.fused_impl, ndev, spec.mesh_axis,
                                init_state is not None)
        args = (traces, params, keys) + (
            (init_state,) if init_state is not None else ())
        return prog(*args)
    fn.backend = "sharded"
    return fn
