"""repro.api — the unified simulation facade.

One door to everything the repo simulates:

    Simulator(WorkloadSpec, ExecSpec).run(PolicySpec, key) -> SimResult

* `PolicySpec` + the policy registry (`api.registry`): every scheduler —
  baselines, the EAT/PPO agents (checkpoint restore via
  `api.checkpoints.restore_params`), the offline meta-heuristics — under
  one protocol, with weight provenance (`trained`) made explicit.
* `WorkloadSpec`: episodic trace grids or streaming arrival processes,
  built on `core.scenarios` + `traffic.arrivals`.
* `ExecSpec`: pluggable execution backends — "reference" (legacy engine),
  "fused" (fused env-step op, default), "sharded" (fused program
  shard_map'd over a device mesh) — all bitwise-identical.

Consumers: `examples/`, `benchmarks/`, SAC/PPO training collection, and
`traffic.sweep`. The pre-facade doors (`traffic.policies.make_policy`,
`baselines.evaluate_policy_batch`) survive as thin deprecated wrappers.
"""
from repro.api.backends import device_count, resolve_shards, rollout_fn_for
from repro.api.checkpoints import restore_params
from repro.api.registry import (ResolvedPolicy, UntrainedPolicyWarning,
                                available_policies, policy_kind, register,
                                resolve)
from repro.api.simulator import (SimResult, Simulator, evaluate_batch,
                                 resolve_cell)
from repro.api.specs import (BACKENDS, MODES, SIM_BACKENDS, ExecSpec,
                             PolicySpec, WorkloadSpec)

__all__ = [
    "Simulator", "SimResult", "evaluate_batch", "resolve_cell",
    "PolicySpec", "WorkloadSpec", "ExecSpec", "BACKENDS", "SIM_BACKENDS",
    "MODES",
    "ResolvedPolicy", "UntrainedPolicyWarning", "available_policies",
    "policy_kind", "register", "resolve",
    "rollout_fn_for", "resolve_shards", "device_count",
    "restore_params",
]
