"""Policy registry: one name -> (rollout policy, params, provenance).

Unifies every scheduler the repo knows under the rollout policy protocol
(`rollout.Policy`): the non-learned baselines (`random`, `fifo`, `greedy`),
the learned agents (`eat` diffusion-SAC actor and its ablation variants,
`ppo`), and the offline meta-heuristics (`genetic`, `harmony`) — the latter
optimise a fixed action sequence on a workload trace at resolve time and
replay it through `rollout.sequence_policy`.

Resolution is explicit about weight provenance: a learned policy resolved
without `params` or `checkpoint` gets *fresh-initialised* weights, is marked
``trained=False`` and emits an `UntrainedPolicyWarning` — sweep summaries
carry the flag, so an untrained agent can never masquerade as the paper's.

    rp = resolve(PolicySpec("eat", checkpoint="runs/eat"), ecfg)
    batch_rollout(ecfg, traces, rp.policy, rp.params, keys)

Builders lazy-import agent/sac/ppo so importing `repro.api` stays cheap.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.api.checkpoints import restore_params
from repro.api.specs import PolicySpec
from repro.core import env as EV
from repro.core import rollout as RO

BASELINE, LEARNED, OFFLINE = "baseline", "learned", "offline"

# trace_fn(key) -> trace dict; offline builders optimise their sequence on it
TraceFn = Callable[[Any], Dict]


class UntrainedPolicyWarning(UserWarning):
    """A learned policy resolved to fresh-initialised weights."""


@dataclass
class ResolvedPolicy:
    name: str
    policy: RO.Policy
    params: Any
    trained: bool          # False iff a learned policy got fresh weights
    kind: str              # "baseline" | "learned" | "offline"
    meta: Dict[str, Any] = field(default_factory=dict)
    #: the shared compiled-inference layer's view of this policy
    #: (`repro.actors.ActorProgram`), attached by `resolve` — consumers
    #: that need the per-decision program or the vmapped view take it from
    #: here instead of re-deriving their own
    program: Any = None


_BUILDERS: Dict[str, Tuple[str, Callable]] = {}


def register(name: str, kind: str = BASELINE):
    """Register a builder: fn(spec, ecfg, trace_fn) -> ResolvedPolicy."""
    def deco(fn):
        _BUILDERS[name] = (kind, fn)
        return fn
    return deco


def available_policies() -> Tuple[str, ...]:
    return tuple(_BUILDERS)


def policy_kind(name: str) -> str:
    if name not in _BUILDERS:
        raise ValueError(f"unknown policy {name!r}; "
                         f"choose from {available_policies()}")
    return _BUILDERS[name][0]


def resolve(spec, ecfg: EV.EnvConfig, *,
            trace_fn: Optional[TraceFn] = None) -> ResolvedPolicy:
    """Resolve a PolicySpec (or bare name) against an env configuration.

    `trace_fn` supplies the workload trace the offline meta-heuristics
    optimise their action sequence on (the Simulator passes its scenario's
    trace sampler); baselines and learned policies ignore it.
    """
    if isinstance(spec, str):
        spec = PolicySpec(name=spec)
    if spec.name not in _BUILDERS:
        raise ValueError(f"unknown policy {spec.name!r}; "
                         f"choose from {available_policies()}")
    kind, builder = _BUILDERS[spec.name]
    rp = builder(spec, ecfg, trace_fn)
    if rp.program is None:
        from repro.actors.program import actor_program
        rp.program = actor_program(ecfg, rp.policy)
    return rp


# ----------------------------------------------------------------------
# learned-weight provenance shared by the eat/ppo builders
def _load_weights(spec: PolicySpec, fresh_init: Callable[[], Any]):
    """(params, trained): explicit weights > checkpoint > fresh + warning."""
    if spec.params is not None:
        return spec.params, True
    params = fresh_init()
    if spec.checkpoint:
        return restore_params(spec.checkpoint, params), True
    # stacklevel 4 = the caller of resolve() (builder <- resolve <- caller)
    warnings.warn(
        f"policy {spec.name!r} resolved with fresh-initialised weights "
        "(no checkpoint= or params= given) — results reflect an UNTRAINED "
        "agent and are flagged trained=False",
        UntrainedPolicyWarning, stacklevel=4)
    return params, False


# ----------------------------------------------------------------------
@register("random", BASELINE)
def _build_random(spec, ecfg, trace_fn):
    return ResolvedPolicy("random", RO.uniform_policy(ecfg), {}, True,
                          BASELINE)


@register("fifo", BASELINE)
def _build_fifo(spec, ecfg, trace_fn):
    steps_frac = float(spec.options.get("steps_frac", 0.5))
    return ResolvedPolicy("fifo", RO.fifo_policy(ecfg, steps_frac), {}, True,
                          BASELINE, {"steps_frac": steps_frac})


@register("greedy", BASELINE)
def _build_greedy(spec, ecfg, trace_fn):
    return ResolvedPolicy("greedy", RO.greedy_policy(ecfg), {}, True,
                          BASELINE)


@register("eat", LEARNED)
def _build_eat(spec, ecfg, trace_fn):
    from repro import actors as ACT
    from repro.core import agent as AG
    acfg = spec.options.get("acfg")
    if acfg is None:
        kw = {k: spec.options[k] for k in ("variant", "T")
              if k in spec.options}
        acfg = AG.AgentConfig(**kw)
    deterministic = bool(spec.options.get("deterministic", True))
    # sampler selection is the one registry knob every consumer inherits:
    # Simulator, StreamRunner, stream training and serving all receive the
    # policy the actor layer builds for it (spec.sampler wins over the
    # legacy options key)
    sampler = ACT.normalize_sampler(
        spec.sampler if spec.sampler is not None
        else spec.options.get("sampler"))

    def fresh():
        p = AG.init_actor(jax.random.PRNGKey(spec.seed), ecfg, acfg)
        if sampler == "distilled":
            p["student"] = ACT.init_student(
                jax.random.fold_in(jax.random.PRNGKey(spec.seed), 1),
                ecfg, acfg)
        return p

    params, trained = _load_weights(spec, fresh)
    if sampler == "distilled" and "student" not in params:
        raise ValueError(
            "sampler='distilled' needs params['student'] (a denoiser-shaped "
            "head from repro.training.distill.distill_actor or "
            "repro.actors.init_student); the given weights have none")
    policy = ACT.actor_policy(ecfg, acfg, deterministic=deterministic,
                              sampler=sampler)
    return ResolvedPolicy(
        "eat", policy, params, trained, LEARNED,
        {"variant": acfg.variant, "sampler": sampler})


@register("ppo", LEARNED)
def _build_ppo(spec, ecfg, trace_fn):
    from repro.core import ppo as PPO
    params, trained = _load_weights(
        spec, lambda: PPO.init_ppo(jax.random.PRNGKey(spec.seed), ecfg).params)
    return ResolvedPolicy("ppo", PPO.ppo_policy(ecfg), params, trained,
                          LEARNED)


# ----------------------------------------------------------------------
def _offline_trace(spec, ecfg, trace_fn, algo: str):
    if trace_fn is None:
        raise ValueError(
            f"policy {algo!r} optimises an action sequence on a workload "
            "trace; resolve it through a Simulator (which supplies its "
            "scenario's traces) or pass trace_fn=")
    return trace_fn(jax.random.PRNGKey(spec.seed))


@register("genetic", OFFLINE)
def _build_genetic(spec, ecfg, trace_fn):
    from repro.core import baselines as BL
    gcfg = spec.options.get("gcfg")
    if gcfg is None:
        kw = {k: spec.options[k] for k in
              ("population", "generations", "parents", "elites", "seq_len",
               "mutation_prob") if k in spec.options}
        gcfg = BL.GeneticConfig(**kw)
    trace = _offline_trace(spec, ecfg, trace_fn, "genetic")
    seq, fit = BL.genetic_schedule(jax.random.PRNGKey(spec.seed + 1), ecfg,
                                   trace, gcfg)
    return ResolvedPolicy("genetic", RO.sequence_policy(ecfg), {"seq": seq},
                          True, OFFLINE, {"fitness": float(fit)})


@register("harmony", OFFLINE)
def _build_harmony(spec, ecfg, trace_fn):
    from repro.core import baselines as BL
    hcfg = spec.options.get("hcfg")
    if hcfg is None:
        kw = {k: spec.options[k] for k in
              ("memory_size", "improvisations", "improv_batch", "seq_len")
              if k in spec.options}
        hcfg = BL.HarmonyConfig(**kw)
    trace = _offline_trace(spec, ecfg, trace_fn, "harmony")
    seq, fit = BL.harmony_schedule(jax.random.PRNGKey(spec.seed + 1), ecfg,
                                   trace, hcfg)
    return ResolvedPolicy("harmony", RO.sequence_policy(ecfg), {"seq": seq},
                          True, OFFLINE, {"fitness": float(fit)})
