"""`Simulator` — the single door to episodic and streaming simulation.

    from repro import api

    sim = api.Simulator(
        api.WorkloadSpec.streaming(scenarios.bursty_traffic(8), streams=32,
                                   num_windows=50, window_tasks=64),
        api.ExecSpec(backend="sharded"))
    result = sim.run(api.PolicySpec("eat", checkpoint="runs/eat"), key)
    result.summary["latency_p99"], result.trained

One Simulator = one workload x one execution backend; `run` takes any
registered policy (see `api.registry`) and returns a `SimResult` whose
`summary` is a flat scalar dict with the same core keys in both modes.
Policies resolve against the workload's env, offline meta-heuristics get
the workload's trace sampler to optimise on, and the execution backend
("reference" | "fused" | "sharded") is bitwise-transparent: the same spec
grid produces the same numbers on every backend.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.api import backends as BK
from repro.api import registry as REG
from repro.api.specs import ExecSpec, PolicySpec, WorkloadSpec
from repro.core.scenarios import Scenario, make_scenario_trace
from repro.faults import FaultTimeline, fault_horizon, faults_active
from repro.placement import placement_active
from repro.telemetry import metrics as MET
from repro.telemetry import profile as PROF
from repro.telemetry.trace import jax_profile, tracer_for
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.stream import ProcessTaskSource, StreamConfig, run_stream

PolicyLike = Union[str, PolicySpec]


def resolve_cell(sc: Scenario, window_tasks: Optional[int] = None):
    """(ecfg, tcfg, process) for streaming a scenario cell: `window_tasks`
    overrides the cell's episodic max_tasks; a missing arrival process means
    Poisson at the cell's configured rate."""
    ecfg, tcfg = sc.ecfg, sc.tcfg
    if window_tasks and window_tasks != ecfg.max_tasks:
        ecfg = dataclasses.replace(ecfg, max_tasks=int(window_tasks))
        tcfg = dataclasses.replace(tcfg, num_tasks=int(window_tasks))
    proc = sc.arrival if sc.arrival is not None else PoissonArrivals(
        tcfg.arrival_rate)
    return ecfg, tcfg, proc


@dataclass
class SimResult:
    policy: str
    trained: bool
    kind: str                    # baseline | learned | offline
    mode: str                    # episodic | streaming
    backend: str
    scenario: str
    summary: Dict[str, float]    # flat scalars (means / QoS aggregates)
    metrics: Dict[str, np.ndarray] = field(default_factory=dict)
    per_window: Optional[List[Dict]] = None       # streaming only
    wall_s: float = 0.0
    raw: Any = None              # RolloutResult | StreamResult

    def row(self) -> Dict[str, Any]:
        """Flat telemetry row (sweep/JSON schema)."""
        out = {"policy": self.policy, "trained": self.trained,
               "mode": self.mode, "exec_backend": self.backend,
               "cell": self.scenario, "wall_s": self.wall_s}
        out.update(self.summary)
        return out


class Simulator:
    """One workload x one execution backend; `run` any registered policy."""

    def __init__(self, workload: WorkloadSpec,
                 exec_spec: ExecSpec = ExecSpec()):
        self.workload = workload
        self.exec_spec = exec_spec
        self.scenario = workload.scenario
        if workload.mode == "streaming":
            self.ecfg, self.tcfg, self.process = resolve_cell(
                workload.scenario, workload.window_tasks)
        else:
            self.ecfg, self.tcfg = workload.scenario.ecfg, workload.scenario.tcfg
            self.process = workload.scenario.arrival
        if exec_spec.backend == "serving" and workload.batch != 1:
            raise ValueError(
                "serving backend runs ONE physical cluster; build the "
                "workload with batch/streams=1, got "
                f"{workload.batch}")
        if placement_active(exec_spec.placement) \
                and workload.mode != "streaming":
            raise ValueError(
                "placement is a streaming-only subsystem (the slow "
                "timescale acts at window seams); use mode='streaming' or "
                "drop ExecSpec.placement")
        self.tracer = tracer_for(exec_spec.trace)
        self._rollout = BK.rollout_fn_for(exec_spec)

    # -- policy resolution against this workload's env ------------------
    def trace_fn(self):
        """Trace sampler of this workload's cell (offline schedulers
        optimise on it; episodic runs draw eval traces from it)."""
        sc = dataclasses.replace(self.scenario, ecfg=self.ecfg,
                                 tcfg=self.tcfg)
        return lambda key: make_scenario_trace(key, sc)

    def resolve(self, policy: PolicyLike) -> REG.ResolvedPolicy:
        return REG.resolve(policy, self.ecfg, trace_fn=self.trace_fn())

    # -- runs ------------------------------------------------------------
    def run(self, policy: PolicyLike, key) -> SimResult:
        tcfg = self.exec_spec.trace
        with self.tracer.span(
                "run", cat="run", mode=self.workload.mode,
                backend=self.exec_spec.backend, cell=self.scenario.name):
            with self.tracer.span("resolve_policy", cat="run"):
                rp = self.resolve(policy)
            if hasattr(self._rollout, "reset"):
                self._rollout.reset()  # serving: fresh cluster per run, so
                #                        a sweep's policies never inherit a
                #                        warm pool from the previous policy
            t0 = time.perf_counter()
            with jax_profile(tcfg):
                if self.workload.mode == "episodic":
                    res = self._run_episodic(rp, key)
                else:
                    res = self._run_streaming(rp, key)
            res.wall_s = time.perf_counter() - t0
            if rp.meta.get("sampler"):
                res.summary["sampler"] = str(rp.meta["sampler"])
            if tcfg.enabled and tcfg.profile_decisions:
                with self.tracer.span("profile_decisions", cat="profile",
                                      policy=rp.name):
                    res.summary.update(PROF.profile_policy(
                        self.ecfg, rp.policy, rp.params,
                        jax.random.fold_in(key, 0x9e77),
                        iters=tcfg.profile_iters))
        self._flush_telemetry()
        return res

    def _labels(self, rp: REG.ResolvedPolicy) -> Dict[str, str]:
        out = {"policy": rp.name, "backend": self.exec_spec.backend,
               "mode": self.workload.mode, "cell": self.scenario.name}
        if rp.meta.get("sampler"):        # diffusion actors: metric rows
            out["sampler"] = str(rp.meta["sampler"])   # split per sampler
        return out

    def _flush_telemetry(self) -> None:
        """Rewrite the trace file and (when configured) the metrics
        snapshots — called at every run end so a sweep's files are always
        consistent on disk."""
        self.tracer.write()
        tcfg = self.exec_spec.trace
        if tcfg.enabled and tcfg.metrics_path:
            reg = MET.default_registry()
            reg.write_prometheus(tcfg.metrics_path)
            reg.write_jsonl(tcfg.metrics_path + ".jsonl")

    def sweep(self, policies: Sequence[PolicyLike], key) -> List[SimResult]:
        out = []
        for i, p in enumerate(policies):
            out.append(self.run(p, jax.random.fold_in(key, i)))
        return out

    def _attach_faults(self, traces, batch: int):
        """Merge window-0 fault columns into episodic traces (no-op when
        `ExecSpec.faults` is absent/inactive, keeping the compiled program
        and results bitwise-identical to a fault-free run)."""
        fspec = self.exec_spec.faults
        if not faults_active(fspec):
            return traces, None
        timeline = FaultTimeline(fspec, self.ecfg.num_servers, batch)
        fa = timeline.window_arrays(0, np.zeros(batch, np.float64),
                                    fault_horizon(self.ecfg.time_limit,
                                                  fspec))
        out = dict(traces)
        out.update(fa)
        return out, timeline

    def _run_episodic(self, rp: REG.ResolvedPolicy, key) -> SimResult:
        wl = self.workload
        k_trace, k_run = jax.random.split(key)
        traces = jax.vmap(self.trace_fn())(jax.random.split(k_trace, wl.batch))
        traces, timeline = self._attach_faults(traces, wl.batch)
        keys = jax.random.split(k_run, wl.batch)
        with self.tracer.span("episodic_rollout", cat="rollout",
                              policy=rp.name, batch=wl.batch):
            res = self._rollout(self.ecfg, traces, rp.policy, rp.params, keys,
                                num_steps=wl.num_steps, collect=wl.collect)
            jax.block_until_ready(res.metrics)
        metrics = {k: np.asarray(v) for k, v in res.metrics.items()}
        summary = {f"mean_{k}": float(np.mean(v)) for k, v in metrics.items()}
        summary["n_episodes"] = wl.batch
        if self.exec_spec.backend == "serving":
            summary.update(self._rollout.serving_stats())
        MET.publish_summary(summary, prefix="eat_episodic",
                            labels=self._labels(rp))
        if timeline is not None:
            self._publish_faults(timeline.counters(), rp)
        return SimResult(policy=rp.name, trained=rp.trained, kind=rp.kind,
                         mode="episodic", backend=self.exec_spec.backend,
                         scenario=self.scenario.name, summary=summary,
                         metrics=metrics, raw=res)

    def _run_streaming(self, rp: REG.ResolvedPolicy, key) -> SimResult:
        wl = self.workload
        k_src, k_run = jax.random.split(key)
        source = ProcessTaskSource(self.process, self.tcfg, k_src,
                                   num_streams=wl.batch,
                                   chunk_size=wl.chunk_size)
        scfg = StreamConfig(num_windows=wl.num_windows, num_streams=wl.batch,
                            max_steps_per_window=wl.max_steps_per_window,
                            max_carry=wl.max_carry, resp_sla=wl.resp_sla,
                            chunk_size=wl.chunk_size,
                            faults=self.exec_spec.faults,
                            placement=self.exec_spec.placement)
        res = run_stream(self.ecfg, rp.policy, rp.params, source, k_run,
                         scfg, rollout_fn=self._rollout, collect=wl.collect,
                         tracer=self.tracer)
        summary = dict(res.summary)
        summary["arrival"] = type(self.process).__name__
        summary["num_servers"] = self.ecfg.num_servers
        if self.exec_spec.backend == "serving":
            summary.update(self._rollout.serving_stats())
            summary["wall_clock"] = self.exec_spec.serving_wall_clock
        labels = self._labels(rp)
        res.aggregator.publish(labels=labels)
        if self.exec_spec.backend == "serving":
            ledger = self._rollout.pool_counters()
            MET.publish_counters(ledger, prefix="eat_serving", labels=labels)
            MET.publish_summary(
                {k: v for k, v in self._rollout.serving_stats().items()
                 if k not in ledger},
                prefix="eat_serving", labels=labels)
        fault_ledger = dict(getattr(res, "fault_counters", {}) or {})
        if self.exec_spec.backend == "serving" and hasattr(
                self._rollout, "fault_counters"):
            fault_ledger.update(self._rollout.fault_counters())
        if fault_ledger:
            self._publish_faults(fault_ledger, rp)
        placement_ledger = dict(getattr(res, "placement_counters", {}) or {})
        if placement_ledger:
            if self.exec_spec.backend == "serving" and hasattr(
                    self._rollout, "placement_counters"):
                placement_ledger.update(self._rollout.placement_counters())
            self._publish_placement(placement_ledger, summary, rp)
        return SimResult(policy=rp.name, trained=rp.trained, kind=rp.kind,
                         mode="streaming", backend=self.exec_spec.backend,
                         scenario=self.scenario.name, summary=summary,
                         per_window=res.per_window, raw=res)

    def _publish_faults(self, ledger: Dict[str, int],
                        rp: REG.ResolvedPolicy) -> None:
        """Fault-injection ledger -> ``eat_fault_*`` counters in the unified
        registry (see docs/telemetry_schema.md)."""
        MET.publish_counters({k: int(v) for k, v in ledger.items()},
                             prefix="eat_fault", labels=self._labels(rp))

    def _publish_placement(self, ledger: Dict, summary: Dict[str, float],
                           rp: REG.ResolvedPolicy) -> None:
        """Placement ledger -> ``eat_placement_*`` metrics: the host
        counters, a warm-hit-rate gauge (the run's gang-reuse rate — what
        pre-warming buys), and per-model cold-start-rate gauges labelled
        ``{model=...}`` (see docs/telemetry_schema.md)."""
        labels = self._labels(rp)
        per_model = ledger.pop("per_model", {})
        MET.publish_counters(
            {k.removeprefix("placement_"): v for k, v in ledger.items()},
            prefix="eat_placement", labels=labels)
        reg = MET.default_registry()
        if "reuse_rate" in summary:
            reg.gauge("eat_placement_warm_hit_rate",
                      "gang-reuse rate of a placement-enabled run").set(
                float(summary["reuse_rate"]), labels=labels)
        g = reg.gauge("eat_placement_cold_start_rate",
                      "per-model reload fraction of scheduled tasks")
        for m, row in per_model.items():
            g.set(float(row["cold_start_rate"]),
                  labels={**labels, "model": str(m)})


# ----------------------------------------------------------------------
def evaluate_batch(ecfg, traces, policy, keys, *, params=None,
                   exec_spec: ExecSpec = ExecSpec(),
                   num_steps: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Facade door for evaluating *explicit* traces (the batched-evaluator
    use case): B traces in one program on any backend. `policy` is either a
    PolicySpec / registered name (resolved here; `params` ignored) or a raw
    rollout policy callable paired with `params`. Returns per-episode (B,)
    numpy metric arrays."""
    if isinstance(policy, (str, PolicySpec)):
        rp = REG.resolve(policy, ecfg)
        policy, params = rp.policy, rp.params
    if faults_active(exec_spec.faults):
        B = int(np.asarray(keys).shape[0])
        timeline = FaultTimeline(exec_spec.faults, ecfg.num_servers, B)
        traces = dict(traces)
        traces.update(timeline.window_arrays(
            0, np.zeros(B, np.float64),
            fault_horizon(ecfg.time_limit, exec_spec.faults)))
    res = BK.rollout_fn_for(exec_spec)(
        ecfg, traces, policy, {} if params is None else params, keys,
        num_steps=num_steps)
    return {k: np.asarray(v) for k, v in res.metrics.items()}
