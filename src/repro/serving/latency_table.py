"""Per-architecture latency scales for the scheduler.

The paper measures Stable-Diffusion wall-clock per inference step (Table VI).
When the scheduler manages the 10 assigned architectures as distinct AIGC
services, each service's per-step and init times scale with its active
parameter count (decode FLOPs ~ 2 N_active) relative to the SD-v1.4
reference (~860M UNet params), and its load time with total checkpoint bytes.
These scales feed EnvConfig.model_scale in multi-service mode.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.common.config import ASSIGNED_ARCHS, get_config

SD_V14_PARAMS = 860e6          # reference service (paper's Table VI)


def arch_scales() -> Dict[str, float]:
    out = {}
    for name in ASSIGNED_ARCHS:
        cfg = get_config(name)
        out[name] = cfg.param_count(active_only=True) / SD_V14_PARAMS
    return out


def env_model_scales(clip: Tuple[float, float] = (0.25, 8.0)) -> Tuple[float, ...]:
    """Clipped scales in ASSIGNED_ARCHS order (extremes clipped so episode
    horizons stay comparable to the paper's)."""
    s = arch_scales()
    lo, hi = clip
    return tuple(min(hi, max(lo, s[n])) for n in ASSIGNED_ARCHS)
