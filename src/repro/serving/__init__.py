"""Real model serving: pool + executor + engine, and the serving execution
backend that plugs the physical cluster into the unified `repro.api` stack
(`ExecSpec(backend="serving")`)."""
from repro.serving.backend import ServingRollout, serving_rollout  # noqa: F401
from repro.serving.engine import Request, ServingEngine            # noqa: F401
from repro.serving.executor import ModelExecutor, chunkable        # noqa: F401
from repro.serving.pool import LogicalServer, ServerPool           # noqa: F401
from repro.serving.runner import (                                  # noqa: F401
    ServingStreamRunner, serve_stream)

__all__ = [
    "Request", "ServingEngine", "ServerPool", "LogicalServer",
    "ModelExecutor", "chunkable", "ServingRollout", "serving_rollout",
    "ServingStreamRunner", "serve_stream",
]
