"""The serving execution backend: real model execution behind the
`batch_rollout` calling convention.

`ServingRollout` is a stateful callable with the unified backend signature

    fn(ecfg, traces, policy, params, keys, *,
       num_steps=None, collect=False, init_state=None) -> RolloutResult

so `Simulator(ExecSpec(backend="serving"))`, `StreamRunner(rollout_fn=...)`
and `train_stream_sac(exec_spec=...)` all drive a real serving cluster
through the exact seam the simulated engines use. One constraint: the batch
axis is 1 — there is one physical pool, not B parallel universes.

Design: the scheduler's view of the cluster is a *mirror* `EnvState`
advanced by the shared, parity-tested `env.step_with_queue` — gang
selection, reuse detection, reward shaping, and the Eq.-6 observation are
therefore byte-for-byte the simulator's. The pool (`serving.pool`) holds the
real per-server weights and the load/reuse ledger; the executor
(`serving.executor`) runs real patch-parallel prefill + decode for every
scheduled task. Two time modes:

* virtual (``serving_wall_clock=False``): latencies stay on the Table-VI
  model inside the decision step, so the whole rollout — final state,
  rewards, collected transitions — is bitwise-identical to the fused
  simulator on the same (trace, policy, key). This is the seam test: real
  execution rides along without perturbing the MDP.
* wall-clock (``serving_wall_clock=True``): each scheduled task's measured
  execution seconds are patched back into the mirror (`server_free_at`,
  `task_finish`), the reward is recomputed from the *measured* t_resp
  (Eq. 4a), and the next observation/queue derive from the patched state —
  the sim-to-real loop closes: `train_stream_sac` fine-tunes on measured
  latencies, and `StreamAggregator` rows report wall-clock QoS.

PRNG, freeze-after-done, and transition layout follow `rollout_episode`
exactly (one `split` per decision; post-done steps replay the frozen state),
so `sac.flatten_valid_transitions` consumes serving-collected windows
unchanged — asserted by tests/test_serving_backend.py.
"""
from __future__ import annotations

import functools
import time
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors.program import actor_program
from repro.common.config import ASSIGNED_ARCHS
from repro.core import env as EV
from repro.core import obs as OBS
from repro.core import quality as Q
from repro.core.rollout import RolloutResult, Transitions
from repro.faults import ExecFaultInjector, ExecutorFault, FaultSpec
from repro.serving.executor import ModelExecutor
from repro.serving.pool import ServerPool
from repro.telemetry.profile import DecisionProfile
from repro.telemetry.trace import NULL_TRACER, tracer_for


def _policy_prog(ecfg: EV.EnvConfig, policy):
    """DEPRECATED door: the per-decision inference program now lives on the
    shared actor layer — use ``repro.actors.actor_program(ecfg,
    policy).act``. This wrapper returns exactly that program (same compiled
    executable, same (key split + actor forward) semantics, same bitwise
    guarantees vs the fused simulator) and will be removed once external
    callers migrate."""
    warnings.warn(
        "serving.backend._policy_prog is deprecated; use "
        "repro.actors.actor_program(ecfg, policy).act",
        DeprecationWarning, stacklevel=2)
    return actor_program(ecfg, policy).act


@functools.lru_cache(maxsize=None)
def _env_prog(ecfg: EV.EnvConfig):
    """The mirror env advance: `env.step_with_queue` on the pre-step queue
    view — the second half of one `rollout_episode` scan iteration."""
    @jax.jit
    def step(trace, state, q, action):
        return EV.step_with_queue(ecfg, trace, state, q, action)
    return step


@functools.lru_cache(maxsize=None)
def _wall_patch_prog(ecfg: EV.EnvConfig):
    """Patch a just-scheduled decision with its measured busy seconds:
    rewrite the gang's `server_free_at` and the task's finish time, recompute
    the reward from the measured t_resp (Eq. 4a; t_avg comes from the same
    pre-step queue view the virtual reward used), re-evaluate done, and
    rebuild the queue/observation from the patched state."""
    @jax.jit
    def patch(trace, q_pre, nstate, k, sel, busy):
        t = nstate.time                      # scheduling never moves time
        finish = t + busy
        st = nstate._replace(
            server_free_at=jnp.where(sel, finish, nstate.server_free_at),
            task_finish=nstate.task_finish.at[k].set(finish))
        q_k = st.task_quality[k]
        pen = Q.quality_penalty(q_k, ecfg.q_min, ecfg.p_quality)
        t_resp = finish - trace["arr_time"][k]
        still = q_pre.queued & (jnp.arange(ecfg.max_tasks) != k)
        n_q = jnp.maximum(jnp.sum(still.astype(jnp.float32)), 1.0)
        t_avg = jnp.sum(jnp.where(still, t - trace["arr_time"], 0.0)) / n_q
        r = ecfg.alpha_q * q_k - ecfg.lambda_q * pen \
            + ecfg.k_time / (ecfg.beta_t * t_resp + ecfg.mu_t * t_avg + 1e-3)
        all_done = jnp.all((st.task_status == 2) |
                           ((st.task_status == 1) & (st.task_finish <= t)))
        d = all_done | (t >= ecfg.time_limit) | \
            (st.steps_taken >= ecfg.max_steps)
        q2 = OBS.visible_queue(ecfg, trace, st)
        obs2 = OBS.observe_from(ecfg, trace, st, q2)
        return st, q2, obs2, r, d
    return patch


@functools.lru_cache(maxsize=None)
def _metrics_prog(ecfg: EV.EnvConfig):
    return jax.jit(lambda trace, st: EV.episode_metrics(ecfg, trace, st))


class ServingRollout:
    """Stateful serving backend under the `batch_rollout` convention.

    The pool (loaded weights, load/reuse counters) persists across calls —
    across stream windows and training rounds, exactly like a long-lived
    cluster. `reset()` drops every loaded model (the Simulator calls it at
    the start of each `run`, so sweep policies never inherit a warm pool).
    """

    backend = "serving"

    def __init__(self, num_servers: int, *, archs=(), reduced: bool = True,
                 wall_clock: bool = False, execute: bool = True,
                 prompt_len: int = 8, max_new_tokens: int = 16,
                 seed: int = 0, warmup: Optional[bool] = None, tracer=None,
                 faults: Optional[FaultSpec] = None):
        self.archs = tuple(archs) if archs else ASSIGNED_ARCHS
        self.reduced = reduced
        self.wall_clock = wall_clock
        self.execute = execute
        self.prompt_len = int(prompt_len)
        self.max_new_tokens = int(max_new_tokens)
        self.seed = int(seed)
        # warmup pre-compiles executor programs outside the timed region so
        # wall-clock latencies measure inference, not XLA compilation; it
        # defaults on exactly when measured seconds feed the MDP
        self.warmup = bool(wall_clock) if warmup is None else bool(warmup)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.pool = ServerPool(num_servers)
        self.executor = ModelExecutor(reduced=reduced, tracer=self.tracer)
        self.profile = DecisionProfile()
        self.faults = faults if (faults is not None and faults.active) \
            else None
        self.injector = ExecFaultInjector(self.faults)
        self.tasks_executed = 0
        self.measured_busy: list = []       # wall seconds per executed task
        self._load_key = jax.random.PRNGKey(seed)
        self._prompt_rng = np.random.default_rng(seed)
        # placement prefetch draws weights from its OWN key stream so the
        # on-demand `_load` sequence — and with it every scheduled task's
        # weights — is identical to a placement-free run
        self._prefetch_key = jax.random.PRNGKey(seed ^ 0x5EED)
        self.placement_prefetches = 0
        self.placement_evictions = 0

    # ------------------------------------------------------------------
    def reset(self) -> None:
        """Fresh cluster: unload every model, zero the ledgers. Compiled
        executor programs (and the warmed-shape memo) survive — compilation
        caches are process-level, not cluster state."""
        self.pool.reset()
        self.injector.reset()
        self.profile = DecisionProfile()
        self.tasks_executed = 0
        self.measured_busy = []
        self._load_key = jax.random.PRNGKey(self.seed)
        self._prompt_rng = np.random.default_rng(self.seed)
        self._prefetch_key = jax.random.PRNGKey(self.seed ^ 0x5EED)
        self.placement_prefetches = 0
        self.placement_evictions = 0

    def serving_stats(self) -> Dict[str, float]:
        out = dict(self.pool.counters())
        out["tasks_executed"] = self.tasks_executed
        if self.measured_busy:
            out["measured_busy_mean_s"] = float(np.mean(self.measured_busy))
        out.update(self.profile.summary())
        out.update(self.placement_counters())
        return out

    def placement_counters(self) -> Dict[str, int]:
        """Real-weight prefetch/evict ledger (zero in a placement-free
        run); kept off `pool.counters()`, whose key set is pinned."""
        return {"placement_weight_prefetches": self.placement_prefetches,
                "placement_weight_evictions": self.placement_evictions}

    def pool_counters(self) -> Dict[str, int]:
        """The pool's monotonic load/reuse/shed ledger alone (metrics
        registry counters; `serving_stats` adds derived scalars)."""
        return dict(self.pool.counters())

    def fault_counters(self) -> Dict[str, int]:
        """Fault-tolerance ledger: pool retry/degrade counts + injected
        errors (all zero in a fault-free run)."""
        out = dict(self.pool.fault_counters())
        out.update(self.injector.counters())
        return out

    # ------------------------------------------------------------------
    def _arch_of(self, m_k: int) -> str:
        return self.archs[m_k % len(self.archs)]

    def _run_task(self, m_k: int, c_k: int, steps: int, sel: np.ndarray,
                  reuse: bool) -> float:
        """Pool bookkeeping + real execution for one scheduled gang.
        Returns measured wall seconds of the load + generate work."""
        arch = self._arch_of(m_k)
        gang = [self.pool.servers[i] for i in np.flatnonzero(sel)]
        if self.execute and self.warmup:
            # compile prefill/decode for this shape bucket BEFORE the timer:
            # the first task of an (arch, shape) pair must not bill XLA
            # compilation as serving latency
            with self.tracer.span("executor_warmup", cat="serving",
                                  arch=arch, c=int(c_k)):
                self.executor.warm(arch, self.prompt_len, c_k, steps,
                                   self.max_new_tokens)
        t0 = time.perf_counter()
        if reuse:
            self.pool.reuse_count += 1
            leader = next((s for s in gang if s.params is not None), None)
            if leader is None:                # defensive: mirror said reuse
                leader = gang[0]              # but pool lost the weights
                self._load(leader, arch)
            for s in gang:
                s.params, s.model_name = leader.params, leader.model_name
        else:
            self._load(gang[0], arch)
            for s in gang[1:]:
                # each member materialises the weights in the real system;
                # the replicas are identical, so share the leader's array
                s.params, s.model_name = gang[0].params, arch
                self.pool.load_count += 1
        if self.execute:
            prompt = self._prompt_rng.integers(
                0, self.executor.model(arch).cfg.vocab_size,
                self.prompt_len, dtype=np.int64).astype(np.int32)
            self._generate_tolerant(arch, gang[0].params, prompt, c_k, steps)
        self.tasks_executed += 1
        return time.perf_counter() - t0

    def _generate_tolerant(self, arch: str, params, prompt, c_k: int,
                           steps: int) -> None:
        """Real generation under the fault-tolerance policy: each attempt is
        wall-clock-bounded (`exec_timeout_s`) and may draw an injected
        transient error; transient failures retry up to `exec_max_attempts`
        tries, with the LAST attempt degraded to `degrade_steps_frac` of the
        requested steps (graceful degradation: a reduced-quality result
        beats no result). Without an active FaultSpec this is exactly one
        plain `executor.generate` call."""
        spec = self.faults
        if spec is None:
            self.executor.generate(arch, params, prompt, c_k, steps,
                                   self.max_new_tokens)
            return
        attempts = max(int(spec.exec_max_attempts), 1)
        for attempt in range(1, attempts + 1):
            run_steps = steps
            if attempt == attempts and attempts > 1:
                run_steps = max(1, int(steps * spec.degrade_steps_frac))
            degraded = run_steps < steps
            try:
                if degraded:
                    with self.tracer.span("executor_degrade", cat="serving",
                                          arch=arch, steps=run_steps,
                                          requested=steps):
                        self.injector.maybe_fail("generate")
                        self.executor.generate(
                            arch, params, prompt, c_k, run_steps,
                            self.max_new_tokens,
                            deadline_s=spec.exec_timeout_s)
                    self.pool.exec_degraded += 1
                else:
                    self.injector.maybe_fail("generate")
                    self.executor.generate(
                        arch, params, prompt, c_k, run_steps,
                        self.max_new_tokens, deadline_s=spec.exec_timeout_s)
                return
            except ExecutorFault as err:
                self.pool.exec_failures += 1
                if attempt == attempts:
                    self.pool.exec_gave_up += 1
                    return          # every attempt failed: serve nothing
                self.pool.exec_retries += 1
                with self.tracer.span("executor_retry", cat="serving",
                                      arch=arch, attempt=attempt,
                                      error=type(err).__name__):
                    pass

    def _load(self, server, arch: str) -> None:
        with self.tracer.span("model_load", cat="serving", arch=arch):
            self._load_key, k = jax.random.split(self._load_key)
            server.params = self.executor.init_params(arch, k)
        server.model_name = arch
        self.pool.load_count += 1

    # ------------------------------------------------------------------
    def apply_placement(self, decision) -> None:
        """Materialise a seam placement in the real pool, OFF the timed
        path: evict weights the plan displaced, prefetch the planned
        models (own PRNG stream — the `_load` sequence stays identical to
        a placement-free run), and pre-compile each placed gang's
        executor programs via the warmup machinery. A subsequent matching
        gang hits `_run_task`'s reuse path with the weights already
        resident — the mirror and the pool agree the start is warm."""
        sp = decision.streams[0]            # serving is one physical cluster
        for i in np.flatnonzero(sp.evict):
            s = self.pool.servers[i]
            with self.tracer.span("evict", cat="placement", server=int(i),
                                  arch=s.model_name or ""):
                s.params, s.model_name = None, None
            self.placement_evictions += 1
        warmed = set()
        for i in np.flatnonzero(sp.prefetch):
            arch = self._arch_of(int(sp.model[i]))
            s = self.pool.servers[i]
            if s.model_name != arch or s.params is None:
                with self.tracer.span("prefetch", cat="placement",
                                      server=int(i), arch=arch):
                    self._prefetch_key, k = jax.random.split(
                        self._prefetch_key)
                    s.params = self.executor.init_params(arch, k)
                    s.model_name = arch
                self.placement_prefetches += 1
            # mirror the carry's synthetic gang into the pool bookkeeping,
            # so pool-level reuse queries see the placed gang as complete
            s.gang = int(sp.gang[i])
            s.gang_size = int(sp.gang_size[i])
            c = int(sp.gang_size[i])
            if self.execute and self.warmup and (arch, c) not in warmed:
                warmed.add((arch, c))
                with self.tracer.span("executor_warmup", cat="serving",
                                      arch=arch, c=c):
                    self.executor.warm(arch, self.prompt_len, c,
                                       self.max_new_tokens,
                                       self.max_new_tokens)

    # ------------------------------------------------------------------
    def __call__(self, ecfg: EV.EnvConfig, traces: Dict, policy, params,
                 keys, *, num_steps: Optional[int] = None,
                 collect: bool = False,
                 init_state: Optional[EV.EnvState] = None) -> RolloutResult:
        B = int(np.asarray(keys).shape[0])
        if B != 1:
            raise ValueError(
                f"serving backend runs ONE physical cluster; got batch {B} "
                "(build the workload with batch/streams=1)")
        if ecfg.num_servers != len(self.pool.servers):
            raise ValueError(
                f"serving pool has {len(self.pool.servers)} servers but "
                f"ecfg.num_servers={ecfg.num_servers}")
        T = int(num_steps) if num_steps else ecfg.max_steps
        trace = {k: v[0] for k, v in traces.items()}
        key = keys[0]
        state = (EV.reset(ecfg) if init_state is None
                 else jax.tree_util.tree_map(lambda x: x[0], init_state))
        q, obs = EV.reset_view(ecfg, trace, state)
        # the shared actor layer owns the per-decision inference program:
        # the jit boundary at the decision seam (key split + actor forward)
        # is the SAME compiled program the latency probe measures, and its
        # sampler label attributes every decision span
        prog = actor_program(ecfg, policy)
        act = prog.act
        sampler = prog.sampler
        env_step = _env_prog(ecfg)
        wall_patch = _wall_patch_prog(ecfg)
        tr = self.tracer

        done = False
        total = np.float32(0.0)
        length = 0
        rows = [] if collect else None
        # per-sampler self-time attribution in the span table
        # (scripts/trace_summary.py groups decision spans by this attr)
        dkw = {"sampler": sampler} if sampler else {}
        for t_i in range(T):
            t0 = time.perf_counter()
            with tr.span("decision", cat="serving", step=t_i, **dkw):
                key, action, extras = act(trace, state, obs, key, params)
                jax.block_until_ready(action)
            self.profile.observe("policy", time.perf_counter() - t0)
            t0 = time.perf_counter()
            with tr.span("env_advance", cat="serving", step=t_i):
                nstate, nq, nobs, r, d, info = env_step(
                    trace, state, q, action)
                jax.block_until_ready(r)
            self.profile.observe("env_advance", time.perf_counter() - t0)
            if (not done and bool(info["scheduled"])
                    and bool(np.asarray(info.get("failed", False)))):
                # the mirror says a selected server crashes mid-run: the
                # gang aborts, so no real execution happens for this task
                self.pool.crashed_tasks += 1
            elif not done and bool(info["scheduled"]):
                k_task = info["task"]
                sel = np.asarray(nstate.server_gang == k_task)
                with tr.span("execute_task", cat="serving", step=t_i,
                             task=int(k_task),
                             arch=self._arch_of(int(trace["model"][k_task])),
                             c=int(trace["c"][k_task]),
                             steps=int(info["steps"]),
                             reuse=bool(info["reuse"])):
                    busy = self._run_task(
                        int(trace["model"][k_task]), int(trace["c"][k_task]),
                        int(info["steps"]), sel, bool(info["reuse"]))
                self.profile.observe("executor", busy)
                if self.wall_clock:
                    self.measured_busy.append(busy)
                    with tr.span("wall_patch", cat="serving", step=t_i,
                                 busy_s=busy):
                        nstate, nq, nobs, r, d = wall_patch(
                            trace, q, nstate, k_task, jnp.asarray(sel),
                            jnp.float32(busy))
            if done:       # frozen episode: replay the carried state
                nstate, nq, nobs = state, q, obs
                r = jnp.float32(0.0)
            if collect:
                rows.append((obs, action, r, nobs, d, not done, extras))
            total = total + np.float32(r)
            length += 0 if done else 1
            state, q, obs = nstate, nq, nobs
            done = done or bool(d)
            if done and not collect:
                break

        metrics = {k: np.asarray(v)[None] for k, v in
                   _metrics_prog(ecfg)(trace, state).items()}
        metrics["episode_return"] = np.asarray([total], np.float32)
        metrics["episode_len"] = np.asarray([length], np.int32)
        final_state = jax.tree_util.tree_map(
            lambda x: jnp.asarray(x)[None], state)
        transitions = self._stack(rows) if collect else None
        return RolloutResult(metrics=metrics, final_state=final_state,
                             transitions=transitions)

    @staticmethod
    def _stack(rows) -> Transitions:
        """Host rows -> the (B=1, T, ...) layout every simulated backend
        emits, so `sac.flatten_valid_transitions` consumes it unchanged."""
        stk = lambda xs: np.stack([np.asarray(x) for x in xs])[None]  # noqa: E731
        extras = {}
        if rows and rows[0][6]:
            extras = {k: stk([r[6][k] for r in rows]) for k in rows[0][6]}
        return Transitions(
            obs=stk([r[0] for r in rows]),
            action=stk([r[1] for r in rows]),
            reward=stk([r[2] for r in rows]),
            next_obs=stk([r[3] for r in rows]),
            done=stk([np.float32(r[4]) for r in rows]),
            valid=np.asarray([r[5] for r in rows], bool)[None],
            extras=extras)


def serving_rollout(spec) -> ServingRollout:
    """Build the serving backend for an `ExecSpec(backend="serving")`.

    Fresh state per call: each Simulator / StreamRunner / trainer gets its
    own pool, which then persists across that consumer's windows and rounds.
    Pool size is deferred to the first call's `ecfg.num_servers` (the spec
    does not know the workload) and fixed thereafter.
    """
    return _from_spec(spec)


def _from_spec(spec) -> "ServingRollout":
    class _Lazy:
        """Defers pool construction to the first call (the spec does not
        know num_servers; the workload's ecfg does)."""
        backend = "serving"

        def __init__(self):
            self.inner: Optional[ServingRollout] = None

        def _ensure(self, num_servers: int) -> ServingRollout:
            if self.inner is None:
                self.inner = ServingRollout(
                    num_servers, archs=spec.serving_archs,
                    reduced=spec.serving_reduced,
                    wall_clock=spec.serving_wall_clock,
                    execute=spec.serving_execute,
                    prompt_len=spec.serving_prompt_len,
                    max_new_tokens=spec.serving_max_new_tokens,
                    seed=spec.serving_seed,
                    warmup=getattr(spec, "serving_warmup", None),
                    tracer=tracer_for(getattr(spec, "trace", None)),
                    faults=getattr(spec, "faults", None))
            return self.inner

        def __call__(self, ecfg, traces, policy, params, keys, **kw):
            return self._ensure(ecfg.num_servers)(
                ecfg, traces, policy, params, keys, **kw)

        def reset(self):
            if self.inner is not None:
                self.inner.reset()

        def serving_stats(self):
            return self.inner.serving_stats() if self.inner else {}

        def pool_counters(self):
            return self.inner.pool_counters() if self.inner else {}

        def fault_counters(self):
            return self.inner.fault_counters() if self.inner else {}

        def apply_placement(self, decision):
            if self.inner is not None:      # placement fires after the
                self.inner.apply_placement(decision)   # first window ran

        def placement_counters(self):
            return self.inner.placement_counters() if self.inner else {}

    return _Lazy()
