"""Logical edge-server pool: loaded weights, gang bookkeeping, cold-start
economics.

`ServerPool` holds the N logical edge servers of the paper's Fig.-1 system.
Each server carries at most one loaded model (real params on device), the
gang it last served (leader id + size) and when it frees up. Loading is real
work (weight materialisation via `model.init`); reuse skips it — exactly the
cold-start economics the scheduler is trained around (paper Eq. 1, §V.B.4).

Two consumers share this module:

* the legacy host-loop `ServingEngine` (`serving.engine`), which asks the
  pool for gangs directly (`find_reusable_gang` / `pick_fresh`), and
* the serving execution backend (`serving.backend`), where gang *selection*
  is decided by the shared env decision step on a pool-derived state mirror
  and the pool supplies/loads the selected servers' weights and counts the
  load/reuse economics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass
class LogicalServer:
    sid: int
    model_name: Optional[str] = None
    params: Optional[object] = None
    gang: int = -1                # request id of last gang
    gang_size: int = 0
    busy_until: float = 0.0


class ServerPool:
    def __init__(self, num_servers: int):
        self.servers = [LogicalServer(i) for i in range(num_servers)]
        self.load_count = 0
        self.reuse_count = 0
        # fault-tolerance ledger (serving.backend's retry/degrade wrapper):
        # kept OUT of `counters()`, whose key set is pinned by tests
        self.exec_failures = 0        # transient errors + timeouts observed
        self.exec_retries = 0         # re-attempts after a transient failure
        self.exec_degraded = 0        # reduced-steps fallback completions
        self.exec_gave_up = 0         # tasks abandoned after the last attempt
        self.crashed_tasks = 0        # gangs skipped: server down at dispatch

    def idle(self, now: float) -> List[LogicalServer]:
        return [s for s in self.servers if s.busy_until <= now]

    def find_reusable_gang(self, arch: str, c: int, now: float):
        """A complete idle gang with matching model and size (paper Eq. 1).

        Exact-match semantics: every member must be idle, hold `arch`, and
        belong to the same gang whose recorded size is exactly `c` — a
        broken gang (any member busy or re-assigned) never matches. Ties
        resolve to the lowest gang id."""
        groups: Dict[int, List[LogicalServer]] = {}
        for s in self.idle(now):
            if s.model_name == arch and s.gang_size == c and s.gang >= 0:
                groups.setdefault(s.gang, []).append(s)
        for gid, members in sorted(groups.items()):
            if len(members) == c:
                return members
        return None

    def pick_fresh(self, c: int, now: float,
                   arch: Optional[str] = None) -> Optional[List[LogicalServer]]:
        """Fragmentation-aware greedy (§V.B.4): prefer breaking already-broken
        gangs; among intact gangs break the smallest.

        Among equally fragmented candidates, servers already holding `arch`
        rank first — a fresh gang on warm idle servers skips their weight
        loads instead of cold-loading next to them (ISSUE 9 satellite).
        Pool-only: the simulated `_select_servers` keeps its historical
        order, whose bitwise-parity gates pin the compiled decision math
        (`arch=None` reproduces the historical order exactly)."""
        idle = self.idle(now)
        if len(idle) < c:
            return None
        idle_ids = {s.sid for s in idle}

        def intact(s: LogicalServer) -> bool:
            if s.gang < 0:
                return False
            members = [t for t in self.servers
                       if t.gang == s.gang and t.gang_size == s.gang_size]
            return all(t.sid in idle_ids for t in members)

        def arch_miss(s: LogicalServer) -> int:
            return 0 if (arch is None or s.model_name == arch) else 1

        idle.sort(key=lambda s: (intact(s) * (100 + 10 * s.gang_size),
                                 arch_miss(s), s.sid))
        return idle[:c]

    # -- economics ------------------------------------------------------
    def counters(self) -> Dict[str, int]:
        return {"model_loads": self.load_count,
                "model_reuses": self.reuse_count}

    def fault_counters(self) -> Dict[str, int]:
        """The fault-tolerance ledger (all zero in a fault-free run)."""
        return {"exec_failures": self.exec_failures,
                "exec_retries": self.exec_retries,
                "exec_degraded": self.exec_degraded,
                "exec_gave_up": self.exec_gave_up,
                "crashed_tasks": self.crashed_tasks}

    def reset(self) -> None:
        """Drop every loaded model and the load/reuse ledger (fresh cluster)."""
        for s in self.servers:
            s.model_name, s.params = None, None
            s.gang, s.gang_size, s.busy_until = -1, 0, 0.0
        self.load_count = 0
        self.reuse_count = 0
        self.exec_failures = 0
        self.exec_retries = 0
        self.exec_degraded = 0
        self.exec_gave_up = 0
        self.crashed_tasks = 0
