"""Windowed streaming against the real serving engine.

`ServingStreamRunner` is `StreamRunner` with the serving execution backend
plugged into the `rollout_fn` seam: every window's decisions drive the one
physical pool (real weight loads, real patch-parallel prefill + decode),
while the backlog carry, `max_carry` shedding, seam ledger, and
`StreamAggregator` QoS rows are byte-for-byte the simulated streaming
machinery. The summary additionally carries the pool's economics
(`model_loads` / `model_reuses` / `tasks_executed`) and a `wall_clock` flag
so downstream tables can tell measured rows from modelled ones.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core import env as EV
from repro.core.rollout import Transitions
from repro.traffic.stream import StreamConfig, StreamResult, StreamRunner


class ServingStreamRunner(StreamRunner):
    """StreamRunner over a serving rollout fn (one physical cluster, B=1)."""

    def __init__(self, ecfg: EV.EnvConfig, policy, params, source, key,
                 scfg: StreamConfig = StreamConfig(), rollout_fn=None):
        if scfg.num_streams != 1:
            raise ValueError(
                "serving streams run ONE physical cluster; set "
                f"StreamConfig(num_streams=1), got {scfg.num_streams}")
        if rollout_fn is None:
            from repro.serving.backend import ServingRollout
            rollout_fn = ServingRollout(ecfg.num_servers)
        if getattr(rollout_fn, "backend", None) != "serving":
            raise ValueError(
                "ServingStreamRunner needs a serving rollout fn (build one "
                "via repro.api ExecSpec(backend='serving') or "
                "serving.backend.ServingRollout)")
        super().__init__(ecfg, policy, params, source, key, scfg,
                         rollout_fn=rollout_fn)

    def result(self, transitions: Optional[List[Transitions]] = None
               ) -> StreamResult:
        res = super().result(transitions=transitions)
        stats = getattr(self.rollout_fn, "serving_stats", None)
        if stats is not None:
            res.summary.update(stats())
        wc = getattr(self.rollout_fn, "wall_clock", None)
        inner = getattr(self.rollout_fn, "inner", None)
        if wc is None and inner is not None:
            wc = inner.wall_clock
        res.summary["wall_clock"] = bool(wc)
        return res


def serve_stream(ecfg: EV.EnvConfig, policy, params, source, key,
                 scfg: StreamConfig = StreamConfig(),
                 rollout_fn=None, collect: bool = False) -> StreamResult:
    """Drive `scfg.num_windows` windows of real serving (`run_stream`'s
    serving twin; loops `ServingStreamRunner.run_window`)."""
    runner = ServingStreamRunner(ecfg, policy, params, source, key, scfg,
                                 rollout_fn=rollout_fn)
    collected: Optional[List[Transitions]] = [] if collect else None
    for _ in range(scfg.num_windows):
        wres = runner.run_window(collect=collect)
        if collect:
            collected.append(wres.transitions)
    return runner.result(transitions=collected)
