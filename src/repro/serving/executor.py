"""Real prefill/decode execution for the serving layer.

`ModelExecutor` owns the model zoo instances (reduced or full configs), the
per-arch jitted prefill/decode programs, and the generation loop the engine
and the serving backend both call. Two correctness properties live here:

KV-cache sizing. The scheduler picks the inference-step count (up to
``s_max``) independently of the request's ``max_new_tokens``; the decode
loop runs ``steps`` iterations, so the cache is sized by
``max(steps, max_new_tokens)`` — the legacy engine sized it by
``max_new_tokens`` alone and silently overflowed the cache once the policy
chose more steps (decode writes past capacity clamp at the boundary).

Patch-parallel prefill. A c_k-patch task splits its prompt into c_k chunks
prefilled as a batch dimension — the DistriFusion patch mapping: each chunk
is one gang member's patch, computed in parallel with no cross-patch
attention (chunk-local RoPE positions come for free from the per-row
``arange(s)`` in `blocks.attn_prefill`). The per-chunk KV caches then merge
back into one sequence-ordered cache (a pure reshape) that decode attends
over. For ``c == 1`` the chunked path is bitwise-identical to the unchunked
one (same positions, same flash-attention block shapes, same cache content)
— tests pin this. Architectures whose caches are not pure attention KV
(SSM/hybrid recurrent state, sliding-window rings, audio/vision frontends)
fall back to the unchunked prefill; the Table-VI latency model still
accounts the parallel speedup either way.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import get_config
from repro.faults.inject import ExecutorTimeout
from repro.models.lm import period_spec
from repro.models.zoo import Model, build_model
from repro.telemetry.trace import NULL_TRACER

# decode-capacity rounding: buckets cache shapes so jit re-traces per
# capacity bucket, not per (steps, max_new_tokens) pair. Value-safe: decode
# attention masks entries at or beyond `pos` (`attention.decode_attention`).
_CAP_ROUND = 8


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def chunkable(cfg) -> bool:
    """True when the patch-parallel (batched-chunk) prefill applies: every
    mixer is plain full attention (KV merge is a reshape) and no frontend
    tokens are prepended per batch row."""
    if cfg.family == "audio" or cfg.frontend != "none":
        return False
    if cfg.sliding_window:
        return False
    return all(mixer == "attn" for mixer, _f in period_spec(cfg))


def _merge_chunk_cache(model: Model, ccache: Dict, S_pad: int,
                       capacity: int) -> Dict:
    """(c, chunk)-batched prefill caches -> one (1, capacity) decode cache.

    Chunks are consecutive prompt slices, so concatenating their KV along
    the sequence axis — a reshape of (periods, c, chunk, kv, hd) — restores
    prompt order exactly; `pos = S_pad` points decode past the merged KV."""
    big = model.make_cache(1, capacity, dtype=jnp.float32)

    def merge(dst, src):
        npd, c, chunk, nk, hd = src.shape
        flat = src.reshape(npd, 1, c * chunk, nk, hd)
        return jax.lax.dynamic_update_slice_in_dim(
            dst, flat.astype(dst.dtype), 0, axis=2)

    periods = jax.tree_util.tree_map(merge, big["periods"],
                                     ccache["periods"])
    return {"periods": periods, "pos": jnp.asarray(S_pad, jnp.int32)}


class ModelExecutor:
    """Cached models + jitted inference programs, shared by every server.

    One executor per engine/backend: all gang leaders of the same arch run
    through the same compiled prefill/decode programs (shapes permitting),
    so a stream pays tracing once per (arch, shape bucket)."""

    def __init__(self, reduced: bool = True, tracer=None):
        self.reduced = reduced
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._models: Dict[str, Model] = {}
        self._prefill: Dict[str, Callable] = {}
        self._decode: Dict[str, Callable] = {}
        self._warm_params: Dict[str, object] = {}   # throwaway compile params
        self._warmed: set = set()                   # shape buckets compiled

    def model(self, arch: str) -> Model:
        if arch not in self._models:
            cfg = get_config(arch)
            model = build_model(cfg.reduced() if self.reduced else cfg)
            self._models[arch] = model
            self._prefill[arch] = jax.jit(
                lambda p, b, c, m=model: m.prefill(
                    p, b, c, compute_dtype=jnp.float32))
            self._decode[arch] = jax.jit(
                lambda p, c, t, m=model: m.decode(
                    p, c, t, compute_dtype=jnp.float32))
        return self._models[arch]

    def init_params(self, arch: str, key):
        """Real weight materialisation — the cold-start cost being scheduled
        around (the Table-VI init_time stands in for its wall-clock)."""
        return self.model(arch).init(key)

    # ------------------------------------------------------------------
    def shape_key(self, arch: str, prompt_len: int, c: int, steps: int,
                  max_new_tokens: int) -> tuple:
        """The compilation bucket a `generate` call lands in: jit retraces
        once per (arch, chunk shape, cache capacity), so two calls with the
        same key reuse the same compiled prefill + decode programs."""
        c = max(int(c), 1)
        S_pad = int(prompt_len) + ((-int(prompt_len)) % c)
        capacity = S_pad + _round_up(max(int(steps), int(max_new_tokens)),
                                     _CAP_ROUND)
        use_chunked = chunkable(self.model(arch).cfg)
        return (arch, c if use_chunked else 1, S_pad, capacity)

    def warm(self, arch: str, prompt_len: int, c: int, steps: int,
             max_new_tokens: int) -> bool:
        """Pre-compile the prefill/decode programs a `generate` with these
        arguments would hit; returns True when compilation actually ran.

        Runs one throwaway single-step generate with per-arch cached dummy
        params (identical shapes, so the jit cache hits) against the SAME
        chunk shape and cache capacity: `max_new_tokens` is inflated to
        keep the capacity bucket fixed while `steps=1` bounds the warm
        decode work. Uses `jax.random.PRNGKey(0)` directly — the serving
        backend's `_load_key` stream is untouched, so warmed and unwarmed
        runs schedule identically."""
        k = self.shape_key(arch, prompt_len, c, steps, max_new_tokens)
        if k in self._warmed:
            return False
        _arch, _c, S_pad, capacity = k
        if arch not in self._warm_params:
            self._warm_params[arch] = self.init_params(
                arch, jax.random.PRNGKey(0))
        prompt = np.zeros(int(prompt_len), np.int32)
        self.generate(arch, self._warm_params[arch], prompt, c, 1,
                      capacity - S_pad)
        self._warmed.add(k)
        return True

    # ------------------------------------------------------------------
    def _full_batch(self, cfg, prompt: np.ndarray) -> Dict:
        batch = {"tokens": jnp.asarray(prompt[None])}
        if cfg.frontend == "vision":
            batch["image_embeds"] = jnp.zeros((1, cfg.frontend_tokens,
                                               cfg.frontend_dim))
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((1, cfg.frontend_tokens, cfg.d_model))
        return batch

    def generate(self, arch: str, params, prompt, c: int, steps: int,
                 max_new_tokens: int = 16, *,
                 force_chunked: Optional[bool] = None,
                 deadline_s: float = 0.0) -> np.ndarray:
        """Greedy generation of `steps` tokens on a c-patch gang's params.

        `force_chunked` overrides the c>1 chunking heuristic (tests assert
        the c=1 chunked path is bitwise-identical to the unchunked one).
        `deadline_s > 0` bounds the attempt's wall clock: the decode loop
        checks the budget once per iteration and raises
        `faults.ExecutorTimeout` when exceeded (the retry/degrade wrapper
        in `serving.backend` catches it); 0 disables the check."""
        t_start = time.perf_counter()
        model = self.model(arch)
        cfg = model.cfg
        prompt = np.asarray(prompt, np.int32)
        c = max(int(c), 1)
        steps = int(steps)
        pad = (-len(prompt)) % c
        S_pad = len(prompt) + pad
        capacity = S_pad + _round_up(max(steps, int(max_new_tokens)),
                                     _CAP_ROUND)
        use_chunked = (chunkable(cfg) if force_chunked is None
                       else force_chunked)
        tr = self.tracer
        with tr.span("prefill", cat="serving", arch=arch, c=c,
                     seq=S_pad, chunked=bool(use_chunked)):
            if use_chunked:
                # left-pad so the prompt's true final token ends the last
                # chunk — its last-position logits are the next-token
                # distribution
                chunks = jnp.asarray(np.pad(prompt, (pad, 0)).reshape(c, -1))
                ccache = model.make_cache(c, chunks.shape[1],
                                          dtype=jnp.float32)
                logits, ccache = self._prefill[arch](
                    params, {"tokens": chunks}, ccache)
                cache = _merge_chunk_cache(model, ccache, S_pad, capacity)
                logits = logits[-1:]  # prompt's last token ends chunk c-1
            else:
                cache = model.make_cache(1, capacity, dtype=jnp.float32)
                logits, cache = self._prefill[arch](
                    params, self._full_batch(cfg, prompt), cache)
            if tr.enabled:   # wall attribution only: sync inside the span
                jax.block_until_ready(logits)
        out = []
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
        with tr.span("decode", cat="serving", arch=arch, steps=steps,
                     capacity=capacity):
            for i in range(steps):
                if deadline_s > 0.0 \
                        and time.perf_counter() - t_start > deadline_s:
                    raise ExecutorTimeout(
                        f"{arch} generate exceeded {deadline_s:.1f}s "
                        f"budget at decode step {i}/{steps}")
                out.append(int(tok[0, 0]))
                logits, cache = self._decode[arch](params, cache, tok)
                tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size],
                                 axis=-1).astype(jnp.int32)
        return np.asarray(out, np.int32)
