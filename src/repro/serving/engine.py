"""In-process edge-serving engine: the paper's Fig.-1 system, executable.

Components (mirroring the paper's implementation, §VI.A.1, minus Docker/NCCL):
  * ``ServerPool`` — N logical edge servers; each holds at most one loaded
    model (params on device). Loading/unloading is real work (param init /
    drop); reuse skips it, exactly the cold-start economics the paper
    schedules around.
  * ``Request`` — an AIGC task: (service/arch id, prompt tokens, patches c_k,
    arrival time). "Inference steps" map to decode steps for LM services.
  * ``ServingEngine`` — the host loop: maintains the waiting queue, builds
    the Eq.-6 state from *real* pool state, asks a policy (EAT or baseline)
    for (execute?, task, steps), gang-allocates c_k servers, runs real
    prefill+decode on the selected model, and records wall-clock metrics.

Patch parallelism: a c_k-patch task splits its prompt into c_k chunks that
are prefilled as a batch dimension (the TPU mapping: each chunk lives on one
mesh slice; on this CPU container they execute as one batched call and we
account the parallel speedup with the Table-VI model). Decode then proceeds
from the merged KV cache.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ArchConfig, get_config
from repro.core import env as EV
from repro.core import timemodel as TM
from repro.core.quality import quality_of
from repro.models.zoo import Model, build_model


@dataclass
class Request:
    rid: int
    arch: str
    prompt: np.ndarray            # (S,) int32
    patches: int                  # c_k
    arrive_t: float
    max_new_tokens: int = 16
    # filled on completion
    tokens: Optional[np.ndarray] = None
    start_t: float = 0.0
    finish_t: float = 0.0
    steps: int = 0
    reused: bool = False
    quality: float = 0.0


@dataclass
class LogicalServer:
    sid: int
    model_name: Optional[str] = None
    params: Optional[object] = None
    gang: int = -1                # request id of last gang
    gang_size: int = 0
    busy_until: float = 0.0


class ServerPool:
    def __init__(self, num_servers: int):
        self.servers = [LogicalServer(i) for i in range(num_servers)]
        self.load_count = 0
        self.reuse_count = 0

    def idle(self, now: float) -> List[LogicalServer]:
        return [s for s in self.servers if s.busy_until <= now]

    def find_reusable_gang(self, arch: str, c: int, now: float):
        """A complete idle gang with matching model and size (paper Eq. 1)."""
        groups: Dict[int, List[LogicalServer]] = {}
        for s in self.idle(now):
            if s.model_name == arch and s.gang_size == c and s.gang >= 0:
                groups.setdefault(s.gang, []).append(s)
        for gid, members in sorted(groups.items()):
            if len(members) == c:
                return members
        return None

    def pick_fresh(self, c: int, now: float) -> Optional[List[LogicalServer]]:
        """Fragmentation-aware greedy (§V.B.4): prefer breaking already-broken
        gangs; among intact gangs break the smallest."""
        idle = self.idle(now)
        if len(idle) < c:
            return None
        idle_ids = {s.sid for s in idle}

        def intact(s: LogicalServer) -> bool:
            if s.gang < 0:
                return False
            members = [t for t in self.servers
                       if t.gang == s.gang and t.gang_size == s.gang_size]
            return all(t.sid in idle_ids for t in members)

        idle.sort(key=lambda s: (intact(s) * (100 + 10 * s.gang_size), s.sid))
        return idle[:c]


class ServingEngine:
    """policy(obs, key) -> action vector in [0,1]^(2+l)."""

    def __init__(self, num_servers: int, archs: List[str], *,
                 queue_window: int = 8, s_min: int = 4, s_max: int = 32,
                 reduced: bool = True, seed: int = 0,
                 time_dilation: float = 0.0):
        self.pool = ServerPool(num_servers)
        self.archs = archs
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.l = queue_window
        self.s_min, self.s_max = s_min, s_max
        self.reduced = reduced
        self._models: Dict[str, Model] = {}
        self._step_fns: Dict[str, Callable] = {}
        self.key = jax.random.PRNGKey(seed)
        self.clock = 0.0
        # >0: simulated seconds per Table-VI unit (deterministic virtual time);
        # 0: wall clock.
        self.time_dilation = time_dilation
        self._t0 = time.time()

    # -- time -----------------------------------------------------------
    def now(self) -> float:
        if self.time_dilation:
            return self.clock
        return time.time() - self._t0

    def _advance(self, dt: float):
        if self.time_dilation:
            self.clock += dt

    # -- model management -------------------------------------------------
    def _model(self, arch: str) -> Model:
        if arch not in self._models:
            cfg = get_config(arch)
            self._models[arch] = build_model(cfg.reduced() if self.reduced else cfg)
        return self._models[arch]

    def _load(self, server: LogicalServer, arch: str):
        model = self._model(arch)
        self.key, k = jax.random.split(self.key)
        server.params = model.init(k)           # real weight materialisation
        server.model_name = arch
        self.pool.load_count += 1

    # -- queue ------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def observe(self) -> np.ndarray:
        """Eq.-6 matrix from real pool state."""
        now = self.now()
        E = len(self.pool.servers)
        obs = np.zeros((3, E + self.l), np.float32)
        for i, s in enumerate(self.pool.servers):
            obs[0, i] = 1.0 if s.busy_until <= now else 0.0
            obs[1, i] = max(0.0, s.busy_until - now) / 60.0
            obs[2, i] = ((self.archs.index(s.model_name) + 1) / len(self.archs)
                         if s.model_name in self.archs else 0.0)
        for j, r in enumerate(sorted(self.queue, key=lambda r: r.arrive_t)[: self.l]):
            obs[0, E + j] = (now - r.arrive_t) / 60.0
            obs[1, E + j] = r.patches / 8.0
            obs[2, E + j] = (self.archs.index(r.arch) + 1) / len(self.archs)
        return obs

    # -- execution ---------------------------------------------------------
    def _generate(self, req: Request, steps: int, servers: List[LogicalServer]):
        """Real patch-parallel prefill + decode on the gang leader's params."""
        model = self._model(req.arch)
        cfg = model.cfg
        params = servers[0].params
        c = len(servers)
        prompt = np.asarray(req.prompt, np.int32)
        # patch-parallel prefill: split the prompt into c chunks -> batch dim
        # (each chunk is one server's patch; merged back into a single cache)
        pad = (-len(prompt)) % c
        chunks = np.pad(prompt, (0, pad)).reshape(c, -1)
        cache = model.make_cache(1, len(prompt) + pad + req.max_new_tokens,
                                 dtype=jnp.float32)
        batch = {"tokens": jnp.asarray(prompt[None])}
        if cfg.frontend == "vision":
            batch["image_embeds"] = jnp.zeros((1, cfg.frontend_tokens,
                                               cfg.frontend_dim))
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((1, cfg.frontend_tokens, cfg.d_model))
        logits, cache = model.prefill(params, batch, cache,
                                      compute_dtype=jnp.float32)
        out = []
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        for _ in range(steps):
            out.append(int(tok[0, 0]))
            logits, cache = model.decode(params, cache, tok,
                                         compute_dtype=jnp.float32)
            tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        req.tokens = np.asarray(out, np.int32)

    def try_schedule(self, action: np.ndarray) -> Optional[Request]:
        """One scheduler decision (Algorithm 1 lines 4-31)."""
        now = self.now()
        if action[0] > 0.5 or not self.queue:
            self._advance(1.0)
            return None
        visible = sorted(self.queue, key=lambda r: r.arrive_t)[: self.l]
        scores = action[2: 2 + len(visible)]
        req = visible[int(np.argmax(scores))]
        steps = int(round(self.s_min + float(np.clip(action[1], 0, 1))
                          * (self.s_max - self.s_min)))
        gang = self.pool.find_reusable_gang(req.arch, req.patches, now)
        reused = gang is not None
        if gang is None:
            gang = self.pool.pick_fresh(req.patches, now)
            if gang is None:
                self._advance(1.0)
                return None              # infeasible: not enough idle servers
        self.queue.remove(req)
        req.start_t = now
        req.steps = steps
        req.reused = reused
        if not reused:
            for s in gang:
                self._load(s, req.arch)
        else:
            self.pool.reuse_count += 1
            # share the already-loaded params across the gang
            for s in gang[1:]:
                s.params = gang[0].params
        self._generate(req, steps, gang)
        # account busy time with the Table-VI latency model (virtual) or
        # wall clock (real)
        t_model = float(TM.exec_time(jnp.asarray(req.patches), jnp.asarray(steps)))
        t_init = 0.0 if reused else float(TM.init_time(jnp.asarray(req.patches)))
        busy = (t_model + t_init) if self.time_dilation else (self.now() - now)
        for s in gang:
            s.gang = req.rid
            s.gang_size = req.patches
            s.busy_until = now + busy
        self._advance(busy if self.time_dilation else 0.0)
        req.finish_t = now + busy
        req.quality = float(quality_of(steps))
        self.done.append(req)
        return req

    # -- metrics ------------------------------------------------------------
    def metrics(self) -> Dict[str, float]:
        if not self.done:
            return {"completed": 0}
        resp = [r.finish_t - r.arrive_t for r in self.done]
        return {
            "completed": len(self.done),
            "avg_response": float(np.mean(resp)),
            "avg_quality": float(np.mean([r.quality for r in self.done])),
            "reload_rate": 1.0 - self.pool.reuse_count / max(1, len(self.done)),
            "loads": self.pool.load_count,
            "reuses": self.pool.reuse_count,
        }
