"""In-process edge-serving engine: the paper's Fig.-1 system, executable.

Components (mirroring the paper's implementation, §VI.A.1, minus Docker/NCCL):
  * ``ServerPool`` (`serving.pool`) — N logical edge servers; each holds at
    most one loaded model (params on device). Loading/unloading is real work
    (param init / drop); reuse skips it, exactly the cold-start economics
    the paper schedules around.
  * ``ModelExecutor`` (`serving.executor`) — cached zoo models + jitted
    prefill/decode; real patch-parallel batched prefill.
  * ``Request`` — an AIGC task: (service/arch id, prompt tokens, patches c_k,
    arrival time). "Inference steps" map to decode steps for LM services.
  * ``ServingEngine`` — the legacy host loop: maintains the waiting queue,
    builds the Eq.-6 state from *real* pool state through the shared
    `core.obs` normalisation path, asks a policy for (execute?, task,
    steps), gang-allocates c_k servers, runs real prefill+decode on the
    selected model, and reports QoS through the shared `StreamAggregator`
    schema (`qos_summary`).

This host loop predates the unified stack; the stream-native door is the
serving execution backend (`serving.backend` / ``ExecSpec(backend=
"serving")``), which drives the same pool + executor from the shared env
decision step under `Simulator` / `StreamRunner` / `train_stream_sac`.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as EV
from repro.core import obs as OBS
from repro.core import timemodel as TM
from repro.core.quality import quality_of
from repro.serving.executor import ModelExecutor
from repro.serving.pool import LogicalServer, ServerPool  # noqa: F401 (re-export)
from repro.traffic import metrics as MX


@dataclass
class Request:
    rid: int
    arch: str
    prompt: np.ndarray            # (S,) int32
    patches: int                  # c_k
    arrive_t: float
    max_new_tokens: int = 16
    # filled on completion
    tokens: Optional[np.ndarray] = None
    start_t: float = 0.0
    finish_t: float = 0.0
    steps: int = 0
    reused: bool = False
    quality: float = 0.0


class ServingEngine:
    """policy(obs, key) -> action vector in [0,1]^(2+l)."""

    def __init__(self, num_servers: int, archs: List[str], *,
                 queue_window: int = 8, s_min: int = 4, s_max: int = 32,
                 reduced: bool = True, seed: int = 0,
                 time_dilation: float = 0.0):
        self.pool = ServerPool(num_servers)
        self.archs = archs
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.l = queue_window
        self.s_min, self.s_max = s_min, s_max
        self.reduced = reduced
        self.executor = ModelExecutor(reduced=reduced)
        self.key = jax.random.PRNGKey(seed)
        self.clock = 0.0
        self.n_submitted = 0
        # >0: simulated seconds per Table-VI unit (deterministic virtual time);
        # 0: wall clock.
        self.time_dilation = time_dilation
        self._t0 = time.time()

    # -- time -----------------------------------------------------------
    def now(self) -> float:
        if self.time_dilation:
            return self.clock
        return time.time() - self._t0

    def _advance(self, dt: float):
        if self.time_dilation:
            self.clock += dt

    # -- model management -------------------------------------------------
    def _load(self, server: LogicalServer, arch: str):
        self.key, k = jax.random.split(self.key)
        server.params = self.executor.init_params(arch, k)
        server.model_name = arch
        self.pool.load_count += 1

    # -- queue ------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)
        self.n_submitted += 1

    def _mirror(self):
        """Pool/queue state as an (EnvConfig, trace, EnvState) triple — the
        exact inputs of the simulator's Eq.-6 path. Queue slots hold the
        visible requests sorted by arrival (the simulated visible-queue
        order); empty task slots get arr_time=+inf so they are never queued."""
        now = self.now()
        E = len(self.pool.servers)
        reqs = sorted(self.queue, key=lambda r: r.arrive_t)
        K = max(len(reqs), self.l, 1)
        arr = np.full(K, np.inf, np.float32)
        c = np.ones(K, np.int32)
        model = np.zeros(K, np.int32)
        for j, r in enumerate(reqs):
            arr[j] = r.arrive_t
            c[j] = r.patches
            model[j] = self.archs.index(r.arch) if r.arch in self.archs else 0
        cfg = EV.EnvConfig(num_servers=E, queue_window=self.l, max_tasks=K,
                           num_models=len(self.archs))
        trace = {"arr_time": jnp.asarray(arr), "c": jnp.asarray(c),
                 "model": jnp.asarray(model),
                 "noise": jnp.zeros((K,), jnp.float32)}
        midx = np.asarray([self.archs.index(s.model_name)
                           if s.model_name in self.archs else -1
                           for s in self.pool.servers], np.int32)
        state = EV.EnvState(
            time=jnp.float32(now),
            server_free_at=jnp.asarray(
                [s.busy_until for s in self.pool.servers], jnp.float32),
            server_model=jnp.asarray(midx),
            server_gang=jnp.asarray(
                [s.gang for s in self.pool.servers], jnp.int32),
            server_gang_size=jnp.asarray(
                [s.gang_size for s in self.pool.servers], jnp.int32),
            task_status=jnp.zeros((K,), jnp.int32),
            task_start=jnp.zeros((K,), jnp.float32),
            task_finish=jnp.zeros((K,), jnp.float32),
            task_steps=jnp.zeros((K,), jnp.int32),
            task_quality=jnp.zeros((K,), jnp.float32),
            task_reload=jnp.zeros((K,), jnp.int32),
            steps_taken=jnp.zeros((), jnp.int32),
        )
        return cfg, trace, state

    def observe(self) -> np.ndarray:
        """Eq.-6 matrix from real pool state, through the one shared
        normalisation path (`core.obs.observe_from`) — pool-derived and
        simulated observations are the same array on matched state."""
        cfg, trace, state = self._mirror()
        q = OBS.visible_queue(cfg, trace, state)
        return np.asarray(OBS.observe_from(cfg, trace, state, q))

    # -- execution ---------------------------------------------------------
    def _generate(self, req: Request, steps: int, servers: List[LogicalServer]):
        """Real patch-parallel prefill + decode on the gang leader's params."""
        req.tokens = self.executor.generate(
            req.arch, servers[0].params, req.prompt, len(servers), steps,
            req.max_new_tokens)

    def try_schedule(self, action: np.ndarray) -> Optional[Request]:
        """One scheduler decision (Algorithm 1 lines 4-31)."""
        now = self.now()
        if action[0] > 0.5 or not self.queue:
            self._advance(1.0)
            return None
        visible = sorted(self.queue, key=lambda r: r.arrive_t)[: self.l]
        scores = action[2: 2 + len(visible)]
        req = visible[int(np.argmax(scores))]
        steps = int(round(self.s_min + float(np.clip(action[1], 0, 1))
                          * (self.s_max - self.s_min)))
        gang = self.pool.find_reusable_gang(req.arch, req.patches, now)
        reused = gang is not None
        if gang is None:
            gang = self.pool.pick_fresh(req.patches, now, arch=req.arch)
            if gang is None:
                self._advance(1.0)
                return None              # infeasible: not enough idle servers
        self.queue.remove(req)
        req.start_t = now
        req.steps = steps
        req.reused = reused
        if not reused:
            for s in gang:
                self._load(s, req.arch)
        else:
            self.pool.reuse_count += 1
            # share the already-loaded params across the gang
            for s in gang[1:]:
                s.params = gang[0].params
        self._generate(req, steps, gang)
        # account busy time with the Table-VI latency model (virtual) or
        # wall clock (real)
        t_model = float(TM.exec_time(jnp.asarray(req.patches), jnp.asarray(steps)))
        t_init = 0.0 if reused else float(TM.init_time(jnp.asarray(req.patches)))
        busy = (t_model + t_init) if self.time_dilation else (self.now() - now)
        for s in gang:
            s.gang = req.rid
            s.gang_size = req.patches
            s.busy_until = now + busy
        self._advance(busy if self.time_dilation else 0.0)
        req.finish_t = now + busy
        req.quality = float(quality_of(steps))
        self.done.append(req)
        return req

    # -- metrics ------------------------------------------------------------
    def qos_summary(self, resp_sla: float = 120.0,
                    q_min: float = 0.23) -> Dict[str, float]:
        """Run-level QoS in the shared `StreamAggregator` schema — the same
        keys (latency_p50/p95/p99, violation, goodput, cold_start,
        utilization, ...) the simulated streaming backends report, so real
        and simulated runs drop into one comparison table."""
        agg = MX.StreamAggregator(len(self.pool.servers), q_min, resp_sla)
        now = self.now()
        resp = np.asarray([r.finish_t - r.arrive_t for r in self.done],
                          np.float64)
        quality = np.asarray([r.quality for r in self.done], np.float64)
        counts = np.zeros(len(MX.DEFAULT_EDGES) + 1, np.int64)
        np.add.at(counts, np.searchsorted(MX.DEFAULT_EDGES, resp), 1)
        viol_q = quality < q_min
        viol_t = resp > resp_sla
        agg.update({
            "n_injected": self.n_submitted,
            "n_sched": len(self.done),
            "n_done": int(sum(r.finish_t <= now for r in self.done)),
            "n_dropped": 0,
            "n_reload": int(sum(not r.reused for r in self.done)),
            "n_viol": int(np.sum(viol_q | viol_t)),
            "n_viol_q": int(np.sum(viol_q)),
            "n_viol_t": int(np.sum(viol_t)),
            "sum_resp": float(resp.sum()),
            "sum_quality": float(quality.sum()),
            "sum_steps": float(sum(r.steps for r in self.done)),
            "busy_time": float(sum(r.patches * (r.finish_t - r.start_t)
                                   for r in self.done)),
            "elapsed": now,
            "hist": counts,
            "max_resp": float(resp.max()) if len(resp) else 0.0,
        })
        out = agg.summary()
        out.update(self.pool.counters())
        out["wall_clock"] = not bool(self.time_dilation)
        return out

    def metrics(self) -> Dict[str, float]:
        """Deprecated ad-hoc metrics dict; use `qos_summary()` (the shared
        StreamAggregator schema) instead."""
        warnings.warn(
            "ServingEngine.metrics is deprecated; use "
            "ServingEngine.qos_summary (the shared StreamAggregator "
            "QoS schema)", DeprecationWarning, stacklevel=2)
        if not self.done:
            return {"completed": 0}
        resp = [r.finish_t - r.arrive_t for r in self.done]
        return {
            "completed": len(self.done),
            "avg_response": float(np.mean(resp)),
            "avg_quality": float(np.mean([r.quality for r in self.done])),
            "reload_rate": 1.0 - self.pool.reuse_count / max(1, len(self.done)),
            "loads": self.pool.load_count,
            "reuses": self.pool.reuse_count,
        }
