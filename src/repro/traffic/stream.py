"""Unbounded-horizon windowed streaming on the batched rollout engine.

The episodic engine (`core/rollout.py`) runs one fixed-size trace to
completion. This module chains it over consecutive fixed-size *task windows*
with carried environment state, so a run covers 10^5-10^6 tasks at O(window)
memory:

    window w trace  ->  batch_rollout(init_state = carry_{w-1})  ->  seam:
        * clock rebased to 0 (float32 stays precise at any horizon)
        * residual server busy time / model / gang metadata carried
        * carried gangs relabelled into [K, K+E) so their labels can never
          collide with the next window's task ids (reuse survives the seam)
        * unscheduled tasks compacted and re-injected into the next window
          (oldest beyond `max_carry` are shed and counted as dropped)

Each window is B parallel independent streams in one jitted program
(`batch_rollout` vmap). Arrival times are open-loop: a `TaskSource` draws
fixed-shape chunks from an arrival process (`arrivals.py`) on its own clock,
regardless of how far the scheduler has fallen behind. Per-window QoS stats
are reduced device-side and folded into a `StreamAggregator` on the host.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.rollout import Transitions
from repro.core.workload import TraceConfig, sample_task_attrs
from repro.faults import (RETRY_COL, FaultSpec, FaultTimeline, fault_horizon,
                          faults_active, retry_backoff)
from repro.placement import PlacementManager, PlacementSpec, placement_active
from repro.telemetry.trace import NULL_TRACER
from repro.traffic import metrics as MX

_COLS = ("arr_time", "c", "model", "noise")
_DTYPES = {"arr_time": np.float32, "c": np.int32, "model": np.int32,
           "noise": np.float32}


@dataclass(frozen=True)
class StreamConfig:
    num_windows: int = 16
    num_streams: int = 1                    # B independent parallel streams
    max_steps_per_window: Optional[int] = None   # default min(4K, max_steps)
    max_carry: Optional[int] = None         # leftover slots kept; default K//2
    resp_sla: float = 120.0                 # QoS latency budget (seconds)
    chunk_size: int = 0                     # arrival buffer refill; 0 = 4K
    fused: bool = True                      # fused env-step engine (bitwise
    #                                         identical; False = legacy path)
    faults: Optional[FaultSpec] = None      # deterministic fault injection;
    #                                         None / FaultSpec.none() =
    #                                         bitwise-identical fault-free run
    placement: Optional[PlacementSpec] = None   # slow-timescale proactive
    #                                         model placement at window seams
    #                                         (repro.placement); None /
    #                                         PlacementSpec.none() = bitwise-
    #                                         identical placement-free run


# ----------------------------------------------------------------------
# task sources: host-side open-loop suppliers of (arr_time, c, model, noise)
class CurriculumTaskSource:
    """Piecewise arrival curriculum over one continuous stream.

    `cells` is a list of (arrival process, TraceConfig) pairs; every stream
    keeps ONE shared absolute arrival clock, and each fixed-size refill
    chunk is drawn from the currently-selected cell's process + attribute
    marginals. `set_cell(i)` switches the generator from the next refill
    on — the chunk default is one window's worth of tasks, so a switch
    typically lands on the very next window — while the clock, buffered
    arrivals, and carried backlog stay continuous across the switch: the
    agent trains on the backlog distribution its own scheduling induced,
    not on fresh resets.

    Refills go through one jitted, vmapped sampler per cell, so chunk
    generation compiles once per (cell, run). `ProcessTaskSource` is the
    single-cell special case (with a larger, refill-amortising chunk
    default), bitwise-identical to its pre-curriculum behaviour.
    """

    def __init__(self, cells, key, num_streams: int = 1, chunk_size: int = 0):
        if not cells:
            raise ValueError("CurriculumTaskSource needs at least one cell")
        self.cells = [(proc, tc) for proc, tc in cells]
        self.B = int(num_streams)
        tc0 = self.cells[0][1]
        self.chunk = int(chunk_size) if chunk_size else max(tc0.num_tasks, 1)
        # key layout: one init key per cell, the attribute key LAST — for a
        # single cell this is exactly the historical ProcessTaskSource
        # split(key) -> (k_init, k_attr) derivation (bitwise-stable streams)
        keys = jax.random.split(key, len(self.cells) + 1)
        self._attr_key = keys[-1]
        self._states, self._samplers, self._attr_fns = [], [], []
        for (proc, tc), k in zip(self.cells, keys[:-1]):
            self._states.append(jax.vmap(proc.init)(
                jax.random.split(k, self.B)))
            self._samplers.append(jax.jit(jax.vmap(
                lambda s, p=proc: p.sample(s, self.chunk))))
            self._attr_fns.append(jax.jit(jax.vmap(
                lambda kk, t=tc: sample_task_attrs(kk, t, self.chunk))))
        self.active = 0
        self._clock = np.zeros(self.B, np.float64)   # absolute arrival clock
        self._buf = [{c: np.zeros((0,), _DTYPES[c]) for c in _COLS}
                     for _ in range(self.B)]

    def set_cell(self, i: int) -> None:
        if not 0 <= int(i) < len(self.cells):
            raise ValueError(f"cell index {i} out of range "
                             f"[0, {len(self.cells)})")
        self.active = int(i)

    def _refill(self) -> None:
        a = self.active
        self._states[a], gaps = self._samplers[a](self._states[a])
        gaps = np.asarray(gaps, np.float64)                    # (B, chunk)
        arr = self._clock[:, None] + np.cumsum(gaps, axis=1)
        self._clock = arr[:, -1].copy()
        self._attr_key, k = jax.random.split(self._attr_key)
        c, model, noise = self._attr_fns[a](jax.random.split(k, self.B))
        c, model, noise = (np.asarray(c), np.asarray(model), np.asarray(noise))
        for b in range(self.B):
            new = {"arr_time": arr[b].astype(np.float64), "c": c[b],
                   "model": model[b], "noise": noise[b]}
            self._buf[b] = {col: np.concatenate([self._buf[b][col], new[col]])
                            for col in _COLS}

    def take(self, stream: int, n: int) -> Dict[str, np.ndarray]:
        """Pop the next n tasks of one stream (arr_time is absolute)."""
        while len(self._buf[stream]["arr_time"]) < n:
            self._refill()
        out = {col: self._buf[stream][col][:n] for col in _COLS}
        self._buf[stream] = {col: self._buf[stream][col][n:] for col in _COLS}
        return out


class ProcessTaskSource(CurriculumTaskSource):
    """Draws tasks from ONE arrival process + TraceConfig attribute
    marginals — the single-cell curriculum source with a larger chunk
    default (4 windows) that amortises refills over a long sweep."""

    def __init__(self, proc, tc: TraceConfig, key, num_streams: int = 1,
                 chunk_size: int = 0):
        super().__init__(
            [(proc, tc)], key, num_streams=num_streams,
            chunk_size=int(chunk_size) if chunk_size
            else max(4 * tc.num_tasks, 64))
        self.proc, self.tc = proc, tc


class TraceTaskSource:
    """Finite source replaying explicit traces with full attributes —
    feed an episodic trace through the streaming engine verbatim (parity
    tests, trace-driven evaluation). `traces` is a dict of (B, N) arrays
    with *absolute* arrival times."""

    def __init__(self, traces: Dict):
        self._cols = {c: np.asarray(traces[c]) for c in _COLS}
        self.B, self.N = self._cols["arr_time"].shape
        self._cursor = np.zeros(self.B, np.int64)

    def take(self, stream: int, n: int) -> Dict[str, np.ndarray]:
        i = int(self._cursor[stream])
        if i + n > self.N:
            raise ValueError(f"TraceTaskSource exhausted: stream {stream} "
                             f"has {self.N - i} tasks left, asked for {n}")
        self._cursor[stream] = i + n
        return {c: v[stream, i:i + n] for c, v in self._cols.items()}


# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("ecfg", "per_model"))
def _window_seam(ecfg: EV.EnvConfig, traces: Dict, state: EV.EnvState,
                 edges: jnp.ndarray, resp_sla: jnp.ndarray,
                 per_model: bool = False):
    """Device-side seam: per-window QoS stats + next-window carry state +
    compacted leftovers, vmapped over the stream axis.

    With fault columns attached the seam additionally excludes crashed
    tasks (status 3) from the served stats, compacts them into a separate
    retry set (with their `f_retries` counts, clock rebased like the
    leftovers), and cold-wipes the model cache of carried servers whose
    crash fell inside this window — the next window's fault arrays drop
    fully-past intervals, so the wipe must happen here. Mode is a static
    property of the trace structure: fault-free traces compile the exact
    program they always did.

    `per_model=True` (static; on iff placement is active) adds per-model
    scheduled/reload counts to the stats — the source of the
    `eat_placement_cold_start_rate{model=...}` telemetry labels. The
    default compiles exactly the historical program."""
    K, E = ecfg.max_tasks, ecfg.num_servers
    faulty = "f_down_start" in traces

    def one(trace, st):
        te = st.time
        if faulty:                   # crashed tasks (status 3) served nothing
            sched = (st.task_status == 1) | (st.task_status == 2)
        else:
            sched = st.task_status >= 1
        fsch = sched.astype(jnp.float32)
        resp = jnp.where(sched, st.task_finish - trace["arr_time"], 0.0)
        viol_q = sched & (st.task_quality < ecfg.q_min)
        viol_t = sched & (resp > resp_sla)
        viol = viol_q | viol_t
        busy = jnp.sum(jnp.where(sched, trace["c"].astype(jnp.float32)
                                 * (st.task_finish - st.task_start), 0.0))
        stats = {
            "n_sched": jnp.sum(sched.astype(jnp.int32)),
            "n_done": jnp.sum((st.task_status == 2).astype(jnp.int32)),
            "n_reload": jnp.sum(jnp.where(sched, st.task_reload, 0)),
            "n_viol": jnp.sum(viol.astype(jnp.int32)),
            "n_viol_q": jnp.sum(viol_q.astype(jnp.int32)),
            "n_viol_t": jnp.sum(viol_t.astype(jnp.int32)),
            "sum_resp": jnp.sum(resp),
            "max_resp": jnp.max(resp),
            "sum_quality": jnp.sum(jnp.where(sched, st.task_quality, 0.0)),
            "sum_steps": jnp.sum(fsch * st.task_steps),
            "busy_time": busy,
            "elapsed": te,
            "hist": MX.bucketize_counts(resp, sched, edges),
        }
        if faulty:
            stats["n_failed"] = jnp.sum(
                (st.task_status == 3).astype(jnp.int32))
        if per_model:
            oh = jax.nn.one_hot(jnp.clip(trace["model"], 0,
                                         ecfg.num_models - 1),
                                ecfg.num_models, dtype=jnp.float32)  # (K, M)
            stats["n_sched_m"] = jnp.sum(oh * fsch[:, None], axis=0)
            stats["n_reload_m"] = jnp.sum(
                oh * (fsch * st.task_reload.astype(jnp.float32))[:, None],
                axis=0)

        # ---- carry: rebase the clock, keep server occupancy + gang ids --
        gang = st.server_gang
        has = gang >= 0
        same = gang[:, None] == gang[None, :]
        leader = jnp.min(jnp.where(same & has[None, :],
                                   jnp.arange(E)[None, :], E), axis=1)
        carry = EV.EnvState(
            time=jnp.zeros((), jnp.float32),
            server_free_at=jnp.maximum(st.server_free_at - te, 0.0),
            server_model=st.server_model,
            server_gang=jnp.where(has, K + leader, -1).astype(jnp.int32),
            server_gang_size=st.server_gang_size,
            task_status=jnp.zeros((K,), jnp.int32),
            task_start=jnp.zeros((K,), jnp.float32),
            task_finish=jnp.zeros((K,), jnp.float32),
            task_steps=jnp.zeros((K,), jnp.int32),
            task_quality=jnp.zeros((K,), jnp.float32),
            task_reload=jnp.zeros((K,), jnp.int32),
            steps_taken=jnp.zeros((), jnp.int32),
        )
        if faulty:                   # carried servers lose their cache if
            wipe = jnp.any(trace["f_down_start"] <= te, axis=1) \
                & (trace["f_cold"][0] > 0)   # their crash began this window
            carry = carry._replace(
                server_model=jnp.where(wipe, -1, carry.server_model),
                server_gang=jnp.where(wipe, -1, carry.server_gang),
                server_gang_size=jnp.where(wipe, 0,
                                           carry.server_gang_size))

        # ---- leftovers: unscheduled tasks, oldest first, clock rebased --
        left = st.task_status == 0
        n_left = jnp.sum(left.astype(jnp.int32))
        order = jnp.argsort(jnp.where(left, trace["arr_time"], EV.INF))
        leftovers = {c: trace[c][order] for c in _COLS}
        leftovers["arr_time"] = leftovers["arr_time"] - te
        if faulty:
            leftovers[RETRY_COL] = trace[RETRY_COL][order]
            # ---- failed tasks: compacted for the host retry machinery --
            failed = st.task_status == 3
            n_fail = jnp.sum(failed.astype(jnp.int32))
            forder = jnp.argsort(jnp.where(failed, trace["arr_time"],
                                           EV.INF))
            fail = {c: trace[c][forder] for c in _COLS}
            fail["arr_time"] = fail["arr_time"] - te
            fail[RETRY_COL] = trace[RETRY_COL][forder]
            return stats, carry, leftovers, n_left, fail, n_fail
        return stats, carry, leftovers, n_left

    return jax.vmap(one)(traces, state)


def _reset_batch(ecfg: EV.EnvConfig, B: int) -> EV.EnvState:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (B,) + x.shape), EV.reset(ecfg))


class StreamResult(NamedTuple):
    summary: Dict
    per_window: List[Dict]
    aggregator: MX.StreamAggregator
    final_carry: EV.EnvState
    transitions: Optional[List[Transitions]] = None   # per window, collect=
    fault_counters: Dict = {}          # host fault ledger (empty: faults off)
    placement_counters: Dict = {}      # slow-timescale placement ledger
    #                                    (empty: placement off); includes a
    #                                    nested "per_model" cold-start table


class WindowResult(NamedTuple):
    """One window of one `StreamRunner`: raw per-stream stats, the flat
    per-window ledger record, rollout metrics, and (collect=True) the
    window's stacked (B, T, ...) transitions."""
    window: int
    stats: Dict[str, np.ndarray]
    record: Dict
    metrics: Dict
    transitions: Optional[Transitions]


class StreamRunner:
    """Stateful windowed streaming: each `run_window()` call advances every
    stream by one window of K = ecfg.max_tasks tasks and returns that
    window's stats (and, with `collect=True`, its stacked transitions),
    while backlog, clock epoch, and server occupancy carry across the seam.

    This is the collect-capable engine under both `run_stream` (which just
    loops it) and the streaming trainers (`repro.training.stream_train`),
    which interleave gradient updates between windows: the policy callable
    and params may be swapped per window (e.g. warmup -> actor, fresh actor
    weights every round) without disturbing the carried stream state.

    Window w uses PRNG key fold_in(key, w) split over the B streams, so a
    single-window run from a fresh carry reproduces the episodic
    `batch_rollout(ecfg, traces, policy, params, split(fold_in(key, 0), B))`
    bit-for-bit — on every execution backend (`rollout_fn` swaps in the
    `repro.api` reference / fused / sharded engines, all bitwise-identical;
    None keeps `batch_rollout` on the `scfg.fused` path). The transition
    layout is stable across seams: always (B, T, ...) with window-local
    clocks in the observations and `valid` masking steps past the drain.
    """

    def __init__(self, ecfg: EV.EnvConfig, policy, params, source, key,
                 scfg: StreamConfig = StreamConfig(), rollout_fn=None,
                 tracer=None):
        K, B = ecfg.max_tasks, scfg.num_streams
        max_carry = K // 2 if scfg.max_carry is None else int(scfg.max_carry)
        if not 0 <= max_carry < K:
            raise ValueError(f"max_carry must be in [0, {K}), got {max_carry}")
        self.ecfg, self.scfg = ecfg, scfg
        self.params = params
        self._set_policy(policy)
        self.source, self.key = source, key
        self.rollout_fn = rollout_fn
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.K, self.B = K, B
        self.T = scfg.max_steps_per_window or min(4 * K, ecfg.max_steps)
        self.max_carry = max_carry
        self._edges = jnp.asarray(MX.DEFAULT_EDGES)
        self._sla = jnp.float32(scfg.resp_sla)
        self.agg = MX.StreamAggregator(ecfg.num_servers, ecfg.q_min,
                                       scfg.resp_sla, edges=MX.DEFAULT_EDGES)
        self.carry = _reset_batch(ecfg, B)
        self.leftovers = [{c: np.zeros((0,), _DTYPES[c]) for c in _COLS}
                          for _ in range(B)]
        self.t0 = np.zeros(B, np.float64)   # absolute epoch of window start
        self.window = 0
        self.per_window: List[Dict] = []
        # ---- fault tolerance: crash timeline + host retry buffers -------
        self.faults = scfg.faults if faults_active(scfg.faults) else None
        if self.faults is not None:
            self.timeline = FaultTimeline(self.faults, ecfg.num_servers, B)
            self._horizon = fault_horizon(ecfg.time_limit, self.faults)
            for lo in self.leftovers:
                lo[RETRY_COL] = np.zeros((0,), np.int32)
            # per stream: failed tasks waiting out their backoff. arr_abs /
            # ready_abs are absolute-clock float64 (windows rebase to f32).
            self._retry = [
                {"arr_abs": np.zeros((0,), np.float64),
                 "c": np.zeros((0,), np.int32),
                 "model": np.zeros((0,), np.int32),
                 "noise": np.zeros((0,), np.float32),
                 "retries": np.zeros((0,), np.int32),
                 "ready_abs": np.zeros((0,), np.float64)}
                for _ in range(B)]
        # ---- slow timescale: proactive model placement at window seams --
        self.placement = None
        if placement_active(scfg.placement):
            self.placement = PlacementManager(scfg.placement, ecfg, B,
                                              tracer=self.tracer)
            # per-model scheduled/reload tallies (cold-start-rate labels)
            self._pm_sched = np.zeros(ecfg.num_models, np.float64)
            self._pm_reload = np.zeros(ecfg.num_models, np.float64)

    # ------------------------------------------------------------------
    def _set_policy(self, policy) -> None:
        """Register the current policy with the shared actor layer: the
        seam swap (`run_window(policy=...)`) re-resolves the cached
        `ActorProgram`, so per-window policy changes (warmup -> actor,
        sampler swaps) reuse compiled programs instead of re-deriving
        callables — and the program's sampler label feeds the window
        span."""
        from repro.actors.program import actor_program
        self.policy = policy
        self.program = actor_program(self.ecfg, policy)

    # ------------------------------------------------------------------
    def _build_window(self):
        """Fill the next window's traces: re-admit retry-buffer tasks whose
        backoff expired (merged into the backlog by original arrival time),
        shed over-carry backlog, re-inject the surviving leftovers, top up
        with fresh arrivals."""
        K, B = self.K, self.B
        faulty = self.faults is not None
        cols = {c: np.zeros((B, K), _DTYPES[c]) for c in _COLS}
        if faulty:
            cols[RETRY_COL] = np.zeros((B, K), np.int32)
        n_injected = np.zeros(B, np.int64)
        n_dropped = np.zeros(B, np.int64)
        n_carried = np.zeros(B, np.int64)
        n_readmit = np.zeros(B, np.int64)
        for b in range(B):
            lo = self.leftovers[b]
            if faulty:
                rb = self._retry[b]
                due = rb["ready_abs"] <= self.t0[b]
                if due.any():
                    # keep the ORIGINAL (rebased) arrival time: latency is
                    # measured from first arrival, not from re-admission
                    add = {"arr_time": (rb["arr_abs"][due] - self.t0[b]
                                        ).astype(np.float32),
                           "c": rb["c"][due], "model": rb["model"][due],
                           "noise": rb["noise"][due],
                           RETRY_COL: rb["retries"][due]}
                    n_readmit[b] = int(due.sum())
                    lo = {c: np.concatenate([lo[c], add[c]]) for c in lo}
                    order = np.argsort(lo["arr_time"], kind="stable")
                    lo = {c: v[order] for c, v in lo.items()}
                    self._retry[b] = {c: v[~due] for c, v in rb.items()}
            nl = len(lo["arr_time"])
            if nl > self.max_carry:        # shed the stalest backlog
                n_dropped[b] = nl - self.max_carry
                lo = {c: v[nl - self.max_carry:] for c, v in lo.items()}
                nl = self.max_carry
            n_carried[b] = nl
            n_new = K - nl
            new = self.source.take(b, n_new)
            n_injected[b] = n_new
            for c in _COLS:
                cols[c][b, :nl] = lo[c]
                if c == "arr_time":        # absolute -> window-local clock
                    cols[c][b, nl:] = (new[c].astype(np.float64)
                                       - self.t0[b]).astype(np.float32)
                else:
                    cols[c][b, nl:] = new[c]
            if faulty:
                cols[RETRY_COL][b, :nl] = lo[RETRY_COL]
        return cols, n_injected, n_dropped, n_carried, n_readmit

    def run_window(self, *, policy=None, params=None,
                   collect: bool = False) -> WindowResult:
        """Advance every stream by one window. `policy`/`params`, when
        given, replace the runner's current ones from this window on (the
        trainers push freshly-updated actor weights each round)."""
        if policy is not None:
            self._set_policy(policy)
        if params is not None:
            self.params = params
        w = self.window
        tr = self.tracer
        wkw = ({"sampler": self.program.sampler}
               if self.program.sampler else {})
        wspan = tr.span("window", cat="stream", window=w,
                        backend=getattr(self.rollout_fn, "backend",
                                        "fused" if self.scfg.fused
                                        else "reference"), **wkw)
        with wspan:
            with tr.span("build_window", cat="stream", window=w):
                (cols, n_injected, n_dropped, n_carried,
                 n_readmit) = self._build_window()
                if self.placement is not None:
                    # demand for the slow timescale: this window's tasks,
                    # folded BEFORE the rollout but only consulted at the
                    # seam AFTER it — the layout for window w+1 sees
                    # arrivals of windows <= w, never its own
                    self.placement.observe_window(w, cols)
                traces = {c: jnp.asarray(v) for c, v in cols.items()}
                if self.faults is not None:
                    fa = self.timeline.window_arrays(w, self.t0,
                                                     self._horizon)
                    traces.update(
                        {k: jnp.asarray(v) for k, v in fa.items()})
                keys = jax.random.split(jax.random.fold_in(self.key, w),
                                        self.B)
            with tr.span("window_rollout", cat="rollout", window=w,
                         streams=self.B, steps=self.T):
                if self.rollout_fn is None:
                    res = RO.batch_rollout(self.ecfg, traces, self.policy,
                                           self.params, keys,
                                           num_steps=self.T,
                                           init_state=self.carry,
                                           collect=collect,
                                           fused=self.scfg.fused)
                else:
                    res = self.rollout_fn(self.ecfg, traces, self.policy,
                                          self.params, keys,
                                          num_steps=self.T,
                                          init_state=self.carry,
                                          collect=collect)
                if tr.enabled:
                    # wall-clock attribution only: make the async rollout
                    # finish inside its span instead of inside the seam's
                    jax.block_until_ready(res.final_state)
            with tr.span("window_seam", cat="stream", window=w):
                seam = _window_seam(self.ecfg, traces, res.final_state,
                                    self._edges, self._sla,
                                    per_model=self.placement is not None)
                if self.faults is not None:
                    stats, self.carry, lcols, n_left, fcols, n_fail = seam
                    lcols_keys = _COLS + (RETRY_COL,)
                else:
                    stats, self.carry, lcols, n_left = seam
                    fcols = n_fail = None
                    lcols_keys = _COLS
                n_left = np.asarray(n_left)
                lcols = {c: np.asarray(v) for c, v in lcols.items()}
                self.leftovers = [{c: lcols[c][b, :n_left[b]]
                                   for c in lcols_keys}
                                  for b in range(self.B)]
                self.t0 += np.asarray(stats["elapsed"], np.float64)
            if self.placement is not None:
                # slow timescale: rewrite the carried host state (idle
                # servers only) and let a real-weight backend prefetch
                # off the timed path
                self.carry, decision = self.placement.apply(self.carry, w)
                if decision is not None:
                    hook = getattr(self.rollout_fn, "apply_placement", None)
                    if hook is not None:
                        hook(decision)

        n_retried = np.zeros(self.B, np.int64)
        n_fail_drop = np.zeros(self.B, np.int64)
        if self.faults is not None:
            with tr.span("fault_requeue", cat="stream", window=w):
                n_retried, n_fail_drop = self._requeue_failed(
                    {c: np.asarray(v) for c, v in fcols.items()},
                    np.asarray(n_fail))
            tr.counter("pending_retry", float(self.pending_retry()),
                       window=w)

        tr.counter("backlog", float(n_left.sum()), window=w)
        rec = {k: np.asarray(v) for k, v in stats.items()}
        if self.placement is not None:
            # per-model tallies are placement telemetry, not window-ledger
            # rows: fold them here and keep the aggregator's schema fixed
            self._pm_sched += rec.pop("n_sched_m").sum(axis=0)
            self._pm_reload += rec.pop("n_reload_m").sum(axis=0)
        rec["n_injected"] = n_injected
        rec["n_dropped"] = n_dropped
        rec["n_carried"] = n_carried
        rec["n_leftover"] = n_left.astype(np.int64)
        if self.faults is not None:
            rec["n_retried"] = n_retried
            rec["n_failed_dropped"] = n_fail_drop
            rec["n_readmitted"] = n_readmit
        self.agg.update(rec)
        n_sched_w = int(rec["n_sched"].sum())
        record = {
            "window": w,
            "injected": int(n_injected.sum()),
            "carried": int(n_carried.sum()),
            "scheduled": n_sched_w,
            "dropped": int(n_dropped.sum()),
            "leftover": int(n_left.sum()),
            "mean_elapsed": float(np.mean(rec["elapsed"])),
            "mean_latency": float(rec["sum_resp"].sum() / max(n_sched_w, 1)),
            "episode_return_mean": float(np.mean(np.asarray(
                res.metrics["episode_return"]))),
        }
        if self.faults is not None:
            record["failed"] = int(rec["n_failed"].sum())
            record["retried"] = int(n_retried.sum())
            record["failed_dropped"] = int(n_fail_drop.sum())
            record["pending_retry"] = self.pending_retry()
        self.per_window.append(record)
        self.window += 1
        return WindowResult(window=w, stats=rec, record=record,
                            metrics=res.metrics,
                            transitions=res.transitions if collect else None)

    # ------------------------------------------------------------------
    def _requeue_failed(self, fcols: Dict[str, np.ndarray],
                        n_fail: np.ndarray):
        """Route this window's crashed tasks into the retry buffers.

        Each failure bumps the task's retry count and earns a capped
        exponential backoff (`faults.retry_backoff`) measured from the new
        window epoch; tasks beyond `max_retries`, or whose age at the
        earliest possible re-admission would already exceed
        `retry_deadline`, are dropped (deadline-aware retry budget — a task
        that cannot possibly meet QoS is not worth a server)."""
        spec = self.faults
        n_retried = np.zeros(self.B, np.int64)
        n_dropped = np.zeros(self.B, np.int64)
        for b in range(self.B):
            m = int(n_fail[b])
            if m == 0:
                continue
            # arr was rebased to the new epoch by the seam (-te), so the
            # absolute original arrival is rebased + t0 (t0 already moved)
            arr_abs = fcols["arr_time"][b, :m].astype(np.float64) \
                + self.t0[b]
            r = fcols[RETRY_COL][b, :m].astype(np.int64) + 1
            ready = self.t0[b] + np.array(
                [retry_backoff(spec, int(ri)) for ri in r], np.float64)
            keep = (r <= spec.max_retries) \
                & ((ready - arr_abs) <= spec.retry_deadline)
            n_retried[b] = int(keep.sum())
            n_dropped[b] = m - int(keep.sum())
            if not keep.any():
                continue
            rb = self._retry[b]
            self._retry[b] = {
                "arr_abs": np.concatenate([rb["arr_abs"], arr_abs[keep]]),
                "c": np.concatenate([rb["c"], fcols["c"][b, :m][keep]]),
                "model": np.concatenate([rb["model"],
                                         fcols["model"][b, :m][keep]]),
                "noise": np.concatenate([rb["noise"],
                                         fcols["noise"][b, :m][keep]]),
                "retries": np.concatenate([rb["retries"],
                                           r[keep].astype(np.int32)]),
                "ready_abs": np.concatenate([rb["ready_abs"], ready[keep]]),
            }
        return n_retried, n_dropped

    def pending_retry(self) -> int:
        """Failed tasks currently waiting out their backoff."""
        if self.faults is None:
            return 0
        return int(sum(len(rb["arr_abs"]) for rb in self._retry))

    def backlog(self) -> int:
        """Tasks currently waiting across all streams (pre-shedding)."""
        return int(sum(len(l["arr_time"]) for l in self.leftovers))

    def fault_counters(self) -> Dict[str, int]:
        """Host-side fault bookkeeping (empty when faults are off)."""
        if self.faults is None:
            return {}
        out = dict(self.timeline.counters())
        out["tasks_pending_retry"] = self.pending_retry()
        return out

    def placement_counters(self) -> Dict:
        """Slow-timescale placement ledger (empty when placement is off):
        the manager's cumulative counts plus a nested "per_model" table of
        {model: {scheduled, reloads, cold_start_rate}} — the source of the
        per-model cold-start-rate telemetry labels."""
        if self.placement is None:
            return {}
        out = dict(self.placement.counters())
        out["per_model"] = {
            int(m): {"scheduled": float(self._pm_sched[m]),
                     "reloads": float(self._pm_reload[m]),
                     "cold_start_rate": float(
                         self._pm_reload[m] / max(self._pm_sched[m], 1.0))}
            for m in range(self.ecfg.num_models)}
        return out

    def result(self, transitions: Optional[List[Transitions]] = None
               ) -> StreamResult:
        summary = self.agg.summary()
        summary["tasks_leftover"] = self.backlog()
        summary["num_streams"] = self.B
        summary["window_tasks"] = self.K
        summary["tasks_failed_pending_retry"] = self.pending_retry()
        return StreamResult(summary=summary, per_window=self.per_window,
                            aggregator=self.agg, final_carry=self.carry,
                            transitions=transitions,
                            fault_counters=self.fault_counters(),
                            placement_counters=self.placement_counters())


# ----------------------------------------------------------------------
def run_stream(ecfg: EV.EnvConfig, policy, params, source, key,
               scfg: StreamConfig = StreamConfig(),
               rollout_fn=None, collect: bool = False,
               tracer=None) -> StreamResult:
    """Drive `num_windows` windows of K = ecfg.max_tasks tasks per stream.

    A thin loop over `StreamRunner.run_window`; see that class for the seam
    and PRNG-key semantics. Device memory is O(B * K) regardless of the
    horizon (`collect=True` additionally returns each window's stacked
    (B, T, ...) transitions, so memory grows with `num_windows` — training
    consumers that need bounded memory drive `StreamRunner` directly and
    drain each window into their replay buffer / GAE pool).
    """
    runner = StreamRunner(ecfg, policy, params, source, key, scfg,
                          rollout_fn=rollout_fn, tracer=tracer)
    collected: Optional[List[Transitions]] = [] if collect else None
    for _ in range(scfg.num_windows):
        wres = runner.run_window(collect=collect)
        if collect:
            collected.append(wres.transitions)
    return runner.result(transitions=collected)
