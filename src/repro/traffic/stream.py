"""Unbounded-horizon windowed streaming on the batched rollout engine.

The episodic engine (`core/rollout.py`) runs one fixed-size trace to
completion. This module chains it over consecutive fixed-size *task windows*
with carried environment state, so a run covers 10^5-10^6 tasks at O(window)
memory:

    window w trace  ->  batch_rollout(init_state = carry_{w-1})  ->  seam:
        * clock rebased to 0 (float32 stays precise at any horizon)
        * residual server busy time / model / gang metadata carried
        * carried gangs relabelled into [K, K+E) so their labels can never
          collide with the next window's task ids (reuse survives the seam)
        * unscheduled tasks compacted and re-injected into the next window
          (oldest beyond `max_carry` are shed and counted as dropped)

Each window is B parallel independent streams in one jitted program
(`batch_rollout` vmap). Arrival times are open-loop: a `TaskSource` draws
fixed-shape chunks from an arrival process (`arrivals.py`) on its own clock,
regardless of how far the scheduler has fallen behind. Per-window QoS stats
are reduced device-side and folded into a `StreamAggregator` on the host.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.workload import TraceConfig, sample_task_attrs
from repro.traffic import metrics as MX

_COLS = ("arr_time", "c", "model", "noise")
_DTYPES = {"arr_time": np.float32, "c": np.int32, "model": np.int32,
           "noise": np.float32}


@dataclass(frozen=True)
class StreamConfig:
    num_windows: int = 16
    num_streams: int = 1                    # B independent parallel streams
    max_steps_per_window: Optional[int] = None   # default min(4K, max_steps)
    max_carry: Optional[int] = None         # leftover slots kept; default K//2
    resp_sla: float = 120.0                 # QoS latency budget (seconds)
    chunk_size: int = 0                     # arrival buffer refill; 0 = 4K
    fused: bool = True                      # fused env-step engine (bitwise
    #                                         identical; False = legacy path)


# ----------------------------------------------------------------------
# task sources: host-side open-loop suppliers of (arr_time, c, model, noise)
class ProcessTaskSource:
    """Draws tasks from an arrival process + TraceConfig attribute marginals.

    Keeps one process state and one absolute arrival clock per stream;
    refills per-stream buffers in fixed-size chunks through a single jitted,
    vmapped sampler, so chunk generation compiles once per run.
    """

    def __init__(self, proc, tc: TraceConfig, key, num_streams: int = 1,
                 chunk_size: int = 0):
        self.proc = proc
        self.tc = tc
        self.B = int(num_streams)
        self.chunk = int(chunk_size) if chunk_size else max(4 * tc.num_tasks, 64)
        k_init, self._attr_key = jax.random.split(key)
        self._states = jax.vmap(proc.init)(jax.random.split(k_init, self.B))
        self._sample = jax.jit(jax.vmap(lambda s: proc.sample(s, self.chunk)))
        self._attrs = jax.jit(jax.vmap(
            lambda k: sample_task_attrs(k, tc, self.chunk)))
        self._clock = np.zeros(self.B, np.float64)   # absolute arrival clock
        self._buf = [{c: np.zeros((0,), _DTYPES[c]) for c in _COLS}
                     for _ in range(self.B)]

    def _refill(self) -> None:
        self._states, gaps = self._sample(self._states)
        gaps = np.asarray(gaps, np.float64)                    # (B, chunk)
        arr = self._clock[:, None] + np.cumsum(gaps, axis=1)
        self._clock = arr[:, -1].copy()
        self._attr_key, k = jax.random.split(self._attr_key)
        c, model, noise = self._attrs(jax.random.split(k, self.B))
        c, model, noise = (np.asarray(c), np.asarray(model), np.asarray(noise))
        for b in range(self.B):
            new = {"arr_time": arr[b].astype(np.float64), "c": c[b],
                   "model": model[b], "noise": noise[b]}
            self._buf[b] = {col: np.concatenate([self._buf[b][col], new[col]])
                            for col in _COLS}

    def take(self, stream: int, n: int) -> Dict[str, np.ndarray]:
        """Pop the next n tasks of one stream (arr_time is absolute)."""
        while len(self._buf[stream]["arr_time"]) < n:
            self._refill()
        out = {col: self._buf[stream][col][:n] for col in _COLS}
        self._buf[stream] = {col: self._buf[stream][col][n:] for col in _COLS}
        return out


class TraceTaskSource:
    """Finite source replaying explicit traces with full attributes —
    feed an episodic trace through the streaming engine verbatim (parity
    tests, trace-driven evaluation). `traces` is a dict of (B, N) arrays
    with *absolute* arrival times."""

    def __init__(self, traces: Dict):
        self._cols = {c: np.asarray(traces[c]) for c in _COLS}
        self.B, self.N = self._cols["arr_time"].shape
        self._cursor = np.zeros(self.B, np.int64)

    def take(self, stream: int, n: int) -> Dict[str, np.ndarray]:
        i = int(self._cursor[stream])
        if i + n > self.N:
            raise ValueError(f"TraceTaskSource exhausted: stream {stream} "
                             f"has {self.N - i} tasks left, asked for {n}")
        self._cursor[stream] = i + n
        return {c: v[stream, i:i + n] for c, v in self._cols.items()}


# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("ecfg",))
def _window_seam(ecfg: EV.EnvConfig, traces: Dict, state: EV.EnvState,
                 edges: jnp.ndarray, resp_sla: jnp.ndarray):
    """Device-side seam: per-window QoS stats + next-window carry state +
    compacted leftovers, vmapped over the stream axis."""
    K, E = ecfg.max_tasks, ecfg.num_servers

    def one(trace, st):
        te = st.time
        sched = st.task_status >= 1
        fsch = sched.astype(jnp.float32)
        resp = jnp.where(sched, st.task_finish - trace["arr_time"], 0.0)
        viol_q = sched & (st.task_quality < ecfg.q_min)
        viol_t = sched & (resp > resp_sla)
        viol = viol_q | viol_t
        busy = jnp.sum(jnp.where(sched, trace["c"].astype(jnp.float32)
                                 * (st.task_finish - st.task_start), 0.0))
        stats = {
            "n_sched": jnp.sum(sched.astype(jnp.int32)),
            "n_done": jnp.sum((st.task_status == 2).astype(jnp.int32)),
            "n_reload": jnp.sum(jnp.where(sched, st.task_reload, 0)),
            "n_viol": jnp.sum(viol.astype(jnp.int32)),
            "n_viol_q": jnp.sum(viol_q.astype(jnp.int32)),
            "n_viol_t": jnp.sum(viol_t.astype(jnp.int32)),
            "sum_resp": jnp.sum(resp),
            "max_resp": jnp.max(resp),
            "sum_quality": jnp.sum(jnp.where(sched, st.task_quality, 0.0)),
            "sum_steps": jnp.sum(fsch * st.task_steps),
            "busy_time": busy,
            "elapsed": te,
            "hist": MX.bucketize_counts(resp, sched, edges),
        }

        # ---- carry: rebase the clock, keep server occupancy + gang ids --
        gang = st.server_gang
        has = gang >= 0
        same = gang[:, None] == gang[None, :]
        leader = jnp.min(jnp.where(same & has[None, :],
                                   jnp.arange(E)[None, :], E), axis=1)
        carry = EV.EnvState(
            time=jnp.zeros((), jnp.float32),
            server_free_at=jnp.maximum(st.server_free_at - te, 0.0),
            server_model=st.server_model,
            server_gang=jnp.where(has, K + leader, -1).astype(jnp.int32),
            server_gang_size=st.server_gang_size,
            task_status=jnp.zeros((K,), jnp.int32),
            task_start=jnp.zeros((K,), jnp.float32),
            task_finish=jnp.zeros((K,), jnp.float32),
            task_steps=jnp.zeros((K,), jnp.int32),
            task_quality=jnp.zeros((K,), jnp.float32),
            task_reload=jnp.zeros((K,), jnp.int32),
            steps_taken=jnp.zeros((), jnp.int32),
        )

        # ---- leftovers: unscheduled tasks, oldest first, clock rebased --
        left = st.task_status == 0
        n_left = jnp.sum(left.astype(jnp.int32))
        order = jnp.argsort(jnp.where(left, trace["arr_time"], EV.INF))
        leftovers = {c: trace[c][order] for c in _COLS}
        leftovers["arr_time"] = leftovers["arr_time"] - te
        return stats, carry, leftovers, n_left

    return jax.vmap(one)(traces, state)


def _reset_batch(ecfg: EV.EnvConfig, B: int) -> EV.EnvState:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (B,) + x.shape), EV.reset(ecfg))


class StreamResult(NamedTuple):
    summary: Dict
    per_window: List[Dict]
    aggregator: MX.StreamAggregator
    final_carry: EV.EnvState


# ----------------------------------------------------------------------
def run_stream(ecfg: EV.EnvConfig, policy, params, source, key,
               scfg: StreamConfig = StreamConfig(),
               rollout_fn=None) -> StreamResult:
    """Drive `num_windows` windows of K = ecfg.max_tasks tasks per stream.

    Window w uses PRNG key fold_in(key, w) split over the B streams, so a
    single-window stream from a fresh carry reproduces the episodic
    `batch_rollout(ecfg, traces, policy, params, split(fold_in(key, 0), B))`
    bit-for-bit. Device memory is O(B * K) regardless of the horizon.

    `rollout_fn` swaps the per-window execution engine (the `repro.api`
    backends — reference / fused / sharded — all bitwise-identical); None
    keeps `batch_rollout` on the `scfg.fused` path.
    """
    K, B = ecfg.max_tasks, scfg.num_streams
    T = scfg.max_steps_per_window or min(4 * K, ecfg.max_steps)
    max_carry = K // 2 if scfg.max_carry is None else int(scfg.max_carry)
    if not 0 <= max_carry < K:
        raise ValueError(f"max_carry must be in [0, {K}), got {max_carry}")
    edges = jnp.asarray(MX.DEFAULT_EDGES)
    sla = jnp.float32(scfg.resp_sla)
    agg = MX.StreamAggregator(ecfg.num_servers, ecfg.q_min, scfg.resp_sla,
                              edges=MX.DEFAULT_EDGES)

    carry = _reset_batch(ecfg, B)
    leftovers = [{c: np.zeros((0,), _DTYPES[c]) for c in _COLS}
                 for _ in range(B)]
    t0 = np.zeros(B, np.float64)            # absolute epoch of window start
    per_window: List[Dict] = []

    for w in range(scfg.num_windows):
        cols = {c: np.zeros((B, K), _DTYPES[c]) for c in _COLS}
        n_injected = np.zeros(B, np.int64)
        n_dropped = np.zeros(B, np.int64)
        for b in range(B):
            lo = leftovers[b]
            nl = len(lo["arr_time"])
            if nl > max_carry:             # shed the stalest backlog
                n_dropped[b] = nl - max_carry
                lo = {c: v[nl - max_carry:] for c, v in lo.items()}
                nl = max_carry
            n_new = K - nl
            new = source.take(b, n_new)
            n_injected[b] = n_new
            for c in _COLS:
                cols[c][b, :nl] = lo[c]
                if c == "arr_time":        # absolute -> window-local clock
                    cols[c][b, nl:] = (new[c].astype(np.float64)
                                       - t0[b]).astype(np.float32)
                else:
                    cols[c][b, nl:] = new[c]
        traces = {c: jnp.asarray(v) for c, v in cols.items()}
        keys = jax.random.split(jax.random.fold_in(key, w), B)
        if rollout_fn is None:
            res = RO.batch_rollout(ecfg, traces, policy, params, keys,
                                   num_steps=T, init_state=carry,
                                   fused=scfg.fused)
        else:
            res = rollout_fn(ecfg, traces, policy, params, keys,
                             num_steps=T, init_state=carry)
        stats, carry, lcols, n_left = _window_seam(ecfg, traces,
                                                   res.final_state, edges, sla)
        n_left = np.asarray(n_left)
        lcols = {c: np.asarray(v) for c, v in lcols.items()}
        leftovers = [{c: lcols[c][b, :n_left[b]] for c in _COLS}
                     for b in range(B)]
        t0 += np.asarray(stats["elapsed"], np.float64)

        rec = {k: np.asarray(v) for k, v in stats.items()}
        rec["n_injected"] = n_injected
        rec["n_dropped"] = n_dropped
        rec["n_leftover"] = n_left.astype(np.int64)
        agg.update(rec)
        n_sched_w = int(rec["n_sched"].sum())
        per_window.append({
            "window": w,
            "injected": int(n_injected.sum()),
            "scheduled": n_sched_w,
            "dropped": int(n_dropped.sum()),
            "leftover": int(n_left.sum()),
            "mean_elapsed": float(np.mean(rec["elapsed"])),
            "mean_latency": float(rec["sum_resp"].sum() / max(n_sched_w, 1)),
            "episode_return_mean": float(np.mean(np.asarray(
                res.metrics["episode_return"]))),
        })

    summary = agg.summary()
    summary["tasks_leftover"] = int(sum(len(l["arr_time"])
                                        for l in leftovers))
    summary["num_streams"] = B
    summary["window_tasks"] = K
    return StreamResult(summary=summary, per_window=per_window,
                        aggregator=agg, final_carry=carry)
