"""Open-loop arrival processes for streaming traffic simulation.

Every process is a frozen dataclass with a tiny functional-state protocol:

    state = proc.init(key)                 # pytree of scalars / small arrays
    state, gaps = proc.sample(state, n)    # n inter-arrival gaps (seconds)
    proc.mean_rate()                       # long-run tasks/second (analytic)

`sample` is traceable with static n, so the streaming engine jits one
fixed-chunk sampler per run (vmapped over independent streams) and the
process state threads through window seams — the horizon is unbounded while
memory stays O(chunk). Gaps compose into absolute arrival times by cumsum on
the caller's arrival clock.

Beyond the paper's fixed-rate exponential (§IV.A.1), the library covers the
workload families motivated by related work: Markov-modulated Poisson bursts
and multi-rate grids (arXiv 2405.08328) and time-varying demand — diurnal
sinusoid and flash-crowd spikes — as in two-timescale caching under
non-stationary load (arXiv 2411.01458), plus replay-from-array for trace-
driven evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class PoissonArrivals:
    """Homogeneous Poisson: i.i.d. exponential gaps (the paper's D_g)."""
    rate: float = 0.1

    def init(self, key):
        return key

    def sample(self, state, n: int):
        key, k = jax.random.split(state)
        gaps = jax.random.exponential(k, (n,)) / self.rate
        return key, gaps.astype(jnp.float32)

    def mean_rate(self) -> float:
        return self.rate


@dataclass(frozen=True)
class MMPPArrivals:
    """Markov-modulated Poisson (bursty): each gap is exponential at the
    current phase's rate; after every arrival the phase jumps to a uniformly
    random *other* phase with probability `switch`. Symmetric switching
    makes the stationary phase distribution uniform, so the long-run rate is
    the harmonic mean of the phase rates."""
    rates: Tuple[float, ...] = (0.02, 0.3)
    switch: float = 0.05

    def init(self, key):
        key, k = jax.random.split(key)
        phase = jax.random.randint(k, (), 0, len(self.rates))
        return (key, phase)

    def sample(self, state, n: int):
        key, phase = state
        rates = jnp.asarray(self.rates, jnp.float32)
        P = len(self.rates)

        def body(ph, k):
            ke, ks, kp = jax.random.split(k, 3)
            gap = jax.random.exponential(ke) / rates[ph]
            jump = jax.random.randint(kp, (), 1, max(P, 2))
            ph_next = jnp.where(jax.random.bernoulli(ks, self.switch),
                                (ph + jump) % P, ph)
            return ph_next, gap

        key, k_scan = jax.random.split(key)
        phase, gaps = jax.lax.scan(body, phase, jax.random.split(k_scan, n))
        return (key, phase), gaps.astype(jnp.float32)

    def mean_rate(self) -> float:
        return len(self.rates) / sum(1.0 / r for r in self.rates)


@dataclass(frozen=True)
class _RateModulated:
    """Shared machinery for time-varying intensity lambda(t): each gap is
    exponential at the intensity evaluated at the current arrival clock — a
    good NHPP approximation whenever gaps are short against the modulation
    period. State carries (key, arrival clock)."""

    def rate_at(self, t):
        raise NotImplementedError

    def init(self, key):
        return (key, jnp.zeros((), jnp.float32))

    def sample(self, state, n: int):
        key, t = state

        def body(tc, k):
            lam = jnp.maximum(self.rate_at(tc), 1e-6)
            gap = jax.random.exponential(k) / lam
            return tc + gap, gap

        key, k_scan = jax.random.split(key)
        t, gaps = jax.lax.scan(body, t, jax.random.split(k_scan, n))
        return (key, t), gaps.astype(jnp.float32)


@dataclass(frozen=True)
class DiurnalArrivals(_RateModulated):
    """Sinusoidal day/night demand: lambda(t) = base * (1 + amp sin(2 pi t / period))."""
    base_rate: float = 0.1
    amplitude: float = 0.6
    period: float = 2000.0

    def rate_at(self, t):
        return self.base_rate * (1.0 + self.amplitude *
                                 jnp.sin(2.0 * jnp.pi * t / self.period))

    def mean_rate(self) -> float:
        return self.base_rate


@dataclass(frozen=True)
class FlashCrowdArrivals(_RateModulated):
    """Periodic flash crowds: baseline rate with a spike of `spike_rate`
    lasting `spike_duration` seconds at the start of every `period`."""
    base_rate: float = 0.05
    spike_rate: float = 0.5
    period: float = 2000.0
    spike_duration: float = 200.0

    def rate_at(self, t):
        in_spike = jnp.mod(t, self.period) < self.spike_duration
        return jnp.where(in_spike, self.spike_rate, self.base_rate)

    def mean_rate(self) -> float:
        duty = self.spike_duration / self.period
        return self.spike_rate * duty + self.base_rate * (1.0 - duty)


@dataclass(frozen=True, eq=False)
class ReplayArrivals:
    """Replay absolute arrival times from an array; wraps around with a
    period of (last arrival + one mean gap) so the stream is unbounded.

    By default every stream replays the array from index 0 (deterministic
    round-trip — gaps cumsum back to `times` exactly). With `stagger=True`,
    `init` draws a key-dependent start index, so parallel streams replay
    phase-shifted copies instead of bit-identical arrival sequences.
    eq=False keeps the dataclass hashable by identity despite the array
    field (required for use as a static jit argument)."""
    times: Any = ()
    stagger: bool = False

    def init(self, key):
        if not self.stagger:
            return (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))
        arr, span = self._arr_span()
        idx = jax.random.randint(key, (), 0, arr.shape[0])
        # last emitted time = the wrapped predecessor of arr[idx], so the
        # first gap matches what a from-zero replay would produce there
        prev = jnp.where(idx > 0, arr[idx - 1], 0.0)
        return (idx, prev)

    def _arr_span(self):
        arr = jnp.asarray(self.times, jnp.float32)
        span = arr[-1] * (arr.shape[0] + 1) / arr.shape[0]
        return arr, span

    def sample(self, state, n: int):
        idx0, last = state
        arr, span = self._arr_span()
        N = arr.shape[0]
        i = idx0 + jnp.arange(n)
        t = arr[i % N] + (i // N).astype(jnp.float32) * span
        gaps = jnp.diff(jnp.concatenate([last[None], t]))
        return (idx0 + n, t[-1]), gaps.astype(jnp.float32)

    def mean_rate(self) -> float:
        import numpy as np
        arr = np.asarray(self.times, np.float32)
        span = float(arr[-1]) * (len(arr) + 1) / len(arr)
        return len(arr) / span


# ----------------------------------------------------------------------
_KINDS = {
    "poisson": PoissonArrivals,
    "mmpp": MMPPArrivals,
    "diurnal": DiurnalArrivals,
    "flash": FlashCrowdArrivals,
    "replay": ReplayArrivals,
}


def make_process(kind: str, **kwargs):
    """Registry constructor: make_process("mmpp", rates=(0.02, 0.3))."""
    if kind not in _KINDS:
        raise ValueError(f"unknown arrival process {kind!r}; "
                         f"choose from {sorted(_KINDS)}")
    return _KINDS[kind](**kwargs)


def scale_rate(proc, factor: float):
    """Uniformly scale a process's arrival intensity by `factor` — the
    sustained-overload knob for streaming training/benchmarks (factor > 1
    offers more load than the cluster drains). Replay traces have no free
    intensity parameter and cannot be scaled."""
    from dataclasses import replace
    if factor == 1.0:
        return proc
    if factor <= 0.0:
        raise ValueError(f"rate factor must be positive, got {factor}")
    if isinstance(proc, PoissonArrivals):
        return replace(proc, rate=proc.rate * factor)
    if isinstance(proc, MMPPArrivals):
        return replace(proc, rates=tuple(r * factor for r in proc.rates))
    if isinstance(proc, DiurnalArrivals):
        return replace(proc, base_rate=proc.base_rate * factor)
    if isinstance(proc, FlashCrowdArrivals):
        return replace(proc, base_rate=proc.base_rate * factor,
                       spike_rate=proc.spike_rate * factor)
    raise ValueError(f"cannot rate-scale {type(proc).__name__}")


def generate_trace(key, proc, tc, n: int = None):
    """Episodic bridge: one fixed-size trace dict (`workload.make_trace`
    schema) whose arrival times come from `proc` instead of the fixed-rate
    exponential. Used by scenarios that carry an arrival-process field."""
    from repro.core.workload import make_trace_from_arrivals
    n = int(n) if n else tc.num_tasks
    k_arr, k_attr = jax.random.split(key)
    _, gaps = proc.sample(proc.init(k_arr), n)
    arr = jnp.cumsum(gaps)
    return make_trace_from_arrivals(k_attr, arr, tc)
