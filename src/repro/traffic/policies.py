"""Policy-adapter layer: one name -> (rollout policy, params) for streaming.

Everything the streaming engine and the sweep driver schedule with goes
through here, so a sweep cell can say `--policies random,fifo,greedy,eat`
and get the paper's baselines plus the EAT SAC agent under one protocol
(`rollout.Policy`). The EAT adapter evaluates the diffusion actor
deterministically; weights come from a checkpoint directory when given,
otherwise from a fresh initialisation (useful for plumbing/perf runs — the
summary then reflects an untrained policy and says so).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.core import env as EV
from repro.core import rollout as RO

BASELINES = ("random", "fifo", "greedy")
LEARNED = ("eat", "ppo")


def available_policies() -> Tuple[str, ...]:
    return BASELINES + LEARNED


def make_policy(name: str, ecfg: EV.EnvConfig, *, acfg=None,
                checkpoint: Optional[str] = None, params=None,
                seed: int = 0) -> Tuple[RO.Policy, Dict]:
    """Resolve a policy name to (policy_fn, params) for `batch_rollout` /
    `run_stream`. `params` short-circuits loading (already-trained weights);
    `checkpoint` restores the latest step from a checkpoint directory."""
    if name == "random":
        return RO.uniform_policy(ecfg), {}
    if name == "fifo":
        return RO.fifo_policy(ecfg), {}
    if name == "greedy":
        return RO.greedy_policy(ecfg), {}
    if name == "eat":
        from repro.core import agent as AG
        from repro.core import sac as SAC
        acfg = acfg or AG.AgentConfig()
        if params is None:
            params = AG.init_actor(jax.random.PRNGKey(seed), ecfg, acfg)
            if checkpoint:
                params = _restore(checkpoint, params)
        return SAC.actor_policy(ecfg, acfg, deterministic=True), params
    if name == "ppo":
        from repro.core import ppo as PPO
        if params is None:
            params = PPO.init_ppo(jax.random.PRNGKey(seed), ecfg).params
            if checkpoint:
                params = _restore(checkpoint, params)
        return PPO.ppo_policy(ecfg), params
    raise ValueError(f"unknown policy {name!r}; "
                     f"choose from {available_policies()}")


def _restore(directory: str, target):
    from repro.common.checkpoint import restore_checkpoint
    return restore_checkpoint(directory, target)
