"""DEPRECATED policy-adapter layer — use `repro.api` instead.

`make_policy(name, ecfg, ...)` predates the unified facade; the policy
registry (`repro.api.registry`) now resolves every scheduler — baselines,
EAT/PPO (with uniform checkpoint restore via `api.restore_params`), and the
offline meta-heuristics — under one protocol, with weight provenance made
explicit (`ResolvedPolicy.trained`). This module survives as a thin wrapper
so pre-facade callers keep working; internal consumers must not use it (CI
errors on DeprecationWarnings raised from `repro.*` modules).

    # old                                # new
    make_policy("eat", ecfg,             api.resolve(
        checkpoint=d)                        api.PolicySpec("eat",
                                                 checkpoint=d), ecfg)
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

from repro.core import env as EV
from repro.core import rollout as RO

BASELINES = ("random", "fifo", "greedy")
LEARNED = ("eat", "ppo")


def available_policies() -> Tuple[str, ...]:
    """Names this wrapper can build: the registry minus the offline
    meta-heuristics (they need a workload trace to optimise on, which the
    tuple-returning `make_policy` interface cannot supply — resolve them
    through `api.Simulator` / `api.resolve(..., trace_fn=)`)."""
    from repro.api import registry as REG
    return tuple(n for n in REG.available_policies()
                 if REG.policy_kind(n) != REG.OFFLINE)


def make_policy(name: str, ecfg: EV.EnvConfig, *, acfg=None,
                checkpoint: Optional[str] = None, params=None,
                seed: int = 0) -> Tuple[RO.Policy, Dict]:
    """Deprecated: resolve a PolicySpec through `repro.api` instead.

    Thin wrapper over `api.registry.resolve`; same (policy_fn, params)
    return. Unlike the pre-facade version, a learned policy resolved to
    fresh weights now emits an `UntrainedPolicyWarning` (the registry's
    `trained=False` flag is dropped by this tuple interface — another
    reason to migrate)."""
    warnings.warn(
        "traffic.policies.make_policy is deprecated; use repro.api "
        "(registry.resolve / PolicySpec)", DeprecationWarning, stacklevel=2)
    from repro.api import registry as REG
    from repro.api.specs import PolicySpec
    options = {"acfg": acfg} if acfg is not None else {}
    rp = REG.resolve(PolicySpec(name=name, checkpoint=checkpoint,
                                params=params, seed=seed, options=options),
                     ecfg)
    return rp.policy, rp.params
