"""Grid-sweep driver on the `repro.api` facade: (scenario cell x policy)
streaming runs with QoS telemetry rows, JSON output, and wall-clock
throughput.

A cell is a `core.scenarios.Scenario`; its `arrival` field selects the
open-loop process (None falls back to Poisson at the cell's tcfg rate). Each
(cell, policy) pair is one `api.Simulator` streaming run — `num_windows`
windows of `window_tasks` tasks over `num_streams` parallel streams on the
chosen execution backend — so a default sweep covers >= 10^5 tasks per
policy at O(window) memory, and `--backend sharded` splits the stream axis
over a device mesh with bitwise-identical telemetry.

    PYTHONPATH=src python examples/traffic_sweep.py --policies random,fifo

is the CLI front-end; `benchmarks/bench_traffic.py` shares the facade.
Every row carries `trained` (weight provenance) and `exec_backend`.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

import jax

from repro.api import (ExecSpec, PolicySpec, Simulator, WorkloadSpec,
                       resolve_cell)
from repro.core.scenarios import Scenario
from repro.traffic.stream import StreamConfig

__all__ = ["resolve_cell", "run_cell", "run_sweep"]


def _workload(sc: Scenario, stream: StreamConfig,
              window_tasks: Optional[int]) -> WorkloadSpec:
    return WorkloadSpec.streaming(
        sc, streams=stream.num_streams, num_windows=stream.num_windows,
        window_tasks=window_tasks,
        max_steps_per_window=stream.max_steps_per_window,
        max_carry=stream.max_carry, resp_sla=stream.resp_sla,
        chunk_size=stream.chunk_size)


def run_cell(sc: Scenario, policy_name: str, key, *,
             stream: StreamConfig = StreamConfig(),
             window_tasks: Optional[int] = None,
             checkpoint: Optional[str] = None, seed: int = 0,
             exec_spec: ExecSpec = ExecSpec()) -> Dict:
    """One (cell, policy) streaming run -> flat telemetry row.

    `exec_spec` picks the execution backend; a pre-facade caller's explicit
    ``StreamConfig(fused=False)`` still selects the legacy engine when
    `exec_spec` is left at its default."""
    if not stream.fused and exec_spec == ExecSpec():
        exec_spec = ExecSpec(backend="reference")
    sim = Simulator(_workload(sc, stream, window_tasks), exec_spec)
    res = sim.run(PolicySpec(name=policy_name, checkpoint=checkpoint,
                             seed=seed), key)
    row = res.row()
    row["tasks_per_wall_s"] = (row["tasks_injected"]
                               / max(row["wall_s"], 1e-9))
    return row


def run_sweep(cells: Sequence[Scenario], policy_names: Sequence[str], key, *,
              stream: StreamConfig = StreamConfig(),
              window_tasks: Optional[int] = None,
              checkpoint: Optional[str] = None,
              exec_spec: ExecSpec = ExecSpec(),
              out: Optional[str] = None, verbose: bool = True) -> List[Dict]:
    """Sweep the (cell x policy) grid; optionally dump rows to JSON."""
    rows = []
    for ci, sc in enumerate(cells):
        for pi, pname in enumerate(policy_names):
            k = jax.random.fold_in(jax.random.fold_in(key, ci), pi)
            row = run_cell(sc, pname, k, stream=stream,
                           window_tasks=window_tasks, checkpoint=checkpoint,
                           exec_spec=exec_spec)
            rows.append(row)
            if verbose:
                flag = "" if row["trained"] else " [UNTRAINED]"
                print(f"[{row['cell']:>18s} | {pname:>6s}{flag}] "
                      f"tasks={row['tasks_injected']:7d} "
                      f"p50={row['latency_p50']:8.1f}s "
                      f"p99={row['latency_p99']:8.1f}s "
                      f"viol={row['qos_violation_rate']:.3f} "
                      f"util={row['utilization']:.2f} "
                      f"goodput={row['goodput_per_s']:.3f}/s "
                      f"wall={row['wall_s']:6.1f}s "
                      f"({row['tasks_per_wall_s']:8.0f} tasks/s)",
                      flush=True)
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rows, f, indent=1)
        if verbose:
            print(f"wrote {len(rows)} rows -> {out}")
    return rows
