"""Grid-sweep driver: (scenario cell x policy) streaming runs with QoS
telemetry rows, JSON output, and wall-clock throughput.

A cell is a `core.scenarios.Scenario`; its `arrival` field selects the
open-loop process (None falls back to Poisson at the cell's tcfg rate). Each
(cell, policy) pair streams `num_windows` windows of `window_tasks` tasks
over `num_streams` parallel streams — one jitted program per window — so a
default sweep covers >= 10^5 tasks per policy at O(window) memory.

    PYTHONPATH=src python examples/traffic_sweep.py --policies random,fifo

is the CLI front-end; `benchmarks/bench_traffic.py` reuses `run_cell` for
the perf-trajectory JSON.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence

import jax

from repro.core.scenarios import Scenario
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.policies import make_policy
from repro.traffic.stream import ProcessTaskSource, StreamConfig, run_stream


def resolve_cell(sc: Scenario, window_tasks: Optional[int] = None):
    """(ecfg, tcfg, process) for streaming: window size overrides the cell's
    episodic max_tasks; a missing arrival process means Poisson at the
    cell's configured rate."""
    ecfg, tcfg = sc.ecfg, sc.tcfg
    if window_tasks and window_tasks != ecfg.max_tasks:
        ecfg = dataclasses.replace(ecfg, max_tasks=int(window_tasks))
        tcfg = dataclasses.replace(tcfg, num_tasks=int(window_tasks))
    proc = sc.arrival if sc.arrival is not None else PoissonArrivals(
        tcfg.arrival_rate)
    return ecfg, tcfg, proc


def run_cell(sc: Scenario, policy_name: str, key, *,
             stream: StreamConfig = StreamConfig(),
             window_tasks: Optional[int] = None,
             checkpoint: Optional[str] = None, seed: int = 0) -> Dict:
    """One (cell, policy) streaming run -> flat telemetry row."""
    ecfg, tcfg, proc = resolve_cell(sc, window_tasks)
    policy, params = make_policy(policy_name, ecfg, checkpoint=checkpoint,
                                 seed=seed)
    k_src, k_run = jax.random.split(key)
    source = ProcessTaskSource(proc, tcfg, k_src,
                               num_streams=stream.num_streams,
                               chunk_size=stream.chunk_size)
    t0 = time.perf_counter()
    res = run_stream(ecfg, policy, params, source, k_run, stream)
    wall = time.perf_counter() - t0
    row = {"cell": sc.name, "policy": policy_name,
           "arrival": type(proc).__name__,
           "num_servers": ecfg.num_servers,
           "wall_s": wall,
           "tasks_per_wall_s": res.summary["tasks_injected"] / max(wall, 1e-9)}
    row.update(res.summary)
    return row


def run_sweep(cells: Sequence[Scenario], policy_names: Sequence[str], key, *,
              stream: StreamConfig = StreamConfig(),
              window_tasks: Optional[int] = None,
              checkpoint: Optional[str] = None,
              out: Optional[str] = None, verbose: bool = True) -> List[Dict]:
    """Sweep the (cell x policy) grid; optionally dump rows to JSON."""
    rows = []
    for ci, sc in enumerate(cells):
        for pi, pname in enumerate(policy_names):
            k = jax.random.fold_in(jax.random.fold_in(key, ci), pi)
            row = run_cell(sc, pname, k, stream=stream,
                           window_tasks=window_tasks, checkpoint=checkpoint)
            rows.append(row)
            if verbose:
                print(f"[{row['cell']:>18s} | {pname:>6s}] "
                      f"tasks={row['tasks_injected']:7d} "
                      f"p50={row['latency_p50']:8.1f}s "
                      f"p99={row['latency_p99']:8.1f}s "
                      f"viol={row['qos_violation_rate']:.3f} "
                      f"util={row['utilization']:.2f} "
                      f"goodput={row['goodput_per_s']:.3f}/s "
                      f"wall={row['wall_s']:6.1f}s "
                      f"({row['tasks_per_wall_s']:8.0f} tasks/s)",
                      flush=True)
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rows, f, indent=1)
        if verbose:
            print(f"wrote {len(rows)} rows -> {out}")
    return rows
