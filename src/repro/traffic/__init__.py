"""Streaming traffic subsystem: open-loop arrival processes, windowed
unbounded-horizon simulation on the batched rollout engine, and streaming
QoS telemetry. See `arrivals`, `stream`, `metrics`, `policies`, `sweep`."""
from repro.traffic.arrivals import (DiurnalArrivals, FlashCrowdArrivals,
                                    MMPPArrivals, PoissonArrivals,
                                    ReplayArrivals, generate_trace,
                                    make_process)
from repro.traffic.metrics import LatencyHistogram, StreamAggregator
from repro.traffic.stream import (ProcessTaskSource, StreamConfig,
                                  StreamResult, TraceTaskSource, run_stream)

__all__ = [
    "PoissonArrivals", "MMPPArrivals", "DiurnalArrivals",
    "FlashCrowdArrivals", "ReplayArrivals", "make_process", "generate_trace",
    "LatencyHistogram", "StreamAggregator",
    "StreamConfig", "StreamResult", "ProcessTaskSource", "TraceTaskSource",
    "run_stream",
]
