"""Streaming traffic subsystem: open-loop arrival processes, windowed
unbounded-horizon simulation on the batched rollout engine, and streaming
QoS telemetry. See `arrivals`, `stream`, `metrics`, `policies`, `sweep`."""
from repro.traffic.arrivals import (DiurnalArrivals, FlashCrowdArrivals,
                                    MMPPArrivals, PoissonArrivals,
                                    ReplayArrivals, generate_trace,
                                    make_process, scale_rate)
from repro.traffic.metrics import LatencyHistogram, StreamAggregator
from repro.traffic.stream import (CurriculumTaskSource, ProcessTaskSource,
                                  StreamConfig, StreamResult, StreamRunner,
                                  TraceTaskSource, WindowResult, run_stream)

__all__ = [
    "PoissonArrivals", "MMPPArrivals", "DiurnalArrivals",
    "FlashCrowdArrivals", "ReplayArrivals", "make_process", "generate_trace",
    "scale_rate",
    "LatencyHistogram", "StreamAggregator",
    "StreamConfig", "StreamResult", "StreamRunner", "WindowResult",
    "CurriculumTaskSource", "ProcessTaskSource", "TraceTaskSource",
    "run_stream",
]
