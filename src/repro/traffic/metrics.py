"""Streaming QoS telemetry: O(bins) latency percentiles and run aggregates.

The streaming engine emits one fixed-shape stats record per window (device
side); `StreamAggregator` folds those records on the host so a 10^6-task run
keeps O(bins) state instead of O(tasks) samples. Latency percentiles come
from a fixed log-spaced histogram (`LatencyHistogram`) with linear
interpolation inside the resolved bin — resolution is the bin width
(~21 log-bins per decade by default), which is plenty for p50/p95/p99
reporting across the 0.1 s .. 10^5 s response range this simulator spans.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

# histogram primitives live in the telemetry layer since the telemetry PR;
# re-exported here (their historical home) for every existing consumer
from repro.telemetry.metrics import DEFAULT_EDGES, LatencyHistogram  # noqa: F401


def bucketize_counts(values, mask, edges):
    """Device-side helper (jnp in, jnp out): per-bin counts of values[mask].

    Returns (len(edges)+1,) counts: slot 0 is the underflow (< edges[0]),
    slot i covers (edges[i-1], edges[i]], the last slot is overflow.
    """
    import jax.numpy as jnp
    idx = jnp.searchsorted(jnp.asarray(edges), values)
    return jnp.zeros((len(edges) + 1,), jnp.int32).at[idx].add(
        mask.astype(jnp.int32))


# ----------------------------------------------------------------------
# Keys the engine emits per window as (B,) arrays (summed here), plus
# "hist" as (B, bins) counts and "elapsed" as per-stream window span. The
# fault-mode keys (n_failed / n_failed_dropped / n_retried / n_readmitted)
# are optional — absent records fold in as zero.
_SUM_KEYS = ("n_injected", "n_sched", "n_done", "n_dropped", "n_reload",
             "n_viol", "n_viol_q", "n_viol_t", "sum_resp", "sum_quality",
             "sum_steps", "busy_time", "elapsed",
             "n_failed", "n_failed_dropped", "n_retried", "n_readmitted")


class StreamAggregator:
    """Folds per-window stats records into run-level QoS telemetry.

    Conventions: a *scheduled* task has a deterministic recorded finish time
    (no preemption), so scheduled counts as served for goodput; `elapsed`
    accumulates per-stream simulated seconds (stream-seconds), so rates are
    per single-cluster second averaged over the parallel streams.
    """

    def __init__(self, num_servers: int, q_min: float, resp_sla: float,
                 edges: Optional[np.ndarray] = None):
        self.num_servers = int(num_servers)
        self.q_min = float(q_min)
        self.resp_sla = float(resp_sla)
        self.hist = LatencyHistogram(edges)
        self.totals = {k: 0.0 for k in _SUM_KEYS}
        self.max_resp = 0.0
        self.num_windows = 0

    def update(self, stats: Dict[str, np.ndarray]) -> None:
        for k in _SUM_KEYS:
            if k in stats:
                self.totals[k] += float(np.sum(stats[k]))
        self.hist.add_counts(np.sum(np.asarray(stats["hist"]), axis=0))
        self.max_resp = max(self.max_resp, float(np.max(stats["max_resp"])))
        self.num_windows += 1

    # -- derived telemetry ------------------------------------------------
    def summary(self) -> Dict[str, float]:
        t = self.totals
        sched = max(t["n_sched"], 1.0)
        secs = max(t["elapsed"], 1e-9)       # stream-seconds
        good = t["n_sched"] - t["n_viol"]
        # a *resolved* task left the system: scheduled, shed by max_carry
        # backlog shedding, or dropped after exhausting its fault-retry
        # budget. Drops are QoS failures (the task was offered and never
        # served), so the headline violation/goodput rates count them — a
        # policy cannot shed its way to a better QoS score. The *_scheduled
        # variants keep the drop-exclusive (conditional on service) view.
        # Crash-then-retried tasks are still in flight (not resolved); they
        # resolve at their eventual success, shed, or retry exhaustion.
        drops = t["n_dropped"] + t["n_failed_dropped"]
        resolved = max(t["n_sched"] + drops, 1.0)
        # histogram percentiles interpolate inside a log bin, which can
        # overshoot the true maximum — clamp to the exact running max
        def pct(q):
            p = self.hist.percentile(q)
            return float(min(p, self.max_resp)) if np.isfinite(p) else p
        return {
            "num_windows": self.num_windows,
            "tasks_injected": int(t["n_injected"]),
            "tasks_scheduled": int(t["n_sched"]),
            "tasks_completed_in_window": int(t["n_done"]),
            "tasks_dropped": int(drops),
            "tasks_dropped_shed": int(t["n_dropped"]),
            "tasks_dropped_retry_exhausted": int(t["n_failed_dropped"]),
            "tasks_failed": int(t["n_failed"]),
            "tasks_retried": int(t["n_retried"]),
            "tasks_resolved": int(t["n_sched"] + drops),
            "sim_seconds": float(secs),
            "latency_p50": pct(0.50),
            "latency_p95": pct(0.95),
            "latency_p99": pct(0.99),
            "latency_mean": float(t["sum_resp"] / sched),
            "latency_max": float(self.max_resp),
            "drop_rate": float(drops / resolved),
            "qos_violation_rate": float((t["n_viol"] + drops) / resolved),
            "qos_violation_rate_quality": float(t["n_viol_q"] / resolved),
            "qos_violation_rate_latency": float((t["n_viol_t"] + drops)
                                                / resolved),
            "qos_violation_rate_scheduled": float(t["n_viol"] / sched),
            "avg_quality": float(t["sum_quality"] / sched),
            "avg_steps": float(t["sum_steps"] / sched),
            "cold_start_rate": float(t["n_reload"] / sched),
            "reuse_rate": float(1.0 - t["n_reload"] / sched),
            "utilization": float(t["busy_time"]
                                 / (self.num_servers * secs)),
            "throughput_per_s": float(t["n_sched"] / secs),
            "goodput_per_s": float(max(good, 0.0) / secs),
            "goodput_rate": float(max(good, 0.0) / resolved),
            "q_min": self.q_min,
            "resp_sla": self.resp_sla,
        }

    # -- unified metrics registry -----------------------------------------
    def publish(self, labels: Optional[Dict[str, str]] = None,
                registry=None) -> None:
        """Publish this aggregator's summary (gauges ``eat_stream_<key>``)
        and its raw latency histogram (``eat_stream_latency_seconds``
        buckets) into the unified telemetry registry
        (`repro.telemetry.metrics`; None = the process default)."""
        from repro.telemetry import metrics as TM
        TM.publish_summary(self.summary(), prefix="eat_stream",
                           labels=labels, registry=registry)
        reg = registry or TM.default_registry()
        reg.histogram("eat_stream_latency_seconds",
                      "scheduled-task response latency",
                      edges=self.hist.edges).observe_counts(
            self.hist.counts, approx_sum=self.totals["sum_resp"],
            labels=labels)
