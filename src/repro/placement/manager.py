"""`PlacementManager` — the slow timescale's stateful driver.

One instance per `StreamRunner` (constructed only when the spec is
active). The runner feeds it two host-side touchpoints per window:

    observe_window(w, cols)   after `_build_window`: fold the window's
                              (B, K) model/c columns into `DemandStats`
    apply(carry, w)           after `_window_seam`: plan a layout from
                              windows <= w and write it into the carried
                              `EnvState` for window w+1

`apply` mutates ONLY the host-side carry between windows — never a trace
column, never a compiled program — so `placement=None` (no manager at all)
runs byte-for-byte the programs and results it always did: a guarantee
stronger than the faults pattern, which at least adds trace columns.

Fault interaction needs no code here: the decision step's cold-restart
wipe (`env.decision_step`) erases any placed cache whose server has
crashed, idempotently, before every selection — a stale placement can
never outlive a cold restart (pinned by tests/test_placement.py).
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import env as EV
from repro.placement.plan import StreamPlacement, plan_stream
from repro.placement.policies import get_placement_policy
from repro.placement.spec import PlacementSpec
from repro.placement.stats import DEFAULT_C_SUPPORT, DemandStats
from repro.telemetry.trace import NULL_TRACER


class PlacementDecision(NamedTuple):
    """One seam's applied placement: per-stream layouts + this decision's
    counter deltas. Execution backends with real weights implement
    `apply_placement(decision)` (serving prefetches/evicts off the timed
    path); the simulated backends need nothing beyond the carry write."""
    window: int
    streams: List[StreamPlacement]
    counters: Dict[str, int]


class PlacementManager:
    def __init__(self, spec: PlacementSpec, ecfg: EV.EnvConfig,
                 num_streams: int = 1, tracer=None):
        if not spec.active:
            raise ValueError("PlacementManager needs an active spec; gate "
                             "construction on placement_active(spec)")
        self.spec = spec
        self.ecfg = ecfg
        self.B = int(num_streams)
        self.tracer = NULL_TRACER if tracer is None else tracer
        # gang sizes larger than the cluster can never be placed
        support = tuple(c for c in DEFAULT_C_SUPPORT
                        if c <= ecfg.num_servers) or (1,)
        self.stats = DemandStats(self.B, ecfg.num_models, support)
        self._policy = get_placement_policy(spec.policy)
        self._counters = {"decisions": 0, "gangs_planned": 0,
                          "gangs_kept": 0, "gangs_bound": 0,
                          "prefetches": 0, "evictions": 0}

    # ------------------------------------------------------------------
    def observe_window(self, window: int, cols: Dict[str, np.ndarray]
                       ) -> None:
        """Fold one built window's demand (host numpy columns)."""
        self.stats.observe(cols["model"], cols["c"])

    def apply(self, carry: EV.EnvState, window: int
              ) -> "tuple[EV.EnvState, Optional[PlacementDecision]]":
        """Plan + write the layout into the carried state at the seam after
        `window`; returns the (possibly unchanged) carry and the decision
        (None on off-interval seams)."""
        if (window + 1) % self.spec.interval != 0:
            return carry, None
        K, E = self.ecfg.max_tasks, self.ecfg.num_servers
        with self.tracer.span("placement_decide", cat="placement",
                              window=window, policy=self.spec.policy):
            free_at = np.asarray(carry.server_free_at)        # (B, E)
            model = np.asarray(carry.server_model)
            gang = np.asarray(carry.server_gang)
            size = np.asarray(carry.server_gang_size)
            streams: List[StreamPlacement] = []
            for b in range(self.B):
                weights = self._policy(self.spec, self.stats, b)
                streams.append(plan_stream(
                    weights, free_at[b] <= 0.0, model[b], gang[b], size[b],
                    self.stats.c_support, K,
                    self.spec.max_gangs_per_cell))
            deltas = {k: sum(s.counters[k] for s in streams)
                      for k in streams[0].counters}
            deltas["decisions"] = 1
            for k, v in deltas.items():
                self._counters[k] += v
            carry = carry._replace(
                server_model=jnp.asarray(
                    np.stack([s.model for s in streams])),
                server_gang=jnp.asarray(
                    np.stack([s.gang for s in streams])),
                server_gang_size=jnp.asarray(
                    np.stack([s.gang_size for s in streams])))
        return carry, PlacementDecision(window=window, streams=streams,
                                        counters=deltas)

    # ------------------------------------------------------------------
    def counters(self) -> Dict[str, int]:
        """Cumulative host ledger (`eat_placement_*` in the registry)."""
        return {f"placement_{k}": int(v) for k, v in self._counters.items()}
