"""Gang-layout planner: demand weights -> a concrete pre-warmed layout.

`plan_stream` turns one stream's (M, NC) demand weights into new
`(server_model, server_gang, server_gang_size)` arrays over that stream's
idle servers, honouring the env's reuse contract exactly: the fast
scheduler (`env._select_servers`) reuses a gang iff a COMPLETE idle gang
with matching model and exact size exists, so pre-warming must form whole
synthetic gangs — writing `server_model` alone warms nothing.

Greedy credit-halving: repeatedly pick the highest-credit (model, c) cell,
place one gang of that shape, halve the cell's credit (so a cell with 2x
the demand ends up with ~2x the gangs), and stop when idle capacity or
credit runs out. Placed gangs then bind to servers in three passes:

1. *keep*: an existing complete idle gang already matching (model, c) is
   consumed as-is — zero churn, zero counters;
2. *bind*: remaining gangs pick idle servers cheapest-first — a server
   already holding the model costs nothing (no prefetch), an empty server
   costs a prefetch, a server holding another model costs an eviction plus
   a prefetch;
3. leftovers keep whatever they held (placement never evicts a model it
   does not need the server for — an un-planned warm server can still get
   lucky).

Gang labels follow the seam convention (`traffic.stream._window_seam`):
`K + min(member index)` in [K, K+E), collision-free against next-window
task ids [0, K) and against carried busy gangs (their leaders are busy;
placed leaders are idle — disjoint index sets).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import numpy as np


class StreamPlacement(NamedTuple):
    """One stream's planned layout + what changed (serving prefetch/evict
    consume the masks; the sim just writes the arrays into the carry)."""
    model: np.ndarray        # (E,) i32 target resident model per server
    gang: np.ndarray         # (E,) i32 gang label (seam convention)
    gang_size: np.ndarray    # (E,) i32
    prefetch: np.ndarray     # (E,) bool — resident model changed
    evict: np.ndarray        # (E,) bool — a previously-resident model left
    counters: Dict[str, int]


def _intact_idle_gangs(idle: np.ndarray, model: np.ndarray,
                       gang: np.ndarray, gang_size: np.ndarray):
    """{label: (member indices, model)} of COMPLETE idle gangs — every
    server sharing the label is idle and the count matches the recorded
    size (the env's reuse test, host-side)."""
    out = {}
    for g in np.unique(gang[idle & (gang >= 0)]):
        members = np.flatnonzero(gang == g)
        size = gang_size[members[0]]
        if size > 0 and len(members) == size and idle[members].all() \
                and (gang_size[members] == size).all() \
                and (model[members] == model[members[0]]).all():
            out[int(g)] = (members, int(model[members[0]]))
    return out


def plan_gangs(weights: np.ndarray, capacity: int,
               c_support: Tuple[int, ...],
               max_gangs_per_cell: int = 0) -> list:
    """Demand weights -> ordered [(model, c), ...] gang shapes fitting in
    `capacity` idle servers, by greedy credit-halving (ties break to the
    lowest model then smallest c — np.argmax on the flat array)."""
    credit = np.asarray(weights, np.float64).copy()
    M, NC = credit.shape
    placed = np.zeros((M, NC), np.int64)
    out = []
    remaining = int(capacity)
    while remaining > 0 and credit.max() > 0.0:
        flat = int(np.argmax(credit))
        m, j = divmod(flat, NC)
        c = int(c_support[j])
        full = max_gangs_per_cell > 0 and placed[m, j] >= max_gangs_per_cell
        if c > remaining or full:
            credit[m, j] = 0.0
            continue
        out.append((m, c))
        placed[m, j] += 1
        remaining -= c
        credit[m, j] *= 0.5
    return out


def plan_stream(weights: np.ndarray, idle: np.ndarray, model: np.ndarray,
                gang: np.ndarray, gang_size: np.ndarray,
                c_support: Tuple[int, ...], K: int,
                max_gangs_per_cell: int = 0) -> StreamPlacement:
    """One stream's placement: see the module docstring for the algorithm.

    `idle` is the (E,) idle mask; `model`/`gang`/`gang_size` are the
    carried arrays. Busy servers are never touched.
    """
    idle = np.asarray(idle, bool)
    new_model = np.asarray(model, np.int32).copy()
    new_gang = np.asarray(gang, np.int32).copy()
    new_size = np.asarray(gang_size, np.int32).copy()
    prefetch = np.zeros(new_model.shape, bool)
    evict = np.zeros(new_model.shape, bool)

    targets = plan_gangs(weights, int(idle.sum()), c_support,
                         max_gangs_per_cell)

    # pass 1: consume existing matching complete idle gangs (zero churn)
    free = idle.copy()
    existing = _intact_idle_gangs(idle, new_model, new_gang, new_size)
    kept = 0
    unbound = []
    for m, c in targets:
        hit = next((g for g, (mem, gm) in sorted(existing.items())
                    if gm == m and len(mem) == c), None)
        if hit is not None:
            free[existing.pop(hit)[0]] = False
            kept += 1
        else:
            unbound.append((m, c))

    # pass 2: bind the rest cheapest-first (model hit < empty < evict)
    for m, c in unbound:
        cand = np.flatnonzero(free)
        if len(cand) < c:       # defensive: plan_gangs bounded total servers
            continue            # by idle capacity, so this cannot fire
        cost = np.where(new_model[cand] == m, 0,
                        np.where(new_model[cand] < 0, 1, 2))
        members = cand[np.lexsort((cand, cost))][:c]
        free[members] = False
        changed = new_model[members] != m
        prefetch[members] |= changed
        evict[members] |= changed & (new_model[members] >= 0)
        new_model[members] = m
        new_gang[members] = K + int(members.min())
        new_size[members] = c

    counters = {"gangs_planned": len(targets), "gangs_kept": kept,
                "gangs_bound": len(unbound),
                "prefetches": int(prefetch.sum()),
                "evictions": int(evict.sum())}
    return StreamPlacement(model=new_model, gang=new_gang,
                           gang_size=new_size, prefetch=prefetch,
                           evict=evict, counters=counters)
