"""Per-window demand statistics the placement policies consume.

`DemandStats` counts, per stream and per window, how many arriving tasks
fell into each (model, gang-size) cell. The counts come straight from the
built window's host-side task columns — the same tasks the fast scheduler
is about to see — so the slow timescale observes exactly the demand the
fast one serves, on one continuous clock. Placement for window w+1 is
planned *after* window w's seam from windows <= w: the policy never peeks
at arrivals it has not yet been shown.

History is bounded (`history` windows, default 64): the EWMA, trend and
seasonal accessors below only ever look that far back, so a million-window
stream holds O(history * B * M * NC) floats.
"""
from __future__ import annotations

from collections import deque
from typing import List, Tuple

import numpy as np

#: the paper's collaboration-requirement support (workload.TraceConfig)
DEFAULT_C_SUPPORT: Tuple[int, ...] = (1, 2, 4, 8)


class DemandStats:
    """Rolling (stream, model, gang-size-bin) demand counts.

    `observe(model, c)` folds one window's (B, K) task columns; accessors
    return (M, NC) float arrays for one stream. `windows` is the number of
    windows observed so far — the window about to be planned has index
    `windows` (0-based), which is what the seasonal accessor phases on.
    """

    def __init__(self, num_streams: int, num_models: int,
                 c_support: Tuple[int, ...] = DEFAULT_C_SUPPORT,
                 history: int = 64):
        if num_models < 1:
            raise ValueError(f"num_models must be >= 1, got {num_models}")
        if not c_support or list(c_support) != sorted(set(c_support)):
            raise ValueError(f"c_support must be sorted unique gang sizes, "
                             f"got {c_support}")
        self.B = int(num_streams)
        self.M = int(num_models)
        self.c_support = tuple(int(c) for c in c_support)
        self.NC = len(self.c_support)
        self._hist: deque = deque(maxlen=int(history))   # (B, M, NC) arrays
        self.windows = 0
        self.total = np.zeros((self.B, self.M, self.NC), np.float64)

    # ------------------------------------------------------------------
    def observe(self, model: np.ndarray, c: np.ndarray) -> None:
        """Fold one window's task columns: `model` and `c` are (B, K) int
        arrays (the built window, leftovers included — backlog is demand
        too). Gang sizes between support points bin to the next size DOWN
        (a placed gang of the smaller size still serves part of the load);
        models outside [0, M) are ignored."""
        model = np.asarray(model)
        c = np.asarray(c)
        if model.shape != c.shape or model.ndim != 2 \
                or model.shape[0] != self.B:
            raise ValueError(f"expected (B={self.B}, K) model/c columns, got "
                             f"{model.shape} / {c.shape}")
        sup = np.asarray(self.c_support)
        cbin = np.clip(np.searchsorted(sup, c, side="right") - 1, 0,
                       self.NC - 1)
        counts = np.zeros((self.B, self.M, self.NC), np.float64)
        ok = (model >= 0) & (model < self.M)
        flat = model.clip(0, self.M - 1) * self.NC + cbin
        for b in range(self.B):
            counts[b] = np.bincount(
                flat[b][ok[b]], minlength=self.M * self.NC
            ).reshape(self.M, self.NC)
        self._hist.append(counts)
        self.total += counts
        self.windows += 1

    # -- accessors (one stream, (M, NC) each) ---------------------------
    def last(self, b: int) -> np.ndarray:
        if not self._hist:
            return np.zeros((self.M, self.NC), np.float64)
        return self._hist[-1][b]

    def history(self, b: int) -> List[np.ndarray]:
        return [h[b] for h in self._hist]

    def ewma(self, b: int, alpha: float) -> np.ndarray:
        """EWMA over the retained history (oldest first): recomputed per
        call so the value is a pure function of the retained windows —
        deterministic regardless of when it is asked for."""
        out = np.zeros((self.M, self.NC), np.float64)
        first = True
        for h in self._hist:
            out = h[b].copy() if first else alpha * h[b] + (1 - alpha) * out
            first = False
        return out

    def seasonal(self, b: int, period: int, phase: int) -> np.ndarray:
        """Mean demand over retained windows sharing `phase` modulo
        `period` (window i in the retained deque has absolute index
        `windows - len(hist) + i`)."""
        if period <= 1:
            return self.last(b)
        base = self.windows - len(self._hist)
        picks = [h[b] for i, h in enumerate(self._hist)
                 if (base + i) % period == phase % period]
        if not picks:
            return np.zeros((self.M, self.NC), np.float64)
        return np.mean(picks, axis=0)
