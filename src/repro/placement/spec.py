"""`PlacementSpec` — the frozen, hashable description of the slow timescale.

The paper's fast scheduler decides *which task runs where* every event; the
two-timescale extension ("Two-Timescale Model Caching and Resource
Allocation for Edge-Enabled AI-Generated Content Services", PAPERS.md) adds
a slow decision — *which models stay resident where* — taken once per
stream-window seam. This spec names the placement policy and its knobs:

* ``policy="none"``: no slow timescale. Nothing is attached anywhere, so
  every compiled program — and therefore every result — is bitwise-identical
  to a run without the spec (the `faults=None` static-presence pattern).
* ``policy="static"``: pin a fixed layout from prior popularity
  (`model_probs` x `c_probs`), independent of observed demand.
* ``policy="lfu"``: demand-weighted from the *trailing window's* per-model
  arrival counts (least-frequently-used models lose their servers first).
* ``policy="forecast"``: EWMA predictor over the per-window arrival history
  with a trend boost (`trend_gain`) that reacts to rising demand faster
  than the EWMA alone — the flash-crowd-on-a-cold-model case — plus an
  optional seasonal average over a known `period` (in windows).

New policies (e.g. a learned placement actor) register through
`repro.placement.policies.register_placement`; the spec validates its
`policy` name against that registry, so a registered name is a valid spec.

The spec rides on ``ExecSpec(placement=...)`` and
``StreamConfig(placement=...)``; it is frozen and hashable so it can key
compiled-program caches (it never reaches one today — placement runs on the
host between windows — but the ExecSpec contract requires it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class PlacementSpec:
    policy: str = "none"
    # -- cadence ---------------------------------------------------------
    interval: int = 1              # decide every N window seams
    # -- forecast predictor ---------------------------------------------
    ewma_alpha: float = 0.5        # EWMA smoothing of per-window demand
    trend_gain: float = 1.5        # boost for (last - ewma) demand rises
    period: int = 0                # seasonal period in windows; 0 = off
    # -- static prior (also the lfu/forecast cold-start prior) -----------
    model_probs: Tuple[float, ...] = ()   # per-model popularity; () = uniform
    c_probs: Tuple[float, ...] = ()       # gang-size prior over (1, 2, 4, 8);
    #                                       () = the paper's task mix
    # -- planner ---------------------------------------------------------
    max_gangs_per_cell: int = 0    # cap per (model, c) demand cell; 0 = none

    def __post_init__(self):
        from repro.placement.policies import known_policies
        if self.policy not in known_policies():
            raise ValueError(
                f"placement policy must be one of {known_policies()}, "
                f"got {self.policy!r}")
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {self.ewma_alpha}")
        if self.trend_gain < 0.0:
            raise ValueError(
                f"trend_gain must be >= 0, got {self.trend_gain}")
        if self.period < 0:
            raise ValueError(f"period must be >= 0, got {self.period}")
        if self.max_gangs_per_cell < 0:
            raise ValueError("max_gangs_per_cell must be >= 0")
        for name, probs in (("model_probs", self.model_probs),
                            ("c_probs", self.c_probs)):
            if probs and (min(probs) < 0.0 or sum(probs) <= 0.0):
                raise ValueError(f"{name} must be non-negative with a "
                                 f"positive sum, got {probs}")

    # ------------------------------------------------------------------
    @property
    def active(self) -> bool:
        """True when this spec places anything at all. An inactive spec
        (``PlacementSpec.none()``) touches no state: the carried stream
        state, the compiled programs, and every result are bitwise-identical
        to running with ``placement=None``."""
        return self.policy != "none"

    @classmethod
    def none(cls) -> "PlacementSpec":
        """The explicit no-placement spec."""
        return cls()


def placement_active(spec) -> bool:
    """None-tolerant activity test used by every plumbing layer."""
    return spec is not None and spec.active
