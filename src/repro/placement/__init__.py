"""Two-timescale model placement: proactive caching ahead of the fast
scheduler (ISSUE 9; see docs/placement.md).

The fast timescale — the paper's per-task scheduler — is untouched. The
slow timescale decides at every stream-window seam which models stay
resident on which idle servers, forming complete synthetic gangs the fast
scheduler's reuse test recognises, so matching tasks skip the ~Table-VI
cold-start penalty. `placement=None` is bitwise-identical to a run without
the subsystem on every backend: placement only ever rewrites the carried
host state between windows.
"""
from repro.placement.manager import PlacementDecision, PlacementManager
from repro.placement.plan import StreamPlacement, plan_gangs, plan_stream
from repro.placement.policies import (get_placement_policy, known_policies,
                                      prior_weights, register_placement)
from repro.placement.spec import PlacementSpec, placement_active
from repro.placement.stats import DemandStats

__all__ = [
    "DemandStats", "PlacementDecision", "PlacementManager", "PlacementSpec",
    "StreamPlacement", "get_placement_policy", "known_policies",
    "placement_active", "plan_gangs", "plan_stream", "prior_weights",
    "register_placement",
]
