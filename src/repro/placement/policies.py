"""Placement-policy registry: demand weights for the slow timescale.

A placement policy is a pure function

    fn(spec: PlacementSpec, stats: DemandStats, stream: int) -> (M, NC) f64

returning non-negative *demand weights* over (model, gang-size) cells —
how much the next window is expected to want each cell. The planner
(`placement.plan`) turns weights into a concrete gang layout; policies
never touch servers. Registering a name makes it a valid
`PlacementSpec(policy=...)` — the hook for a learned placement actor later
is exactly `@register_placement("learned")` around a params-closing
callable.

Built-ins (ISSUE 9 / the two-timescale caching paper):

    none      zero weights — never called in practice (an inactive spec is
              short-circuited before planning), registered so the name
              validates.
    static    a fixed prior: outer(model_probs, c_probs), demand-blind.
    lfu       the trailing window's observed counts (least-frequently-used
              models lose their servers first); falls back to the static
              prior before any window has been observed.
    forecast  EWMA over the window history plus a trend boost
              `trend_gain * (last - ewma)` clamped at zero — a flash crowd
              on a cold model shows up as a large positive trend one window
              after it starts — blended 50/50 with the seasonal mean when
              `spec.period` is set (diurnal cells).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from repro.placement.stats import DemandStats

#: the paper's D_c marginal (workload.TraceConfig.c_probs) — the default
#: gang-size prior when a spec does not pin its own
DEFAULT_C_PRIOR: Tuple[float, ...] = (0.35, 0.35, 0.2, 0.1)

PlacementPolicy = Callable[["PlacementSpec", DemandStats, int], np.ndarray]

_REGISTRY: Dict[str, PlacementPolicy] = {}


def register_placement(name: str):
    """Decorator: register a placement policy under `name` (also makes the
    name a valid `PlacementSpec.policy`)."""
    def deco(fn: PlacementPolicy) -> PlacementPolicy:
        _REGISTRY[str(name)] = fn
        return fn
    return deco


def known_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_placement_policy(name: str) -> PlacementPolicy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown placement policy {name!r}; known: "
                       f"{known_policies()}") from None


# ----------------------------------------------------------------------
def _normalised(probs: Tuple[float, ...], n: int,
                fallback: Tuple[float, ...]) -> np.ndarray:
    """Spec probs -> length-n simplex vector: () takes the fallback,
    short vectors pad with zero, long ones truncate, then renormalise."""
    src = probs if probs else fallback
    v = np.zeros(n, np.float64)
    v[:min(len(src), n)] = np.asarray(src[:n], np.float64)
    s = v.sum()
    return v / s if s > 0 else np.full(n, 1.0 / n)


def prior_weights(spec, M: int, c_support: Tuple[int, ...]) -> np.ndarray:
    """The static (M, NC) prior: outer(model popularity, gang-size mix)."""
    mp = _normalised(spec.model_probs, M, tuple([1.0] * M))
    cp = _normalised(spec.c_probs, len(c_support), DEFAULT_C_PRIOR)
    return np.outer(mp, cp)


# ----------------------------------------------------------------------
@register_placement("none")
def _none(spec, stats: DemandStats, b: int) -> np.ndarray:
    return np.zeros((stats.M, stats.NC), np.float64)


@register_placement("static")
def _static(spec, stats: DemandStats, b: int) -> np.ndarray:
    return prior_weights(spec, stats.M, stats.c_support)


@register_placement("lfu")
def _lfu(spec, stats: DemandStats, b: int) -> np.ndarray:
    last = stats.last(b)
    if last.sum() <= 0:
        return prior_weights(spec, stats.M, stats.c_support)
    return last.copy()


@register_placement("forecast")
def _forecast(spec, stats: DemandStats, b: int) -> np.ndarray:
    if stats.windows == 0:
        return prior_weights(spec, stats.M, stats.c_support)
    last = stats.last(b)
    ew = stats.ewma(b, spec.ewma_alpha)
    w = np.maximum(ew + spec.trend_gain * (last - ew), 0.0)
    if spec.period > 1 and stats.windows >= spec.period:
        # the window being planned has absolute index stats.windows
        seas = stats.seasonal(b, spec.period, stats.windows % spec.period)
        w = 0.5 * w + 0.5 * seas
    return w
