"""Partition rules: param/cache path -> PartitionSpec, divisibility-aware.

Weights shard FSDP-style: the d_model-like dim over the ``data`` axis and the
wide (d_ff / heads*head_dim / vocab / experts) dim over the ``model`` axis.
Any rule whose sharded dim does not divide the mesh axis size degrades to
replication on that dim (this keeps one rule-set valid across all ten
architectures). On the multi-pod mesh, weights are replicated over ``pod``
(classic cross-pod data parallelism) while the batch shards over
``("pod", "data")``.
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.pytree import map_with_paths

# (regex over 'a/b/c' path, spec entries aligned to the LAST ndim dims)
# None entries mean replicate. Leading dims (e.g. the stacked period axis)
# are implicitly replicated.
PARAM_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    # embeddings / heads
    (r"embed/table$", ("model", "data")),
    (r"lm_head/w$", ("data", "model")),
    (r"frontend_proj/w$", ("data", "model")),
    # attention
    (r"(attn|self_attn|cross_attn)/w[qkv]/w$", ("data", "model")),
    (r"(attn|self_attn|cross_attn)/w[qkv]/b$", ("model",)),
    (r"(attn|self_attn|cross_attn)/wo/w$", ("model", "data")),
    (r"(attn|self_attn|cross_attn)/wo/b$", (None,)),
    # dense FFN
    (r"ffn/(gate|up)/w$", ("data", "model")),
    (r"ffn/(gate|up)/b$", ("model",)),
    (r"ffn/down/w$", ("model", "data")),
    (r"ffn/down/b$", (None,)),
    # MoE (expert-parallel over `model`)
    (r"moe/router/w$", ("data", None)),
    (r"moe/(gate|up)$", ("model", "data", None)),
    (r"moe/down$", ("model", None, "data")),
    # Mamba
    (r"mamba/in_proj/w$", ("data", "model")),
    (r"mamba/conv_w$", (None, "model")),
    (r"mamba/conv_b$", ("model",)),
    (r"mamba/x_proj/w$", ("model", None)),
    (r"mamba/dt_proj/w$", (None, "model")),
    (r"mamba/dt_proj/b$", ("model",)),
    (r"mamba/A_log$", ("model", None)),
    (r"mamba/D$", ("model",)),
    (r"mamba/out_proj/w$", ("model", "data")),
    # xLSTM
    (r"(mlstm|slstm)/up/w$", ("data", "model")),
    (r"mlstm/w[qkv]/w$", ("data", "model")),
    (r"mlstm/w_if/w$", ("model", None)),
    (r"mlstm/w_if/b$", (None,)),
    (r"slstm/w_gates/w$", ("data", "model")),
    (r"slstm/w_gates/b$", ("model",)),
    (r"slstm/r_gates$", (None, None, None)),
    (r"(mlstm|slstm)/down/w$", ("model", "data")),
    # norms and everything else: replicate
    (r".*", ()),
]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for(path: str, shape: Sequence[int], mesh: Mesh,
             rules=PARAM_RULES) -> P:
    for pat, entries in rules:
        if re.search(pat, path):
            nd = len(shape)
            ne = len(entries)
            full = [None] * (nd - ne) + list(entries) if ne <= nd else list(entries[-nd:])
            out = []
            for dim, ax in zip(shape, full):
                if ax is not None and dim % _axis_size(mesh, ax) == 0 and dim > 0:
                    out.append(ax)
                else:
                    out.append(None)
            # trim trailing Nones
            while out and out[-1] is None:
                out.pop()
            return P(*out)
    return P()


def tree_shardings(tree: Any, mesh: Mesh, rules=PARAM_RULES):
    """Map a pytree (arrays or ShapeDtypeStructs) to NamedShardings."""
    def fn(path, leaf):
        return NamedSharding(mesh, spec_for(path, leaf.shape, mesh, rules))
    return map_with_paths(fn, tree)


# ----------------------------------------------------------------------
# cache rules: attention KV caches shard (batch over dp_axes, seq over `seq_ax`)
def cache_rules(dp_axes, seq_ax) -> List[Tuple[str, Tuple[Optional[str], ...]]]:
    """Caches are stacked (periods/L, B, T, KV, hd) for attention KV;
    (B, T-1/W, inner) conv; (B, inner, N) ssm; mlstm/slstm small states."""
    return [
        (r"(attn|self|cross)/[kv]$", (dp_axes, seq_ax, None, None)),
        (r"mamba/conv$", (dp_axes, None, "model")),
        (r"mamba/ssm$", (dp_axes, "model", None)),
        # mLSTM matrix memory: shard the k-contraction dim over `model`,
        # matching wk/wq output sharding — keeps the (B,nh,dh,dh) state
        # resident-sharded across decode steps (§Perf iteration: removes a
        # 212 MB/step state all-gather; the contraction against q becomes a
        # small (B,nh,dh) all-reduce instead).
        (r"mlstm/C$", (dp_axes, None, None, "model")),
        (r"mlstm/n$", (dp_axes, None, "model")),
        (r"mlstm/m$", (dp_axes, None)),
        (r"slstm/[cnhm]$", (dp_axes, None, None)),
        (r"pos$", ()),
        (r".*", ()),
    ]


def batch_spec(mesh: Mesh, batch: int):
    """Shard the global batch over every data-parallel axis that divides."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    dp = tuple(axes)
    if batch % _axis_size(mesh, dp) != 0:
        # degrade: drop pod, then drop data
        for cand in (("data",), ()):
            cand = tuple(a for a in cand if a in mesh.shape)
            if not cand or batch % _axis_size(mesh, cand) == 0:
                dp = cand
                break
    return dp
