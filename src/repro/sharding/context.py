"""Activation-sharding constraint context.

GSPMD left to itself may all-gather the batch and shard d_model instead
(observed: [22, 256, 4096, 128] activations on the 16x16 mesh). Pinning the
token activations to P(dp, None, None) at period boundaries forces the
FSDP-style solution (weights all-gathered per layer, activations stay
batch-sharded). The launch layer arms this context while tracing/lowering.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax

_ACT_SHARDING = None  # Optional[NamedSharding] for rank-3 (B, S, D) tensors
_MOE_SHARDING = None  # Optional[NamedSharding] for (E, C, D) expert buffers


@contextmanager
def activation_sharding(sharding, moe_sharding=None):
    global _ACT_SHARDING, _MOE_SHARDING
    prev, prev_m = _ACT_SHARDING, _MOE_SHARDING
    _ACT_SHARDING = sharding
    _MOE_SHARDING = moe_sharding
    try:
        yield
    finally:
        _ACT_SHARDING = prev
        _MOE_SHARDING = prev_m


def constrain(x):
    """Apply the ambient activation constraint to a (B, S, D) tensor."""
    if _ACT_SHARDING is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _ACT_SHARDING)


def constrain_moe(x):
    """Pin a (B, E, C, D) expert-parallel dispatch buffer."""
    if _MOE_SHARDING is None or x.ndim != 4:
        return x
    return jax.lax.with_sharding_constraint(x, _MOE_SHARDING)
