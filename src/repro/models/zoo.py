"""Model zoo: uniform interface over all architecture families.

    model = build_model(cfg)
    params = model.init(key, dtype)
    loss, metrics = model.loss(params, batch)        # training
    logits, cache = model.prefill(params, batch)     # inference prefill
    logits, cache = model.decode(params, cache, token)
    cache = model.make_cache(batch, cache_len, dtype)

``batch`` is a dict: tokens/labels (+ frames for audio, image_embeds for vlm).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig
from repro.models import encdec as ED
from repro.models import lm as LM


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    make_cache: Callable[..., Any]


def _frontend_of(cfg: ArchConfig, batch: Dict):
    if cfg.frontend == "vision":
        return batch["image_embeds"]
    if cfg.frontend == "audio":
        return batch.get("frames")
    return None


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "audio":
        def init(key, dtype=jnp.float32):
            return ED.init_encdec(cfg, key, dtype)

        def loss(params, batch, compute_dtype=jnp.float32, remat: bool = False):
            del remat  # 12+12 layers: fits without activation checkpointing
            return ED.encdec_loss(params, cfg, batch["frames"], batch["tokens"],
                                  batch["labels"], compute_dtype)

        def make_cache(batch_size, cache_len, dtype=jnp.bfloat16,
                       enc_len: Optional[int] = None):
            return ED.init_encdec_cache(cfg, batch_size, cache_len,
                                        enc_len or cfg.frontend_tokens, dtype)

        def prefill(params, batch, cache, compute_dtype=jnp.bfloat16,
                    moe_dropless: bool = True):
            del moe_dropless  # no MoE in the enc-dec family
            return ED.encdec_prefill(params, cfg, batch["frames"],
                                     batch["tokens"], cache, compute_dtype)

        def decode(params, cache, token, compute_dtype=jnp.bfloat16,
                   moe_dropless: bool = True):
            del moe_dropless
            return ED.encdec_decode(params, cfg, cache, token, compute_dtype)

        return Model(cfg, init, loss, prefill, decode, make_cache)

    # decoder-only families (dense / moe / ssm / hybrid / vlm)
    def init(key, dtype=jnp.float32):
        return LM.init_lm(cfg, key, dtype)

    def loss(params, batch, compute_dtype=jnp.float32, remat: bool = False):
        return LM.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                          frontend=_frontend_of(cfg, batch),
                          compute_dtype=compute_dtype, remat=remat)

    def make_cache(batch_size, cache_len, dtype=jnp.bfloat16, **_kw):
        # VLM prefill prepends the projected vision-patch embeddings, so the
        # KV cache must hold frontend_tokens extra positions.
        if cfg.frontend == "vision":
            cache_len = cache_len + cfg.frontend_tokens
        return LM.init_cache(cfg, batch_size, cache_len, dtype)

    def prefill(params, batch, cache, compute_dtype=jnp.bfloat16,
                moe_dropless: bool = True):
        return LM.lm_prefill(params, cfg, batch["tokens"], cache,
                             frontend=_frontend_of(cfg, batch),
                             compute_dtype=compute_dtype,
                             moe_dropless=moe_dropless)

    def decode(params, cache, token, compute_dtype=jnp.bfloat16,
               moe_dropless: bool = True):
        return LM.lm_decode(params, cfg, cache, token, compute_dtype,
                            moe_dropless=moe_dropless)

    return Model(cfg, init, loss, prefill, decode, make_cache)
