"""Unified decoder-only LM covering dense / MoE / SSM / hybrid / VLM families.

The layer stack is organised into *periods*: a period is the smallest
repeating pattern of blocks (1 layer for homogeneous stacks; 8 for Jamba's
7-Mamba+1-attention interleave; 4 for xLSTM's 3-mLSTM+1-sLSTM). Parameters of
all periods are stacked along a leading axis and the forward pass is a single
``lax.scan`` over periods — compile time is O(period), not O(depth).

Public API (all pure functions):

    period_spec(cfg)                 -> ((mixer, ffn), ...) per layer in period
    init_lm(cfg, key, dtype)         -> params
    lm_loss(params, cfg, tokens, labels, ...)         -> scalar loss, metrics
    lm_logits(params, cfg, tokens, frontend=None)     -> (B, S, padded_vocab)
    init_cache(cfg, batch, cache_len, dtype)          -> cache pytree
    lm_prefill(params, cfg, tokens, cache, frontend=None) -> (logits_last, cache)
    lm_decode(params, cfg, cache, token)              -> (logits, cache)
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig
from repro.common.pytree import KeyGen, normal_init
from repro.sharding.context import constrain
from repro.models import blocks as B
from repro.models.layers import embed, init_embedding, init_rmsnorm, init_ffn, ffn, linear, rmsnorm


# ----------------------------------------------------------------------
def period_spec(cfg: ArchConfig) -> Tuple[Tuple[str, str], ...]:
    """Per-layer (mixer, ffn) pattern within one period."""
    if cfg.layer_pattern == "attn":
        ffn_kind = "moe" if cfg.moe is not None else "dense"
        if cfg.moe is not None and cfg.moe.layer_period > 1:
            return tuple(
                ("attn", "moe" if (i % cfg.moe.layer_period == cfg.moe.layer_period - 1)
                 else "dense")
                for i in range(cfg.moe.layer_period))
        return (("attn", ffn_kind),)
    if cfg.layer_pattern == "jamba":
        out = []
        for i in range(cfg.attn_period):
            mixer = "attn" if i == cfg.attn_period - 1 else "mamba"
            f = "moe" if (i % 2 == 1 and cfg.moe is not None) else "dense"
            out.append((mixer, f))
        return tuple(out)
    if cfg.layer_pattern == "mamba":
        return (("mamba", "dense" if cfg.d_ff else "none"),)
    if cfg.layer_pattern == "xlstm":
        return (("mlstm", "none"), ("mlstm", "none"), ("mlstm", "none"), ("slstm", "none"))
    raise ValueError(cfg.layer_pattern)


def n_periods(cfg: ArchConfig) -> int:
    plen = len(period_spec(cfg))
    assert cfg.num_layers % plen == 0, (cfg.name, cfg.num_layers, plen)
    return cfg.num_layers // plen


# ----------------------------------------------------------------------
def _init_period(cfg: ArchConfig, key) -> Dict:
    kg = KeyGen(key)
    p: Dict = {}
    for i, (mixer, f) in enumerate(period_spec(cfg)):
        p[f"norm{i}_mix"] = init_rmsnorm(cfg.d_model)
        if mixer == "attn":
            p[f"blk{i}_attn"] = B.init_attn(kg(), cfg)
        elif mixer == "mamba":
            p[f"blk{i}_mamba"] = B.init_mamba(kg(), cfg, cfg.ssm)
        elif mixer == "mlstm":
            p[f"blk{i}_mlstm"] = B.init_mlstm(kg(), cfg, cfg.ssm)
        elif mixer == "slstm":
            p[f"blk{i}_slstm"] = B.init_slstm(kg(), cfg, cfg.ssm)
        if f == "dense":
            p[f"norm{i}_ffn"] = init_rmsnorm(cfg.d_model)
            p[f"blk{i}_ffn"] = init_ffn(kg(), cfg.d_model, cfg.d_ff, cfg.activation)
        elif f == "moe":
            p[f"norm{i}_ffn"] = init_rmsnorm(cfg.d_model)
            p[f"blk{i}_moe"] = B.init_moe(kg(), cfg, cfg.moe)
    return p


def init_lm(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    np_ = n_periods(cfg)
    keys = jax.random.split(kg(), np_)
    periods = jax.vmap(lambda k: _init_period(cfg, k))(keys)
    params = {
        "embed": init_embedding(kg(), cfg.padded_vocab, cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
        "periods": periods,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": normal_init(kg(), (cfg.d_model, cfg.padded_vocab),
                                              stddev=1 / math.sqrt(cfg.d_model))}
    if cfg.frontend != "none":
        # projector from stub frontend embeddings into d_model
        fd = cfg.frontend_dim or cfg.d_model
        params["frontend_proj"] = {"w": normal_init(kg(), (fd, cfg.d_model),
                                                    stddev=1 / math.sqrt(fd))}
    if dtype != jnp.float32:
        from repro.common.pytree import cast_tree
        params = cast_tree(params, dtype)
    return params


# ----------------------------------------------------------------------
def _mixer_train(pp, cfg: ArchConfig, i: int, mixer: str, x, aux):
    h = rmsnorm(pp[f"norm{i}_mix"], x, cfg.norm_eps)
    if mixer == "attn":
        y = B.attn_train(pp[f"blk{i}_attn"], cfg, h, causal=True,
                         window=cfg.sliding_window)
    elif mixer == "mamba":
        y = B.mamba_train(pp[f"blk{i}_mamba"], cfg, cfg.ssm, h)
    elif mixer == "mlstm":
        y = B.mlstm_train(pp[f"blk{i}_mlstm"], cfg, cfg.ssm, h)
    elif mixer == "slstm":
        y = B.slstm_train(pp[f"blk{i}_slstm"], cfg, cfg.ssm, h)
    return x + y, aux


def _ffn_apply(pp, cfg: ArchConfig, i: int, f: str, x, aux,
               moe_dropless: bool = False):
    if f == "none":
        return x, aux
    h = rmsnorm(pp[f"norm{i}_ffn"], x, cfg.norm_eps)
    if f == "dense":
        y = ffn(pp[f"blk{i}_ffn"], h, cfg.activation)
    else:
        y, moe_aux = B.moe_apply(pp[f"blk{i}_moe"], cfg, cfg.moe, h,
                                 dropless=moe_dropless)
        aux = aux + moe_aux
    return x + y, aux


def _embed_tokens(params, cfg: ArchConfig, tokens, frontend, dtype):
    x = embed(params["embed"], tokens, dtype=dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    if frontend is not None:
        fe = frontend.astype(dtype) @ params["frontend_proj"]["w"].astype(dtype)
        x = jnp.concatenate([fe, x], axis=1)
    return constrain(x)


def _head(params, cfg: ArchConfig, x):
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["table"].astype(h.dtype).T
    else:
        logits = linear(params["lm_head"], h)
    # mask padding vocab entries
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.asarray(-1e30, logits.dtype)
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, neg, logits)
    return logits


def lm_logits(params, cfg: ArchConfig, tokens, frontend=None,
              compute_dtype=jnp.float32, remat: bool = False,
              moe_dropless: bool = False):
    """Full-sequence causal logits (training path).

    ``moe_dropless=True`` gives the slicing-invariant exact MoE forward
    (matches prefill+decode token for token); the default keeps the
    capacity-dropped training dispatch."""
    x = _embed_tokens(params, cfg, tokens, frontend, compute_dtype)
    spec = period_spec(cfg)

    def period_fn(carry, pp):
        x, aux = carry
        for i, (mixer, f) in enumerate(spec):
            x, aux = _mixer_train(pp, cfg, i, mixer, x, aux)
            x, aux = _ffn_apply(pp, cfg, i, f, x, aux, moe_dropless)
        return (constrain(x), aux), None

    if remat:
        period_fn = jax.checkpoint(period_fn)
    (x, aux), _ = jax.lax.scan(period_fn, (x, jnp.zeros((), jnp.float32)),
                               params["periods"])
    return _head(params, cfg, x), aux


def lm_loss(params, cfg: ArchConfig, tokens, labels, frontend=None,
            compute_dtype=jnp.float32, remat: bool = False):
    """Next-token cross entropy. labels: (B, S) with -100 = ignore."""
    logits, aux = lm_logits(params, cfg, tokens, frontend, compute_dtype, remat)
    if frontend is not None:
        logits = logits[:, frontend.shape[1]:]
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    loss = nll.sum() / jnp.maximum(valid.sum(), 1)
    return loss + aux, {"nll": loss, "aux": aux,
                        "ntokens": valid.sum().astype(jnp.float32)}


# ----------------------------------------------------------------------
# caches
def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16) -> Dict:
    """cache_len: attention KV capacity. With cfg.sliding_window > 0 and
    cache_len >= window, attention caches are rolling ``window``-sized rings."""
    spec = period_spec(cfg)
    np_ = n_periods(cfg)
    attn_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    per: Dict = {}
    for i, (mixer, _f) in enumerate(spec):
        if mixer == "attn":
            per[f"blk{i}_attn"] = B.init_attn_cache(cfg, batch, attn_len, dtype)
        elif mixer == "mamba":
            per[f"blk{i}_mamba"] = B.init_mamba_cache(cfg, cfg.ssm, batch, dtype)
        elif mixer == "mlstm":
            per[f"blk{i}_mlstm"] = B.init_mlstm_cache(cfg, cfg.ssm, batch)
        elif mixer == "slstm":
            per[f"blk{i}_slstm"] = B.init_slstm_cache(cfg, cfg.ssm, batch)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (np_,) + x.shape), per)
    return {"periods": stacked, "pos": jnp.zeros((), jnp.int32)}


def _run_cached(params, cfg: ArchConfig, x, cache, pos, *, decode: bool,
                moe_dropless: bool = True):
    """Shared prefill/decode scan over periods. x: (B, S, d)."""
    spec = period_spec(cfg)

    def period_fn(carry, xs):
        x, aux = carry
        pp, pc = xs
        new_pc = dict(pc)
        for i, (mixer, f) in enumerate(spec):
            with jax.named_scope(f"blk{i}_{mixer}"):
                h = rmsnorm(pp[f"norm{i}_mix"], x, cfg.norm_eps)
                key = f"blk{i}_{mixer}"
                if mixer == "attn":
                    if decode:
                        y, new_pc[key] = B.attn_decode(pp[key], cfg, h,
                                                       pc[key], pos,
                                                       window=cfg.sliding_window)
                    else:
                        y, new_pc[key] = B.attn_prefill(pp[key], cfg, h,
                                                        pc[key],
                                                        window=cfg.sliding_window)
                elif mixer == "mamba":
                    fn = B.mamba_decode if decode else B.mamba_prefill
                    y, new_pc[key] = fn(pp[key], cfg, cfg.ssm, h, pc[key])
                elif mixer == "mlstm":
                    y, new_pc[key] = B.mlstm_prefill(pp[key], cfg, cfg.ssm, h,
                                                     pc[key])
                elif mixer == "slstm":
                    y, new_pc[key] = B.slstm_prefill(pp[key], cfg, cfg.ssm, h,
                                                     pc[key])
                x = x + y
            with jax.named_scope(f"blk{i}_ffn_{f}"):
                x, aux = _ffn_apply(pp, cfg, i, f, x, aux, moe_dropless)
        return (constrain(x), aux), new_pc

    (x, _aux), new_periods = jax.lax.scan(
        period_fn, (x, jnp.zeros((), jnp.float32)),
        (params["periods"], cache["periods"]))
    return x, new_periods


def lm_prefill(params, cfg: ArchConfig, tokens, cache, frontend=None,
               compute_dtype=jnp.bfloat16, moe_dropless: bool = True):
    """Process the prompt; returns last-position logits + filled cache.

    MoE defaults to the exact dropless dispatch (consistent with decode);
    the large-shape dry-run passes ``moe_dropless=False`` to keep the
    capacity-bounded e/k-cheaper expert einsum."""
    x = _embed_tokens(params, cfg, tokens, frontend, compute_dtype)
    s = x.shape[1]
    x, new_periods = _run_cached(params, cfg, x, cache, jnp.zeros((), jnp.int32),
                                 decode=False, moe_dropless=moe_dropless)
    logits = _head(params, cfg, x[:, -1:])
    return logits, {"periods": new_periods, "pos": jnp.asarray(s, jnp.int32)}


def lm_decode(params, cfg: ArchConfig, cache, token, compute_dtype=jnp.bfloat16,
              moe_dropless: bool = True):
    """token: (B, 1) -> (logits (B, 1, V), cache')."""
    x = _embed_tokens(params, cfg, token, None, compute_dtype)
    pos = cache["pos"]
    x, new_periods = _run_cached(params, cfg, x, cache, pos, decode=True,
                                 moe_dropless=moe_dropless)
    logits = _head(params, cfg, x)
    return logits, {"periods": new_periods, "pos": pos + 1}