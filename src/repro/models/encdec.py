"""Whisper-style encoder–decoder backbone.

The conv/mel frontend is a STUB per the assignment carve-out: the caller
provides precomputed frame embeddings (B, T_enc, d_model). We implement the
full encoder transformer (bidirectional), the causal decoder with
self-attention KV cache + cross-attention to the encoder output, and the
teacher-forced training loss. Positional encoding is sinusoidal (adaptation:
Whisper uses learned tables capped at 1500/448; sinusoidal extends to the
assignment's stress shapes — noted in DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig
from repro.common.pytree import KeyGen, normal_init
from repro.sharding.context import constrain
from repro.models import attention as attn_lib
from repro.models import blocks as B
from repro.models.layers import (embed, init_embedding, init_ffn, ffn,
                                 init_layernorm, layernorm, linear)


def sinusoid_pos(positions, d: int, dtype=jnp.float32):
    """positions: (...,) -> (..., d)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ----------------------------------------------------------------------
def _init_enc_layer(cfg: ArchConfig, key):
    kg = KeyGen(key)
    return {
        "ln1": init_layernorm(cfg.d_model),
        "attn": B.init_attn(kg(), cfg),
        "ln2": init_layernorm(cfg.d_model),
        "ffn": init_ffn(kg(), cfg.d_model, cfg.d_ff, "gelu"),
    }


def _init_dec_layer(cfg: ArchConfig, key):
    kg = KeyGen(key)
    return {
        "ln1": init_layernorm(cfg.d_model),
        "self_attn": B.init_attn(kg(), cfg),
        "ln2": init_layernorm(cfg.d_model),
        "cross_attn": B.init_cross_attn(kg(), cfg),
        "ln3": init_layernorm(cfg.d_model),
        "ffn": init_ffn(kg(), cfg.d_model, cfg.d_ff, "gelu"),
    }


def init_encdec(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict:
    kg = KeyGen(key)
    enc_keys = jax.random.split(kg(), cfg.encoder_layers)
    dec_keys = jax.random.split(kg(), cfg.num_layers)
    params = {
        "embed": init_embedding(kg(), cfg.padded_vocab, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(cfg, k))(enc_keys),
        "enc_ln": init_layernorm(cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dec_keys),
        "dec_ln": init_layernorm(cfg.d_model),
    }
    if dtype != jnp.float32:
        from repro.common.pytree import cast_tree
        params = cast_tree(params, dtype)
    return params


# ----------------------------------------------------------------------
def encode(params, cfg: ArchConfig, frames, compute_dtype=jnp.float32):
    """frames: (B, T_enc, d_model) stub embeddings -> encoder output."""
    b, t, _ = frames.shape
    x = frames.astype(compute_dtype) + sinusoid_pos(jnp.arange(t), cfg.d_model,
                                                    compute_dtype)

    def layer_fn(x, lp):
        h = layernorm(lp["ln1"], x, cfg.norm_eps)
        x = x + B.attn_train(lp["attn"], cfg, h, causal=False)
        h = layernorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, "gelu")
        return constrain(x), None

    x, _ = jax.lax.scan(layer_fn, x, params["enc_layers"])
    return layernorm(params["enc_ln"], x, cfg.norm_eps)


def _dec_embed(params, cfg, tokens, pos0, dtype):
    x = embed(params["embed"], tokens, dtype=dtype)
    pos = jnp.arange(tokens.shape[1]) + pos0
    return x + sinusoid_pos(pos, cfg.d_model, dtype)


def _head(params, cfg: ArchConfig, x):
    h = layernorm(params["dec_ln"], x, cfg.norm_eps)
    logits = h @ params["embed"]["table"].astype(h.dtype).T
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


def encdec_logits(params, cfg: ArchConfig, frames, tokens,
                  compute_dtype=jnp.float32):
    """Teacher-forced decoder logits (training path)."""
    enc = encode(params, cfg, frames, compute_dtype)
    x = _dec_embed(params, cfg, tokens, 0, compute_dtype)

    def layer_fn(x, lp):
        h = layernorm(lp["ln1"], x, cfg.norm_eps)
        x = x + B.attn_train(lp["self_attn"], cfg, h, causal=True)
        h = layernorm(lp["ln2"], x, cfg.norm_eps)
        kv = B.cross_attn_kv(lp["cross_attn"], cfg, enc)
        x = x + B.cross_attn_apply(lp["cross_attn"], cfg, h, kv)
        h = layernorm(lp["ln3"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, "gelu")
        return constrain(x), None

    x, _ = jax.lax.scan(layer_fn, x, params["dec_layers"])
    return _head(params, cfg, x)


def encdec_loss(params, cfg: ArchConfig, frames, tokens, labels,
                compute_dtype=jnp.float32):
    logits = encdec_logits(params, cfg, frames, tokens, compute_dtype).astype(jnp.float32)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    loss = ((logz - gold) * valid).sum() / jnp.maximum(valid.sum(), 1)
    return loss, {"nll": loss, "ntokens": valid.sum().astype(jnp.float32)}


# ----------------------------------------------------------------------
# serving: prefill builds self-KV + cross-KV caches; decode steps one token.
def init_encdec_cache(cfg: ArchConfig, batch: int, cache_len: int,
                      enc_len: int, dtype=jnp.bfloat16) -> Dict:
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    self_kv = {"k": jnp.zeros((L, batch, cache_len, cfg.num_kv_heads, hd), dtype),
               "v": jnp.zeros((L, batch, cache_len, cfg.num_kv_heads, hd), dtype)}
    cross_kv = {"k": jnp.zeros((L, batch, enc_len, cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((L, batch, enc_len, cfg.num_kv_heads, hd), dtype)}
    return {"self": self_kv, "cross": cross_kv, "pos": jnp.zeros((), jnp.int32)}


def encdec_prefill(params, cfg: ArchConfig, frames, tokens, cache,
                   compute_dtype=jnp.bfloat16):
    enc = encode(params, cfg, frames, compute_dtype)
    x = _dec_embed(params, cfg, tokens, 0, compute_dtype)
    s = tokens.shape[1]

    def layer_fn(carry, xs):
        x = carry
        lp, sc, cc = xs
        h = layernorm(lp["ln1"], x, cfg.norm_eps)
        y, sc = B.attn_prefill(lp["self_attn"], cfg, h, sc)
        x = x + y
        h = layernorm(lp["ln2"], x, cfg.norm_eps)
        kv = B.cross_attn_kv(lp["cross_attn"], cfg, enc)
        cc = {"k": kv["k"].astype(cc["k"].dtype), "v": kv["v"].astype(cc["v"].dtype)}
        x = x + B.cross_attn_apply(lp["cross_attn"], cfg, h, kv)
        h = layernorm(lp["ln3"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, "gelu")
        return constrain(x), (sc, cc)

    x, (self_kv, cross_kv) = jax.lax.scan(
        layer_fn, x, (params["dec_layers"], cache["self"], cache["cross"]))
    logits = _head(params, cfg, x[:, -1:])
    return logits, {"self": self_kv, "cross": cross_kv,
                    "pos": jnp.asarray(s, jnp.int32)}


def encdec_decode(params, cfg: ArchConfig, cache, token,
                  compute_dtype=jnp.bfloat16):
    """token: (B, 1)."""
    pos = cache["pos"]
    x = _dec_embed(params, cfg, token, pos, compute_dtype)

    def layer_fn(carry, xs):
        x = carry
        lp, sc, cc = xs
        h = layernorm(lp["ln1"], x, cfg.norm_eps)
        y, sc = B.attn_decode(lp["self_attn"], cfg, h, sc, pos)
        x = x + y
        h = layernorm(lp["ln2"], x, cfg.norm_eps)
        b = h.shape[0]
        hd = cfg.resolved_head_dim
        q = linear(lp["cross_attn"]["wq"], h).reshape(b, 1, cfg.num_heads, hd)
        o = attn_lib.decode_attention(q, cc["k"].astype(h.dtype),
                                      cc["v"].astype(h.dtype), cc["k"].shape[1])
        x = x + linear(lp["cross_attn"]["wo"], o.reshape(b, 1, -1))
        h = layernorm(lp["ln3"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, "gelu")
        return constrain(x), sc

    x, self_kv = jax.lax.scan(
        layer_fn, x, (params["dec_layers"], cache["self"], cache["cross"]))
    logits = _head(params, cfg, x)
    return logits, {"self": self_kv, "cross": cache["cross"], "pos": pos + 1}