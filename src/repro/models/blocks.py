"""Transformer / SSM / MoE building blocks with train, prefill and decode paths.

Every block type exposes::

    init_<blk>(key, cfg, ...)               -> params subtree
    <blk>_train(p, cfg, x, ...)             -> y               (full sequence)
    <blk>_prefill(p, cfg, x, cache, ...)    -> y, cache'       (build caches)
    <blk>_decode(p, cfg, x, cache, pos)     -> y, cache'       (one token)

``x`` is (B, S, d_model); blocks are residual-free (the LM adds residuals and
norms). Caches are plain dicts of arrays so they stack along a leading period
axis for ``lax.scan`` over layers.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig, MoEConfig, SSMConfig
from repro.common.pytree import KeyGen, normal_init
from repro.models import attention as attn_lib
from repro.models.layers import init_linear, linear, apply_rope
from repro.sharding.context import constrain_moe


# ======================================================================
# attention block (GQA + RoPE, full / causal / sliding-window)
def init_attn(key, cfg: ArchConfig):
    kg = KeyGen(key)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": init_linear(kg(), d, cfg.num_heads * hd, bias=cfg.qkv_bias),
        "wk": init_linear(kg(), d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": init_linear(kg(), d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": init_linear(kg(), cfg.num_heads * hd, d,
                          stddev=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _qkv(p, cfg: ArchConfig, x, positions, rope: bool = True):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k = linear(p["wk"], x).reshape(b, s, cfg.num_kv_heads, hd)
    v = linear(p["wv"], x).reshape(b, s, cfg.num_kv_heads, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(p, cfg: ArchConfig, x, *, causal: bool = True, window: int = 0,
               q_block: int = 512, k_block: int = 1024):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(p, cfg, x, positions, rope=(cfg.layer_pattern != "encdec") or True)
    o = attn_lib.flash_attention_jnp(q, k, v, causal=causal, window=window,
                                     q_block=min(q_block, s), k_block=min(k_block, s))
    return linear(p["wo"], o.reshape(b, s, -1))


def init_attn_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shp = (batch, cache_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def attn_prefill(p, cfg: ArchConfig, x, cache: Dict, *, window: int = 0):
    """Run full-sequence attention and populate the KV cache.

    The cache length may exceed S (room for decode); with a ring cache
    (window > 0 and cache_len == window) the tail of the sequence is kept.
    """
    b, s, _ = x.shape
    t = cache["k"].shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(p, cfg, x, positions)
    o = attn_lib.flash_attention_jnp(q, k, v, causal=True, window=window,
                                     q_block=min(512, s), k_block=min(1024, s))
    if window and t == window and s > t:
        k_keep, v_keep = k[:, -t:], v[:, -t:]
        # ring layout: entry for absolute position p lives at p % window
        idx = (jnp.arange(s - t, s)) % t
        cache = {"k": cache["k"].at[:, idx].set(k_keep.astype(cache["k"].dtype)),
                 "v": cache["v"].at[:, idx].set(v_keep.astype(cache["v"].dtype))}
    else:
        cache = {"k": jax.lax.dynamic_update_slice_in_dim(
                     cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
                 "v": jax.lax.dynamic_update_slice_in_dim(
                     cache["v"], v.astype(cache["v"].dtype), 0, axis=1)}
    return linear(p["wo"], o.reshape(b, s, -1)), cache


def attn_decode(p, cfg: ArchConfig, x, cache: Dict, pos, *, window: int = 0):
    """x: (B, 1, d); pos: scalar int32 absolute position of this token."""
    b = x.shape[0]
    t = cache["k"].shape[1]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k, v = _qkv(p, cfg, x, positions)
    ring = bool(window) and t == window
    widx = (pos % t) if ring else pos
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), widx, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), widx, axis=1)
    o = attn_lib.decode_attention(q, kc, vc, pos + 1, window=window, ring=ring)
    return linear(p["wo"], o.reshape(b, 1, -1)), {"k": kc, "v": vc}


# cross attention (whisper decoder): KV from encoder output, computed once.
def init_cross_attn(key, cfg: ArchConfig):
    return init_attn(key, cfg)


def cross_attn_kv(p, cfg: ArchConfig, enc_out):
    b, t, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = linear(p["wk"], enc_out).reshape(b, t, cfg.num_kv_heads, hd)
    v = linear(p["wv"], enc_out).reshape(b, t, cfg.num_kv_heads, hd)
    return {"k": k, "v": v}


def cross_attn_apply(p, cfg: ArchConfig, x, kv: Dict):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    o = attn_lib.flash_attention_jnp(q, kv["k"].astype(x.dtype), kv["v"].astype(x.dtype),
                                     causal=False, q_block=min(512, s))
    return linear(p["wo"], o.reshape(b, s, -1))


# ======================================================================
# mixture-of-experts FFN (top-k routing, index-based dispatch)
def init_moe(key, cfg: ArchConfig, mcfg: MoEConfig):
    kg = KeyGen(key)
    d, e, f = cfg.d_model, mcfg.num_experts, mcfg.expert_d_ff
    def ew(std):
        return normal_init(kg(), (e, d, f), stddev=std)
    return {
        "router": init_linear(kg(), d, e, stddev=0.02),
        "gate": normal_init(kg(), (e, d, f), stddev=1 / math.sqrt(d)),
        "up": normal_init(kg(), (e, d, f), stddev=1 / math.sqrt(d)),
        "down": normal_init(kg(), (e, f, d), stddev=1 / math.sqrt(f) / math.sqrt(2 * cfg.num_layers)),
    }


def moe_apply(p, cfg: ArchConfig, mcfg: MoEConfig, x,
              capacity_factor: float = 1.25,
              dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    Grouped capacity-dropped dispatch: each batch row is a routing group
    (group-limited capacity), and all index computations (top-k, position-
    within-expert cumsum, scatter/gather) are per-group and vmapped over the
    batch dim — so under pjit with a batch-sharded input the dispatch shards
    cleanly (GSPMD turns the expert einsums into all-to-alls when experts
    are model-sharded) instead of replicating a global-token index
    computation on every device.

    ``dropless=True`` sets capacity to S (each token routes to a given
    expert at most once, so S slots per expert can never overflow). This
    makes the output *exactly* slicing-invariant — full forward == prefill
    == token-by-token decode — at the cost of e/k-times the expert FLOPs,
    so it is the default only on the small-scale inference paths; the
    large-shape dry-run and the training loss keep capacity dispatch
    (with its cap = ceil(S·k/e·cf) fixed shape).
    """
    b, s, d = x.shape
    e, k = mcfg.num_experts, mcfg.experts_per_token
    if dropless:
        cap = s
    else:
        cap = max(1, min(s, int(math.ceil(s * k / e * capacity_factor))))

    logits = linear(p["router"], x).astype(jnp.float32)           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                          # (B, S, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style, over all tokens)
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(topi, e, dtype=jnp.float32),
                  axis=(0, 1, 2))
    aux = e * jnp.sum(me * ce) * mcfg.aux_loss_coef

    def dispatch_group(xg, topi_g, topw_g):
        """xg: (S, d); topi/topw: (S, k)."""
        flat_e = topi_g.reshape(-1)                               # (S*k,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (S*k, E)
        pos_all = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
        keep = pos < cap
        pos_c = jnp.minimum(pos, cap - 1)
        tok = jnp.repeat(jnp.arange(s), k)
        # scatter-SET with OOB-drop for dropped tokens (§Perf: each (e, pos)
        # slot receives at most one token, so no accumulation is needed —
        # scatter-add gets f32-promoted by XLA and costs a (E,C,d) f32
        # all-reduce; a set-scatter stays bf16).
        pos_w = jnp.where(keep, pos, cap)            # cap = out of bounds
        buf = jnp.zeros((e, cap, d), xg.dtype).at[flat_e, pos_w].set(
            xg[tok], mode="drop")
        return buf, (flat_e, pos_c, keep, tok, topw_g.reshape(-1))

    buf, meta = jax.vmap(dispatch_group)(x, topi, topw)           # (B,E,C,d)
    buf = constrain_moe(buf)      # (B, E, C, d): experts over `model`

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["gate"].astype(x.dtype))) * \
        jnp.einsum("becd,edf->becf", buf, p["up"].astype(x.dtype))
    out_e = jnp.einsum("becf,efd->becd", h, p["down"].astype(x.dtype))
    out_e = constrain_moe(out_e)                                  # (B, E, C, d)

    def combine_group(oe, m):
        # gather + reshape-sum combine (§Perf: a scatter-add here gets
        # f32-promoted by XLA and costs a full (B,S,d) f32 all-reduce per
        # MoE layer; each token's k expert slots are consecutive in flat_e,
        # so the combine is an exact reshape + weighted sum over k).
        flat_e, pos_c, keep, tok, w_flat = m
        del tok
        gathered = oe[flat_e, pos_c]                              # (S*k, d)
        w = (w_flat * keep).astype(oe.dtype)
        return (gathered.reshape(s, k, d) * w.reshape(s, k, 1)).sum(axis=1)

    y = jax.vmap(combine_group)(out_e, meta)
    return y, aux


# ======================================================================
# Mamba selective-SSM block
def init_mamba(key, cfg: ArchConfig, scfg: SSMConfig):
    kg = KeyGen(key)
    d = cfg.d_model
    inner = scfg.expand * d
    dt_rank = scfg.dt_rank or max(1, math.ceil(d / 16))
    n = scfg.state_dim
    # S4D-real initialisation for A
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (inner, 1))
    return {
        "in_proj": init_linear(kg(), d, 2 * inner),
        "conv_w": normal_init(kg(), (scfg.conv_width, inner), stddev=0.3),
        "conv_b": jnp.zeros((inner,), jnp.float32),
        "x_proj": init_linear(kg(), inner, dt_rank + 2 * n),
        "dt_proj": {"w": normal_init(kg(), (dt_rank, inner), stddev=dt_rank ** -0.5),
                    "b": jnp.log(jnp.exp(jnp.exp(
                        jax.random.uniform(kg(), (inner,), minval=math.log(1e-3),
                                           maxval=math.log(1e-1)))) - 1.0 + 1e-9)},
        "A_log": jnp.log(a),
        "D": jnp.ones((inner,), jnp.float32),
        "out_proj": init_linear(kg(), inner, d,
                                stddev=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _mamba_conv_train(p, xi):
    """Causal depthwise conv over time. xi: (B, S, inner)."""
    w = p["conv_w"].astype(xi.dtype)                            # (W, inner)
    width = w.shape[0]
    xp = jnp.pad(xi, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xi)
    for i in range(width):                                      # tiny static loop
        out = out + xp[:, i:i + xi.shape[1]] * w[i]
    return out + p["conv_b"].astype(xi.dtype)


def _mamba_inner(p, cfg, scfg, xi_conv, dt_rank, n):
    """Common post-conv computation -> (dA, dBx, C_mat). xi_conv: (B,S,inner)."""
    xi = jax.nn.silu(xi_conv)
    proj = linear(p["x_proj"], xi)                              # (B,S,dtr+2n)
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"]["w"].astype(xi.dtype) +
                         p["dt_proj"]["b"].astype(xi.dtype))    # (B,S,inner)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                # (inner, n)
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)         # (B,S,inner,n)
    dbx = (dt * xi).astype(jnp.float32)[..., None] * bmat.astype(jnp.float32)[:, :, None, :]
    return xi, da, dbx, cmat


def _ssm_comb(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def _mamba_scan_chunked(p, cfg, scfg, xi_conv, h0, chunk: int, dt_rank: int,
                        n: int):
    """Chunked selective scan, memory-lean (§Perf iteration 1).

    The per-position projections (x_proj / dt_proj) and the discretised
    (dA, dBx) tensors are computed INSIDE the per-chunk step and the step is
    ``jax.checkpoint``-ed, so the f32 (B,S,inner,n) tensors — 34 GB/device
    for jamba train_4k — are never fully live and the backward saves only
    the (B,inner,n) chunk-boundary states plus the bf16 chunk inputs.

    xi_conv: (B,S,inner) post-conv pre-silu. Returns (y (B,S,inner) f32
    including the D skip term, h_last).
    """
    b, s, inner = xi_conv.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        xi_conv = jnp.pad(xi_conv, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    xi_c = xi_conv.reshape(b, nc, chunk, inner).swapaxes(0, 1)  # (nc,B,C,in)
    # identity-mask for padded tail positions: dA -> 1, dBx -> 0, so padding
    # never perturbs the recurrent state handed to decode.
    valid = (jnp.arange(nc * chunk) < s).reshape(nc, 1, chunk, 1, 1)

    def chunk_step(h0, xs):
        xi_k, v_k = xs
        _, da_k, dbx_k, c_k = _mamba_inner(p, cfg, scfg, xi_k, dt_rank, n)
        da_k = jnp.where(v_k, da_k, 1.0)
        dbx_k = jnp.where(v_k, dbx_k, 0.0)
        cum_a, cum_b = jax.lax.associative_scan(_ssm_comb, (da_k, dbx_k),
                                                axis=1)
        h = cum_a * h0[:, None] + cum_b                         # (B,C,inner,n)
        y = jnp.einsum("bsin,bsn->bsi", h, c_k.astype(jnp.float32))
        return h[:, -1], y.astype(xi_k.dtype)

    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, (xi_c, valid))
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, inner)[:, :s]
    return y, h_last


def _mamba_full(p, cfg: ArchConfig, scfg: SSMConfig, x, h0, chunk: int = 64):
    """Shared full-sequence path. Returns (out, final_state, conv_tail)."""
    b, s, d = x.shape
    n = scfg.state_dim
    dt_rank = scfg.dt_rank or max(1, math.ceil(d / 16))
    xz = linear(p["in_proj"], x)
    xi_raw, z = jnp.split(xz, 2, axis=-1)
    xi_conv = _mamba_conv_train(p, xi_raw)
    y, h_last = _mamba_scan_chunked(p, cfg, scfg, xi_conv, h0, chunk,
                                    dt_rank, n)
    xi = jax.nn.silu(xi_conv)
    y = y.astype(x.dtype) + xi * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    w = scfg.conv_width
    conv_tail = jnp.pad(xi_raw, ((0, 0), (w - 1, 0), (0, 0)))[:, -(w - 1):]
    return linear(p["out_proj"], y), h_last, conv_tail


def mamba_train(p, cfg: ArchConfig, scfg: SSMConfig, x, chunk: int = 64):
    """x: (B, S, d) -> (B, S, d). Chunked associative scan over time."""
    inner = scfg.expand * cfg.d_model
    h0 = jnp.zeros((x.shape[0], inner, scfg.state_dim), jnp.float32)
    out, _, _ = _mamba_full(p, cfg, scfg, x, h0, chunk)
    return out


def init_mamba_cache(cfg: ArchConfig, scfg: SSMConfig, batch: int, dtype=jnp.float32):
    inner = scfg.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, scfg.conv_width - 1, inner), dtype),
            "ssm": jnp.zeros((batch, inner, scfg.state_dim), jnp.float32)}


def mamba_prefill(p, cfg: ArchConfig, scfg: SSMConfig, x, cache: Dict, chunk: int = 64):
    """Full-sequence pass that also leaves the recurrent state in the cache."""
    out, h_last, conv_tail = _mamba_full(p, cfg, scfg, x, cache["ssm"], chunk)
    return out, {"conv": conv_tail.astype(cache["conv"].dtype), "ssm": h_last}


def mamba_decode(p, cfg: ArchConfig, scfg: SSMConfig, x, cache: Dict):
    """x: (B, 1, d). O(1) step via the recurrent form."""
    b, _, d = x.shape
    inner = scfg.expand * d
    n = scfg.state_dim
    dt_rank = scfg.dt_rank or max(1, math.ceil(d / 16))
    xz = linear(p["in_proj"], x)
    xi_raw, z = jnp.split(xz, 2, axis=-1)                       # (B,1,inner)
    conv_buf = jnp.concatenate([cache["conv"].astype(x.dtype), xi_raw], axis=1)
    w = p["conv_w"].astype(x.dtype)
    xi = jnp.einsum("bwi,wi->bi", conv_buf, w)[:, None] + p["conv_b"].astype(x.dtype)
    xi, da, dbx, cmat = _mamba_inner(p, cfg, scfg, xi, dt_rank, n)
    h = da[:, 0] * cache["ssm"] + dbx[:, 0]                     # (B, inner, n)
    y = jnp.einsum("bin,bn->bi", h, cmat[:, 0].astype(jnp.float32))[:, None].astype(x.dtype)
    y = y + xi * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return linear(p["out_proj"], y), {"conv": conv_buf[:, 1:].astype(cache["conv"].dtype),
                                      "ssm": h}


# ======================================================================
# xLSTM blocks (mLSTM: matrix memory; sLSTM: scalar memory w/ recurrence)
def init_mlstm(key, cfg: ArchConfig, scfg: SSMConfig):
    kg = KeyGen(key)
    d = cfg.d_model
    inner = scfg.expand * d
    nh = scfg.mlstm_heads
    dh = inner // nh
    return {
        "up": init_linear(kg(), d, 2 * inner),
        "wq": init_linear(kg(), inner, inner),
        "wk": init_linear(kg(), inner, inner),
        "wv": init_linear(kg(), inner, inner),
        "w_if": init_linear(kg(), inner, 2 * nh, bias=True),
        "down": init_linear(kg(), inner, d, stddev=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def init_mlstm_cache(cfg: ArchConfig, scfg: SSMConfig, batch: int):
    inner = scfg.expand * cfg.d_model
    nh = scfg.mlstm_heads
    dh = inner // nh
    return {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def _mlstm_scan(qkvif, cache, nh, dh, chunk: int = 64):
    """Sequential stabilized mLSTM recurrence, chunk-checkpointed (§Perf:
    a flat scan saves the (B,nh,dh,dh) matrix memory per STEP for backward
    — 77 GB for xlstm train_4k; checkpointing per 64-step chunk saves only
    chunk-boundary states and recomputes inside the chunk).

    Shapes per step: (B, nh, dh)."""
    q, k, v, igate, fgate = qkvif                              # (B,S,nh,dh) x3, (B,S,nh) x2
    b, s = q.shape[:2]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    nc = (s + pad) // chunk

    def prep(a):
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        return (a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
                .swapaxes(1, 2))                               # (nc, C, B, ...)

    xs_c = tuple(prep(a) for a in (q, k, v, igate, fgate))
    # identity for padded steps: f_p = 1 (ft = 0, m unchanged), i_p = 0
    valid = (jnp.arange(nc * chunk) < s).reshape(nc, chunk, 1, 1)

    def step(carry, xs):
        C, nvec, m = carry
        qt, kt, vt, it, ft, v_t = xs                           # (B,nh,dh)...
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        i_p = jnp.where(v_t, i_p, 0.0)
        f_p = jnp.where(v_t, f_p, 1.0)
        m_new = jnp.where(v_t, m_new, m)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])               # (B,nh,dh,dh)
        nvec = f_p[..., None] * nvec + i_p[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", nvec, qt)), 1.0)
        h = num / den[..., None]
        return (C, nvec, m_new), h

    def chunk_fn(carry, xs_k):
        return jax.lax.scan(step, carry, xs_k)

    (C, nvec, m), hs = jax.lax.scan(
        jax.checkpoint(chunk_fn), (cache["C"], cache["n"], cache["m"]),
        xs_c + (valid,))
    hs = hs.reshape(nc * chunk, b, nh, dh)[:s]                 # (S, B, nh, dh)
    return hs.swapaxes(0, 1), {"C": C, "n": nvec, "m": m}


def _mlstm_qkvif(p, cfg, scfg, x):
    b, s, d = x.shape
    inner = scfg.expand * d
    nh = scfg.mlstm_heads
    dh = inner // nh
    xz = linear(p["up"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    q = linear(p["wq"], xi).reshape(b, s, nh, dh).astype(jnp.float32) / math.sqrt(dh)
    k = linear(p["wk"], xi).reshape(b, s, nh, dh).astype(jnp.float32)
    v = linear(p["wv"], xi).reshape(b, s, nh, dh).astype(jnp.float32)
    gif = linear(p["w_if"], xi).astype(jnp.float32)
    igate, fgate = jnp.split(gif, 2, axis=-1)                  # (B,S,nh)
    fgate = jax.nn.log_sigmoid(fgate)
    return (q, k, v, igate, fgate), z, nh, dh


def mlstm_train(p, cfg: ArchConfig, scfg: SSMConfig, x):
    cache = init_mlstm_cache(cfg, scfg, x.shape[0])
    y, _ = mlstm_prefill(p, cfg, scfg, x, cache)
    return y


def mlstm_prefill(p, cfg: ArchConfig, scfg: SSMConfig, x, cache: Dict):
    qkvif, z, nh, dh = _mlstm_qkvif(p, cfg, scfg, x)
    hs, cache = _mlstm_scan(qkvif, cache, nh, dh)              # (B,S,nh,dh)
    b, s = x.shape[:2]
    y = hs.reshape(b, s, nh * dh).astype(x.dtype) * jax.nn.silu(z)
    return linear(p["down"], y), cache


def mlstm_decode(p, cfg: ArchConfig, scfg: SSMConfig, x, cache: Dict):
    return mlstm_prefill(p, cfg, scfg, x, cache)


def init_slstm(key, cfg: ArchConfig, scfg: SSMConfig):
    kg = KeyGen(key)
    d = cfg.d_model
    inner = scfg.expand * d
    nh = scfg.mlstm_heads
    dh = inner // nh
    return {
        "up": init_linear(kg(), d, inner),
        "w_gates": init_linear(kg(), inner, 4 * inner, bias=True),
        "r_gates": normal_init(kg(), (nh, dh, 4 * dh), stddev=1 / math.sqrt(dh)),
        "down": init_linear(kg(), inner, d, stddev=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def init_slstm_cache(cfg: ArchConfig, scfg: SSMConfig, batch: int):
    inner = scfg.expand * cfg.d_model
    nh = scfg.mlstm_heads
    dh = inner // nh
    z = lambda: jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, nh, dh), -1e30, jnp.float32)}


def slstm_prefill(p, cfg: ArchConfig, scfg: SSMConfig, x, cache: Dict):
    b, s, d = x.shape
    inner = scfg.expand * d
    nh = scfg.mlstm_heads
    dh = inner // nh
    xi = linear(p["up"], x)
    wx = linear(p["w_gates"], xi).reshape(b, s, nh, 4 * dh).astype(jnp.float32)
    rk = p["r_gates"].astype(jnp.float32)

    def step(carry, wxt):
        c, n, h, m = carry
        rec = jnp.einsum("bhj,hjk->bhk", h, rk)                # (B,nh,4dh)
        zt, it, ft, ot = jnp.split(wxt + rec, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        ft = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c = f_p * c + i_p * zt
        n = f_p * n + i_p
        h = ot * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    (c, n, h, m), hs = jax.lax.scan(
        step, (cache["c"], cache["n"], cache["h"], cache["m"]), wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(b, s, inner).astype(x.dtype)
    return linear(p["down"], y), {"c": c, "n": n, "h": h, "m": m}


def slstm_train(p, cfg, scfg, x):
    y, _ = slstm_prefill(p, cfg, scfg, x, init_slstm_cache(cfg, scfg, x.shape[0]))
    return y


def slstm_decode(p, cfg, scfg, x, cache):
    return slstm_prefill(p, cfg, scfg, x, cache)
