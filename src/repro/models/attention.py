"""Memory-efficient attention in pure JAX (the XLA path used by the dry-run).

Three entry points:

* :func:`flash_attention_jnp` — blocked online-softmax attention (training /
  prefill). Doubly chunked (query blocks outer ``lax.scan``, KV blocks inner)
  so peak memory is O(Bq*Bk) per head regardless of sequence length. Supports
  full, causal, and causal-sliding-window masking, and GQA without
  materialising repeated KV heads. This is also the oracle contract for the
  Pallas ``kernels/flash_attention``.
* :func:`decode_attention` — one-query-token attention against a (possibly
  rolling) KV cache; linear in cache length, GSPMD-friendly when the cache's
  sequence dim is sharded (partial max/sum lower to all-reduces).
* :func:`simple_attention` — naive O(S^2) reference used only in tests.
"""
from __future__ import annotations

import math
from functools import lru_cache, partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_split(q, num_kv: int):
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def simple_attention(q, k, v, *, causal: bool, window: int = 0,
                     q_offset: int = 0):
    """Naive attention oracle. q: (B,S,H,hd) k/v: (B,T,KV,hd)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    qg = _gqa_split(q, kv)                                    # (B,S,KV,G,hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(b, s, h, hd)


def _mask_block(qpos, kposb, kvalid_b, causal: bool, window: int):
    msk = kvalid_b[None, :]
    if causal:
        msk = msk & (kposb[None, :] <= qpos[:, None])
    if window:
        msk = msk & (kposb[None, :] > (qpos[:, None] - window))
    return msk


def _flash_fwd_scan(qg, kb, vb, kpos, kvalid, *, causal, window, q_block,
                    q_offset, scale):
    """qg: (B, nq, qb, KV, G, hd); kb/vb: (B, nk, kb, KV, hd).
    Returns out (B,KV,G,nq,qb,hd) f32 and lse (B,KV,G,nq,qb)."""
    b, nq, qb, kv, g, hd = qg.shape

    def q_step(_, qi):
        qblk, qidx = qi
        qpos = qidx * q_block + jnp.arange(q_block) + q_offset

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kposb, kvalb = ki
            sc = jnp.einsum("bqkgh,bckh->bkgqc", qblk, kblk).astype(jnp.float32) * scale
            msk = _mask_block(qpos, kposb, kvalb, causal, window)
            sc = jnp.where(msk[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckh->bkgqh", p.astype(vblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kpos, kvalid))
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KV,G,qb,hd)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))              # (B,KV,G,qb)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(
        q_step, None, (qg.swapaxes(0, 1), jnp.arange(nq)))
    # outs: (nq,B,KV,G,qb,hd) -> (B,KV,G,nq,qb,hd); lses -> (B,KV,G,nq,qb)
    return outs.transpose(1, 2, 3, 0, 4, 5), lses.transpose(1, 2, 3, 0, 4)


@lru_cache(maxsize=None)
def _make_flash(causal: bool, window: int, q_block: int, k_block: int,
                q_offset: int):
    """Flash attention with a flash *backward* (custom VJP): only
    (q, k, v, out, lse) are saved — O(S) memory — and dq/dk/dv are
    recomputed blockwise, exactly like the FlashAttention-2 backward."""

    def fwd_impl(qg, kb, vb, kpos, kvalid):
        scale = 1.0 / math.sqrt(qg.shape[-1])
        return _flash_fwd_scan(qg, kb, vb, kpos, kvalid, causal=causal,
                               window=window, q_block=q_block,
                               q_offset=q_offset, scale=scale)

    @jax.custom_vjp
    def flash(qg, kb, vb, kpos, kvalid):
        return fwd_impl(qg, kb, vb, kpos, kvalid)[0]

    def flash_fwd(qg, kb, vb, kpos, kvalid):
        out, lse = fwd_impl(qg, kb, vb, kpos, kvalid)
        return out, (qg, kb, vb, kpos, kvalid, out, lse)

    def flash_bwd(res, dout):
        qg, kb, vb, kpos, kvalid, out, lse = res
        b, nq, qb, kv, g, hd = qg.shape
        nk, kblk_sz = kb.shape[1], kb.shape[2]
        scale = 1.0 / math.sqrt(hd)
        # D_i = rowsum(dO * O): (B,KV,G,nq,qb)
        dmat = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
        qg32 = qg.astype(jnp.float32)

        def kv_step(dq_acc, ki):
            kblk, vblk, kposb, kvalb, j = ki                   # (B,kb,KV,hd)...
            kblk32 = kblk.astype(jnp.float32)
            vblk32 = vblk.astype(jnp.float32)

            def q_step(carry, qi):
                dk_j, dv_j = carry
                qblk, do_b, lse_b, d_b, qidx = qi
                # qblk: (B,qb,KV,G,hd); do_b/(B,KV,G,qb,hd); lse_b,(B,KV,G,qb)
                qpos = qidx * q_block + jnp.arange(q_block) + q_offset
                sc = jnp.einsum("bqkgh,bckh->bkgqc", qblk.astype(jnp.float32),
                                kblk32) * scale
                msk = _mask_block(qpos, kposb, kvalb, causal, window)
                sc = jnp.where(msk[None, None, None], sc, NEG_INF)
                p = jnp.exp(sc - lse_b[..., None])             # (B,KV,G,qb,kb)
                dv_j = dv_j + jnp.einsum("bkgqc,bkgqh->bckh", p,
                                         do_b.astype(jnp.float32))
                dp = jnp.einsum("bkgqh,bckh->bkgqc",
                                do_b.astype(jnp.float32), vblk32)
                ds = p * (dp - d_b[..., None]) * scale
                dq_b = jnp.einsum("bkgqc,bckh->bqkgh", ds, kblk32)
                dk_j = dk_j + jnp.einsum("bkgqc,bqkgh->bckh", ds,
                                         qblk.astype(jnp.float32))
                return (dk_j, dv_j), dq_b

            z = jnp.zeros((b, kblk_sz, kv, hd), jnp.float32)
            (dk_j, dv_j), dq_blocks = jax.lax.scan(
                q_step, (z, z),
                (qg.swapaxes(0, 1), dout.transpose(3, 0, 1, 2, 4, 5),
                 lse.transpose(3, 0, 1, 2, 4), dmat.transpose(3, 0, 1, 2, 4),
                 jnp.arange(nq)))
            # dq_blocks: (nq, B, qb, KV, G, hd) -> accumulate
            dq_acc = dq_acc + dq_blocks.swapaxes(0, 1)
            return dq_acc, (dk_j, dv_j)

        dq0 = jnp.zeros_like(qg, dtype=jnp.float32)
        dq, (dk_blocks, dv_blocks) = jax.lax.scan(
            kv_step, dq0,
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kpos, kvalid,
             jnp.arange(nk)))
        dk = dk_blocks.swapaxes(0, 1)                          # (B,nk,kb,KV,hd)
        dv = dv_blocks.swapaxes(0, 1)
        return (dq.astype(qg.dtype), dk.astype(kb.dtype), dv.astype(vb.dtype),
                None, None)

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "k_block", "q_offset"))
def flash_attention_jnp(q, k, v, *, causal: bool = True, window: int = 0,
                        q_block: int = 512, k_block: int = 1024, q_offset: int = 0):
    """Blocked online-softmax attention with flash backward.

    q: (B, S, H, hd); k, v: (B, T, KV, hd); H % KV == 0.
    Returns (B, S, H, hd). Padding to block multiples is handled internally.
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    q_block = min(q_block, s)
    k_block = min(k_block, t)
    s_pad = (-s) % q_block
    t_pad = (-t) % k_block
    qp = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    nq, nk = (s + s_pad) // q_block, (t + t_pad) // k_block

    qg = _gqa_split(qp, kv).reshape(b, nq, q_block, kv, g, hd)
    kb = kp.reshape(b, nk, k_block, kv, hd)
    vb = vp.reshape(b, nk, k_block, kv, hd)
    kpos = (jnp.arange(nk * k_block)).reshape(nk, k_block)
    kvalid = kpos < t

    flash = _make_flash(causal, window, q_block, k_block, q_offset)
    out = flash(qg, kb, vb, kpos, kvalid)                     # (B,KV,G,nq,qb,hd)
    out = out.transpose(0, 3, 4, 1, 2, 5).reshape(b, nq * q_block, h, hd)
    return out[:, :s].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     ring: bool = False):
    """Single-step decode attention against a KV cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, T, KV, hd); cache_len: () or (B,)
    number of valid cache entries (includes the current token's KV, which the
    caller has already written). If ``ring`` the cache is a rolling buffer of
    size ``window`` (positions wrap); validity is then min(cache_len, window).
    """
    b, _, h, hd = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    qg = _gqa_split(q, kv)[:, 0]                              # (B, KV, G, hd)
    qg = qg.swapaxes(1, 1)
    sc = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    sc = sc / jnp.sqrt(hd).astype(jnp.float32)
    pos = jnp.arange(t)
    clen = jnp.asarray(cache_len)
    clen = clen.reshape(-1, *([1] * 1))                       # (B or 1, 1)
    if ring:
        valid = pos[None, :] < jnp.minimum(clen, t)
    else:
        valid = pos[None, :] < clen
        if window:
            valid = valid & (pos[None, :] >= clen - window)
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    m = sc.max(axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkh->bkgh", (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype),
                     v_cache)
    return out.reshape(b, 1, h, hd).astype(q.dtype)
