"""Core layers: norms, rotary embeddings, linear/embedding init+apply, FFNs.

Everything is functional: ``init_*`` builds a params subtree, ``apply`` style
functions are pure. Params live in nested dicts so they stack cleanly along a
leading layer axis for ``lax.scan`` over layers.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import KeyGen, lecun_init, normal_init, ones_init, zeros_init


# ----------------------------------------------------------------------
# norms
def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(dt)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mean), axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ----------------------------------------------------------------------
# linear / embedding
def init_linear(key, d_in: int, d_out: int, bias: bool = False, stddev: Optional[float] = None):
    kg = KeyGen(key)
    std = stddev if stddev is not None else 1.0 / math.sqrt(d_in)
    p = {"w": normal_init(kg(), (d_in, d_out), stddev=std)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, d: int):
    return {"table": normal_init(key, (vocab, d), stddev=0.02)}


def embed(p, tokens, dtype=jnp.float32):
    return p["table"].astype(dtype)[tokens]


# ----------------------------------------------------------------------
# rotary position embeddings
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# activations / FFN
def mish(x):
    return x * jnp.tanh(jax.nn.softplus(x))


_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "geglu": jax.nn.gelu, "mish": mish,
        "relu": jax.nn.relu, "tanh": jnp.tanh}


def init_ffn(key, d_model: int, d_ff: int, activation: str = "silu", bias: bool = False):
    """Gated FFN (llama silu-gate / gemma geglu) or plain 2-layer (gelu)."""
    kg = KeyGen(key)
    gated = activation in ("silu", "geglu")
    p = {"up": init_linear(kg(), d_model, d_ff, bias=bias),
         "down": init_linear(kg(), d_ff, d_model, bias=bias)}
    if gated:
        p["gate"] = init_linear(kg(), d_model, d_ff, bias=bias)
    return p


def ffn(p, x, activation: str = "silu"):
    act = _ACT[activation]
    if "gate" in p:
        h = act(linear(p["gate"], x)) * linear(p["up"], x)
    else:
        h = act(linear(p["up"], x))
    return linear(p["down"], h)
