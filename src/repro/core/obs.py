"""The one Eq.-6 observation path shared by simulation and serving.

Every consumer that builds the paper's 3 x (E + l) state matrix — the
episodic/fused/sharded rollout engines (`core.env`), the Pallas env-step
reference, and the real-model serving engine (`repro.serving`, which derives
an `EnvState` mirror from live pool state) — normalises through these
functions, so simulated observations and pool-derived observations are the
*same array* on matched state (tests/test_serving.py pins this).

The math is bitwise-armored: scaling uses reciprocal multiplies, not
divisions, because LLVM rewrites division by a constant into
multiply-by-reciprocal per fusion context, which would put differently
compiled engines 1 ulp apart (see `env._pin`).

Functions are duck-typed over (cfg, trace, state) so this module imports
neither `env` (which imports it) nor anything heavier than jax.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.float32(1e30)


class QueueView(NamedTuple):
    """One per-decision visible-queue top-k, threaded through the rollout so
    each decision computes it once (step + next observation share it)."""
    idx: jnp.ndarray     # (l,) i32 task ids, arrival order
    valid: jnp.ndarray   # (l,) bool slot holds a queued task
    queued: jnp.ndarray  # (K,) bool arrived & unscheduled


def server_down(trace: Dict, t) -> jnp.ndarray:
    """(E,) bool: server inside one of its trace-scheduled down intervals at
    time t. Only meaningful when the fault columns are attached
    (`repro.faults.schedule`); padded slots sit at INF so the interval test
    is vacuously false for them."""
    ds, de = trace["f_down_start"], trace["f_down_end"]
    return jnp.any((ds <= t) & (t < de), axis=-1)


def visible_queue(cfg, trace: Dict, state) -> QueueView:
    """Indices of the l earliest queued (arrived & unscheduled) tasks."""
    queued = (state.task_status == 0) & (trace["arr_time"] <= state.time)
    prio = jnp.where(queued, trace["arr_time"], INF)
    neg, idx = jax.lax.top_k(-prio, cfg.queue_window)
    valid = -neg < INF
    return QueueView(idx=idx, valid=valid, queued=queued)


def observe_from(cfg, trace: Dict, state, q: QueueView) -> jnp.ndarray:
    """Eq.-6 state matrix from an already-computed queue view.

    Scaling uses reciprocal multiplies, not divisions: LLVM rewrites
    division by a constant into multiply-by-reciprocal per fusion context,
    which would put the episodic and fused engines 1 ulp apart."""
    t = state.time
    idx, valid = q.idx, q.valid
    inv_ts = 1.0 / cfg.time_scale
    inv_nm = 1.0 / max(cfg.num_models, 1)
    up = state.server_free_at <= t
    if "f_down_start" in trace:      # fault columns attached: a down server
        up = up & ~server_down(trace, t)   # is unavailable to the policy too
    avail = up.astype(jnp.float32)
    remaining = jnp.maximum(state.server_free_at - t, 0.0) * inv_ts
    model = (state.server_model.astype(jnp.float32) + 1.0) * inv_nm
    wait = jnp.where(valid, (t - trace["arr_time"][idx]) * inv_ts, 0.0)
    c = jnp.where(valid, trace["c"][idx].astype(jnp.float32) / 8.0, 0.0)
    if cfg.num_models > 1:
        mrow = jnp.where(valid, (trace["model"][idx].astype(jnp.float32) + 1.0)
                         * inv_nm, 0.0)
    else:
        mrow = jnp.zeros_like(c)   # paper zero-pads this row
    row0 = jnp.concatenate([avail, wait])
    row1 = jnp.concatenate([remaining, c])
    row2 = jnp.concatenate([model, mrow])
    return jnp.stack([row0, row1, row2])
