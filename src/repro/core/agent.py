"""Actor/critic construction for EAT and its ablations.

Variant table (paper §VI.A.3):
    EAT     = attention encoder + diffusion policy
    EAT-A   = mlp encoder       + diffusion policy   (no attention)
    EAT-D   = attention encoder + gaussian policy    (no diffusion)
    EAT-DA  = mlp encoder       + gaussian policy    (vanilla SAC)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.pytree import KeyGen, normal_init
from repro.core import diffusion as DF
from repro.core.env import EnvConfig
from repro.core.networks import init_mlp, make_encoder, mlp_apply
from repro.models.layers import mish

VARIANTS = {
    "eat": ("attention", "diffusion"),
    "eat-a": ("mlp", "diffusion"),
    "eat-d": ("attention", "gaussian"),
    "eat-da": ("mlp", "gaussian"),
}


@dataclass(frozen=True)
class AgentConfig:
    variant: str = "eat"
    T: int = 10                   # diffusion denoising steps (Table VIII)
    hidden: int = 256
    d_attn: int = 32
    entropy_alpha: float = 0.05
    log_sigma_min: float = -5.0
    log_sigma_max: float = 1.0

    @property
    def encoder(self) -> str:
        return VARIANTS[self.variant][0]

    @property
    def policy(self) -> str:
        return VARIANTS[self.variant][1]


def init_actor(key, ecfg: EnvConfig, acfg: AgentConfig) -> Dict:
    kg = KeyGen(key)
    enc_params, _, feat_dim = make_encoder(acfg.encoder, kg(), ecfg.obs_shape,
                                           acfg.d_attn)
    a_dim = ecfg.action_dim
    p = {"enc": enc_params,
         "sigma_head": {"w": normal_init(kg(), (a_dim, a_dim), stddev=0.01),
                        "b": jnp.full((a_dim,), -2.0)}}
    if acfg.policy == "diffusion":
        p["denoiser"] = DF.init_denoiser(kg(), a_dim, feat_dim, acfg.hidden)
    else:
        p["mlp"] = init_mlp(kg(), [feat_dim, acfg.hidden, acfg.hidden, a_dim])
    return p


def _encode(params, acfg: AgentConfig, ecfg: EnvConfig, obs):
    from repro.core.networks import attention_encode, mlp_encode
    if acfg.encoder == "attention":
        return attention_encode(params["enc"], obs)
    return mlp_encode(params["enc"], obs)


def actor_mean(params, acfg: AgentConfig, ecfg: EnvConfig, sched, obs, key):
    """Action mean x_0 in [-1, 1]. obs: (..., 3, E+l)."""
    f_s = _encode(params, acfg, ecfg, obs)
    if acfg.policy == "diffusion":
        return DF.reverse_sample(params["denoiser"], sched, f_s, key,
                                 ecfg.action_dim), f_s
    return jnp.tanh(mlp_apply(params["mlp"], f_s, activation=mish)), f_s


def actor_sample(params, acfg: AgentConfig, ecfg: EnvConfig, sched, obs, key,
                 deterministic: bool = False):
    """Sample action (Eq. 13). Returns (action [-1,1], mean, log_sigma, entropy)."""
    kd, ks = jax.random.split(key)
    mean, _ = actor_mean(params, acfg, ecfg, sched, obs, kd)
    log_sigma = jnp.clip(mean @ params["sigma_head"]["w"] + params["sigma_head"]["b"],
                         acfg.log_sigma_min, acfg.log_sigma_max)
    sigma = jnp.exp(log_sigma)
    eps = jax.random.normal(ks, mean.shape)
    a = mean if deterministic else mean + sigma * eps
    a = jnp.clip(a, -1.0, 1.0)
    # Gaussian entropy (Eq. 14), no tanh correction (paper)
    entropy = 0.5 * jnp.sum(jnp.log(2 * jnp.pi * jnp.e) + 2 * log_sigma, axis=-1)
    return a, mean, log_sigma, entropy


def to_env_action(a):
    """[-1, 1] -> [0, 1] (the env's native action range)."""
    return (a + 1.0) * 0.5


# ----------------------------------------------------------------------
# critics (paper Table VII: 2 x 256 FC, Mish)
def init_critic(key, ecfg: EnvConfig, hidden: int = 256) -> Dict:
    obs_dim = ecfg.obs_shape[0] * ecfg.obs_shape[1]
    return init_mlp(key, [obs_dim + ecfg.action_dim, hidden, hidden, 1])


def critic_apply(params, obs, action):
    flat = obs.reshape(obs.shape[:-2] + (-1,))
    x = jnp.concatenate([flat, action], axis=-1)
    return mlp_apply(params, x, activation=mish)[..., 0]
