# EAT: QoS-aware edge-collaborative AIGC task scheduling (the paper's core).
from repro.core.env import EnvConfig, EnvState, reset, step, observe, episode_metrics  # noqa: F401
from repro.core.agent import AgentConfig, VARIANTS  # noqa: F401
from repro.core.sac import SACConfig, train, init_train_state  # noqa: F401
