"""Device-resident batched rollout engine (fully jitted, vmapped episodes).

The env (`env.py`) is fixed-shape and jittable; this module exploits that to
run B episodes at once: `lax.scan` over decision steps inside, `vmap` over a
batch axis of (trace, PRNG key) pairs outside, one XLA program total. Every
consumer that previously stepped the env from a host Python loop (baseline
evaluation, SAC experience collection, PPO trajectory collection, scenario
sweeps) sits on top of `batch_rollout`.

Policy protocol
---------------
    policy(params, key, trace, state, obs) -> (env_action in [0,1]^A, extras)

`params` is an arbitrary pytree threaded through jit (NOT baked into the
compiled program — actor weights can change between calls without
recompiling); `extras` is a (possibly empty) dict of per-step auxiliary
outputs (e.g. raw agent-space actions, log-probs, values) that comes back
stacked in `Transitions.extras`. The policy callable itself is a static jit
argument: build it once (the factories here cache on `EnvConfig`) and reuse
it, or every call recompiles.

Parity with the host loop: the scan splits the carried key exactly like the
host-side evaluators (`key, k_act = split(key)` per decision step) and
freezes the state once `done`, so a batched episode reproduces the host-loop
episode bit-for-bit on the same (trace, policy, key).

Fused engine (`fused=True`, the default): instead of vmapping per-episode
scans, one `lax.scan` over decision steps advances all B envs per step
through the fused decision op (`kernels/env_step`): a single Pallas kernel
launch per decision on gpu/tpu, the op-minimized jnp reference on CPU.
Bitwise-identical to the unfused path — same key splits, same freeze
semantics, same float expressions — just one queue top-k per decision and
no `argsort`/scatter ops in the hot loop.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import env as EV
from repro.kernels.env_step import ops as EK

Policy = Callable[..., Any]


class Transitions(NamedTuple):
    """Stacked per-step records; leading axes (T,) or (B, T) when batched."""
    obs: jnp.ndarray        # (..., 3, E+l) observation before the action
    action: jnp.ndarray     # (..., A) env-space action in [0, 1]
    reward: jnp.ndarray     # (...,) f32, 0 after episode end
    next_obs: jnp.ndarray   # (..., 3, E+l)
    done: jnp.ndarray       # (...,) f32 done flag after this step
    valid: jnp.ndarray      # (...,) bool, step executed before episode end
    extras: Dict[str, jnp.ndarray]


class RolloutResult(NamedTuple):
    metrics: Dict[str, jnp.ndarray]   # episode_metrics + return + length
    final_state: EV.EnvState
    transitions: Optional[Transitions]


# ----------------------------------------------------------------------
def rollout_episode(ecfg: EV.EnvConfig, trace: Dict, policy: Policy, params,
                    key, *, num_steps: Optional[int] = None,
                    collect: bool = False,
                    init_state: Optional[EV.EnvState] = None) -> RolloutResult:
    """One episode as a lax.scan (traceable; jit/vmap at the call site).

    `init_state` lets a caller resume from carried environment state (the
    streaming engine threads server loads / clock between task windows);
    None means a fresh `EV.reset`, which reproduces the episodic behaviour.
    """
    T = int(num_steps) if num_steps else ecfg.max_steps
    state0 = EV.reset(ecfg) if init_state is None else init_state
    q0, obs0 = EV.reset_view(ecfg, trace, state0)

    def body(carry, _):
        state, q, obs, k, done, total, length = carry
        k, k_act = jax.random.split(k)
        action, extras = policy(params, k_act, trace, state, obs)
        # queue threading: the step consumes this decision's queue view and
        # hands back the next one, so one decision = one top-k (the legacy
        # step + observe pair did two)
        nstate, nq, nobs, r, d, _ = EV.step_with_queue(
            ecfg, trace, state, q, action)
        # freeze the episode once done so trailing scan steps are no-ops
        nstate = jax.tree_util.tree_map(
            lambda n, o: jnp.where(done, o, n), nstate, state)
        nq = jax.tree_util.tree_map(
            lambda n, o: jnp.where(done, o, n), nq, q)
        nobs = jnp.where(done, obs, nobs)
        r = jnp.where(done, 0.0, r)
        valid = ~done
        out = (Transitions(obs=obs, action=action, reward=r, next_obs=nobs,
                           done=d.astype(jnp.float32), valid=valid,
                           extras=extras)
               if collect else None)
        carry = (nstate, nq, nobs, k, done | d, total + r,
                 length + valid.astype(jnp.int32))
        return carry, out

    carry0 = (state0, q0, obs0, key, jnp.zeros((), bool),
              jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
    (state, _, _, _, _, total, length), traj = jax.lax.scan(
        body, carry0, None, length=T)
    metrics = dict(EV.episode_metrics(ecfg, trace, state))
    metrics["episode_return"] = total
    metrics["episode_len"] = length
    return RolloutResult(metrics=metrics, final_state=state,
                         transitions=traj if collect else None)


def _bcast(flag, like):
    """Broadcast a (B,) flag against a (B, ...) leaf."""
    return flag.reshape(flag.shape + (1,) * (like.ndim - flag.ndim))


def _batch_reset(ecfg: EV.EnvConfig, B: int) -> EV.EnvState:
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (B,) + x.shape), EV.reset(ecfg))


def _batch_rollout_fused(ecfg: EV.EnvConfig, traces: Dict, policy: Policy,
                         params, keys, *, num_steps, collect, init_state,
                         impl) -> RolloutResult:
    """Scan over decision steps; each step advances all B envs through one
    fused decision op (`kernels.env_step.ops.env_step_fused`). Bitwise-equal
    to `vmap(rollout_episode)` — the per-env op sequence is identical."""
    T = int(num_steps) if num_steps else ecfg.max_steps
    B = keys.shape[0]
    state0 = _batch_reset(ecfg, B) if init_state is None else init_state
    statics = jax.vmap(lambda tr: EV.decision_statics(ecfg, tr))(traces)
    q0, obs0 = jax.vmap(
        lambda tr, st: EV.reset_view(ecfg, tr, st))(traces, state0)
    # the batch-axis policy view comes from the shared actor layer — one
    # cached vmap per (ecfg, policy) instead of a fresh closure per trace
    from repro.actors.program import actor_program
    vpolicy = actor_program(ecfg, policy).vmapped

    def body(carry, _):
        state, q, obs, ks, done, total, length = carry
        splits = jax.vmap(jax.random.split)(ks)          # (B, 2, 2)
        ks_next, k_act = splits[:, 0], splits[:, 1]
        action, extras = vpolicy(params, k_act, traces, state, obs)
        nstate, nq, nobs, r, d = EK.env_step_fused(
            ecfg, statics, state, action, q, impl=impl)
        nstate = jax.tree_util.tree_map(
            lambda n, o: jnp.where(_bcast(done, n), o, n), nstate, state)
        nq = jax.tree_util.tree_map(
            lambda n, o: jnp.where(_bcast(done, n), o, n), nq, q)
        nobs = jnp.where(_bcast(done, nobs), obs, nobs)
        r = jnp.where(done, 0.0, r)
        valid = ~done
        out = (Transitions(obs=obs, action=action, reward=r, next_obs=nobs,
                           done=d.astype(jnp.float32), valid=valid,
                           extras=extras)
               if collect else None)
        carry = (nstate, nq, nobs, ks_next, done | d, total + r,
                 length + valid.astype(jnp.int32))
        return carry, out

    carry0 = (state0, q0, obs0, keys, jnp.zeros((B,), bool),
              jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32))
    (state, _, _, _, _, total, length), traj = jax.lax.scan(
        body, carry0, None, length=T)
    metrics = dict(jax.vmap(
        lambda tr, st: EV.episode_metrics(ecfg, tr, st))(traces, state))
    metrics["episode_return"] = total
    metrics["episode_len"] = length
    if collect:   # scan stacks (T, B, ...) -> match the unfused (B, T, ...)
        traj = jax.tree_util.tree_map(lambda x: jnp.swapaxes(x, 0, 1), traj)
    return RolloutResult(metrics=metrics, final_state=state,
                         transitions=traj if collect else None)


@functools.partial(jax.jit,
                   static_argnames=("ecfg", "policy", "num_steps", "collect",
                                    "fused", "fused_impl"))
def batch_rollout(ecfg: EV.EnvConfig, traces: Dict, policy: Policy, params,
                  keys, *, num_steps: Optional[int] = None,
                  collect: bool = False,
                  init_state: Optional[EV.EnvState] = None,
                  fused: bool = True,
                  fused_impl: str = "auto") -> RolloutResult:
    """B episodes in one jitted program.

    `traces`: trace dict with a leading (B,) batch axis (see
    `workload.make_trace_batch` / `workload.stack_traces`); `keys`: (B, 2)
    PRNG keys. `params` is broadcast (shared policy weights). `init_state`,
    when given, is an `EnvState` whose leaves carry the same (B, ...) batch
    axis — each episode resumes from its own carried state. Returns a
    `RolloutResult` whose leaves all carry the (B, ...) batch axis.

    `fused=True` (default) advances all B envs per decision through the
    fused env-step op — one Pallas kernel launch per decision on gpu/tpu
    (`fused_impl="auto"`), the fused jnp reference on CPU. `fused=False` is
    the legacy vmap-of-scans engine on the compositional `env.step` path.
    Both produce bitwise-identical results on the same inputs.
    """
    if fused:
        return _batch_rollout_fused(ecfg, traces, policy, params, keys,
                                    num_steps=num_steps, collect=collect,
                                    init_state=init_state, impl=fused_impl)
    if init_state is None:
        def one(trace, key):
            return rollout_episode(ecfg, trace, policy, params, key,
                                   num_steps=num_steps, collect=collect)
        return jax.vmap(one)(traces, keys)

    def one_from(trace, key, st0):
        return rollout_episode(ecfg, trace, policy, params, key,
                               num_steps=num_steps, collect=collect,
                               init_state=st0)
    return jax.vmap(one_from)(traces, keys, init_state)


# ----------------------------------------------------------------------
# cached policy factories (the callable must stay identical across calls —
# it is a static jit argument of batch_rollout)
@functools.lru_cache(maxsize=None)
def uniform_policy(ecfg: EV.EnvConfig) -> Policy:
    """Random baseline: uniform env-space action (paper §VI.A.3 Random)."""
    def policy(params, key, trace, state, obs):
        return jax.random.uniform(key, (ecfg.action_dim,)), {}
    return policy


@functools.lru_cache(maxsize=None)
def greedy_policy(ecfg: EV.EnvConfig) -> Policy:
    """Greedy baseline: immediate quality-first candidate search."""
    from repro.core import baselines as BL
    def policy(params, key, trace, state, obs):
        return BL.greedy_act(ecfg, trace, state), {}
    return policy


@functools.lru_cache(maxsize=None)
def sequence_policy(ecfg: EV.EnvConfig) -> Policy:
    """Replay a precomputed action sequence (`params["seq"]`, (T, A) in
    env space) by decision index: step i plays seq[i] (clamped at the end).
    This is how the offline meta-heuristic schedules (genetic/harmony,
    which optimise a fixed sequence with no run-time feedback) run through
    the batched/streaming engines under the common policy protocol."""
    def policy(params, key, trace, state, obs):
        seq = params["seq"]
        idx = jnp.minimum(state.steps_taken, seq.shape[0] - 1)
        return seq[idx], {}
    return policy


@functools.lru_cache(maxsize=None)
def fifo_policy(ecfg: EV.EnvConfig, steps_frac: float = 0.5) -> Policy:
    """FIFO baseline: always try to schedule the earliest-arrived visible
    task (queue slot 0 — the visible queue is sorted by arrival) at a fixed
    inference-step fraction. When the head-of-line gang does not fit the
    idle servers, the env no-ops and time advances to the next event, so
    FIFO exhibits classic head-of-line blocking under bursts."""
    a = jnp.zeros((ecfg.action_dim,), jnp.float32)
    a = a.at[1].set(steps_frac).at[2].set(1.0)   # a_c=0 (execute), slot 0
    def policy(params, key, trace, state, obs):
        return a, {}
    return policy
