"""Scenario grids for large-scale batched evaluation sweeps.

The paper's headline tables (IX–XI) sweep cluster size {4, 8, 12} and
arrival rate; related work (arXiv 2405.08328, 2412.18212) adds multi-task /
multi-rate grids. A `Scenario` bundles the (EnvConfig, TraceConfig) pair for
one cell; `run_scenario` evaluates B traces of that cell in one jitted
program via the batched rollout engine, and `run_grid` sweeps a whole list.

`EnvConfig` is a static (shape-determining) jit argument, so scenarios batch
over traces/seeds *within* a cell and iterate cells on the host — each
distinct cluster size compiles once and is reused for every rate/trace.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.workload import (TraceConfig, make_trace, make_trace_batch,
                                 paper_rate_for)

# paper cluster configs: servers -> arrival-rate sweep (Tables IX-XI)
PAPER_RATE_GRID = {
    4: (0.01, 0.03, 0.05, 0.07, 0.09),
    8: (0.06, 0.08, 0.10, 0.12, 0.14),
    12: (0.11, 0.13, 0.15, 0.17, 0.19),
}


@dataclass(frozen=True)
class Scenario:
    name: str
    ecfg: EV.EnvConfig
    tcfg: TraceConfig
    # optional open-loop arrival process (repro.traffic.arrivals); None means
    # the paper's fixed-rate exponential from tcfg.arrival_rate
    arrival: Optional[object] = None


def _make(name: str, num_servers: int, rate: float, *, num_tasks: int = 32,
          num_models: int = 1, model_scale: Tuple[float, ...] = (),
          c_support: Tuple[int, ...] = (1, 2, 4, 8),
          c_probs: Tuple[float, ...] = (0.35, 0.35, 0.2, 0.1),
          model_probs: Tuple[float, ...] = (), arrival=None) -> Scenario:
    ecfg = EV.EnvConfig(num_servers=num_servers, max_tasks=num_tasks,
                        num_models=num_models, model_scale=model_scale)
    tcfg = TraceConfig(num_tasks=num_tasks, arrival_rate=rate,
                       max_servers=num_servers, num_models=num_models,
                       c_support=c_support, c_probs=c_probs,
                       model_probs=model_probs)
    return Scenario(name=name, ecfg=ecfg, tcfg=tcfg, arrival=arrival)


def zipf_probs(n: int, a: float = 1.5) -> Tuple[float, ...]:
    """Zipf popularity over n models: p_k proportional to 1/(k+1)^a."""
    w = np.arange(1, n + 1, dtype=np.float64) ** -float(a)
    return tuple(float(x) for x in w / w.sum())


def make_scenario_trace(key, sc: Scenario):
    """One trace for a scenario cell, honouring its arrival process."""
    if sc.arrival is None:
        return make_trace(key, sc.tcfg)
    from repro.traffic.arrivals import generate_trace
    return generate_trace(key, sc.arrival, sc.tcfg)


def make_scenario_trace_batch(key, sc: Scenario, batch: int):
    """Batch of scenario traces as one dict of (B, K) arrays."""
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda k: make_scenario_trace(k, sc))(keys)


# ----------------------------------------------------------------------
def paper_scenarios() -> List[Scenario]:
    """The three paper clusters at their §VI.A.2 arrival rates."""
    return [_make(f"paper-{e}srv", e, paper_rate_for(e)) for e in (4, 8, 12)]


def arrival_sweep(num_servers: int = 8,
                  rates: Optional[Sequence[float]] = None) -> List[Scenario]:
    """One cluster size across the paper's rate sweep (Tables IX-XI cols)."""
    rates = tuple(rates) if rates is not None else PAPER_RATE_GRID[num_servers]
    return [_make(f"rate-{num_servers}srv-{r:.2f}", num_servers, r)
            for r in rates]


def multi_model_mix(num_servers: int = 8, num_models: int = 3,
                    model_scale: Tuple[float, ...] = (1.0, 0.6, 1.4)) -> Scenario:
    """Heterogeneous AIGC services with distinct per-step costs
    (multi-task edge serving, arXiv 2405.08328)."""
    return _make(f"multimodel-{num_models}x{num_servers}srv", num_servers,
                 paper_rate_for(num_servers), num_models=num_models,
                 model_scale=model_scale[:num_models])


def cold_start_heavy(num_servers: int = 8) -> Scenario:
    """Gang-size distribution skewed to large gangs: reuse is rare, so the
    scheduler pays the ~30 s model (re)init often — stresses reload_rate."""
    return _make(f"coldstart-{num_servers}srv", num_servers,
                 paper_rate_for(num_servers),
                 c_probs=(0.05, 0.15, 0.35, 0.45))


def poisson_scenario(num_servers: int = 8,
                     rate: Optional[float] = None) -> Scenario:
    """Public baseline cell: Poisson arrivals at the paper rate (or an
    explicit override) — the reference point for the traffic cells."""
    r = paper_rate_for(num_servers) if rate is None else rate
    return _make(f"poisson-{num_servers}srv-{r:g}", num_servers, r)


def _mmpp_rates(base: float, factor: float) -> Tuple[float, float]:
    """(quiet, hot) phase rates in ratio factor^2 whose harmonic mean — the
    long-run MMPP rate under symmetric switching — equals `base`, so bursty
    cells offer the same mean load as the Poisson reference."""
    scale = (factor * factor + 1.0) / (2.0 * factor)
    return (scale * base / factor, scale * base * factor)


def bursty_traffic(num_servers: int = 8, *, burst_factor: float = 3.0,
                   switch: float = 0.05) -> Scenario:
    """Markov-modulated bursts at the paper's mean rate: quiet/hot phases a
    factor burst_factor^2 apart, calibrated so the long-run offered load
    matches the Poisson cell (arXiv 2405.08328)."""
    from repro.traffic.arrivals import MMPPArrivals
    base = paper_rate_for(num_servers)
    proc = MMPPArrivals(rates=_mmpp_rates(base, burst_factor), switch=switch)
    return _make(f"bursty-{num_servers}srv", num_servers, base, arrival=proc)


def diurnal_traffic(num_servers: int = 8, *, amplitude: float = 0.6,
                    period: float = 2000.0) -> Scenario:
    """Sinusoidal day/night demand around the paper rate (time-varying
    workloads, arXiv 2411.01458)."""
    from repro.traffic.arrivals import DiurnalArrivals
    base = paper_rate_for(num_servers)
    proc = DiurnalArrivals(base_rate=base, amplitude=amplitude, period=period)
    return _make(f"diurnal-{num_servers}srv", num_servers, base, arrival=proc)


def flash_crowd(num_servers: int = 8, *, spike_factor: float = 8.0,
                period: float = 2000.0, spike_duration: float = 200.0) -> Scenario:
    """Baseline load with periodic flash-crowd spikes (viral AIGC events)."""
    from repro.traffic.arrivals import FlashCrowdArrivals
    base = paper_rate_for(num_servers)
    proc = FlashCrowdArrivals(base_rate=base, spike_rate=base * spike_factor,
                              period=period, spike_duration=spike_duration)
    return _make(f"flashcrowd-{num_servers}srv", num_servers, base,
                 arrival=proc)


def model_skew(num_servers: int = 8, num_models: int = 3, *,
               zipf_a: float = 1.5,
               model_scale: Tuple[float, ...] = (1.0, 0.6, 1.4)) -> Scenario:
    """Zipf-skewed model popularity at the paper rate: a few hot services
    dominate demand, so proactive placement (repro.placement) has a stable
    signal to exploit (ISSUE 9 satellite)."""
    return _make(f"modelskew-{num_models}x{num_servers}srv", num_servers,
                 paper_rate_for(num_servers), num_models=num_models,
                 model_scale=model_scale[:num_models],
                 model_probs=zipf_probs(num_models, zipf_a))


def model_skew_flashcrowd(num_servers: int = 8, num_models: int = 3, *,
                          zipf_a: float = 1.5, spike_factor: float = 8.0,
                          period: float = 2000.0,
                          spike_duration: float = 200.0) -> Scenario:
    """Zipf popularity under flash-crowd arrival spikes — the placement
    benchmark's skewed cell (`BENCH_placement.json`): reactive loading
    degenerates into cold-start storms at every spike, a demand-following
    layout mostly rides them out."""
    from repro.traffic.arrivals import FlashCrowdArrivals
    base = paper_rate_for(num_servers)
    proc = FlashCrowdArrivals(base_rate=base, spike_rate=base * spike_factor,
                              period=period, spike_duration=spike_duration)
    return _make(f"modelskew-flashcrowd-{num_models}x{num_servers}srv",
                 num_servers, base, num_models=num_models,
                 model_probs=zipf_probs(num_models, zipf_a), arrival=proc)


def model_shift_cells(num_servers: int = 8, num_models: int = 3, *,
                      zipf_a: float = 1.5, spike_factor: float = 8.0):
    """Time-shifting popularity as a curriculum cell pair sharing one ecfg:
    a Zipf-skewed base cell, then a flash crowd whose popularity is the
    REVERSED Zipf — the crowd lands on the previously-coldest model.
    Cycle them through `CurriculumTaskSource.set_cell` on one continuous
    clock (`benchmarks/bench_placement.py` does) to test whether a
    placement policy re-warms fast enough."""
    from repro.traffic.arrivals import FlashCrowdArrivals, PoissonArrivals
    base = paper_rate_for(num_servers)
    probs = zipf_probs(num_models, zipf_a)
    hot = _make(f"modelshift-base-{num_models}x{num_servers}srv",
                num_servers, base, num_models=num_models, model_probs=probs,
                arrival=PoissonArrivals(base))
    cold = _make(f"modelshift-crowd-{num_models}x{num_servers}srv",
                 num_servers, base, num_models=num_models,
                 model_probs=tuple(reversed(probs)),
                 arrival=FlashCrowdArrivals(base_rate=base,
                                            spike_rate=base * spike_factor))
    return [hot, cold]


def traffic_grid(num_servers: int = 8) -> List[Scenario]:
    """Arrival-process cells for streaming sweeps (poisson baseline via
    paper_scenarios / arrival_sweep; these add the non-stationary ones)."""
    return [bursty_traffic(num_servers), diurnal_traffic(num_servers),
            flash_crowd(num_servers)]


def default_grid() -> List[Scenario]:
    return (paper_scenarios() + arrival_sweep(8)
            + [multi_model_mix(), cold_start_heavy()] + traffic_grid(8))


# ----------------------------------------------------------------------
def training_curriculum(ecfg: EV.EnvConfig, *,
                        rates: Optional[Sequence[float]] = None,
                        include_arrival_processes: bool = True) -> List[Scenario]:
    """Scenario cells for curriculum training (ROADMAP item): every cell
    shares `ecfg` (so one compiled rollout program serves them all) and
    varies the workload — arrival rate sweep, cold-start-heavy gang mix,
    and the non-stationary arrival processes. `sac.train` / `ppo.train_ppo`
    sample one cell per collection round when given `curriculum=`."""
    from repro.traffic.arrivals import FlashCrowdArrivals, MMPPArrivals
    base = paper_rate_for(ecfg.num_servers)
    rates = tuple(rates) if rates is not None else (0.5 * base, base,
                                                    1.5 * base)

    def tc(rate, **kw):
        return TraceConfig(num_tasks=ecfg.max_tasks, arrival_rate=rate,
                           max_servers=ecfg.num_servers,
                           num_models=ecfg.num_models, **kw)

    cells = [Scenario(name=f"rate-{r:.3f}", ecfg=ecfg, tcfg=tc(r))
             for r in rates]
    cells.append(Scenario(name="coldstart", ecfg=ecfg,
                          tcfg=tc(base, c_probs=(0.05, 0.15, 0.35, 0.45))))
    if include_arrival_processes:
        cells.append(Scenario(
            name="bursty", ecfg=ecfg, tcfg=tc(base),
            arrival=MMPPArrivals(rates=_mmpp_rates(base, 3.0))))
        cells.append(Scenario(
            name="flashcrowd", ecfg=ecfg, tcfg=tc(base),
            arrival=FlashCrowdArrivals(base_rate=base,
                                       spike_rate=base * 8.0)))
    if ecfg.num_models > 1:
        # model-skew cells (ISSUE 9): Zipf-skewed popularity, plus a flash
        # crowd whose popularity is the reversed Zipf — the crowd lands on
        # the previously-coldest model, so the agent (and any placement
        # policy riding along) trains against shifting popularity too
        probs = zipf_probs(ecfg.num_models)
        cells.append(Scenario(name="modelskew", ecfg=ecfg,
                              tcfg=tc(base, model_probs=probs)))
        if include_arrival_processes:
            cells.append(Scenario(
                name="modelshift", ecfg=ecfg,
                tcfg=tc(base, model_probs=tuple(reversed(probs))),
                arrival=FlashCrowdArrivals(base_rate=base,
                                           spike_rate=base * 8.0)))
    return cells


def curriculum_picker(ecfg: EV.EnvConfig, curriculum: Sequence[Scenario]):
    """Validate a scenario curriculum against the training env and return
    pick(rng) -> (cell name, trace_fn). Every cell must share the training
    ecfg so one compiled rollout program serves them all."""
    for sc in curriculum:
        if sc.ecfg != ecfg:
            raise ValueError(
                f"curriculum cell {sc.name!r} has a different EnvConfig than "
                "the training env; build cells with "
                "scenarios.training_curriculum(ecfg)")

    def pick(rng):
        sc = curriculum[int(rng.integers(len(curriculum)))]
        return sc.name, (lambda k: make_scenario_trace(k, sc))
    return pick


# ----------------------------------------------------------------------
def run_scenario(scenario: Scenario, policy, key, *, batch: int = 32,
                 params=None, num_steps: Optional[int] = None) -> Dict:
    """B fresh traces of one scenario through one jitted batched rollout.
    Returns per-episode (B,) arrays plus scalar mean_* summaries."""
    k_trace, k_run = jax.random.split(key)
    if scenario.arrival is None:
        traces = make_trace_batch(k_trace, scenario.tcfg, batch)
    else:
        traces = make_scenario_trace_batch(k_trace, scenario, batch)
    keys = jax.random.split(k_run, batch)
    res = RO.batch_rollout(scenario.ecfg, traces, policy,
                           {} if params is None else params, keys,
                           num_steps=num_steps)
    out: Dict = {k: np.asarray(v) for k, v in res.metrics.items()}
    out.update({f"mean_{k}": float(np.mean(v)) for k, v in out.items()})
    out["scenario"] = scenario.name
    out["batch"] = batch
    return out


def run_grid(scenarios: Sequence[Scenario], policy_fn, key, *,
             batch: int = 32, params=None, verbose: bool = False) -> List[Dict]:
    """Sweep a scenario list. `policy_fn(ecfg)` -> rollout policy (e.g.
    `rollout.uniform_policy` / `rollout.greedy_policy`), so each cluster
    shape gets its own (cached) policy closure."""
    results = []
    for sc in scenarios:
        key, k = jax.random.split(key)
        m = run_scenario(sc, policy_fn(sc.ecfg), k, batch=batch, params=params)
        results.append(m)
        if verbose:
            print(f"[{sc.name:24s}] q={m['mean_avg_quality']:.3f} "
                  f"resp={m['mean_avg_response']:7.1f} "
                  f"reload={m['mean_reload_rate']:.3f} "
                  f"R={m['mean_episode_return']:7.1f}", flush=True)
    return results
