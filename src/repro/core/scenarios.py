"""Scenario grids for large-scale batched evaluation sweeps.

The paper's headline tables (IX–XI) sweep cluster size {4, 8, 12} and
arrival rate; related work (arXiv 2405.08328, 2412.18212) adds multi-task /
multi-rate grids. A `Scenario` bundles the (EnvConfig, TraceConfig) pair for
one cell; `run_scenario` evaluates B traces of that cell in one jitted
program via the batched rollout engine, and `run_grid` sweeps a whole list.

`EnvConfig` is a static (shape-determining) jit argument, so scenarios batch
over traces/seeds *within* a cell and iterate cells on the host — each
distinct cluster size compiles once and is reused for every rate/trace.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.workload import TraceConfig, make_trace_batch, paper_rate_for

# paper cluster configs: servers -> arrival-rate sweep (Tables IX-XI)
PAPER_RATE_GRID = {
    4: (0.01, 0.03, 0.05, 0.07, 0.09),
    8: (0.06, 0.08, 0.10, 0.12, 0.14),
    12: (0.11, 0.13, 0.15, 0.17, 0.19),
}


@dataclass(frozen=True)
class Scenario:
    name: str
    ecfg: EV.EnvConfig
    tcfg: TraceConfig


def _make(name: str, num_servers: int, rate: float, *, num_tasks: int = 32,
          num_models: int = 1, model_scale: Tuple[float, ...] = (),
          c_support: Tuple[int, ...] = (1, 2, 4, 8),
          c_probs: Tuple[float, ...] = (0.35, 0.35, 0.2, 0.1)) -> Scenario:
    ecfg = EV.EnvConfig(num_servers=num_servers, max_tasks=num_tasks,
                        num_models=num_models, model_scale=model_scale)
    tcfg = TraceConfig(num_tasks=num_tasks, arrival_rate=rate,
                       max_servers=num_servers, num_models=num_models,
                       c_support=c_support, c_probs=c_probs)
    return Scenario(name=name, ecfg=ecfg, tcfg=tcfg)


# ----------------------------------------------------------------------
def paper_scenarios() -> List[Scenario]:
    """The three paper clusters at their §VI.A.2 arrival rates."""
    return [_make(f"paper-{e}srv", e, paper_rate_for(e)) for e in (4, 8, 12)]


def arrival_sweep(num_servers: int = 8,
                  rates: Optional[Sequence[float]] = None) -> List[Scenario]:
    """One cluster size across the paper's rate sweep (Tables IX-XI cols)."""
    rates = tuple(rates) if rates is not None else PAPER_RATE_GRID[num_servers]
    return [_make(f"rate-{num_servers}srv-{r:.2f}", num_servers, r)
            for r in rates]


def multi_model_mix(num_servers: int = 8, num_models: int = 3,
                    model_scale: Tuple[float, ...] = (1.0, 0.6, 1.4)) -> Scenario:
    """Heterogeneous AIGC services with distinct per-step costs
    (multi-task edge serving, arXiv 2405.08328)."""
    return _make(f"multimodel-{num_models}x{num_servers}srv", num_servers,
                 paper_rate_for(num_servers), num_models=num_models,
                 model_scale=model_scale[:num_models])


def cold_start_heavy(num_servers: int = 8) -> Scenario:
    """Gang-size distribution skewed to large gangs: reuse is rare, so the
    scheduler pays the ~30 s model (re)init often — stresses reload_rate."""
    return _make(f"coldstart-{num_servers}srv", num_servers,
                 paper_rate_for(num_servers),
                 c_probs=(0.05, 0.15, 0.35, 0.45))


def default_grid() -> List[Scenario]:
    return (paper_scenarios() + arrival_sweep(8)
            + [multi_model_mix(), cold_start_heavy()])


# ----------------------------------------------------------------------
def run_scenario(scenario: Scenario, policy, key, *, batch: int = 32,
                 params=None, num_steps: Optional[int] = None) -> Dict:
    """B fresh traces of one scenario through one jitted batched rollout.
    Returns per-episode (B,) arrays plus scalar mean_* summaries."""
    k_trace, k_run = jax.random.split(key)
    traces = make_trace_batch(k_trace, scenario.tcfg, batch)
    keys = jax.random.split(k_run, batch)
    res = RO.batch_rollout(scenario.ecfg, traces, policy,
                           {} if params is None else params, keys,
                           num_steps=num_steps)
    out: Dict = {k: np.asarray(v) for k, v in res.metrics.items()}
    out.update({f"mean_{k}": float(np.mean(v)) for k, v in out.items()})
    out["scenario"] = scenario.name
    out["batch"] = batch
    return out


def run_grid(scenarios: Sequence[Scenario], policy_fn, key, *,
             batch: int = 32, params=None, verbose: bool = False) -> List[Dict]:
    """Sweep a scenario list. `policy_fn(ecfg)` -> rollout policy (e.g.
    `rollout.uniform_policy` / `rollout.greedy_policy`), so each cluster
    shape gets its own (cached) policy closure."""
    results = []
    for sc in scenarios:
        key, k = jax.random.split(key)
        m = run_scenario(sc, policy_fn(sc.ecfg), k, batch=batch, params=params)
        results.append(m)
        if verbose:
            print(f"[{sc.name:24s}] q={m['mean_avg_quality']:.3f} "
                  f"resp={m['mean_avg_response']:7.1f} "
                  f"reload={m['mean_reload_rate']:.3f} "
                  f"R={m['mean_episode_return']:7.1f}", flush=True)
    return results
