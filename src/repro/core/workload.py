"""AIGC task workload generation (paper §IV.A.1).

Tasks exhibit dual randomness: the collaboration requirement c_k ~ D_c over
{1, 2, 4, 8} and the generation interval t^g_k ~ D_g (exponential with the
paper's per-cluster arrival rates: 0.05 / 0.1 / 0.15 for 4 / 8 / 12 servers).
A trace is a dict of fixed-size arrays so the environment stays jittable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TraceConfig:
    num_tasks: int = 32
    arrival_rate: float = 0.1            # tasks / second (lambda of D_g)
    c_support: Tuple[int, ...] = (1, 2, 4, 8)
    c_probs: Tuple[float, ...] = (0.35, 0.35, 0.2, 0.1)
    num_models: int = 1                  # distinct AIGC services (arch ids)
    max_servers: int = 8                 # c_k is clipped to the cluster size
    quality_noise: float = 0.004         # per-task CLIP-score jitter
    # per-model popularity; () keeps the historical uniform draw (and its
    # exact PRNG path — existing configs stay bitwise-identical). Shorter
    # tuples pad with zero, longer ones truncate; renormalised either way.
    model_probs: Tuple[float, ...] = ()


def _sample_attrs(k_c, k_model, k_noise, tc: TraceConfig, n: int):
    """(c, model, noise) arrays of length n from the TraceConfig marginals."""
    support = jnp.asarray(tc.c_support, jnp.int32)
    probs = jnp.asarray(tc.c_probs, jnp.float32)
    # renormalise after clipping support to the cluster size
    ok = support <= tc.max_servers
    probs = jnp.where(ok, probs, 0.0)
    probs = probs / probs.sum()
    idx = jax.random.categorical(k_c, jnp.log(probs + 1e-30), shape=(n,))
    c = support[idx]
    if tc.model_probs:
        mp = jnp.zeros((tc.num_models,), jnp.float32).at[
            :min(len(tc.model_probs), tc.num_models)].set(
            jnp.asarray(tc.model_probs[:tc.num_models], jnp.float32))
        model = jax.random.categorical(k_model, jnp.log(mp / mp.sum() + 1e-30),
                                       shape=(n,))
    else:
        model = jax.random.randint(k_model, (n,), 0, tc.num_models)
    noise = tc.quality_noise * jax.random.normal(k_noise, (n,))
    return c, model.astype(jnp.int32), noise.astype(jnp.float32)


def sample_task_attrs(key, tc: TraceConfig, n: int):
    """Chunked attribute generation for streaming traffic: (c, model, noise)
    for n tasks whose arrival times come from an external arrival process."""
    k_c, k_model, k_noise = jax.random.split(key, 3)
    return _sample_attrs(k_c, k_model, k_noise, tc, n)


def make_trace_from_arrivals(key, arr_times, tc: TraceConfig):
    """Trace dict for externally supplied (absolute) arrival times."""
    n = arr_times.shape[0]
    c, model, noise = sample_task_attrs(key, tc, n)
    return {"arr_time": jnp.asarray(arr_times, jnp.float32), "c": c,
            "model": model, "noise": noise}


def make_trace(key, tc: TraceConfig):
    """Returns dict of (K,) arrays: arr_time, c, model, noise."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    gaps = jax.random.exponential(k1, (tc.num_tasks,)) / tc.arrival_rate
    arr = jnp.cumsum(gaps)
    c, model, noise = _sample_attrs(k2, k3, k4, tc, tc.num_tasks)
    return {"arr_time": arr.astype(jnp.float32), "c": c,
            "model": model, "noise": noise}


def make_trace_batch(key, tc: TraceConfig, batch: int):
    """Batch of traces as one dict of (B, K) arrays (for batch_rollout)."""
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda k: make_trace(k, tc))(keys)


def stack_traces(traces):
    """Stack a list of trace dicts along a new leading batch axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *traces)


def paper_rate_for(num_servers: int) -> float:
    """Arrival rates used in the paper's experiments (§VI.A.2)."""
    return {4: 0.05, 8: 0.1, 12: 0.15}.get(num_servers, 0.0125 * num_servers)
