"""PPO baseline (paper §VI.A.3, hyper-parameters from Table VIII).

On-policy clipped-surrogate PPO with GAE; Gaussian MLP actor (mean = tanh
MLP over the flattened state, learned state-independent log-sigma) and an
MLP value head — the standard 256x256 architecture the paper compares with.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agent as AG
from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.networks import init_mlp, mlp_apply
from repro.core.workload import stack_traces
from repro.models.layers import mish
from repro.training.optimizer import (AdamState, adam_init, adam_update,
                                      apply_updates, clip_by_global_norm)


@dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    gamma: float = 0.95
    gae_lambda: float = 0.95      # lambda_G
    clip_eps: float = 0.2         # epsilon
    value_coef: float = 0.5       # nu
    entropy_coef: float = 0.01    # beta
    max_grad_norm: float = 0.5    # g
    rollout_len: int = 1024
    minibatches: int = 8
    epochs: int = 4


class PPOState(NamedTuple):
    params: Any
    opt: AdamState
    step: jnp.ndarray


def init_ppo(key, ecfg: EV.EnvConfig) -> PPOState:
    k1, k2, k3 = jax.random.split(key, 3)
    obs_dim = ecfg.obs_shape[0] * ecfg.obs_shape[1]
    params = {
        "actor": init_mlp(k1, [obs_dim, 256, 256, ecfg.action_dim]),
        "log_sigma": jnp.full((ecfg.action_dim,), -0.5),
        "value": init_mlp(k2, [obs_dim, 256, 256, 1]),
    }
    return PPOState(params=params, opt=adam_init(params), step=jnp.zeros((), jnp.int32))


def _dist(params, obs):
    flat = obs.reshape(obs.shape[:-2] + (-1,))
    mean = jnp.tanh(mlp_apply(params["actor"], flat, activation=mish))
    return mean, params["log_sigma"]


def _logp(mean, log_sigma, a):
    var = jnp.exp(2 * log_sigma)
    return jnp.sum(-0.5 * jnp.square(a - mean) / var - log_sigma
                   - 0.5 * jnp.log(2 * jnp.pi), axis=-1)


def value_of(params, obs):
    flat = obs.reshape(obs.shape[:-2] + (-1,))
    return mlp_apply(params["value"], flat, activation=mish)[..., 0]


@functools.partial(jax.jit, static_argnames=("ecfg",))
def ppo_act(params, obs, key, *, ecfg: EV.EnvConfig):
    mean, log_sigma = _dist(params, obs)
    a = mean + jnp.exp(log_sigma) * jax.random.normal(key, mean.shape)
    a = jnp.clip(a, -1.0, 1.0)
    return a, _logp(mean, log_sigma, a), value_of(params, obs)


@functools.lru_cache(maxsize=None)
def ppo_policy(ecfg: EV.EnvConfig):
    """Gaussian-MLP actor as a batch_rollout policy (logp/value in extras)."""
    def policy(params, key, trace, state, obs):
        mean, log_sigma = _dist(params, obs)
        a = mean + jnp.exp(log_sigma) * jax.random.normal(key, mean.shape)
        a = jnp.clip(a, -1.0, 1.0)
        return AG.to_env_action(a), {"agent_action": a,
                                     "logp": _logp(mean, log_sigma, a),
                                     "value": value_of(params, obs)}
    return policy


def compute_gae(rewards, values, dones, last_value, gamma, lam):
    """numpy GAE over a rollout."""
    T = len(rewards)
    adv = np.zeros(T, np.float32)
    last = 0.0
    next_v = last_value
    for t in reversed(range(T)):
        nonterm = 1.0 - dones[t]
        delta = rewards[t] + gamma * next_v * nonterm - values[t]
        last = delta + gamma * lam * nonterm * last
        adv[t] = last
        next_v = values[t]
    return adv, adv + values


def pool_gae(tr, pcfg: PPOConfig, last_values=None) -> Dict[str, np.ndarray]:
    """Per-episode GAE over the valid prefix of stacked (B, T, ...)
    transitions, pooled into one flat update batch.

    `last_values` ((B,) array or None) bootstraps each row past its last
    valid step. None = episodic semantics: the row ran to termination and
    the env's final done flag zeroes any bootstrap. Given = streaming
    semantics: the caller asserts the row ended at a window seam, which is
    a truncation, not a terminal state — the final step's done flag is
    overridden so the critic's value of the final `next_obs` actually
    bootstraps (the env raises done when the window drains or hits its
    step/time budget, but the stream, its backlog, and its server
    occupancy continue into the next window).
    """
    valid = np.asarray(tr.valid)
    B = valid.shape[0]
    lens = valid.sum(axis=1)
    chunks = {k: [] for k in ("obs", "action", "logp", "adv", "ret")}
    for b in range(B):
        L = int(lens[b])
        if L == 0:
            continue
        last_v = 0.0 if last_values is None else float(last_values[b])
        dones = np.asarray(tr.done[b, :L])
        if last_values is not None:
            dones = dones.copy()
            dones[-1] = 0.0            # seam = truncation, keep the bootstrap
        adv, ret = compute_gae(np.asarray(tr.reward[b, :L]),
                               np.asarray(tr.extras["value"][b, :L]),
                               dones, last_v,
                               pcfg.gamma, pcfg.gae_lambda)
        chunks["obs"].append(np.asarray(tr.obs[b, :L]))
        chunks["action"].append(np.asarray(tr.extras["agent_action"][b, :L]))
        chunks["logp"].append(np.asarray(tr.extras["logp"][b, :L]))
        chunks["adv"].append(adv)
        chunks["ret"].append(ret)
    if not chunks["adv"]:
        empty = {"obs": tr.obs, "action": tr.extras["agent_action"],
                 "logp": tr.extras["logp"]}
        return {k: np.zeros((0,) + np.asarray(v).shape[2:], np.float32)
                for k, v in {**empty, "adv": tr.reward,
                             "ret": tr.reward}.items()}
    return {k: np.concatenate(v).astype(np.float32)
            for k, v in chunks.items()}


def run_ppo_epochs(st: PPOState, data: Dict[str, np.ndarray], rng,
                   ecfg: EV.EnvConfig, pcfg: PPOConfig,
                   max_updates: Optional[int] = None
                   ) -> Tuple[PPOState, int]:
    """Clipped-surrogate epochs over one pooled batch (shared by the
    episodic and streaming trainers); `max_updates` caps the minibatch
    gradient steps. Returns (state, updates actually run)."""
    n = len(data["adv"])
    done = 0
    if n == 0:
        return st, 0
    for _ in range(pcfg.epochs):
        perm = rng.permutation(n)
        mb = max(1, n // pcfg.minibatches)
        for i in range(0, n, mb):
            if max_updates is not None and done >= max_updates:
                return st, done
            idx = perm[i:i + mb]
            batch = {k: jnp.asarray(v[idx]) for k, v in data.items()}
            st, _ = ppo_update(st, batch, ecfg=ecfg, pcfg=pcfg)
            done += 1
    return st, done


@functools.partial(jax.jit, static_argnames=("ecfg", "pcfg"))
def ppo_update(st: PPOState, batch: Dict, *, ecfg: EV.EnvConfig, pcfg: PPOConfig):
    def loss_fn(params):
        mean, log_sigma = _dist(params, batch["obs"])
        logp = _logp(mean, log_sigma, batch["action"])
        ratio = jnp.exp(logp - batch["logp"])
        adv = batch["adv"]
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
        surr = jnp.minimum(ratio * adv,
                           jnp.clip(ratio, 1 - pcfg.clip_eps, 1 + pcfg.clip_eps) * adv)
        v = value_of(params, batch["obs"])
        v_loss = jnp.mean(jnp.square(batch["ret"] - v))
        ent = jnp.sum(log_sigma + 0.5 * jnp.log(2 * jnp.pi * jnp.e))
        loss = -jnp.mean(surr) + pcfg.value_coef * v_loss - pcfg.entropy_coef * ent
        return loss, (v_loss, jnp.mean(ratio))

    (loss, (vl, ratio)), grads = jax.value_and_grad(loss_fn, has_aux=True)(st.params)
    grads, gnorm = clip_by_global_norm(grads, pcfg.max_grad_norm)
    upd, opt = adam_update(grads, st.opt, st.params, pcfg.lr)
    params = apply_updates(st.params, upd)
    return PPOState(params=params, opt=opt, step=st.step + 1), \
        {"loss": loss, "value_loss": vl, "ratio": ratio, "grad_norm": gnorm}


def train_ppo(ecfg: EV.EnvConfig, pcfg: PPOConfig, trace_fn, num_episodes: int,
              seed: int = 0, log_every: int = 10, num_envs: int = 4,
              curriculum=None, exec_spec=None):
    """On-policy training on top of the batched rollout engine: each
    iteration collects `num_envs` full episodes in one jitted program, then
    runs clipped-surrogate epochs over the pooled (valid) transitions with
    per-episode GAE. `curriculum` (list of `scenarios.Scenario` sharing
    `ecfg`) replaces `trace_fn` with per-round sampling from the grid.
    `exec_spec` (an `api.ExecSpec`) picks the collection execution backend
    (reference / fused / sharded, all bitwise-identical)."""
    from repro.api.backends import rollout_fn_for
    from repro.api.specs import ExecSpec
    from repro.core.sac import host_rng
    rollout = rollout_fn_for(exec_spec or ExecSpec())
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    st = init_ppo(k0, ecfg)
    history = []
    rng = host_rng(key)
    if curriculum:
        from repro.core.scenarios import curriculum_picker
        pick = curriculum_picker(ecfg, curriculum)
    else:
        pick = None

    ep = 0
    while ep < num_episodes:
        B = min(num_envs, num_episodes - ep)
        key, kt, ke = jax.random.split(key, 3)
        round_trace_fn = pick(rng)[1] if pick else trace_fn
        traces = stack_traces([round_trace_fn(k)
                               for k in jax.random.split(kt, B)])
        keys = jax.random.split(ke, B)
        res = rollout(ecfg, traces, ppo_policy(ecfg), st.params,
                      keys, collect=True)
        tr = res.transitions
        lens = np.asarray(tr.valid).sum(axis=1)
        # -- per-episode GAE over the valid prefix, pooled into one batch
        data = pool_gae(tr, pcfg)
        st, _ = run_ppo_epochs(st, data, rng, ecfg, pcfg)
        for b in range(B):
            em = {k: float(v[b]) for k, v in res.metrics.items()}
            em.update(episode=ep, episode_len=int(lens[b]))
            history.append(em)
            if log_every and ep % log_every == 0:
                print(f"[ppo ep {ep:4d}] R={em['episode_return']:8.2f} "
                      f"len={em['episode_len']:4d} "
                      f"resp={em['avg_response']:7.2f} "
                      f"q={em['avg_quality']:.3f}")
            ep += 1
    return st, history
