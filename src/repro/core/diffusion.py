"""Diffusion-based policy (paper §V.B.2, Eqs. 10–13).

A T-step DDPM over the action vector, conditioned on the state feature f_s.
The denoiser eps_theta(x_i, i, f_s) is a Mish MLP (256x256) with a
16-dim sinusoidal timestep embedding (paper Table VII). The reverse chain
produces the action mean x_0 (tanh-bounded to [-1, 1]); a linear head on x_0
produces a per-dimension variance, and the final action is sampled from
N(x_0, sigma^2) (Eq. 13) — the SAC head.

Deviation noted in DESIGN.md: the paper's Eq. 11 references alpha-bar_0 and a
tanh on eps; we run the standard DDPM posterior (their Eq. 10/12) and apply
the tanh bound to the chain output, which realises the same bounded-action
intent with well-defined quantities.

The noise schedule follows the VP-SDE discretisation used by D2SAC
(beta_i = 1 - exp(-bmin/T - (bmax-bmin)(2i-1)/(2T^2))).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.common.pytree import KeyGen
from repro.core.networks import init_mlp, mlp_apply
from repro.models.layers import mish


class DiffusionSchedule(NamedTuple):
    betas: jnp.ndarray         # (T,)
    alphas: jnp.ndarray        # (T,)
    alpha_bars: jnp.ndarray    # (T,)


def vp_schedule(T: int, beta_min: float = 0.1, beta_max: float = 10.0) -> DiffusionSchedule:
    i = jnp.arange(1, T + 1, dtype=jnp.float32)
    betas = 1.0 - jnp.exp(-beta_min / T - 0.5 * (beta_max - beta_min)
                          * (2 * i - 1) / T ** 2)
    alphas = 1.0 - betas
    return DiffusionSchedule(betas=betas, alphas=alphas,
                             alpha_bars=jnp.cumprod(alphas))


def timestep_embedding(i, dim: int = 16):
    """i: (...,) int -> (..., dim) sinusoidal embedding."""
    half = dim // 2
    freqs = jnp.exp(-math.log(1000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = i[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_denoiser(key, action_dim: int, feat_dim: int, hidden: int = 256,
                  t_dim: int = 16) -> Dict:
    return init_mlp(key, [action_dim + t_dim + feat_dim, hidden, hidden, action_dim])


def denoise_eps(p: Dict, x, i, f_s, t_dim: int = 16):
    """eps_theta(x_i, i, f_s). x: (..., A); i: (...,); f_s: (..., F)."""
    temb = timestep_embedding(i, t_dim)
    inp = jnp.concatenate([x, temb, f_s], axis=-1)
    return mlp_apply(p, inp, activation=mish, final_activation=jnp.tanh)


def reverse_sample(p: Dict, sched: DiffusionSchedule, f_s, key,
                   action_dim: int):
    """Run the reverse chain x_T -> x_0 (Alg. 1 lines 5-11), differentiable
    w.r.t. p (reparameterised noise). f_s: (..., F). Returns x_0 in [-1,1]."""
    T = sched.betas.shape[0]
    batch_shape = f_s.shape[:-1]
    kx, kn = jax.random.split(key)
    x = jax.random.normal(kx, batch_shape + (action_dim,))
    noises = jax.random.normal(kn, (T,) + batch_shape + (action_dim,))

    def body(step, x):
        i = T - 1 - step                       # i = T-1 .. 0 (0-indexed)
        beta = sched.betas[i]
        alpha = sched.alphas[i]
        abar = sched.alpha_bars[i]
        abar_prev = jnp.where(i > 0, sched.alpha_bars[jnp.maximum(i - 1, 0)], 1.0)
        eps = denoise_eps(p, x, jnp.full(batch_shape, i + 1), f_s)
        mean = (x - beta / jnp.sqrt(1.0 - abar) * eps) / jnp.sqrt(alpha)   # Eq. 12
        var = beta * (1.0 - abar_prev) / (1.0 - abar)                      # Eq. 10
        noise = jnp.where(i > 0, noises[step], 0.0)
        return mean + jnp.sqrt(jnp.maximum(var, 1e-12)) * noise

    x0 = jax.lax.fori_loop(0, T, body, x, unroll=True)
    return jnp.tanh(x0)


def bc_loss(p: Dict, sched: DiffusionSchedule, f_s, actions, key):
    """Behaviour-cloning denoising loss (optional regulariser, Diffusion-QL
    style): predict the noise added to real actions."""
    T = sched.betas.shape[0]
    b = actions.shape[:-1]
    ki, kn = jax.random.split(key)
    i = jax.random.randint(ki, b, 0, T)
    abar = sched.alpha_bars[i][..., None]
    noise = jax.random.normal(kn, actions.shape)
    x_i = jnp.sqrt(abar) * actions + jnp.sqrt(1 - abar) * noise
    eps = denoise_eps(p, x_i, i + 1, f_s)
    return jnp.mean(jnp.square(eps - noise))
