"""Experience replay buffer (numpy ring buffer, host-side)."""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, obs_shape: Tuple[int, int], action_dim: int):
        self.capacity = capacity
        self.obs = np.zeros((capacity,) + obs_shape, np.float32)
        self.action = np.zeros((capacity, action_dim), np.float32)
        self.reward = np.zeros((capacity,), np.float32)
        self.next_obs = np.zeros((capacity,) + obs_shape, np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.size = 0
        self.ptr = 0

    def add(self, obs, action, reward, next_obs, done):
        i = self.ptr
        self.obs[i] = obs
        self.action[i] = action
        self.reward[i] = reward
        self.next_obs[i] = next_obs
        self.done[i] = float(done)
        self.ptr = (i + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def add_batch(self, obs, action, reward, next_obs, done):
        """Vectorized ring insertion of n transitions (one numpy scatter)."""
        n = len(reward)
        if n == 0:
            return
        idx = (self.ptr + np.arange(n)) % self.capacity
        self.obs[idx] = obs
        self.action[idx] = action
        self.reward[idx] = reward
        self.next_obs[idx] = next_obs
        self.done[idx] = np.asarray(done, np.float32)
        self.ptr = int((self.ptr + n) % self.capacity)
        self.size = int(min(self.size + n, self.capacity))

    def sample(self, rng: np.random.Generator, batch: int) -> Dict[str, np.ndarray]:
        idx = rng.integers(0, self.size, size=batch)
        return {"obs": self.obs[idx], "action": self.action[idx],
                "reward": self.reward[idx], "next_obs": self.next_obs[idx],
                "done": self.done[idx]}
