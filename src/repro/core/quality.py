"""CLIP-score quality proxy q_k = h(s_k, g_k) (paper Eq. 2).

Calibrated to the paper's anchors: 17-18 steps -> ~0.24, 20 steps -> 0.251
(the traditional fixed-20-step policy in Table IV), >=25 steps saturating
toward the Greedy ceiling 0.270 (Table IX). We use a saturating exponential
q(s) = q_max (1 - exp(-s / tau)) with q_max = 0.285, tau = 10, plus per-task
noise from the trace. Exact CLIP scoring needs the real CLIP model (GPU);
this proxy preserves the latency-quality trade-off the scheduler optimises.
"""
from __future__ import annotations

import jax.numpy as jnp

Q_MAX = 0.285
TAU = 10.0


def quality_of(steps, noise=0.0):
    s = jnp.asarray(steps, jnp.float32)
    # Two guards keep this bitwise-stable across every engine that computes
    # it (host loop, vmapped episodic scan, fused batched env step, Pallas
    # kernel): the reciprocal multiply replaces `s / TAU` — LLVM rewrites
    # division by a constant into multiply-by-reciprocal in some fusion
    # contexts and not others — and the value-preserving min (quality is
    # far below 1e30) pins the product so `Q_MAX * (...) + noise` cannot be
    # contracted into an FMA in one program and left split in another.
    return jnp.minimum(Q_MAX * (1.0 - jnp.exp(-s * (1.0 / TAU))), 1e30) \
        + noise


def quality_penalty(q, q_min: float, p_quality: float):
    """Eq. 3: I_k = p_quality if q < q_min else 0."""
    return jnp.where(q < q_min, p_quality, 0.0)
