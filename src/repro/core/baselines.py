"""Non-learned baselines (paper §VI.A.3): Random, Greedy, Genetic, Harmony.

* Random: uniform action vector, keeps the Task/Server selector machinery.
* Greedy: enumerates (visible task x step grid) candidate actions plus no-op,
  simulates each with the jittable env step (vmap) and takes the best
  immediate reward — the paper notes this maximises steps/quality.
* Genetic / Harmony: meta-heuristics that optimise a fixed 2048-step action
  *sequence* (pre-computed, no environment feedback at run time, as the paper
  describes) with episode return as fitness, evaluated by a lax.scan rollout.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import env as EV
from repro.core import quality as Q
from repro.core import rollout as RO


# ----------------------------------------------------------------------
def random_policy(key, ecfg: EV.EnvConfig):
    return jax.random.uniform(key, (ecfg.action_dim,))


# ----------------------------------------------------------------------
def _candidate_actions(ecfg: EV.EnvConfig, n_steps: int = 9) -> jnp.ndarray:
    """(1 + l*n_steps, action_dim) candidates in env space [0,1]."""
    l = ecfg.queue_window
    acts = [jnp.full((ecfg.action_dim,), 0.9)]           # no-op (a_c > 0.5)
    step_grid = jnp.linspace(0.0, 1.0, n_steps)
    for slot in range(l):
        for s in step_grid:
            a = jnp.zeros((ecfg.action_dim,))            # a_c = 0 -> execute
            a = a.at[1].set(s)
            a = a.at[2 + slot].set(1.0)
            acts.append(a)
    return jnp.stack(acts)


@functools.partial(jax.jit, static_argnames=("ecfg",))
def greedy_act(ecfg: EV.EnvConfig, trace: Dict, state: EV.EnvState):
    """Quality-first candidate search (paper §VI.B.3).

    The paper's Greedy maximises immediate quality — it lands on near-max
    inference steps at the cost of response time. Scoring candidates by the
    raw env reward does NOT reproduce that: the reciprocal-time term shrinks
    with every extra inference step, dragging the argmax to interior step
    counts (~30 instead of ~s_max). So the quality component of the reward
    (alpha_q q - lambda_q I) is the primary criterion and the full reward
    only breaks ties between equal-quality candidates (earlier task, less
    queue wait).

    All candidates share one visible-queue view and are simulated with
    `env.decision_step`, so the search costs a single top-k — the legacy
    `env.step` recomputed the queue (and a discarded observation) per
    candidate.
    """
    cands = _candidate_actions(ecfg)
    qview = EV.visible_queue(ecfg, trace, state)

    def eval_a(a):
        _, r, _, info = EV.decision_step(ecfg, trace, state, a, qview)
        q = info["quality"]
        pen = Q.quality_penalty(q, ecfg.q_min, ecfg.p_quality)
        qual = jnp.where(info["scheduled"],
                         ecfg.alpha_q * q - ecfg.lambda_q * pen + 1e-6, 0.0)
        return 1e3 * qual + r

    scores = jax.vmap(eval_a)(cands)
    return cands[jnp.argmax(scores)]


# ----------------------------------------------------------------------
# sequence rollout for meta-heuristics
@functools.partial(jax.jit, static_argnames=("ecfg",))
def rollout_sequence(ecfg: EV.EnvConfig, trace: Dict, seq: jnp.ndarray):
    """seq: (T, action_dim) in [0,1]. Returns (return, final_state).

    Sequence replay needs no observations, so the scan threads the visible
    queue through `env.decision_step`: one top-k per decision and no Eq.-6
    matrix assembly (the legacy `env.step` computed both, twice over)."""
    state0 = EV.reset(ecfg)
    q0 = EV.visible_queue(ecfg, trace, state0)

    def body(carry, a):
        state, q, total, done = carry
        new_state, r, d, _ = EV.decision_step(ecfg, trace, state, a, q)
        nq = EV.visible_queue(ecfg, trace, new_state)
        # freeze once done
        state = jax.tree_util.tree_map(
            lambda n, o: jnp.where(done, o, n), new_state, state)
        nq = jax.tree_util.tree_map(
            lambda n, o: jnp.where(done, o, n), nq, q)
        total = total + jnp.where(done, 0.0, r)
        return (state, nq, total, done | d), None

    (state, _, total, _), _ = jax.lax.scan(
        body, (state0, q0, jnp.zeros(()), jnp.zeros((), bool)), seq)
    return total, state


@dataclass(frozen=True)
class GeneticConfig:
    population: int = 64
    generations: int = 32
    parents: int = 10
    crossover_prob: float = 1.0
    mutation_prob: float = 0.1
    elites: int = 1
    seq_len: int = 2048


@functools.partial(jax.jit, static_argnames=("ecfg", "gcfg"))
def _genetic_generation(ecfg: EV.EnvConfig, gcfg: GeneticConfig, trace: Dict,
                        pop: jnp.ndarray, key):
    """One fully-jitted generation: vmapped fitness, selection, crossover,
    mutation. The host loop used to dispatch each of these as separate ops
    per generation; now one compiled program per generation (same RNG
    stream and op order as the host version, so results are unchanged)."""
    T, A = pop.shape[1], pop.shape[2]
    fit = jax.vmap(lambda s: rollout_sequence(ecfg, trace, s)[0])(pop)
    order = jnp.argsort(-fit)
    pop = pop[order]
    parents = pop[: gcfg.parents]
    key, kc, kp1, kp2, km, kmv = jax.random.split(key, 6)
    n_child = gcfg.population - gcfg.elites
    i1 = jax.random.randint(kp1, (n_child,), 0, gcfg.parents)
    i2 = jax.random.randint(kp2, (n_child,), 0, gcfg.parents)
    xmask = jax.random.bernoulli(kc, 0.5, (n_child, T, A))
    children = jnp.where(xmask, parents[i1], parents[i2])
    mmask = jax.random.bernoulli(km, gcfg.mutation_prob, (n_child, T, A))
    children = jnp.where(mmask, jax.random.uniform(kmv, (n_child, T, A)),
                         children)
    return jnp.concatenate([pop[: gcfg.elites], children]), key


def genetic_schedule(key, ecfg: EV.EnvConfig, trace: Dict,
                     gcfg: GeneticConfig = GeneticConfig()):
    """Returns (best action sequence, best fitness)."""
    A = ecfg.action_dim
    T = gcfg.seq_len
    rollout = jax.vmap(lambda s: rollout_sequence(ecfg, trace, s)[0])
    key, k0 = jax.random.split(key)
    pop = jax.random.uniform(k0, (gcfg.population, T, A))

    for _gen in range(gcfg.generations):
        pop, key = _genetic_generation(ecfg, gcfg, trace, pop, key)
    fit = rollout(pop)
    best = jnp.argmax(fit)
    return pop[best], fit[best]


@dataclass(frozen=True)
class HarmonyConfig:
    memory_size: int = 64
    improvisations: int = 64     # total candidates (across batched rounds)
    improv_batch: int = 16       # candidates improvised/evaluated per round
    hmcr: float = 0.8            # memory consideration
    par: float = 0.2             # pitch adjustment
    bandwidth: float = 0.05      # continuous-action pitch bandwidth
    seq_len: int = 2048


def _harmony_improvise(key, memory, hcfg: HarmonyConfig, T: int, A: int):
    """One candidate from the current memory (classic HS improvisation)."""
    km, kr, kp, kb, kn = jax.random.split(key, 5)
    pick = jax.random.randint(km, (T, A), 0, hcfg.memory_size)
    from_mem = memory[pick, jnp.arange(T)[:, None], jnp.arange(A)[None, :]]
    use_mem = jax.random.bernoulli(kr, hcfg.hmcr, (T, A))
    rand = jax.random.uniform(kn, (T, A))
    new = jnp.where(use_mem, from_mem, rand)
    adj = jax.random.bernoulli(kp, hcfg.par, (T, A))
    return jnp.where(adj & use_mem,
                     jnp.clip(new + hcfg.bandwidth *
                              jax.random.uniform(kb, (T, A), minval=-1.0,
                                                 maxval=1.0), 0, 1),
                     new)


@jax.jit
def _harmony_merge(memory, fit, new, f_new):
    """Fold a batch of evaluated candidates into (memory, fit) one at a
    time — each replaces the then-worst entry iff it improves it, exactly
    like the sequential algorithm applied to a round's snapshot."""
    def body(carry, x):
        mem, ft = carry
        cand, fc = x
        worst = jnp.argmin(ft)
        better = fc > ft[worst]
        mem = mem.at[worst].set(jnp.where(better, cand, mem[worst]))
        ft = ft.at[worst].set(jnp.where(better, fc, ft[worst]))
        return (mem, ft), None
    (memory, fit), _ = jax.lax.scan(body, (memory, fit), (new, f_new))
    return memory, fit


def harmony_schedule(key, ecfg: EV.EnvConfig, trace: Dict,
                     hcfg: HarmonyConfig = HarmonyConfig()):
    """Batched harmony search: each round improvises `improv_batch`
    candidates from the current memory with one vmapped generator, scores
    them with one vmapped sequence rollout (the way PR 1 batched baseline
    evaluation), and merges them sequentially. The host loop used to
    improvise and evaluate one candidate per step."""
    A = ecfg.action_dim
    T = hcfg.seq_len
    nb = max(1, min(hcfg.improv_batch, hcfg.improvisations))
    rounds = -(-hcfg.improvisations // nb)
    rollout = jax.vmap(lambda s: rollout_sequence(ecfg, trace, s)[0])
    key, k0 = jax.random.split(key)
    memory = jax.random.uniform(k0, (hcfg.memory_size, T, A))
    fit = rollout(memory)

    improvise = jax.vmap(
        lambda k, mem: _harmony_improvise(k, mem, hcfg, T, A),
        in_axes=(0, None))
    remaining = hcfg.improvisations
    for _ in range(rounds):
        nb_r = min(nb, remaining)           # trim the last round so the
        remaining -= nb_r                   # total stays `improvisations`
        key, kb = jax.random.split(key)
        new = improvise(jax.random.split(kb, nb_r), memory)
        f_new = rollout(new)
        memory, fit = _harmony_merge(memory, fit, new, f_new)
    best = jnp.argmax(fit)
    return memory[best], fit[best]


# ----------------------------------------------------------------------
def evaluate_policy(ecfg: EV.EnvConfig, trace: Dict, act_fn, key,
                    max_steps: int = 4096) -> Dict:
    """Generic host-loop evaluation for random/greedy-style policies.
    act_fn(key, state, obs) -> action in [0,1]^A."""
    step_jit = jax.jit(lambda s, a: EV.step(ecfg, trace, s, a))
    state = EV.reset(ecfg)
    obs = EV.observe(ecfg, trace, state)
    # f32 accumulation so the return matches batch_rollout's scan bitwise
    total, done, n = np.float32(0.0), False, 0
    while not done and n < max_steps:
        key, ka = jax.random.split(key)
        a = act_fn(ka, state, obs)
        state, obs, r, d, _ = step_jit(state, a)
        total = total + np.float32(r)
        done = bool(d)
        n += 1
    m = {k: float(v) for k, v in EV.episode_metrics(ecfg, trace, state).items()}
    m.update(episode_return=float(total), episode_len=n)
    return m


def evaluate_policy_batch(ecfg: EV.EnvConfig, traces: Dict, policy, keys,
                          params=None, num_steps: int = None) -> Dict:
    """Deprecated: use `repro.api.evaluate_batch` (same per-episode metric
    arrays, plus PolicySpec resolution and pluggable execution backends).

    Batched evaluation: B traces in one jitted program. `traces` carries a
    leading (B,) axis; `policy` follows the rollout protocol. Row b is
    bitwise what ``evaluate_policy`` returns on (traces[b], keys[b]).
    """
    import warnings
    warnings.warn(
        "baselines.evaluate_policy_batch is deprecated; use "
        "repro.api.evaluate_batch", DeprecationWarning, stacklevel=2)
    from repro.api import evaluate_batch
    return evaluate_batch(ecfg, traces, policy, keys, params=params,
                          num_steps=num_steps)
