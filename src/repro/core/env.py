"""Jittable edge-cluster gang-scheduling environment (paper §IV–V.A).

The MDP is event-driven: a decision is taken whenever the agent acts; if the
agent schedules a task, time stays put (more tasks can gang-schedule onto the
remaining idle servers at the same instant); otherwise time advances to the
next event (task arrival or server completion).

State (Eq. 6): a 3 x (E + l) matrix
    [ a_e ... | wait_k ... ]
    [ t^r_e...| c_k    ... ]
    [ d_e ... | m_k/0  ... ]
Action (Eq. 8): [a_c, a_s, a_k1..a_kl] in [0, 1]^(2+l)
    a_c <= 0.5 -> schedule; a_s -> inference steps in [S_min, S_max];
    a_k -> per-visible-task preference scores.
Reward: R = alpha_q q - lambda_q I + 1 / (beta_t t_r + mu_t t_avg_wait).

Model reuse: servers remember the gang (leader = task id), gang size and
model of the last task they served; a new task reuses iff a *complete* idle
gang with matching model and size c_k exists (the DistriFusion process group
can be reused without reloading). Server selection otherwise greedily avoids
fragmenting intact idle gangs (paper §V.B.4).

Everything is fixed-shape jnp, so the env jits, vmaps (batched rollouts) and
is differentiable-free (used under lax.scan in the meta-heuristic baselines).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import quality as Q
from repro.core import timemodel as TM
# The Eq.-6 observation path lives in `core.obs` (shared verbatim with the
# real-model serving engine, which observes a pool-derived state mirror);
# re-exported here so every existing `EV.observe_from` consumer — including
# the bitwise-parity-tested fused/Pallas engines — keeps one import path.
from repro.core.obs import (INF, QueueView, observe_from, server_down,
                            visible_queue)

#: fault-schedule trace columns (`repro.faults.schedule.FaultTimeline`):
#: f_down_start/f_down_end (E, F) window-local crash intervals, f_slow (E,)
#: straggler exec multipliers, f_cold (1,) cold-restart flag. Their PRESENCE
#: in the trace dict switches the decision step into fault mode — a static
#: property under jit, so fault-free traces compile the exact program they
#: always did (bitwise-identical results, zero overhead).
FAULT_COLS = ("f_down_start", "f_down_end", "f_slow", "f_cold")


def has_faults(trace: Dict) -> bool:
    """Static (trace-structure) test: fault columns attached?"""
    return "f_down_start" in trace


def _pin(x):
    """Value-preserving min that pins a product before an add/sub.

    LLVM may contract `a * b + c` into an FMA in one compilation context
    (one fusion shape) and not another; the decision math runs in several —
    the host loop, the vmapped episodic scan, the fused batched step and
    its Pallas kernel — and they must all round identically for episode
    metrics to stay bitwise-comparable. Every pinned value is far below
    1e30, so the min only breaks the mul->add pattern, never the value.
    """
    return jnp.minimum(x, 1e30)


@dataclass(frozen=True)
class EnvConfig:
    num_servers: int = 8
    queue_window: int = 8              # l: visible queue slots
    s_min: int = 10
    s_max: int = 50
    max_tasks: int = 32                # K per episode
    time_limit: float = 1024.0
    max_steps: int = 1024              # decision-step limit
    # reward coefficients (Eq. 4a / reward R_t)
    alpha_q: float = 10.0
    beta_t: float = 0.1
    mu_t: float = 0.1
    # numerator of the reciprocal time term. The paper leaves the weight
    # coefficients unspecified; k_time = 10 balances d(reward)/d(steps) so
    # the learned policy lands on interior step counts (~17-25, as in the
    # paper's Table II) instead of saturating at S_max (see DESIGN.md §6).
    k_time: float = 10.0
    lambda_q: float = 1.0
    p_quality: float = 2.0
    q_min: float = 0.23
    # observation scaling
    time_scale: float = 60.0
    num_models: int = 1                # distinct services; 1 = paper's SD-only
    # per-model execution-time scale (len num_models); defaults to ones
    model_scale: Tuple[float, ...] = ()

    @property
    def action_dim(self) -> int:
        return 2 + self.queue_window

    @property
    def obs_shape(self) -> Tuple[int, int]:
        return (3, self.num_servers + self.queue_window)

    def scales(self):
        if self.model_scale:
            return jnp.asarray(self.model_scale, jnp.float32)
        return jnp.ones((self.num_models,), jnp.float32)


class EnvState(NamedTuple):
    time: jnp.ndarray            # () f32
    server_free_at: jnp.ndarray  # (E,) f32 absolute
    server_model: jnp.ndarray    # (E,) i32, -1 = none
    server_gang: jnp.ndarray     # (E,) i32 task-id of last gang, -1 = none
    server_gang_size: jnp.ndarray  # (E,) i32
    task_status: jnp.ndarray     # (K,) i32 0=unscheduled 1=running 2=done
                                 #          3=failed (fault mode only)
    task_start: jnp.ndarray      # (K,) f32
    task_finish: jnp.ndarray     # (K,) f32
    task_steps: jnp.ndarray      # (K,) i32
    task_quality: jnp.ndarray    # (K,) f32
    task_reload: jnp.ndarray     # (K,) i32 1 = had to (re)init
    steps_taken: jnp.ndarray     # () i32


def reset(cfg: EnvConfig) -> EnvState:
    E, K = cfg.num_servers, cfg.max_tasks
    return EnvState(
        time=jnp.zeros((), jnp.float32),
        server_free_at=jnp.zeros((E,), jnp.float32),
        server_model=-jnp.ones((E,), jnp.int32),
        server_gang=-jnp.ones((E,), jnp.int32),
        server_gang_size=jnp.zeros((E,), jnp.int32),
        task_status=jnp.zeros((K,), jnp.int32),
        task_start=jnp.zeros((K,), jnp.float32),
        task_finish=jnp.zeros((K,), jnp.float32),
        task_steps=jnp.zeros((K,), jnp.int32),
        task_quality=jnp.zeros((K,), jnp.float32),
        task_reload=jnp.zeros((K,), jnp.int32),
        steps_taken=jnp.zeros((), jnp.int32),
    )


# ----------------------------------------------------------------------
def observe(cfg: EnvConfig, trace: Dict, state: EnvState) -> jnp.ndarray:
    """Eq.-6 state matrix, normalised."""
    return observe_from(cfg, trace, state, visible_queue(cfg, trace, state))


# ----------------------------------------------------------------------
def _select_servers(cfg: EnvConfig, state: EnvState, idle, m_k, c_k):
    """Returns (selected mask (E,), reuse flag). Greedy §V.B.4.

    Gang membership is counted by pairwise label equality over servers, so a
    gang label is an opaque int: any two servers with the same non-negative
    label form one gang. Within an episode labels are task ids in [0, K);
    the streaming engine (`traffic/stream.py`) relabels gangs carried across
    window seams into [K, K+E) so they can never collide with the next
    window's task ids.
    """
    E = cfg.num_servers
    gang = state.server_gang
    has_gang = gang >= 0
    same = gang[:, None] == gang[None, :]                       # (E, E)

    # complete reusable gang: idle, same model, gang size == c_k
    ok = idle & has_gang & (state.server_model == m_k) & (state.server_gang_size == c_k)
    counts = jnp.sum(same & ok[None, :], axis=1)               # ok peers per server
    complete = ok & (counts == c_k)
    any_reuse = jnp.any(complete)
    g_star = jnp.min(jnp.where(complete, gang, jnp.int32(2 ** 30)))
    reuse_sel = ok & (gang == g_star)

    # fragmentation-aware fresh selection: avoid breaking intact idle gangs
    member_ok = idle & has_gang
    counts_all = jnp.sum(same & member_ok[None, :], axis=1)
    intact = member_ok & (counts_all == state.server_gang_size) \
        & (state.server_gang_size > 0)
    score = jnp.where(idle,
                      intact.astype(jnp.float32) * (100.0 + 10.0 * state.server_gang_size)
                      + 0.001 * jnp.arange(E),
                      INF)
    order = jnp.argsort(score)
    rank = jnp.zeros((E,), jnp.int32).at[order].set(jnp.arange(E, dtype=jnp.int32))
    fresh_sel = idle & (rank < c_k)

    sel = jnp.where(any_reuse, reuse_sel, fresh_sel)
    return sel, any_reuse


def decision_step(cfg: EnvConfig, trace: Dict, state: EnvState,
                  action: jnp.ndarray, q: QueueView):
    """The per-decision state transition as a fixed-shape pure function.

    `q` must be `visible_queue(cfg, trace, state)`; a view computed on the
    previous decision's post-step state is exact, because the lazy
    retirement below only flips task status 1 -> 2 (the queued mask tests
    status == 0) and time does not move between decisions. Returns
    (state', reward, done, info) — the caller owns the next observation, so
    one decision costs exactly one visible-queue top-k.
    """
    t = state.time
    faulty = has_faults(trace)
    # lazily retire finished tasks
    finished = (state.task_status == 1) & (state.task_finish <= t)
    status = jnp.where(finished, 2, state.task_status)
    state = state._replace(task_status=status)

    if faulty:
        ds, de = trace["f_down_start"], trace["f_down_end"]       # (E, F)
        down = jnp.any((ds <= t) & (t < de), axis=1)
        # cold restart: every server whose crash has begun (past or
        # ongoing) loses its cached model + gang metadata. Idempotent per
        # decision, so recovery order does not matter; a down server is
        # invisible anyway (masked below), an already-recovered one pays
        # the full reload on its next assignment.
        wipe = jnp.any(ds <= t, axis=1) & (trace["f_cold"][0] > 0)
        state = state._replace(
            server_model=jnp.where(wipe, -1, state.server_model),
            server_gang=jnp.where(wipe, -1, state.server_gang),
            server_gang_size=jnp.where(wipe, 0, state.server_gang_size))

    idx, valid, queued = q.idx, q.valid, q.queued
    scores = jnp.where(valid, action[2:], -INF)
    slot = jnp.argmax(scores)
    k = idx[slot]
    k_valid = valid[slot]

    want_exec = action[0] <= 0.5
    c_k = trace["c"][k]
    m_k = trace["model"][k]
    scale = cfg.scales()[m_k]
    idle = state.server_free_at <= t
    if faulty:                       # a down server cannot join a gang
        idle = idle & ~down
    n_idle = jnp.sum(idle.astype(jnp.int32))
    feasible = want_exec & k_valid & (n_idle >= c_k)

    sel, reuse = _select_servers(cfg, state, idle, m_k, c_k)
    steps = jnp.round(cfg.s_min + _pin(jnp.clip(action[1], 0.0, 1.0)
                      * (cfg.s_max - cfg.s_min))).astype(jnp.int32)
    t_exec = _pin(TM.exec_time(c_k, steps, scale))
    if faulty:                       # gang speed = slowest member's speed
        slow_k = jnp.max(jnp.where(sel, trace["f_slow"], 1.0))
        t_exec = _pin(t_exec * slow_k)
    t_init = _pin(jnp.where(reuse, 0.0, TM.init_time(c_k, scale)))
    finish = t + t_exec + t_init
    q_k = Q.quality_of(steps, trace["noise"][k])
    pen = Q.quality_penalty(q_k, cfg.q_min, cfg.p_quality)
    t_resp = finish - trace["arr_time"][k]

    if faulty:
        # in-flight failure: a selected server crashes before the gang
        # finishes -> the whole gang aborts at the first member's crash
        # instant (task status 3, servers freed at the crash, no reward)
        crash_cand = sel[:, None] & (ds > t) & (ds < finish)      # (E, F)
        crash_t = jnp.min(jnp.where(crash_cand, ds, INF))
        will_fail = crash_t < INF
        sched_status = jnp.where(will_fail, 3, 1)
        rec_finish = jnp.where(will_fail, crash_t, finish)
    else:
        sched_status, rec_finish = 1, finish

    # --- apply schedule (masked) -------------------------------------
    f = feasible
    sel_f = sel & f
    new_free = jnp.where(sel_f, rec_finish, state.server_free_at)
    new_model = jnp.where(sel_f, m_k, state.server_model)
    new_gang = jnp.where(sel_f, k.astype(jnp.int32), state.server_gang)
    new_gsize = jnp.where(sel_f, c_k, state.server_gang_size)

    def set_if(arr, val):
        return arr.at[k].set(jnp.where(f, val, arr[k]))

    status = set_if(state.task_status, sched_status)
    start = set_if(state.task_start, t)
    tfin = set_if(state.task_finish, rec_finish)
    tsteps = set_if(state.task_steps, steps)
    tq = set_if(state.task_quality, q_k)
    trl = set_if(state.task_reload, jnp.where(reuse, 0, 1).astype(jnp.int32))

    # reward (only on successful schedule)
    still_queued = queued & (jnp.arange(cfg.max_tasks) != k)
    n_q = jnp.maximum(jnp.sum(still_queued.astype(jnp.float32)), 1.0)
    t_avg = jnp.sum(jnp.where(still_queued, t - trace["arr_time"], 0.0)) / n_q
    r = _pin(cfg.alpha_q * q_k) - _pin(cfg.lambda_q * pen) \
        + cfg.k_time / (_pin(cfg.beta_t * t_resp) + _pin(cfg.mu_t * t_avg)
                        + 1e-3)
    reward = jnp.where(f, r, 0.0)
    if faulty:                       # a gang that will crash earns nothing
        reward = jnp.where(will_fail, 0.0, reward)

    # --- advance time on no-op ----------------------------------------
    arr = trace["arr_time"]
    next_arrival = jnp.min(jnp.where(arr > t, arr, INF))
    next_completion = jnp.min(jnp.where(new_free > t, new_free, INF))
    next_event = jnp.minimum(next_arrival, next_completion)
    if faulty:                       # recoveries are events too, or a fully
        next_recovery = jnp.min(     # down cluster would stall the clock
            jnp.where((ds <= t) & (de > t), de, INF))
        next_event = jnp.minimum(next_event, next_recovery)
    t_new = jnp.where(f, t, jnp.where(next_event < INF, next_event, t + 1.0))

    new_state = EnvState(
        time=t_new, server_free_at=new_free, server_model=new_model,
        server_gang=new_gang, server_gang_size=new_gsize,
        task_status=status, task_start=start, task_finish=tfin,
        task_steps=tsteps, task_quality=tq, task_reload=trl,
        steps_taken=state.steps_taken + 1,
    )
    resolved = (new_state.task_status == 2) | \
        ((new_state.task_status == 1) & (new_state.task_finish <= t_new))
    if faulty:                       # failed tasks are resolved (host retries)
        resolved = resolved | (new_state.task_status == 3)
    all_done = jnp.all(resolved)
    done = all_done | (t_new >= cfg.time_limit) | (new_state.steps_taken >= cfg.max_steps)
    info = {"scheduled": f, "task": k, "reuse": reuse & f, "steps": steps,
            "quality": jnp.where(f, q_k, 0.0),
            "response": jnp.where(f, t_resp, 0.0)}
    if faulty:
        info["failed"] = f & will_fail
    return new_state, reward, done, info


def step(cfg: EnvConfig, trace: Dict, state: EnvState, action: jnp.ndarray):
    """One decision. Returns (state', obs', reward, done, info)."""
    q = visible_queue(cfg, trace, state)
    new_state, reward, done, info = decision_step(cfg, trace, state, action, q)
    return new_state, observe(cfg, trace, new_state), reward, done, info


def step_with_queue(cfg: EnvConfig, trace: Dict, state: EnvState,
                    q: QueueView, action: jnp.ndarray):
    """`step` with the visible queue threaded through: consumes the view of
    the current state and returns the next one alongside the observation, so
    a rollout does one top-k per decision instead of two (the legacy `step`
    recomputed it inside `observe`). Bitwise-identical to `step`.
    Returns (state', queue', obs', reward, done, info)."""
    new_state, reward, done, info = decision_step(cfg, trace, state, action, q)
    q2 = visible_queue(cfg, trace, new_state)
    obs2 = observe_from(cfg, trace, new_state, q2)
    return new_state, q2, obs2, reward, done, info


def reset_view(cfg: EnvConfig, trace: Dict, state: EnvState):
    """(queue, obs) of a (possibly carried) state — the rollout's carry seed."""
    q = visible_queue(cfg, trace, state)
    return q, observe_from(cfg, trace, state, q)


# ----------------------------------------------------------------------
def decision_statics(cfg: EnvConfig, trace: Dict) -> Dict[str, jnp.ndarray]:
    """Per-task constants of the decision step, hoisted out of the rollout
    scan (the fused kernel and its jnp reference consume these instead of
    re-deriving latency-table lookups every decision). All (K,) arrays."""
    c = trace["c"]
    scale = cfg.scales()[trace["model"]]
    out = {
        "arr_time": trace["arr_time"],
        "c": c,
        "model": trace["model"],
        "noise": trace["noise"],
        "step_base": TM.STEP_TIME[TM._log2i(c)],   # s / inference step
        "init_base": TM.INIT_TIME[TM._log2i(c)],   # model (re)load s
        "scale": scale,
    }
    if has_faults(trace):            # fault schedules ride along unchanged
        for col in FAULT_COLS:
            out[col] = trace[col]
    return out


# ----------------------------------------------------------------------
def episode_metrics(cfg: EnvConfig, trace: Dict, state: EnvState) -> Dict:
    """Aggregates matching the paper's Tables IX/X/XI.

    In fault mode, crashed tasks (status 3) are excluded from the quality /
    response / reload averages — they produced nothing — and reported
    separately as `num_failed`."""
    if has_faults(trace):
        sched = (state.task_status == 1) | (state.task_status == 2)
    else:
        sched = state.task_status >= 1
    n = jnp.maximum(jnp.sum(sched.astype(jnp.float32)), 1.0)
    resp = jnp.where(sched, state.task_finish - trace["arr_time"], 0.0)
    out = {
        "num_scheduled": jnp.sum(sched.astype(jnp.int32)),
        "num_done": jnp.sum((state.task_status == 2).astype(jnp.int32)),
        "avg_quality": jnp.sum(jnp.where(sched, state.task_quality, 0.0)) / n,
        "avg_response": jnp.sum(resp) / n,
        "reload_rate": jnp.sum(jnp.where(sched, state.task_reload, 0).astype(jnp.float32)) / n,
        "avg_steps": jnp.sum(jnp.where(sched, state.task_steps, 0).astype(jnp.float32)) / n,
    }
    if has_faults(trace):
        out["num_failed"] = jnp.sum((state.task_status == 3).astype(jnp.int32))
    return out
