"""Policy/critic networks and the attention feature extractor (paper Table VII).

All hidden layers use Mish (paper §VI.A.2); feature extraction treats each
column of the Eq.-6 state matrix as a token and applies one scaled-dot-product
attention layer (Eq. 9), producing a feature vector f_s of dim |E| + l.
"""
from __future__ import annotations

import math
from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from repro.common.pytree import KeyGen, normal_init
from repro.models.layers import mish


def init_mlp(key, dims: Sequence[int], final_bias: bool = True) -> Dict:
    kg = KeyGen(key)
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        layers.append({"w": normal_init(kg(), (a, b), stddev=1.0 / math.sqrt(a)),
                       "b": jnp.zeros((b,), jnp.float32)})
    return {"layers": layers}


def mlp_apply(p: Dict, x, activation=mish, final_activation=None):
    n = len(p["layers"])
    for i, l in enumerate(p["layers"]):
        x = x @ l["w"] + l["b"]
        if i < n - 1:
            x = activation(x)
        elif final_activation is not None:
            x = final_activation(x)
    return x


# ----------------------------------------------------------------------
# attention feature extractor (Eq. 9)
def init_attention_encoder(key, n_rows: int, n_cols: int, d_attn: int = 32) -> Dict:
    """State matrix (n_rows, n_cols): columns are tokens of dim n_rows."""
    kg = KeyGen(key)
    return {
        "wq": normal_init(kg(), (n_rows, d_attn), stddev=1.0 / math.sqrt(n_rows)),
        "wk": normal_init(kg(), (n_rows, d_attn), stddev=1.0 / math.sqrt(n_rows)),
        "wv": normal_init(kg(), (n_rows, d_attn), stddev=1.0 / math.sqrt(n_rows)),
        "wo": normal_init(kg(), (d_attn,), stddev=1.0 / math.sqrt(d_attn)),
    }


def attention_encode(p: Dict, s) -> jnp.ndarray:
    """s: (..., 3, E+l) -> f_s: (..., E+l)."""
    x = jnp.swapaxes(s, -1, -2)                              # (..., E+l, 3)
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    d = q.shape[-1]
    att = jax.nn.softmax(q @ jnp.swapaxes(k, -1, -2) / math.sqrt(d), axis=-1)
    ctx = att @ v                                            # (..., E+l, d)
    return ctx @ p["wo"]                                     # (..., E+l)


# MLP fallback encoder (the EAT-A / EAT-DA ablations: no attention layer)
def init_mlp_encoder(key, n_rows: int, n_cols: int) -> Dict:
    return init_mlp(key, [n_rows * n_cols, n_cols])


def mlp_encode(p: Dict, s) -> jnp.ndarray:
    flat = s.reshape(s.shape[:-2] + (-1,))
    return mlp_apply(p, flat)


def make_encoder(kind: str, key, obs_shape, d_attn: int = 32):
    """Returns (params, encode_fn, feature_dim)."""
    n_rows, n_cols = obs_shape
    if kind == "attention":
        return (init_attention_encoder(key, n_rows, n_cols, d_attn),
                attention_encode, n_cols)
    if kind == "mlp":
        return init_mlp_encoder(key, n_rows, n_cols), mlp_encode, n_cols
    raise ValueError(kind)
