"""Latency model calibrated to the paper's Table VI measurements.

| patches | init time (s) | time per inference step (s) |
|   1     |     33.5      |            0.53             |
|   2     |     31.9      |            0.29             |
|   4     |     35.0      |            0.20             |

Init time is ~constant in the patch count; execution time is linear in the
number of inference steps with a per-step cost that shrinks sub-linearly with
parallelism (Table I acceleration: x1.8 @2, x3.1 @4, x4.9 @8). The 8-patch
per-step time is extrapolated from Table I's x4.9 speedup (0.53/4.9≈0.108)
blended with the trend of Table VI -> 0.135 s.

For multi-architecture mode each service scales these by its per-step FLOP
ratio relative to Stable Diffusion v1.4 (see serving/latency_table.py).
"""
from __future__ import annotations

import jax.numpy as jnp

# indexed by log2(patches): 1, 2, 4, 8
INIT_TIME = jnp.asarray([33.5, 31.9, 35.0, 36.0], jnp.float32)
STEP_TIME = jnp.asarray([0.53, 0.29, 0.20, 0.135], jnp.float32)


def _log2i(c):
    # c in {1,2,4,8} -> {0,1,2,3}
    return jnp.asarray(jnp.round(jnp.log2(jnp.maximum(c, 1))), jnp.int32)


def init_time(c, model_scale=1.0):
    """Model (re)initialisation latency for a c-patch gang."""
    return INIT_TIME[_log2i(c)] * model_scale


def exec_time(c, steps, model_scale=1.0):
    """Inference latency for `steps` diffusion steps on a c-patch gang."""
    return STEP_TIME[_log2i(c)] * steps.astype(jnp.float32) * model_scale


def predict_remaining(c, steps, reuse, model_scale=1.0):
    """The scheduler's remaining-time predictor t^r_e (paper §V.A.3):
    linear-in-steps execution + init when the model must be (re)loaded."""
    t = exec_time(c, steps, model_scale)
    return t + jnp.where(reuse, 0.0, init_time(c, model_scale))
