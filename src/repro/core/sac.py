"""EAT training (paper Algorithm 2): SAC with double critics + target nets.

Actor loss (Eq. 15/16): maximise min-Q(s, a_theta(s)) + alpha * H(N(mu, sigma^2)),
with gradients flowing through the T-step diffusion chain (reparameterised).
Critic loss (Eq. 19/20): TD toward r + gamma * min target-Q(s', a'(s')).
Soft target update (Eq. 22) with rate tau. Hyper-parameters from Table VIII.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agent as AG
from repro.core import diffusion as DF
from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.replay import ReplayBuffer
from repro.core.workload import stack_traces
from repro.training.optimizer import AdamState, adam_init, adam_update, apply_updates


@dataclass(frozen=True)
class SACConfig:
    actor_lr: float = 3e-4        # eta_a
    critic_lr: float = 3e-4       # eta_c
    gamma: float = 0.95
    tau: float = 0.005
    batch_size: int = 512
    buffer_capacity: int = 1_000_000
    updates_per_step: int = 1
    update_every: int = 1         # gradient updates every N env steps
    warmup_steps: int = 256
    weight_decay: float = 1e-4    # lambda (Table VIII)
    bc_coef: float = 0.0          # optional diffusion BC regulariser


class TrainState(NamedTuple):
    actor: Any
    critic1: Any
    critic2: Any
    target1: Any
    target2: Any
    opt_actor: AdamState
    opt_critic1: AdamState
    opt_critic2: AdamState
    step: jnp.ndarray


def init_train_state(key, ecfg: EV.EnvConfig, acfg: AG.AgentConfig) -> TrainState:
    k1, k2, k3 = jax.random.split(key, 3)
    actor = AG.init_actor(k1, ecfg, acfg)
    c1 = AG.init_critic(k2, ecfg)
    c2 = AG.init_critic(k3, ecfg)
    return TrainState(
        actor=actor, critic1=c1, critic2=c2,
        target1=jax.tree_util.tree_map(jnp.copy, c1),
        target2=jax.tree_util.tree_map(jnp.copy, c2),
        opt_actor=adam_init(actor), opt_critic1=adam_init(c1),
        opt_critic2=adam_init(c2), step=jnp.zeros((), jnp.int32))


def _soft_update(target, online, tau: float):
    return jax.tree_util.tree_map(lambda t, o: (1 - tau) * t + tau * o, target, online)


@functools.partial(jax.jit, static_argnames=("ecfg",))
def _jit_env_step(ecfg: EV.EnvConfig, trace, state, action):
    """One cached jitted env step with the trace as a *traced* argument.

    The host-loop drivers (`run_episode`, `seed_with_demonstrations`) used
    to build `jax.jit(lambda s, a: EV.step(ecfg, trace, s, a))` per episode,
    closing over the trace as a compile-time constant — every episode
    compiled a fresh program. One program per (ecfg, shape) now serves every
    trace; tests/test_stream_train.py pins the compile count.
    """
    return EV.step(ecfg, trace, state, action)


def host_rng(key) -> np.random.Generator:
    """Host-side RNG (curriculum cell picks, replay sampling, minibatch
    permutations) derived from the JAX key by folding in a fixed constant
    and drawing fresh bits — never from the raw integer seed, which would
    mirror `PRNGKey(seed)` and couple curriculum/replay sampling to network
    initialisation across seeds."""
    bits = jax.random.bits(jax.random.fold_in(key, 0x9E3779B9), (4,),
                           jnp.uint32)
    return np.random.default_rng(np.asarray(bits).tolist())


@functools.partial(jax.jit, static_argnames=("ecfg", "acfg", "scfg"))
def update_step(ts: TrainState, batch: Dict, key, *, ecfg: EV.EnvConfig,
                acfg: AG.AgentConfig, scfg: SACConfig) -> Tuple[TrainState, Dict]:
    sched = DF.vp_schedule(acfg.T)
    obs, act, rew = batch["obs"], batch["action"], batch["reward"]
    nobs, done = batch["next_obs"], batch["done"]
    k_next, k_actor, k_bc = jax.random.split(key, 3)

    # ---- critic update ------------------------------------------------
    a_next, _, _, _ = AG.actor_sample(ts.actor, acfg, ecfg, sched, nobs, k_next)
    q1t = AG.critic_apply(ts.target1, nobs, a_next)
    q2t = AG.critic_apply(ts.target2, nobs, a_next)
    y = rew + scfg.gamma * (1.0 - done) * jnp.minimum(q1t, q2t)     # Eq. 20
    y = jax.lax.stop_gradient(y)

    def critic_loss(cp):
        q = AG.critic_apply(cp, obs, act)
        return jnp.mean(jnp.square(y - q)), q

    (l1, q1), g1 = jax.value_and_grad(critic_loss, has_aux=True)(ts.critic1)
    (l2, _), g2 = jax.value_and_grad(critic_loss, has_aux=True)(ts.critic2)
    u1, oc1 = adam_update(g1, ts.opt_critic1, ts.critic1, scfg.critic_lr,
                          weight_decay=scfg.weight_decay)
    u2, oc2 = adam_update(g2, ts.opt_critic2, ts.critic2, scfg.critic_lr,
                          weight_decay=scfg.weight_decay)
    c1 = apply_updates(ts.critic1, u1)
    c2 = apply_updates(ts.critic2, u2)

    # ---- actor update (Eq. 15/16) -------------------------------------
    def actor_loss(ap):
        a, mean, log_sigma, ent = AG.actor_sample(ap, acfg, ecfg, sched, obs, k_actor)
        q = jnp.minimum(AG.critic_apply(c1, obs, a), AG.critic_apply(c2, obs, a))
        loss = -jnp.mean(q + acfg.entropy_alpha * ent)
        if scfg.bc_coef > 0.0 and acfg.policy == "diffusion":
            from repro.core.agent import _encode
            f_s = _encode(ap, acfg, ecfg, obs)
            loss = loss + scfg.bc_coef * DF.bc_loss(ap["denoiser"], sched, f_s,
                                                    act, k_bc)
        return loss, (jnp.mean(q), jnp.mean(ent))

    (la, (qm, entm)), ga = jax.value_and_grad(actor_loss, has_aux=True)(ts.actor)
    ua, oa = adam_update(ga, ts.opt_actor, ts.actor, scfg.actor_lr,
                         weight_decay=scfg.weight_decay)
    actor = apply_updates(ts.actor, ua)

    ts = TrainState(actor=actor, critic1=c1, critic2=c2,
                    target1=_soft_update(ts.target1, c1, scfg.tau),
                    target2=_soft_update(ts.target2, c2, scfg.tau),
                    opt_actor=oa, opt_critic1=oc1, opt_critic2=oc2,
                    step=ts.step + 1)
    metrics = {"critic_loss": 0.5 * (l1 + l2), "actor_loss": la,
               "q_mean": qm, "entropy": entm, "q_batch": jnp.mean(q1)}
    return ts, metrics


# ----------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("ecfg", "acfg", "deterministic"))
def policy_act(actor_params, obs, key, *, ecfg: EV.EnvConfig,
               acfg: AG.AgentConfig, deterministic: bool = False):
    sched = DF.vp_schedule(acfg.T)
    a, _, _, _ = AG.actor_sample(actor_params, acfg, ecfg, sched, obs, key,
                                 deterministic=deterministic)
    return a


# ----------------------------------------------------------------------
# rollout-engine policies (cached: the callable is a static jit argument)
def actor_policy(ecfg: EV.EnvConfig, acfg: AG.AgentConfig,
                 deterministic: bool = False):
    """Diffusion/Gaussian actor as a batch_rollout policy; actor weights are
    the traced `params`, so training updates never trigger a recompile.

    Thin delegate to the unified actor layer (`repro.actors.actor_policy`
    with the default full-chain ``sampler="ddpm"``) — the SAME cached
    callable object, so jit-program caches keyed on policy identity keep
    hitting across both doors. Kept (without a deprecation warning: the
    trainers and benchmarks still route through it) as the historical
    door; new consumers should import `repro.actors`.
    """
    from repro.actors import actor_policy as _actor_policy
    return _actor_policy(ecfg, acfg, deterministic=deterministic,
                         sampler="ddpm")


@functools.lru_cache(maxsize=None)
def warmup_policy(ecfg: EV.EnvConfig):
    """Uniform agent-space exploration used until the buffer warms up."""
    def policy(params, key, trace, state, obs):
        a = jax.random.uniform(key, (ecfg.action_dim,), minval=-1.0,
                               maxval=1.0)
        return AG.to_env_action(a), {"agent_action": a}
    return policy


def flatten_valid_transitions(tr) -> Tuple[np.ndarray, ...]:
    """Stacked (B, T, ...) collected transitions -> flat (N, ...) arrays of
    the valid steps, in the replay-buffer layout (obs, agent-space action,
    reward, next_obs, done). One layout shared by episodic collection and
    the streaming trainer (`repro.training.stream_train`), so their buffers
    are bitwise-comparable."""
    valid = np.asarray(tr.valid).reshape(-1)
    flat = lambda x: np.asarray(x).reshape((-1,) + x.shape[2:])[valid]  # noqa: E731
    return (flat(tr.obs), flat(tr.extras["agent_action"]), flat(tr.reward),
            flat(tr.next_obs), flat(tr.done))


def push_transitions(buffer: ReplayBuffer, tr) -> int:
    """Flatten the valid steps of stacked transitions into the buffer;
    returns the number of transitions added."""
    flat = flatten_valid_transitions(tr)
    buffer.add_batch(*flat)
    return len(flat[2])


def collect_batch(ecfg: EV.EnvConfig, acfg: AG.AgentConfig, actor_params,
                  traces, keys, buffer: ReplayBuffer, *,
                  warmup: bool = False, exec_spec=None) -> Tuple[Dict, int]:
    """Roll out B parallel episodes and push the valid transitions into the
    replay buffer (agent-space actions). Returns (stacked metrics, n added).

    `exec_spec` (an `api.ExecSpec`, default fused) picks the execution
    backend — collection shards over a device mesh with
    ``ExecSpec(backend="sharded")``, bitwise-identically."""
    from repro.api.backends import rollout_fn_for
    from repro.api.specs import ExecSpec
    policy = warmup_policy(ecfg) if warmup else actor_policy(ecfg, acfg)
    params = {} if warmup else actor_params
    rollout = rollout_fn_for(exec_spec or ExecSpec())
    res = rollout(ecfg, traces, policy, params, keys, collect=True)
    n = push_transitions(buffer, res.transitions)
    return res.metrics, n


def run_update_schedule(ts: TrainState, buffer: ReplayBuffer, rng, key,
                        n_new: int, *, ecfg: EV.EnvConfig,
                        acfg: AG.AgentConfig, scfg: SACConfig,
                        max_updates: int = None):
    """The per-step gradient schedule over `n_new` fresh env steps: once the
    buffer passes warmup, run (n_new // update_every) * updates_per_step
    update steps (capped by `max_updates`) on batches sampled with the host
    `rng`. Shared by the episodic and streaming trainers. Returns
    (new train state, advanced key, updates run)."""
    n_upd = 0
    if buffer.size >= scfg.warmup_steps:
        n_upd = (n_new // scfg.update_every) * scfg.updates_per_step
        if max_updates is not None:
            n_upd = min(n_upd, max_updates)
        for _ in range(n_upd):
            key, ku = jax.random.split(key)
            batch = {k: jnp.asarray(v) for k, v in
                     buffer.sample(rng, scfg.batch_size).items()}
            ts, _ = update_step(ts, batch, ku, ecfg=ecfg, acfg=acfg,
                                scfg=scfg)
    return ts, key, n_upd


def run_episode(ecfg: EV.EnvConfig, trace, actor_params, acfg: AG.AgentConfig,
                key, buffer: ReplayBuffer = None, deterministic: bool = False,
                step_fn=None):
    """Host-driven episode; returns (metrics, transitions, total_reward)."""
    if step_fn is None:
        step_fn = functools.partial(_jit_env_step, ecfg, trace)
    state = EV.reset(ecfg)
    obs = EV.observe(ecfg, trace, state)
    total_r, steps = 0.0, 0
    done = False
    while not done:
        key, ka = jax.random.split(key)
        a = policy_act(actor_params, obs, ka, ecfg=ecfg, acfg=acfg,
                       deterministic=deterministic)
        env_a = AG.to_env_action(a)
        state, next_obs, r, done_arr, info = step_fn(state, env_a)
        done = bool(done_arr)
        if buffer is not None:
            buffer.add(np.asarray(obs), np.asarray(a), float(r),
                       np.asarray(next_obs), done)
        total_r += float(r)
        obs = next_obs
        steps += 1
    metrics = {k: float(v) for k, v in
               EV.episode_metrics(ecfg, trace, state).items()}
    metrics["episode_return"] = total_r
    metrics["episode_len"] = steps
    return metrics


def seed_with_demonstrations(buffer: ReplayBuffer, ecfg: EV.EnvConfig,
                             trace_fn, key, episodes: int = 8):
    """Beyond-paper: fill the replay buffer with Greedy-oracle episodes so
    the off-policy critics see high-reward (reuse-aware) transitions before
    the diffusion actor has learned to produce them. The actor itself is
    never behavior-cloned — this is pure off-policy demonstration seeding."""
    from repro.core import baselines as BL
    n = 0
    for _ in range(episodes):
        key, kt = jax.random.split(key)
        trace = trace_fn(kt)
        step_fn = functools.partial(_jit_env_step, ecfg, trace)
        state = EV.reset(ecfg)
        obs = EV.observe(ecfg, trace, state)
        done = False
        while not done:
            a_env = BL.greedy_act(ecfg, trace, state)
            state, next_obs, r, d, _ = step_fn(state, a_env)
            done = bool(d)
            # store in the agent's native [-1, 1] range
            buffer.add(np.asarray(obs), np.asarray(a_env) * 2.0 - 1.0,
                       float(r), np.asarray(next_obs), done)
            obs = next_obs
            n += 1
    return n


def train(ecfg: EV.EnvConfig, acfg: AG.AgentConfig, scfg: SACConfig,
          trace_fn, num_episodes: int, seed: int = 0, log_every: int = 10,
          callback=None, demo_episodes: int = 0, num_envs: int = 4,
          curriculum=None, exec_spec=None):
    """Full training loop (Algorithm 2). trace_fn(key) -> trace dict.

    Experience comes from the batched rollout engine: each iteration rolls
    out `num_envs` parallel envs (fresh traces) in one jitted program, pushes
    every transition into the buffer, then runs the same number of gradient
    updates the per-step schedule would have done
    (updates_per_step * new_steps / update_every).
    demo_episodes > 0 seeds the buffer with Greedy demonstrations.
    `curriculum` (a list of `scenarios.Scenario` sharing `ecfg`, e.g. from
    `scenarios.training_curriculum`) replaces `trace_fn`: each collection
    round samples one cell, so the policy trains across the workload grid
    — rate sweep, cold-start-heavy mixes, bursty/flash arrivals.
    `exec_spec` (an `api.ExecSpec`) picks the collection execution backend
    (reference / fused / sharded, all bitwise-identical)."""
    key = jax.random.PRNGKey(seed)
    rng = host_rng(key)
    if curriculum:
        from repro.core.scenarios import curriculum_picker
        pick = curriculum_picker(ecfg, curriculum)
    else:
        pick = None
    key, k0 = jax.random.split(key)
    ts = init_train_state(k0, ecfg, acfg)
    buffer = ReplayBuffer(scfg.buffer_capacity, ecfg.obs_shape, ecfg.action_dim)
    if demo_episodes:
        key, kd = jax.random.split(key)
        n = seed_with_demonstrations(buffer, ecfg, trace_fn, kd, demo_episodes)
        if log_every:
            print(f"[demo] seeded buffer with {n} greedy transitions")
    history = []

    ep = 0
    while ep < num_episodes:
        B = min(num_envs, num_episodes - ep)
        key, kt, ke = jax.random.split(key, 3)
        round_trace_fn = pick(rng)[1] if pick else trace_fn
        traces = stack_traces([round_trace_fn(k)
                               for k in jax.random.split(kt, B)])
        keys = jax.random.split(ke, B)
        warmup = buffer.size < scfg.warmup_steps
        metrics, n_new = collect_batch(ecfg, acfg, ts.actor, traces, keys,
                                       buffer, warmup=warmup,
                                       exec_spec=exec_spec)
        # -- updates (same update/env-step ratio as the per-step schedule)
        ts, key, _ = run_update_schedule(ts, buffer, rng, key, n_new,
                                         ecfg=ecfg, acfg=acfg, scfg=scfg)
        for b in range(B):
            em = {k: float(v[b]) for k, v in metrics.items()}
            em.update(episode=ep, episode_len=int(metrics["episode_len"][b]))
            history.append(em)
            if callback:
                callback(ep, em, ts)
            if log_every and ep % log_every == 0:
                print(f"[ep {ep:4d}] R={em['episode_return']:8.2f} "
                      f"len={em['episode_len']:4d} "
                      f"resp={em['avg_response']:7.2f} q={em['avg_quality']:.3f} "
                      f"reload={em['reload_rate']:.2f}")
            ep += 1
    return ts, history
