"""Serving-side fault injection + the exceptions the tolerance layer
catches.

`ExecFaultInjector` draws deterministic transient prefill/decode errors for
the serving executor (one seeded stream per injector, advanced once per
generation attempt in call order — the serving backend is single-threaded,
so the draw sequence is reproducible for a given run). The executor raises
`ExecutorTimeout` itself when a generation attempt exceeds its wall budget;
both exception types are *expected* failures the retry/degrade wrapper in
`serving.backend` handles — anything else propagates.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.faults.spec import FaultSpec


class ExecutorFault(Exception):
    """Base of the transient executor failures the serving layer retries."""


class InjectedExecutorError(ExecutorFault):
    """A deterministic injected transient error (fault-injection testing)."""


class ExecutorTimeout(ExecutorFault):
    """A generation attempt exceeded its wall-clock budget."""


class ExecFaultInjector:
    """Deterministic transient-error source for real executor attempts."""

    def __init__(self, spec: Optional[FaultSpec]):
        self.spec = spec
        self.errors_injected = 0
        self._reseed()

    def _reseed(self) -> None:
        import numpy as np
        seed = 0 if self.spec is None else self.spec.seed
        self._rng = np.random.default_rng(
            np.random.SeedSequence([int(seed) & 0xFFFFFFFF, 0xE33C]))

    def reset(self) -> None:
        """Back to the attempt-0 draw stream (fresh run)."""
        self.errors_injected = 0
        self._reseed()

    @property
    def enabled(self) -> bool:
        return self.spec is not None and self.spec.exec_error_prob > 0.0

    def maybe_fail(self, phase: str = "generate") -> None:
        """Advance the draw stream by one attempt; raise on an injected
        error. Called once per real generation attempt."""
        if not self.enabled:
            return
        if self._rng.random() < self.spec.exec_error_prob:
            self.errors_injected += 1
            raise InjectedExecutorError(
                f"injected transient {phase} error "
                f"(#{self.errors_injected}, p={self.spec.exec_error_prob})")

    def counters(self) -> Dict[str, int]:
        return {"exec_errors_injected": int(self.errors_injected)}
