"""`FaultSpec` — the frozen, hashable description of a fault regime.

One spec covers every fault class the stack can inject and the tolerance
policy that answers it:

* **server crashes**: each server alternates Exp(`mtbf`) up-time with
  Exp(`mttr`) down-time (a classic renewal availability process). A down
  server is masked out of gang selection; a gang whose member crashes
  mid-execution fails in-flight (task status 3) and its servers free at the
  crash instant. With `cold_restart` a crash also wipes the server's cached
  model + gang metadata — recovery pays the full reload (the model-load
  storm EAT schedules around).
* **stragglers**: per (window, server) with probability `straggler_prob`
  the server's execution slows by `straggler_factor`; a gang runs at its
  slowest member's speed (the DistriFusion sync barrier).
* **executor faults** (serving backend only): transient prefill/decode
  errors injected with `exec_error_prob`, plus a wall-clock `exec_timeout_s`
  on real generation; both are answered by retry (`exec_max_attempts`) and
  a final graceful-degradation attempt at `degrade_steps_frac` of the
  requested inference steps.
* **requeue policy** (streaming engine): failed gangs re-enter the backlog
  with capped exponential backoff (`backoff_base` * 2^retries, capped at
  `backoff_cap`) under a per-task budget of `max_retries` and a hard age
  deadline `retry_deadline` — a retry that could not possibly be re-served
  inside the deadline is dropped immediately (deadline-aware).

The spec rides on ``ExecSpec(faults=...)`` and ``StreamConfig(faults=...)``;
it is frozen and hashable so it can key compiled-program caches. Everything
is seeded (`seed`) and host-generated, so the same spec + key produces the
identical fault schedule on every execution backend.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class FaultSpec:
    seed: int = 0
    # -- server crash/recovery renewal process (stream seconds) ----------
    mtbf: float = 0.0              # mean up-time; 0 disables crashes
    mttr: float = 60.0             # mean down-time
    max_down_events: int = 16      # per-window down-interval slots/server
    cold_restart: bool = True      # recovery wipes cached model + gang
    # -- stragglers ------------------------------------------------------
    straggler_prob: float = 0.0    # P(server straggles) per window
    straggler_factor: float = 4.0  # exec-time multiplier when straggling
    # -- gang requeue policy (host, StreamRunner) ------------------------
    max_retries: int = 3           # fail budget per task; 0 = naive drop
    backoff_base: float = 2.0      # s; delay = base * 2^(retries-1)
    backoff_cap: float = 60.0      # s; exponential backoff ceiling
    retry_deadline: float = 480.0  # s; max age at re-admission, else drop
    # -- serving executor faults + tolerance -----------------------------
    exec_error_prob: float = 0.0   # injected transient prefill/decode error
    exec_timeout_s: float = 0.0    # wall budget per attempt; 0 = none
    exec_max_attempts: int = 3     # generation attempts before giving up
    degrade_steps_frac: float = 0.5  # last-attempt steps fraction; 0 = off

    def __post_init__(self):
        if self.mtbf < 0 or self.mttr <= 0:
            raise ValueError(
                f"mtbf must be >= 0 and mttr > 0, got {self.mtbf}/{self.mttr}")
        if self.max_down_events < 1:
            raise ValueError("max_down_events must be >= 1")
        if not 0.0 <= self.straggler_prob <= 1.0:
            raise ValueError("straggler_prob must be in [0, 1]")
        if self.straggler_factor < 1.0:
            raise ValueError("straggler_factor must be >= 1")
        if self.max_retries < 0 or self.exec_max_attempts < 1:
            raise ValueError("max_retries >= 0 and exec_max_attempts >= 1")
        if self.backoff_base < 0 or self.backoff_cap < 0:
            raise ValueError("backoff_base/backoff_cap must be >= 0")
        if not 0.0 <= self.exec_error_prob <= 1.0:
            raise ValueError("exec_error_prob must be in [0, 1]")
        if not 0.0 <= self.degrade_steps_frac <= 1.0:
            raise ValueError("degrade_steps_frac must be in [0, 1]")

    # ------------------------------------------------------------------
    @property
    def active(self) -> bool:
        """True when this spec injects any fault at all. An inactive spec
        (``FaultSpec.none()``) attaches nothing to the rollout: the compiled
        programs — and therefore every result — are bitwise-identical to
        running with ``faults=None``."""
        return (self.mtbf > 0.0 or self.straggler_prob > 0.0
                or self.exec_error_prob > 0.0 or self.exec_timeout_s > 0.0)

    @classmethod
    def none(cls) -> "FaultSpec":
        """The explicit no-faults spec (all injection rates zero)."""
        return cls()

    @classmethod
    def chaos(cls, seed: int = 0) -> "FaultSpec":
        """An aggressive everything-on regime for smoke tests: frequent
        crashes, slow recovery relative to task service times, stragglers,
        and injected executor errors."""
        return cls(seed=seed, mtbf=120.0, mttr=30.0, straggler_prob=0.25,
                   straggler_factor=3.0, max_retries=2, backoff_base=1.0,
                   backoff_cap=16.0, retry_deadline=600.0,
                   exec_error_prob=0.5, exec_timeout_s=30.0,
                   exec_max_attempts=2, degrade_steps_frac=0.5)


def faults_active(spec) -> bool:
    """None-tolerant activity test used by every plumbing layer."""
    return spec is not None and spec.active
