"""Deterministic host-side fault schedules.

`FaultTimeline` materialises a `FaultSpec` into concrete per-server
crash/recovery intervals and per-window straggler factors, then slices them
into the fixed-shape device arrays the fused decision step consumes:

    f_down_start  (B, E, F) f32  window-local down-interval starts
    f_down_end    (B, E, F) f32  window-local down-interval ends
    f_slow        (B, E)    f32  execution-time multiplier (>= 1)
    f_cold        (B, 1)    f32  1.0 when crashes wipe the model cache

Crash intervals are an alternating Exp(mtbf)/Exp(mttr) renewal process per
(stream, server) on the ABSOLUTE stream clock, drawn lazily from a
counter-seeded numpy generator — the timeline is a pure function of
(spec.seed, stream, server), independent of window boundaries, batch order,
or execution backend. Window `w` sees the intervals overlapping
[t0, t0 + horizon) rebased to the window-local clock (starts may be
negative for a window that opens mid-outage); unused slots pad at INF so
every device-side test (`start <= t < end`) is vacuously false.

Everything here is numpy on the host; the arrays ride inside the rollout's
`traces` dict, so they shard (leading batch axis), vmap, and jit exactly
like the task columns.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.faults.spec import FaultSpec

INF = np.float32(1e30)

#: trace keys the fused decision step consumes (presence = faults enabled)
FAULT_COLS = ("f_down_start", "f_down_end", "f_slow", "f_cold")
#: per-task retry-count column threaded through the window for the seam
RETRY_COL = "f_retries"


def _rng(*tokens: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence(
        [int(t) & 0xFFFFFFFF for t in tokens]))


class FaultTimeline:
    """Lazily-extended absolute crash timeline + per-window array slicer.

    One instance per run (the StreamRunner / Simulator owns it); windows
    must be requested with non-decreasing `t0` per stream (the stream clock
    only moves forward), which lets the timeline prune spent intervals.
    """

    def __init__(self, spec: FaultSpec, num_servers: int,
                 num_streams: int = 1):
        self.spec = spec
        self.E = int(num_servers)
        self.B = int(num_streams)
        # per (stream, server): absolute (start, end) down intervals
        self._events = [[[] for _ in range(self.E)] for _ in range(self.B)]
        self._rngs = [[_rng(spec.seed, 0xC7A5, b, e) for e in range(self.E)]
                      for b in range(self.B)]
        self._gen_until = np.zeros((self.B, self.E), np.float64)
        self.down_events = 0            # intervals materialised so far
        self.overflow_events = 0        # intervals beyond max_down_events

    # ------------------------------------------------------------------
    def _extend(self, b: int, e: int, until: float) -> None:
        """Grow (b, e)'s renewal process to cover [0, until)."""
        if self.spec.mtbf <= 0.0:
            self._gen_until[b, e] = max(self._gen_until[b, e], until)
            return
        rng = self._rngs[b][e]
        t = self._gen_until[b, e]
        while t < until:
            up = rng.exponential(self.spec.mtbf)
            down = rng.exponential(self.spec.mttr)
            start = t + up
            self._events[b][e].append((start, start + down))
            self.down_events += 1
            t = start + down
        self._gen_until[b, e] = t

    def window_arrays(self, window: int, t0: np.ndarray,
                      horizon: float) -> Dict[str, np.ndarray]:
        """Fixed-shape fault arrays for one window.

        `t0` is the (B,) absolute epoch of each stream's window start;
        `horizon` bounds how far past t0 crash intervals are materialised —
        it must cover the window's decision span (`ecfg.time_limit`) plus
        the longest possible in-flight execution, so a crash landing inside
        any schedulable gang's run is visible at schedule time.
        """
        B, E, F = self.B, self.E, int(self.spec.max_down_events)
        t0 = np.asarray(t0, np.float64)
        if t0.shape != (B,):
            raise ValueError(f"t0 must be shape ({B},), got {t0.shape}")
        ds = np.full((B, E, F), INF, np.float32)
        de = np.full((B, E, F), INF, np.float32)
        for b in range(B):
            for e in range(E):
                self._extend(b, e, float(t0[b]) + float(horizon))
                # prune intervals fully behind this window (the stream
                # clock is monotonic, so they can never be needed again)
                evs = [ev for ev in self._events[b][e] if ev[1] > t0[b]]
                self._events[b][e] = evs
                if len(evs) > F:
                    self.overflow_events += len(evs) - F
                    evs = evs[:F]
                for i, (s, t_end) in enumerate(evs):
                    ds[b, e, i] = np.float32(s - t0[b])
                    de[b, e, i] = np.float32(t_end - t0[b])
        slow = np.ones((B, E), np.float32)
        if self.spec.straggler_prob > 0.0:
            for b in range(B):
                r = _rng(self.spec.seed, 0x57A6, window, b)
                hit = r.random(E) < self.spec.straggler_prob
                slow[b] = np.where(hit, self.spec.straggler_factor,
                                   1.0).astype(np.float32)
        cold = np.full((B, 1), 1.0 if self.spec.cold_restart else 0.0,
                       np.float32)
        return {"f_down_start": ds, "f_down_end": de, "f_slow": slow,
                "f_cold": cold}

    # ------------------------------------------------------------------
    def counters(self) -> Dict[str, int]:
        return {"down_events": int(self.down_events),
                "down_events_truncated": int(self.overflow_events)}


def fault_horizon(time_limit: float, spec: Optional[FaultSpec] = None
                  ) -> float:
    """Crash-visibility horizon past a window's t0: the decision span plus a
    generous bound on in-flight execution (Table-VI init ~36 s + 50 steps
    at the slowest per-step cost, times the worst straggler factor)."""
    overhang = 36.0 + 0.53 * 50.0
    if spec is not None and spec.straggler_prob > 0.0:
        overhang *= float(spec.straggler_factor)
    return float(time_limit) + overhang


def retry_backoff(spec: FaultSpec, retries: int) -> float:
    """Capped exponential backoff before re-admission attempt `retries`
    (1-indexed: the first retry waits `backoff_base`)."""
    return float(min(spec.backoff_base * (2.0 ** max(retries - 1, 0)),
                     spec.backoff_cap))
