"""Seeded, deterministic fault injection + the fault-tolerance policy.

Front door: build a `FaultSpec` and hand it to ``ExecSpec(faults=...)`` (the
Simulator plumbs it through every execution backend) or directly to
``StreamConfig(faults=...)``. `FaultSpec.none()` — or leaving it None — is
bitwise-identical to a fault-free run: no arrays are attached, so the
compiled programs are unchanged.
"""
from repro.faults.inject import (ExecFaultInjector, ExecutorFault,
                                 ExecutorTimeout, InjectedExecutorError)
from repro.faults.schedule import (FAULT_COLS, RETRY_COL, FaultTimeline,
                                   fault_horizon, retry_backoff)
from repro.faults.spec import FaultSpec, faults_active

__all__ = [
    "FaultSpec", "faults_active", "FaultTimeline", "fault_horizon",
    "retry_backoff", "FAULT_COLS", "RETRY_COL", "ExecFaultInjector",
    "ExecutorFault", "ExecutorTimeout", "InjectedExecutorError",
]
