"""Public wrappers: adapt the diffusion-policy params dict to the fused
denoiser kernels.

Two entry points, mirroring `kernels/env_step/ops.py`:

* ``denoise_eps_fused`` — one eps-MLP forward (drop-in for
  `repro.core.diffusion.denoise_eps`), one `denoiser_step` kernel launch.
* ``denoise_chain`` — the whole K-step reverse chain with
  ``impl="auto"|"ref"|"pallas"`` dispatch: "ref" is the jnp oracle/CPU fast
  path (`ref.denoiser_chain_ref`, shape-polymorphic so it vmaps inside the
  fused rollout scan), "pallas" is the single-launch whole-chain kernel
  (`kernel.denoiser_chain`; interpret mode on CPU). "auto" picks pallas on
  gpu/tpu and ref elsewhere. Both are bitwise-identical on the same inputs.

Both validate the params dict shape up front: the kernels hard-code the
paper's 3-layer Mish MLP (Table VII), and a params dict with any other
depth used to be silently mis-read (extra layers ignored / IndexError).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.diffusion import timestep_embedding
from repro.kernels.denoiser import ref as KREF
from repro.kernels.denoiser.kernel import denoiser_chain, denoiser_step


def _flat_weights(denoiser_params):
    """Validate the 3-layer MLP shape and flatten to (w1, b1, ..., b3)."""
    layers = denoiser_params.get("layers") \
        if hasattr(denoiser_params, "get") else None
    if layers is None:
        raise ValueError(
            "denoiser params must be the core.networks.init_mlp dict "
            "{'layers': [{'w','b'}, ...]}; got "
            f"{type(denoiser_params).__name__}")
    if len(layers) != 3:
        raise ValueError(
            f"fused denoiser kernels support exactly 3 MLP layers "
            f"(in -> hidden -> hidden -> out, paper Table VII); got "
            f"{len(layers)} layers — use repro.core.diffusion.denoise_eps "
            "for other depths")
    return (layers[0]["w"], layers[0]["b"], layers[1]["w"], layers[1]["b"],
            layers[2]["w"], layers[2]["b"])


def resolve_impl(impl: str = "auto") -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() in ("gpu", "tpu") else "ref"
    if impl not in ("ref", "pallas"):
        raise ValueError(f"impl must be auto|ref|pallas, got {impl!r}")
    return impl


def denoise_eps_fused(denoiser_params, x, i, f_s, t_dim: int = 16,
                      interpret: bool = True):
    """Drop-in for repro.core.diffusion.denoise_eps (batched inputs)."""
    w1, b1, w2, b2, w3, b3 = _flat_weights(denoiser_params)
    temb = timestep_embedding(i, t_dim)
    inp = jnp.concatenate([x, temb, f_s], axis=-1)
    squeeze = inp.ndim == 1
    if squeeze:
        inp = inp[None]
    out = denoiser_step(inp, w1, b1, w2, b2, w3, b3, interpret=interpret)
    return out[0] if squeeze else out


def denoise_chain(denoiser_params, x, noises, f_s, tembs, coef_x, coef_e,
                  coef_n, *, impl: str = "auto", block_b: int = 128,
                  interpret=None):
    """Whole K-step reverse chain on the params dict.

    x: (..., A); noises: (K, ..., A); f_s: (..., F); tembs: (K, t_dim);
    coef_*: (K,). Returns tanh(x_0) with x's shape. The pallas path
    requires a 2-D batch (1-D inputs are expanded and squeezed back).
    """
    w = _flat_weights(denoiser_params)
    impl = resolve_impl(impl)
    if impl == "ref":
        return KREF.denoiser_chain_ref(x, noises, f_s, tembs,
                                       coef_x, coef_e, coef_n, *w)
    if interpret is None:
        interpret = jax.default_backend() not in ("gpu", "tpu")
    squeeze = x.ndim == 1
    if squeeze:
        x, noises, f_s = x[None], noises[:, None], f_s[None]
    out = denoiser_chain(x, noises, f_s, tembs, coef_x, coef_e, coef_n,
                         *w, block_b=block_b, interpret=bool(interpret))
    return out[0] if squeeze else out
