"""Public wrapper: adapts the diffusion-policy params dict to the fused kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.diffusion import timestep_embedding
from repro.kernels.denoiser.kernel import denoiser_step


def denoise_eps_fused(denoiser_params, x, i, f_s, t_dim: int = 16,
                      interpret: bool = True):
    """Drop-in for repro.core.diffusion.denoise_eps (batched inputs)."""
    layers = denoiser_params["layers"]
    temb = timestep_embedding(i, t_dim)
    inp = jnp.concatenate([x, temb, f_s], axis=-1)
    squeeze = inp.ndim == 1
    if squeeze:
        inp = inp[None]
    out = denoiser_step(inp,
                        layers[0]["w"], layers[0]["b"],
                        layers[1]["w"], layers[1]["b"],
                        layers[2]["w"], layers[2]["b"],
                        interpret=interpret)
    return out[0] if squeeze else out
