"""Fused diffusion-policy denoiser step (the paper's per-decision hot loop).

EAT runs T=10 sequential denoiser forward passes per scheduling decision
(Algorithm 1 lines 5–11); each pass is a small 2x256 Mish MLP. Launch
overhead and HBM round-trips between the three matmuls dominate at this
size, so we fuse concat(x, t_emb, f_s) -> fc1 -> mish -> fc2 -> mish ->
fc3 -> tanh into a single kernel: all weights (~0.5 MB) and activations stay
in VMEM, and the batch dimension is tiled across the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mish(x):
    return x * jnp.tanh(jax.nn.softplus(x))


def _pin(x):
    """Value-preserving FMA blocker (`env._pin`, replicated here so the
    kernel module stays import-light): the chain's affine update must emit
    the same mul/add sequence as the ref oracle in every compilation
    context."""
    return jnp.minimum(x, 1e30)


def _denoiser_kernel(inp_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                     out_ref):
    x = inp_ref[...].astype(jnp.float32)
    h = _mish(jax.lax.dot_general(x, w1_ref[...].astype(jnp.float32),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
              + b1_ref[...])
    h = _mish(jax.lax.dot_general(h, w2_ref[...].astype(jnp.float32),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
              + b2_ref[...])
    o = jnp.tanh(jax.lax.dot_general(h, w3_ref[...].astype(jnp.float32),
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
                 + b3_ref[...])
    out_ref[...] = o.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def denoiser_step(inp, w1, b1, w2, b2, w3, b3, *, block_b: int = 128,
                  interpret: bool = True):
    """inp: (B, D_in) = concat(x_i, t_emb, f_s); returns eps (B, A)."""
    B, din = inp.shape
    h1 = w1.shape[1]
    h2 = w2.shape[1]
    a = w3.shape[1]
    block_b = min(block_b, B)
    bp = (-B) % block_b
    inp_p = jnp.pad(inp, ((0, bp), (0, 0)))
    nb = (B + bp) // block_b
    out = pl.pallas_call(
        _denoiser_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, din), lambda i: (i, 0)),
            pl.BlockSpec((din, h1), lambda i: (0, 0)),
            pl.BlockSpec((h1,), lambda i: (0,)),
            pl.BlockSpec((h1, h2), lambda i: (0, 0)),
            pl.BlockSpec((h2,), lambda i: (0,)),
            pl.BlockSpec((h2, a), lambda i: (0, 0)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + bp, a), inp.dtype),
        interpret=interpret,
    )(inp_p, w1, b1, w2, b2, w3, b3)
    return out[:B]


def _chain_kernel(x_ref, noises_ref, f_ref, temb_ref, cx_ref, ce_ref,
                  cn_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                  out_ref):
    """All K reverse steps for one batch block, weights resident across the
    whole chain — one kernel launch per decision instead of K."""
    w1 = w1_ref[...].astype(jnp.float32)
    b1 = b1_ref[...]
    w2 = w2_ref[...].astype(jnp.float32)
    b2 = b2_ref[...]
    w3 = w3_ref[...].astype(jnp.float32)
    b3 = b3_ref[...]
    f = f_ref[...].astype(jnp.float32)
    K, t_dim = temb_ref.shape
    block_b = x_ref.shape[0]

    def step(j, x):
        t_b = jnp.broadcast_to(temb_ref[j], (block_b, t_dim))
        inp = jnp.concatenate([x, t_b, f], axis=-1)
        h = _mish(jax.lax.dot_general(inp, w1, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                  + b1)
        h = _mish(jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                  + b2)
        eps = jnp.tanh(jax.lax.dot_general(h, w3, (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
                       + b3)
        return (_pin(cx_ref[j] * x) + _pin(ce_ref[j] * eps)
                + _pin(cn_ref[j] * noises_ref[j]))

    x0 = jax.lax.fori_loop(0, K, step, x_ref[...].astype(jnp.float32))
    out_ref[...] = jnp.tanh(x0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def denoiser_chain(x, noises, f_s, tembs, coef_x, coef_e, coef_n,
                   w1, b1, w2, b2, w3, b3, *, block_b: int = 128,
                   interpret: bool = True):
    """Whole K-step reverse-diffusion chain as ONE kernel launch.

    x: (B, A) initial x_K; noises: (K, B, A); f_s: (B, F); tembs: (K, t_dim);
    coef_*: (K,) affine chain coefficients (see `actors.samplers`). Returns
    tanh(x_0) (B, A) — bitwise-identical to `ref.denoiser_chain_ref` on the
    same inputs (tests/test_actors.py).
    """
    B, a = x.shape
    K = tembs.shape[0]
    fdim = f_s.shape[1]
    t_dim = tembs.shape[1]
    h1 = w1.shape[1]
    h2 = w2.shape[1]
    block_b = min(block_b, B)
    bp = (-B) % block_b
    x_p = jnp.pad(x, ((0, bp), (0, 0)))
    n_p = jnp.pad(noises, ((0, 0), (0, bp), (0, 0)))
    f_p = jnp.pad(f_s, ((0, bp), (0, 0)))
    nb = (B + bp) // block_b
    out = pl.pallas_call(
        _chain_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, a), lambda i: (i, 0)),
            pl.BlockSpec((K, block_b, a), lambda i: (0, i, 0)),
            pl.BlockSpec((block_b, fdim), lambda i: (i, 0)),
            pl.BlockSpec((K, t_dim), lambda i: (0, 0)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((a + t_dim + fdim, h1), lambda i: (0, 0)),
            pl.BlockSpec((h1,), lambda i: (0,)),
            pl.BlockSpec((h1, h2), lambda i: (0, 0)),
            pl.BlockSpec((h2,), lambda i: (0,)),
            pl.BlockSpec((h2, a), lambda i: (0, 0)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + bp, a), x.dtype),
        interpret=interpret,
    )(x_p, n_p, f_p, tembs, coef_x, coef_e, coef_n, w1, b1, w2, b2, w3, b3)
    return out[:B]
