"""Fused diffusion-policy denoiser step (the paper's per-decision hot loop).

EAT runs T=10 sequential denoiser forward passes per scheduling decision
(Algorithm 1 lines 5–11); each pass is a small 2x256 Mish MLP. Launch
overhead and HBM round-trips between the three matmuls dominate at this
size, so we fuse concat(x, t_emb, f_s) -> fc1 -> mish -> fc2 -> mish ->
fc3 -> tanh into a single kernel: all weights (~0.5 MB) and activations stay
in VMEM, and the batch dimension is tiled across the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mish(x):
    return x * jnp.tanh(jax.nn.softplus(x))


def _denoiser_kernel(inp_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                     out_ref):
    x = inp_ref[...].astype(jnp.float32)
    h = _mish(jax.lax.dot_general(x, w1_ref[...].astype(jnp.float32),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
              + b1_ref[...])
    h = _mish(jax.lax.dot_general(h, w2_ref[...].astype(jnp.float32),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
              + b2_ref[...])
    o = jnp.tanh(jax.lax.dot_general(h, w3_ref[...].astype(jnp.float32),
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
                 + b3_ref[...])
    out_ref[...] = o.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def denoiser_step(inp, w1, b1, w2, b2, w3, b3, *, block_b: int = 128,
                  interpret: bool = True):
    """inp: (B, D_in) = concat(x_i, t_emb, f_s); returns eps (B, A)."""
    B, din = inp.shape
    h1 = w1.shape[1]
    h2 = w2.shape[1]
    a = w3.shape[1]
    block_b = min(block_b, B)
    bp = (-B) % block_b
    inp_p = jnp.pad(inp, ((0, bp), (0, 0)))
    nb = (B + bp) // block_b
    out = pl.pallas_call(
        _denoiser_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, din), lambda i: (i, 0)),
            pl.BlockSpec((din, h1), lambda i: (0, 0)),
            pl.BlockSpec((h1,), lambda i: (0,)),
            pl.BlockSpec((h1, h2), lambda i: (0, 0)),
            pl.BlockSpec((h2,), lambda i: (0,)),
            pl.BlockSpec((h2, a), lambda i: (0, 0)),
            pl.BlockSpec((a,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, a), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + bp, a), inp.dtype),
        interpret=interpret,
    )(inp_p, w1, b1, w2, b2, w3, b3)
    return out[:B]
