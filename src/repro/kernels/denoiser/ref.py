"""Pure-jnp oracles for the fused denoiser kernels.

`denoiser_ref` matches one `repro.core.diffusion.denoise_eps` forward given
the same flattened weights. `denoiser_chain_ref` is the whole-chain oracle:
K affine reverse-diffusion steps (x <- c_x x + c_e eps + c_n noise) with the
eps-MLP inside the loop, finished by the tanh action bound. It doubles as
the CPU fast path of `ops.denoise_chain` — exactly the env-step idiom where
`ref.py` is both the parity oracle and the production implementation off
accelerators.

The affine update is `_pin`-armored (`env._pin`): each product is pinned
before the sum so LLVM cannot contract a context-dependent subset of the
multiply-adds into FMAs, which would break bitwise kernel-vs-oracle parity
in pallas interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.env import _pin
from repro.models.layers import mish


def denoiser_ref(inp, w1, b1, w2, b2, w3, b3):
    h = mish(inp @ w1 + b1)
    h = mish(h @ w2 + b2)
    return jnp.tanh(h @ w3 + b3)


def denoiser_chain_ref(x, noises, f_s, tembs, coef_x, coef_e, coef_n,
                       w1, b1, w2, b2, w3, b3):
    """Run the K-step reverse chain. Shapes:

        x       (..., A)      initial x_K ~ N(0, I)
        noises  (K, ..., A)   per-step posterior noise (zeros for DDIM)
        f_s     (..., F)      state feature, constant across steps
        tembs   (K, t_dim)    per-step timestep embeddings
        coef_*  (K,)          affine chain coefficients

    Returns tanh(x_0), (..., A). The step order is j = 0..K-1 (step j
    denoises timestep index K-1-j; the coefficient builders in
    `repro.actors.samplers` encode the schedule).
    """
    K = tembs.shape[0]
    t_shape = x.shape[:-1] + (tembs.shape[-1],)

    def body(j, x):
        t_b = jnp.broadcast_to(tembs[j], t_shape)
        inp = jnp.concatenate([x, t_b, f_s], axis=-1)
        eps = denoiser_ref(inp, w1, b1, w2, b2, w3, b3)
        return (_pin(coef_x[j] * x) + _pin(coef_e[j] * eps)
                + _pin(coef_n[j] * noises[j]))

    x0 = jax.lax.fori_loop(0, K, body, x, unroll=True)
    return jnp.tanh(x0)
