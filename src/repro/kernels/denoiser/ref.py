"""Pure-jnp oracle: matches repro.core.diffusion.denoise_eps given the same
flattened weights."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mish


def denoiser_ref(inp, w1, b1, w2, b2, w3, b3):
    h = mish(inp @ w1 + b1)
    h = mish(h @ w2 + b2)
    return jnp.tanh(h @ w3 + b3)
