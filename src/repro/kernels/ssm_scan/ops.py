"""Public wrapper for the selective-scan kernel (pads seq/channels)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan


@functools.partial(jax.jit, static_argnames=("block_s", "block_i", "interpret"))
def selective_scan(dt, a, bm, cm, x, h0=None, *, block_s: int = 64,
                   block_i: int = 256, interpret: bool = True):
    B, S, I = dt.shape
    N = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, I, N), jnp.float32)
    block_s = min(block_s, S)
    block_i = min(block_i, I)
    sp = (-S) % block_s
    ip = (-I) % block_i
    pad3 = lambda z: jnp.pad(z, ((0, 0), (0, sp), (0, 0)))
    dt_p = jnp.pad(dt, ((0, 0), (0, sp), (0, ip)))
    x_p = jnp.pad(x, ((0, 0), (0, sp), (0, ip)))
    a_p = jnp.pad(a, ((0, ip), (0, 0)))
    h0_p = jnp.pad(h0, ((0, 0), (0, ip), (0, 0)))
    y, hT = ssm_scan(dt_p, a_p, pad3(bm), pad3(cm), x_p, h0_p,
                     block_s=block_s, block_i=block_i, interpret=interpret)
    return y[:, :S, :I], hT[:, :I]
