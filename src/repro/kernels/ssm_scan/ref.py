"""Pure-jnp oracle for the selective-scan kernel (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(dt, a, bm, cm, x, h0):
    """Same contract as kernel.ssm_scan. Straight lax.scan over time."""
    def step(h, xs):
        dt_t, b_t, c_t, x_t = xs                       # (B,I), (B,N), (B,N), (B,I)
        da = jnp.exp(dt_t[..., None].astype(jnp.float32) * a.astype(jnp.float32))
        h = da * h + (dt_t * x_t)[..., None].astype(jnp.float32) * b_t[:, None, :].astype(jnp.float32)
        y = jnp.einsum("bin,bn->bi", h, c_t.astype(jnp.float32))
        return h, y

    xs = (dt.swapaxes(0, 1), bm.swapaxes(0, 1), cm.swapaxes(0, 1), x.swapaxes(0, 1))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1).astype(dt.dtype), hT
