"""Pallas TPU selective-scan (Mamba) kernel.

TPU adaptation of the CUDA selective-scan: instead of one thread block per
(batch, channel-chunk) with warp-level scans, we tile the *channel* (inner)
dimension across the parallel grid axes and keep the *sequence* axis as the
trailing (sequential) grid dimension; the recurrent state (block_i, N) lives
in VMEM scratch and carries across sequence blocks. Within a sequence block
the recurrence runs as an unrolled fori_loop over timesteps — each step is a
(block_i, N) elementwise FMA, which maps onto the VPU; the state never
round-trips to HBM.

Contract (matches ref.py):
    dt:   (B, S, I)   softplus-discretised timestep
    A:    (I, N)      negative-real state matrix
    Bm:   (B, S, N)   input projection
    Cm:   (B, S, N)   output projection
    x:    (B, S, I)   post-conv activations
    h0:   (B, I, N)   initial state
 -> y:    (B, S, I)   with  h_t = exp(dt A) h_{t-1} + dt B x ;  y_t = C.h_t
    hT:   (B, I, N)   final state
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(dt_ref, a_ref, b_ref, c_ref, x_ref, h0_ref, y_ref, hT_ref,
                h_scr, *, block_s: int, block_i: int, num_s_blocks: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)           # (bi, N)

    a = a_ref[...].astype(jnp.float32)                       # (bi, N)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)              # (bi,)
        x_t = x_ref[0, t].astype(jnp.float32)                # (bi,)
        b_t = b_ref[0, t].astype(jnp.float32)                # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)                # (N,)
        da = jnp.exp(dt_t[:, None] * a)                      # (bi, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t] = (h @ c_t).astype(y_ref.dtype)          # (bi,)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h

    @pl.when(si == num_s_blocks - 1)
    def _final():
        hT_ref[0] = h.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "block_i", "interpret"))
def ssm_scan(dt, a, bm, cm, x, h0, *, block_s: int = 64, block_i: int = 256,
             interpret: bool = True):
    B, S, I = dt.shape
    N = a.shape[1]
    block_s = min(block_s, S)
    block_i = min(block_i, I)
    assert S % block_s == 0 and I % block_i == 0, (S, I, block_s, block_i)
    ns = S // block_s
    ni = I // block_i

    kernel = functools.partial(_ssm_kernel, block_s=block_s, block_i=block_i,
                               num_s_blocks=ns)
    # layout: channel-blocked inputs (B, S, I) -> blocks (1, bs, bi)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, ni, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_i), lambda b, i, s: (b, s, i)),  # dt
            pl.BlockSpec((block_i, N), lambda b, i, s: (i, 0)),             # A
            pl.BlockSpec((1, block_s, N), lambda b, i, s: (b, s, 0)),       # B
            pl.BlockSpec((1, block_s, N), lambda b, i, s: (b, s, 0)),       # C
            pl.BlockSpec((1, block_s, block_i), lambda b, i, s: (b, s, i)),  # x
            pl.BlockSpec((1, block_i, N), lambda b, i, s: (b, i, 0)),       # h0
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_i), lambda b, i, s: (b, s, i)),
            pl.BlockSpec((1, block_i, N), lambda b, i, s: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, I), dt.dtype),
            jax.ShapeDtypeStruct((B, I, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_i, N), jnp.float32)],
        interpret=interpret,
    )(dt, a, bm, cm, x, h0)
    return y, hT
