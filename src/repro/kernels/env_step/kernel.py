"""Pallas-fused environment decision step over a batch axis of envs.

One kernel launch advances a block of B parallel envs by one scheduling
decision: lazy retirement, visible-queue slot pick, reuse detection,
fragmentation-aware server selection, masked server/task state update,
reward terms, next-event time advance, and the *next* visible-queue top-k +
Eq.-6 observation — everything the per-decision hot path of ``env.step``
used to spend dozens of small XLA ops on.

Kernel-friendly restructurings (shared with ``ref.env_step_ref``, which is
the bitwise oracle):

* no `lax.top_k` / `argsort`: the queue top-k and the idle-server ranking
  are counting/rank passes (sum of pairwise strict comparisons), which the
  VPU handles as plain vectorized compares + reductions;
* no scatters/gathers: task updates are one-hot `where` masks, per-task
  attribute reads are one-hot masked reductions (exact — a single non-zero
  term per reduction);
* per-env scalars travel as (B, 1) lanes so every ref is at least 2-D
  (TPU-friendly); boolean masks cross the kernel boundary as int32.

The batch axis is tiled across the grid; E/K/queue-window dims stay whole.
``interpret=True`` is the CPU fallback used by the parity tests (the CPU
fast path in ``ops.env_step_fused`` is the vmapped jnp reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import env as EV
from repro.core import quality as Q

_I32 = jnp.int32
_F32 = jnp.float32


def _iota(shape, axis):
    return jax.lax.broadcasted_iota(_I32, shape, axis)


def _env_step_kernel(cfg: EV.EnvConfig, faults: bool, *refs):
    (time_ref, free_ref, smodel_ref, sgang_ref, sgsize_ref,
     tstatus_ref, tstart_ref, tfinish_ref, tsteps_ref,
     tqual_ref, treload_ref, staken_ref,
     arr_ref, c_ref, model_ref, noise_ref,
     stepb_ref, initb_ref, scalem_ref,
     action_ref, qidx_ref, qvalid_ref, qqueued_ref) = refs[:23]
    n_in = 23
    if faults:                      # four extra fault-schedule inputs
        fds_ref, fde_ref, fslow_ref, fcold_ref = refs[23:27]
        n_in = 27
    (o_time, o_free, o_smodel, o_sgang, o_sgsize,
     o_tstatus, o_tstart, o_tfinish, o_tsteps,
     o_tqual, o_treload, o_staken,
     o_qidx, o_qvalid, o_qqueued, o_obs, o_reward, o_done) = refs[n_in:]
    E, K, l = cfg.num_servers, cfg.max_tasks, cfg.queue_window
    t = time_ref[...]                       # (bb, 1)
    free = free_ref[...]                    # (bb, E)
    smodel = smodel_ref[...]
    sgang = sgang_ref[...]
    sgsize = sgsize_ref[...]
    tstatus = tstatus_ref[...]              # (bb, K)
    tstart = tstart_ref[...]
    tfinish = tfinish_ref[...]
    tsteps = tsteps_ref[...]
    tqual = tqual_ref[...]
    treload = treload_ref[...]
    staken = staken_ref[...]                # (bb, 1)
    arr = arr_ref[...]                      # (bb, K)
    c = c_ref[...]
    model = model_ref[...]
    noise = noise_ref[...]
    step_base = stepb_ref[...]
    init_base = initb_ref[...]
    scale = scalem_ref[...]
    action = action_ref[...]                # (bb, 2 + l)
    qidx = qidx_ref[...]                    # (bb, l) i32
    qvalid = qvalid_ref[...] != 0           # (bb, l) bool
    queued = qqueued_ref[...] != 0          # (bb, K) bool

    bb = t.shape[0]
    iota_l = _iota((bb, l), 1)
    iota_K = _iota((bb, K), 1)

    # lazily retire finished tasks
    finished = (tstatus == 1) & (tfinish <= t)
    status = jnp.where(finished, 2, tstatus)

    if faults:
        # same fault semantics (and expressions) as env.decision_step /
        # ref.env_step_ref: down mask + cold-restart cache wipe
        ds = fds_ref[...]               # (bb, E, F)
        de = fde_ref[...]               # (bb, E, F)
        fslow = fslow_ref[...]          # (bb, E)
        fcold = fcold_ref[...]          # (bb, 1)
        t3 = t[:, :, None]              # (bb, 1, 1)
        down = jnp.any((ds <= t3) & (t3 < de), axis=2)            # (bb, E)
        wipe = jnp.any(ds <= t3, axis=2) & (fcold > 0)
        smodel = jnp.where(wipe, -1, smodel)
        sgang = jnp.where(wipe, -1, sgang)
        sgsize = jnp.where(wipe, 0, sgsize)

    # visible-queue slot pick (first-match argmax over preference scores)
    scores = jnp.where(qvalid, action[:, 2:], -1e30)
    smax = jnp.max(scores, axis=1, keepdims=True)
    slot = jnp.min(jnp.where(scores == smax, iota_l, l), axis=1, keepdims=True)
    at_slot = iota_l == slot
    k = jnp.sum(jnp.where(at_slot, qidx, 0), axis=1, keepdims=True)
    k_valid = jnp.sum(jnp.where(at_slot, qvalid.astype(_I32), 0),
                      axis=1, keepdims=True) > 0

    hotk = iota_K == k                                        # (bb, K)

    def pick(a, zero):
        return jnp.sum(jnp.where(hotk, a, zero), axis=1, keepdims=True)

    want_exec = action[:, 0:1] <= 0.5
    c_k = pick(c, 0)
    m_k = pick(model, 0)
    scale_k = pick(scale, 0.0)
    idle = free <= t
    if faults:                          # a down server cannot join a gang
        idle = idle & ~down
    n_idle = jnp.sum(idle.astype(_I32), axis=1, keepdims=True)
    feasible = want_exec & k_valid & (n_idle >= c_k)

    # --- server selection: reuse detection + counting-rank fresh pick -----
    has_gang = sgang >= 0
    same = sgang[:, :, None] == sgang[:, None, :]             # (bb, E, E)
    ok = idle & has_gang & (smodel == m_k) & (sgsize == c_k)
    counts = jnp.sum((same & ok[:, None, :]).astype(_I32), axis=2)
    complete = ok & (counts == c_k)
    reuse = jnp.any(complete, axis=1, keepdims=True)
    g_star = jnp.min(jnp.where(complete, sgang, 2 ** 30),
                     axis=1, keepdims=True)
    reuse_sel = ok & (sgang == g_star)

    member_ok = idle & has_gang
    counts_all = jnp.sum((same & member_ok[:, None, :]).astype(_I32), axis=2)
    intact = member_ok & (counts_all == sgsize) & (sgsize > 0)
    score = jnp.where(idle,
                      intact.astype(_F32) * (100.0 + 10.0 * sgsize)
                      + 0.001 * _iota((bb, E), 1),
                      1e30)
    rank = jnp.sum((score[:, None, :] < score[:, :, None]).astype(_I32),
                   axis=2)
    fresh_sel = idle & (rank < c_k)
    sel = jnp.where(reuse, reuse_sel, fresh_sel)

    # --- timing / quality of the candidate decision -----------------------
    # env._pin blocks FMA contraction of product-then-add chains: the
    # kernel is code-generated in its own context where LLVM may fuse
    # mul+add (1 ulp off the jnp reference); an optimization_barrier alone
    # does not survive the fused loop body, the value-preserving min does.
    _pin = EV._pin
    steps = jnp.round(cfg.s_min + _pin(jnp.clip(action[:, 1:2], 0.0, 1.0)
                      * (cfg.s_max - cfg.s_min))).astype(_I32)
    steps_f = steps.astype(_F32)
    t_exec = _pin(pick(step_base, 0.0) * steps_f * scale_k)
    if faults:                          # gang speed = slowest member's speed
        slow_k = jnp.max(jnp.where(sel, fslow, 1.0), axis=1, keepdims=True)
        t_exec = _pin(t_exec * slow_k)
    t_init = _pin(jnp.where(reuse, 0.0, pick(init_base, 0.0) * scale_k))
    finish = t + t_exec + t_init
    q_k = Q.quality_of(steps, pick(noise, 0.0))
    pen = Q.quality_penalty(q_k, cfg.q_min, cfg.p_quality)
    t_resp = finish - pick(arr, 0.0)

    if faults:
        # in-flight failure: a selected server crashes before the gang
        # finishes (status 3, servers freed at the crash, no reward)
        fin3 = finish[:, :, None]       # (bb, 1, 1)
        crash_cand = sel[:, :, None] & (ds > t3) & (ds < fin3)    # (bb, E, F)
        crash_t = jnp.min(jnp.min(jnp.where(crash_cand, ds, 1e30), axis=2),
                          axis=1, keepdims=True)
        will_fail = crash_t < 1e30
        sched_status = jnp.where(will_fail, 3, 1)
        rec_finish = jnp.where(will_fail, crash_t, finish)
    else:
        sched_status, rec_finish = 1, finish

    # --- apply schedule (masked) ------------------------------------------
    f = feasible
    sel_f = sel & f
    new_free = jnp.where(sel_f, rec_finish, free)
    new_model = jnp.where(sel_f, m_k, smodel)
    new_gang = jnp.where(sel_f, k, sgang)
    new_gsize = jnp.where(sel_f, c_k, sgsize)

    hit = hotk & f
    status2 = jnp.where(hit, sched_status, status)
    start2 = jnp.where(hit, t, tstart)
    tfin2 = jnp.where(hit, rec_finish, tfinish)
    tsteps2 = jnp.where(hit, steps, tsteps)
    tq2 = jnp.where(hit, q_k, tqual)
    trl2 = jnp.where(hit, jnp.where(reuse, 0, 1).astype(_I32), treload)

    # reward (only on successful schedule)
    still_queued = queued & (iota_K != k)
    n_q = jnp.maximum(jnp.sum(still_queued.astype(_F32), axis=1,
                              keepdims=True), 1.0)
    t_avg = jnp.sum(jnp.where(still_queued, t - arr, 0.0), axis=1,
                    keepdims=True) / n_q
    r = _pin(cfg.alpha_q * q_k) - _pin(cfg.lambda_q * pen) \
        + cfg.k_time / (_pin(cfg.beta_t * t_resp) + _pin(cfg.mu_t * t_avg)
                        + 1e-3)
    reward = jnp.where(f, r, 0.0)
    if faults:                          # a gang that will crash earns nothing
        reward = jnp.where(will_fail, 0.0, reward)

    # --- advance time on no-op --------------------------------------------
    next_arrival = jnp.min(jnp.where(arr > t, arr, 1e30), axis=1,
                           keepdims=True)
    next_completion = jnp.min(jnp.where(new_free > t, new_free, 1e30),
                              axis=1, keepdims=True)
    next_event = jnp.minimum(next_arrival, next_completion)
    if faults:                          # recoveries are events too
        next_recovery = jnp.min(
            jnp.min(jnp.where((ds <= t3) & (de > t3), de, 1e30), axis=2),
            axis=1, keepdims=True)
        next_event = jnp.minimum(next_event, next_recovery)
    t_new = jnp.where(f, t, jnp.where(next_event < 1e30, next_event, t + 1.0))

    staken2 = staken + 1
    resolved = (status2 == 2) | ((status2 == 1) & (tfin2 <= t_new))
    if faults:                          # failed tasks resolve (host retries)
        resolved = resolved | (status2 == 3)
    all_done = jnp.all(resolved, axis=1, keepdims=True)
    done = all_done | (t_new >= cfg.time_limit) | (staken2 >= cfg.max_steps)

    # --- next visible queue: counting-rank top-k --------------------------
    queued2 = (status2 == 0) & (arr <= t_new)
    prio = jnp.where(queued2, arr, 1e30)
    earlier = (prio[:, None, :] < prio[:, :, None]) \
        | ((prio[:, None, :] == prio[:, :, None])
           & (iota_K[:, None, :] < iota_K[:, :, None]))
    rank_q = jnp.sum(earlier.astype(_I32), axis=2)            # (bb, K)
    slot_hit = rank_q[:, None, :] == iota_l[:, :, None]       # (bb, l, K)
    idx2 = jnp.sum(jnp.where(slot_hit, iota_K[:, None, :], 0), axis=2)
    valid2 = iota_l < jnp.sum(queued2.astype(_I32), axis=1, keepdims=True)

    # --- Eq.-6 observation of the new state -------------------------------
    up = new_free <= t_new
    if faults:                          # obs mirrors core.obs: down servers
        t_new3 = t_new[:, :, None]      # are unavailable to the policy too
        up = up & ~jnp.any((ds <= t_new3) & (t_new3 < de), axis=2)
    avail = up.astype(_F32)
    inv_ts = 1.0 / cfg.time_scale
    inv_nm = 1.0 / max(cfg.num_models, 1)
    remaining = jnp.maximum(new_free - t_new, 0.0) * inv_ts
    modelrow = (new_model.astype(_F32) + 1.0) * inv_nm
    arr_v = jnp.sum(jnp.where(slot_hit, arr[:, None, :], 0.0), axis=2)
    c_v = jnp.sum(jnp.where(slot_hit, c[:, None, :], 0), axis=2)
    wait = jnp.where(valid2, (t_new - arr_v) * inv_ts, 0.0)
    crow = jnp.where(valid2, c_v.astype(_F32) / 8.0, 0.0)
    if cfg.num_models > 1:
        m_v = jnp.sum(jnp.where(slot_hit, model[:, None, :], 0), axis=2)
        mrow = jnp.where(valid2, (m_v.astype(_F32) + 1.0) * inv_nm, 0.0)
    else:
        mrow = jnp.zeros_like(crow)
    obs = jnp.stack([jnp.concatenate([avail, wait], axis=1),
                     jnp.concatenate([remaining, crow], axis=1),
                     jnp.concatenate([modelrow, mrow], axis=1)], axis=1)

    o_time[...] = t_new
    o_free[...] = new_free
    o_smodel[...] = new_model
    o_sgang[...] = new_gang
    o_sgsize[...] = new_gsize
    o_tstatus[...] = status2
    o_tstart[...] = start2
    o_tfinish[...] = tfin2
    o_tsteps[...] = tsteps2
    o_tqual[...] = tq2
    o_treload[...] = trl2
    o_staken[...] = staken2
    o_qidx[...] = idx2
    o_qvalid[...] = valid2.astype(_I32)
    o_qqueued[...] = queued2.astype(_I32)
    o_obs[...] = obs
    o_reward[...] = reward
    o_done[...] = done.astype(_I32)


@functools.partial(jax.jit, static_argnames=("cfg", "block_b", "interpret"))
def env_step_pallas(cfg: EV.EnvConfig, time, free, smodel, sgang, sgsize,
                    tstatus, tstart, tfinish, tsteps, tqual, treload, staken,
                    arr, c, model, noise, step_base, init_base, scale,
                    action, qidx, qvalid, qqueued, *,
                    fds=None, fde=None, fslow=None, fcold=None,
                    block_b: int = 256, interpret: bool = True):
    """Raw batched kernel entry: (B, ...) arrays in, tuple of 18 arrays out.

    Per-env scalars are (B, 1); boolean masks are int32 0/1 on both sides.
    The optional fault-schedule quartet (`fds`/`fde` (B, E, F) down
    intervals, `fslow` (B, E) straggler multipliers, `fcold` (B, 1)
    cold-restart flag — see `repro.faults.schedule`) switches the kernel
    into fault mode; leaving them None traces the exact fault-free program.
    Use ``ops.env_step_fused`` for the EnvState/QueueView-level wrapper.
    """
    faults = fds is not None
    B = time.shape[0]
    E, K, l = cfg.num_servers, cfg.max_tasks, cfg.queue_window
    A = cfg.action_dim
    bb = min(block_b, B)
    pad = (-B) % bb
    ins = [time, free, smodel, sgang, sgsize, tstatus, tstart, tfinish,
           tsteps, tqual, treload, staken, arr, c, model, noise,
           step_base, init_base, scale, action, qidx, qvalid, qqueued]
    if faults:
        F = fds.shape[2]
        ins += [fds, fde, fslow, fcold]
    if pad:
        ins = [jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)) for x in ins]
    nb = (B + pad) // bb

    def spec(*dims):
        return pl.BlockSpec((bb,) + dims, lambda i: (i,) + (0,) * len(dims))

    in_specs = [spec(1), spec(E), spec(E), spec(E), spec(E),        # server
                spec(K), spec(K), spec(K), spec(K), spec(K), spec(K),
                spec(1),                                            # staken
                spec(K), spec(K), spec(K), spec(K), spec(K), spec(K),
                spec(K),                                            # statics
                spec(A), spec(l), spec(l), spec(K)]                 # act + q
    if faults:
        in_specs += [spec(E, F), spec(E, F), spec(E), spec(1)]      # faults
    out_specs = [spec(1), spec(E), spec(E), spec(E), spec(E),
                 spec(K), spec(K), spec(K), spec(K), spec(K), spec(K),
                 spec(1),
                 spec(l), spec(l), spec(K), spec(3, E + l), spec(1), spec(1)]

    def shp(dtype, *dims):
        return jax.ShapeDtypeStruct((B + pad,) + dims, dtype)

    out_shape = [shp(_F32, 1), shp(_F32, E), shp(_I32, E), shp(_I32, E),
                 shp(_I32, E),
                 shp(_I32, K), shp(_F32, K), shp(_F32, K), shp(_I32, K),
                 shp(_F32, K), shp(_I32, K),
                 shp(_I32, 1),
                 shp(_I32, l), shp(_I32, l), shp(_I32, K),
                 shp(_F32, 3, E + l), shp(_F32, 1), shp(_I32, 1)]

    outs = pl.pallas_call(
        functools.partial(_env_step_kernel, cfg, faults),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*ins)
    if pad:
        outs = [o[:B] for o in outs]
    return tuple(outs)
