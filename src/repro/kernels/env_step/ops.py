"""Public wrapper: fused batched env decision step over (B,) parallel envs.

``env_step_fused`` advances every env in a batch by one scheduling decision
in a single fused op and returns the next queue view + observation along
with the state, so a rollout costs one queue pass per decision. Two
interchangeable implementations (bitwise-identical outputs):

* ``impl="ref"``   — the vmapped pure-jnp reference (`ref.env_step_ref`).
  This is the CPU fast path: XLA compiles the whole decision into one
  fused loop nest, with no `top_k`/`argsort`/scatter ops.
* ``impl="pallas"`` — the Pallas kernel (`kernel.env_step_pallas`), one
  kernel launch per decision across the batch. On CPU it runs with
  ``interpret=True`` (parity testing); on GPU/TPU it compiles.

``impl="auto"`` picks "pallas" on gpu/tpu backends and "ref" elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import env as EV
from repro.kernels.env_step.kernel import env_step_pallas
from repro.kernels.env_step.ref import env_step_ref


def resolve_impl(impl: str = "auto") -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() in ("gpu", "tpu") else "ref"
    if impl not in ("ref", "pallas"):
        raise ValueError(f"impl must be auto|ref|pallas, got {impl!r}")
    return impl


def env_step_fused(ecfg: EV.EnvConfig, statics, state: EV.EnvState,
                   action, queue: EV.QueueView, *, impl: str = "auto",
                   block_b: int = 256, interpret=None):
    """One fused decision for B envs.

    All of `statics` (per-task constants from ``env.decision_statics``),
    `state`, `action` (B, A) and `queue` carry a leading (B,) batch axis.
    Returns (state', queue', obs', reward (B,), done (B,)) — bitwise equal
    to vmapping the legacy ``env.step`` and re-observing, minus the
    redundant second top-k.
    """
    impl = resolve_impl(impl)
    if impl == "ref":
        return jax.vmap(
            lambda st, a, qv, sx: env_step_ref(ecfg, sx, st, a, qv)
        )(state, action, queue, statics)

    if interpret is None:
        interpret = jax.default_backend() not in ("gpu", "tpu")
    as_i32 = lambda b: b.astype(jnp.int32)
    fault_kw = {}
    if EV.has_faults(statics):      # fault schedules ride as extra inputs
        fault_kw = dict(fds=statics["f_down_start"],
                        fde=statics["f_down_end"],
                        fslow=statics["f_slow"], fcold=statics["f_cold"])
    outs = env_step_pallas(
        ecfg,
        state.time[:, None], state.server_free_at, state.server_model,
        state.server_gang, state.server_gang_size,
        state.task_status, state.task_start, state.task_finish,
        state.task_steps, state.task_quality, state.task_reload,
        state.steps_taken[:, None],
        statics["arr_time"], statics["c"], statics["model"],
        statics["noise"], statics["step_base"], statics["init_base"],
        statics["scale"],
        action, queue.idx, as_i32(queue.valid), as_i32(queue.queued),
        **fault_kw, block_b=block_b, interpret=bool(interpret))
    (time, free, smodel, sgang, sgsize, tstatus, tstart, tfinish, tsteps,
     tqual, treload, staken, qidx, qvalid, qqueued, obs, reward, done) = outs
    new_state = EV.EnvState(
        time=time[:, 0], server_free_at=free, server_model=smodel,
        server_gang=sgang, server_gang_size=sgsize,
        task_status=tstatus, task_start=tstart, task_finish=tfinish,
        task_steps=tsteps, task_quality=tqual, task_reload=treload,
        steps_taken=staken[:, 0])
    new_queue = EV.QueueView(idx=qidx, valid=qvalid != 0,
                             queued=qqueued != 0)
    return new_state, new_queue, obs, reward[:, 0], done[:, 0] != 0
