"""Pure-jnp oracle for the fused environment decision step.

One call advances one env by one scheduling decision and also produces the
next visible-queue view and observation, so a rollout costs exactly one
queue pass per decision. The math mirrors ``env.decision_step`` +
``env.visible_queue`` + ``env.observe_from`` bit-for-bit, but restructured
the way the Pallas kernel computes it:

* the visible-queue top-k is a counting/rank pass (`lax.top_k` is stable —
  ties broken by lowest index — which the strict (prio, index) order below
  reproduces exactly);
* fragmentation-aware server selection ranks idle servers by counting
  strictly-smaller scores instead of a full `argsort` (idle scores are
  unique thanks to the 0.001*arange tie-breaker, busy servers sit at INF and
  are masked out, so the counting rank equals the argsort rank wherever it
  is consumed);
* task-array updates are one-hot `where` masks instead of scatters;
* latency-table lookups come from per-task ``env.decision_statics`` hoisted
  out of the rollout scan (same multiplication order as
  ``timemodel.exec_time`` / ``init_time``, so floats are bitwise equal).

Batch with `jax.vmap` (``ops.env_step_fused`` does) — everything here is
fixed-shape jnp on one env.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core import env as EV
from repro.core import quality as Q

INF = EV.INF


def env_step_ref(cfg: EV.EnvConfig, statics: Dict, state: EV.EnvState,
                 action: jnp.ndarray, q: EV.QueueView):
    """Fused decision: (state', queue', obs', reward, done) for one env."""
    E, K, l = cfg.num_servers, cfg.max_tasks, cfg.queue_window
    arr = statics["arr_time"]
    t = state.time
    faulty = EV.has_faults(statics)

    # lazily retire finished tasks
    finished = (state.task_status == 1) & (state.task_finish <= t)
    status = jnp.where(finished, 2, state.task_status)

    if faulty:
        # same fault semantics (and the same expressions, for bitwise
        # parity) as env.decision_step: down mask, cold-restart cache wipe
        ds, de = statics["f_down_start"], statics["f_down_end"]   # (E, F)
        down = jnp.any((ds <= t) & (t < de), axis=1)
        wipe = jnp.any(ds <= t, axis=1) & (statics["f_cold"][0] > 0)
        state = state._replace(
            server_model=jnp.where(wipe, -1, state.server_model),
            server_gang=jnp.where(wipe, -1, state.server_gang),
            server_gang_size=jnp.where(wipe, 0, state.server_gang_size))

    idx, valid, queued = q.idx, q.valid, q.queued
    scores = jnp.where(valid, action[2:], -INF)
    slot = jnp.argmax(scores)
    k = idx[slot]
    k_valid = valid[slot]

    want_exec = action[0] <= 0.5
    c_k = statics["c"][k]
    m_k = statics["model"][k]
    scale_k = statics["scale"][k]
    idle = state.server_free_at <= t
    if faulty:                       # a down server cannot join a gang
        idle = idle & ~down
    n_idle = jnp.sum(idle.astype(jnp.int32))
    feasible = want_exec & k_valid & (n_idle >= c_k)

    # --- server selection: reuse detection + counting-rank fresh pick -----
    gang = state.server_gang
    has_gang = gang >= 0
    same = gang[:, None] == gang[None, :]
    ok = idle & has_gang & (state.server_model == m_k) \
        & (state.server_gang_size == c_k)
    counts = jnp.sum(same & ok[None, :], axis=1)
    complete = ok & (counts == c_k)
    reuse = jnp.any(complete)
    g_star = jnp.min(jnp.where(complete, gang, jnp.int32(2 ** 30)))
    reuse_sel = ok & (gang == g_star)

    member_ok = idle & has_gang
    counts_all = jnp.sum(same & member_ok[None, :], axis=1)
    intact = member_ok & (counts_all == state.server_gang_size) \
        & (state.server_gang_size > 0)
    score = jnp.where(idle,
                      intact.astype(jnp.float32) * (100.0 + 10.0 * state.server_gang_size)
                      + 0.001 * jnp.arange(E),
                      INF)
    rank = jnp.sum(score[None, :] < score[:, None], axis=1).astype(jnp.int32)
    fresh_sel = idle & (rank < c_k)
    sel = jnp.where(reuse, reuse_sel, fresh_sel)

    # --- timing / quality of the candidate decision -----------------------
    # env._pin keeps mul->add chains FMA-proof, exactly as in decision_step
    _pin = EV._pin
    steps = jnp.round(cfg.s_min + _pin(jnp.clip(action[1], 0.0, 1.0)
                      * (cfg.s_max - cfg.s_min))).astype(jnp.int32)
    steps_f = steps.astype(jnp.float32)
    t_exec = _pin(statics["step_base"][k] * steps_f * scale_k)
    if faulty:                       # gang speed = slowest member's speed
        slow_k = jnp.max(jnp.where(sel, statics["f_slow"], 1.0))
        t_exec = _pin(t_exec * slow_k)
    t_init = _pin(jnp.where(reuse, 0.0, statics["init_base"][k] * scale_k))
    finish = t + t_exec + t_init
    q_k = Q.quality_of(steps, statics["noise"][k])
    pen = Q.quality_penalty(q_k, cfg.q_min, cfg.p_quality)
    t_resp = finish - arr[k]

    if faulty:
        # in-flight failure: a selected server crashes before the gang
        # finishes (status 3, servers freed at the crash, no reward)
        crash_cand = sel[:, None] & (ds > t) & (ds < finish)      # (E, F)
        crash_t = jnp.min(jnp.where(crash_cand, ds, INF))
        will_fail = crash_t < INF
        sched_status = jnp.where(will_fail, 3, 1)
        rec_finish = jnp.where(will_fail, crash_t, finish)
    else:
        sched_status, rec_finish = 1, finish

    # --- apply schedule (masked; one-hot instead of scatter) --------------
    f = feasible
    sel_f = sel & f
    new_free = jnp.where(sel_f, rec_finish, state.server_free_at)
    new_model = jnp.where(sel_f, m_k, state.server_model)
    new_gang = jnp.where(sel_f, k.astype(jnp.int32), state.server_gang)
    new_gsize = jnp.where(sel_f, c_k, state.server_gang_size)

    iota = jnp.arange(K)
    hit = (iota == k) & f
    status2 = jnp.where(hit, sched_status, status)
    start2 = jnp.where(hit, t, state.task_start)
    tfin2 = jnp.where(hit, rec_finish, state.task_finish)
    tsteps2 = jnp.where(hit, steps, state.task_steps)
    tq2 = jnp.where(hit, q_k, state.task_quality)
    trl2 = jnp.where(hit, jnp.where(reuse, 0, 1).astype(jnp.int32),
                     state.task_reload)

    # reward (only on successful schedule)
    still_queued = queued & (iota != k)
    n_q = jnp.maximum(jnp.sum(still_queued.astype(jnp.float32)), 1.0)
    t_avg = jnp.sum(jnp.where(still_queued, t - arr, 0.0)) / n_q
    r = _pin(cfg.alpha_q * q_k) - _pin(cfg.lambda_q * pen) \
        + cfg.k_time / (_pin(cfg.beta_t * t_resp) + _pin(cfg.mu_t * t_avg)
                        + 1e-3)
    reward = jnp.where(f, r, 0.0)
    if faulty:                       # a gang that will crash earns nothing
        reward = jnp.where(will_fail, 0.0, reward)

    # --- advance time on no-op --------------------------------------------
    next_arrival = jnp.min(jnp.where(arr > t, arr, INF))
    next_completion = jnp.min(jnp.where(new_free > t, new_free, INF))
    next_event = jnp.minimum(next_arrival, next_completion)
    if faulty:                       # recoveries are events too, or a fully
        next_recovery = jnp.min(     # down cluster would stall the clock
            jnp.where((ds <= t) & (de > t), de, INF))
        next_event = jnp.minimum(next_event, next_recovery)
    t_new = jnp.where(f, t, jnp.where(next_event < INF, next_event, t + 1.0))

    steps_taken = state.steps_taken + 1
    new_state = EV.EnvState(
        time=t_new, server_free_at=new_free, server_model=new_model,
        server_gang=new_gang, server_gang_size=new_gsize,
        task_status=status2, task_start=start2, task_finish=tfin2,
        task_steps=tsteps2, task_quality=tq2, task_reload=trl2,
        steps_taken=steps_taken,
    )
    resolved = (status2 == 2) | ((status2 == 1) & (tfin2 <= t_new))
    if faulty:                       # failed tasks are resolved (host retries)
        resolved = resolved | (status2 == 3)
    all_done = jnp.all(resolved)
    done = all_done | (t_new >= cfg.time_limit) | (steps_taken >= cfg.max_steps)

    # --- next visible queue + Eq.-6 observation ---------------------------
    # `decision_statics` keeps the trace columns (`arr_time`/`c`/`model`),
    # so the env's own queue/observation helpers apply directly: the jnp
    # reference keeps `lax.top_k` (O(K log K), bitwise-stable ties by
    # index), while the Pallas kernel — where no top_k primitive exists —
    # reproduces it with its counting/rank pass.
    q2 = EV.visible_queue(cfg, statics, new_state)
    obs = EV.observe_from(cfg, statics, new_state, q2)
    return new_state, q2, obs, reward, done
