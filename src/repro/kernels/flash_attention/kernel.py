"""Pallas TPU flash attention (causal / full / sliding-window, GQA).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) with the KV axis
innermost — TPU grid iteration is sequential over the trailing axis, so the
online-softmax running statistics (m, l, acc) live in VMEM scratch and carry
across KV steps. BlockSpecs tile Q/K/V into (block_q, head_dim) /
(block_k, head_dim) VMEM blocks; head_dim is expected to be a multiple of
128 on real TPUs (the MXU lane width) — the ops.py wrapper pads if needed.

Causality is handled two ways: fully-masked KV blocks are skipped with
``pl.when`` (no FLOPs issued), and the diagonal block applies an elementwise
mask built from ``broadcasted_iota``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, window: int, block_q: int, block_k: int,
                 num_kv_blocks: int, sm_scale: float, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip: block fully above the diagonal (causal) or fully
    # outside the sliding window
    run = k_start < kv_len
    if causal:
        run = run & (k_start <= q_start + block_q - 1)
    if window:
        run = run & (k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask = mask & (kpos <= qpos)
        if window:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret",
                     "kv_len"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True, kv_len: int = 0):
    """q: (B, H, S, hd); k/v: (B, KV, T, hd); returns (B, H, S, hd).

    H % KV == 0 (GQA). S and T must be multiples of block_q/block_k (the
    ops.py wrapper pads). ``interpret=True`` executes on CPU for validation;
    on a real TPU pass interpret=False.
    """
    b, h, s, hd = q.shape
    kvh, t = k.shape[1], k.shape[2]
    g = h // kvh
    nq = s // block_q
    nk = t // block_k
    sm_scale = float(hd) ** -0.5

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk, sm_scale=sm_scale,
        kv_len=kv_len or t)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, q_, k_, g=g: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, q_, k_, g=g: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
