"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import simple_attention


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, S, hd); k/v: (B, KV, T, hd) — kernel layout (head-major)."""
    # simple_attention expects (B, S, H, hd)
    o = simple_attention(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                         causal=causal, window=window)
    return o.swapaxes(1, 2)
