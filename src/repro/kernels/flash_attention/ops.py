"""Jitted public wrapper: pads to block multiples, handles layout.

Public contract matches ``repro.models.attention.flash_attention_jnp``:
q: (B, S, H, hd); k/v: (B, T, KV, hd) -> (B, S, H, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128, interpret: bool = True):
    b, s, h, hd = q.shape
    t = k.shape[1]
    block_q = min(block_q, max(8, s))
    block_k = min(block_k, max(8, t))
    sp = (-s) % block_q
    tp = (-t) % block_k
    qp = jnp.pad(q, ((0, 0), (0, sp), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tp), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tp), (0, 0), (0, 0)))
    # head-major layout for the kernel
    qm = qp.transpose(0, 2, 1, 3)
    km = kp.transpose(0, 2, 1, 3)
    vm = vp.transpose(0, 2, 1, 3)
    o = flash_attention(qm, km, vm, causal=causal, window=window,
                        block_q=block_q, block_k=block_k, interpret=interpret,
                        kv_len=t)
    return o.transpose(0, 2, 1, 3)[:, :s]
