"""Train in the stream: SAC/PPO learning from windowed streaming rollouts.

The paper trains EAT on fixed-length episodes that reset the cluster every
K tasks; PR 2's finding was that paper arrival rates *overload* the cluster
in sustained streams — the regime a deployed scheduler actually faces
(arXiv 2412.18212, 2405.08328). This module closes that gap: each training
round advances one (or more) windows of an open-loop arrival stream through
the unified execution backends with `collect=True`, carries environment
state across the window seam (clock rebase, residual server occupancy,
backlog carry + max_carry shedding — `traffic.stream.StreamRunner`), pushes
the window's valid transitions into the replay buffer (SAC) or GAE pool
(PPO), then runs gradient updates. The policy therefore trains on the
backlog distribution it *induces*, not on fresh resets.

Execution is backend-transparent: `exec_spec` picks reference / fused /
sharded (`api.backends`), all bitwise-identical — with
``ExecSpec(backend="sharded")`` the stream axis shards over the device
mesh. Arrival curricula (`curriculum=` — Poisson / MMPP bursts / diurnal /
flash-crowd cells from `core.scenarios.training_curriculum`) steer the
traffic mix per round through one continuous clock
(`traffic.stream.CurriculumTaskSource`), and every round logs streaming QoS
telemetry (p95/p99 latency, drop-inclusive violation rate, drop rate,
goodput) alongside the usual training metrics.

    from repro.training import stream_train as ST
    res = ST.train_stream_sac(ecfg, acfg, SACConfig(),
                              ST.StreamTrainConfig(rounds=32, streams=8,
                                                   rate_scale=2.0),
                              exec_spec=ExecSpec(backend="sharded"))
    res.state, res.history[-1]["latency_p99"], res.stream.summary
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import actors as ACT
from repro.core import agent as AG
from repro.core import env as EV
from repro.core import ppo as PPO
from repro.core import sac as SAC
from repro.core.replay import ReplayBuffer
from repro.core.scenarios import Scenario
from repro.core.workload import TraceConfig, paper_rate_for
from repro.telemetry import metrics as MET
from repro.telemetry.trace import tracer_for
from repro.traffic import metrics as MX
from repro.traffic.arrivals import PoissonArrivals, scale_rate
from repro.traffic.stream import (CurriculumTaskSource, StreamConfig,
                                  StreamResult, StreamRunner)

# per-round QoS telemetry copied from the round aggregator into history rows
QOS_KEYS = ("latency_p50", "latency_p95", "latency_p99",
            "qos_violation_rate", "drop_rate", "goodput_per_s",
            "throughput_per_s", "utilization")


@dataclass(frozen=True)
class StreamTrainConfig:
    """Shape of a streaming training run (shared by SAC and PPO).

    One *round* = `windows_per_round` stream windows of K = ecfg.max_tasks
    tasks per stream, collected with the current policy, followed by
    gradient updates. `rate_scale` multiplies every cell's arrival
    intensity (`traffic.arrivals.scale_rate`) — > 1 trains under sustained
    overload, the regime the ROADMAP item targets. `max_updates_per_round`
    caps the gradient work per round (smoke tests / benches); None keeps
    the algorithm's own update/env-step ratio.
    """
    rounds: int = 32
    windows_per_round: int = 1
    streams: int = 4                      # B parallel streams (shard axis)
    rate_scale: float = 1.0
    max_steps_per_window: Optional[int] = None
    max_carry: Optional[int] = None
    resp_sla: float = 120.0
    chunk_size: int = 0
    max_updates_per_round: Optional[int] = None
    log_every: int = 0
    #: collection-time sampler for the SAC diffusion actor ("ddpm" — the
    #: default, bitwise-identical to the historical trainer — or "ddim:K"
    #: for cheaper per-decision inference during collection; resolved
    #: through the shared actor layer. "distilled" is rejected: the student
    #: head does not exist in a TrainState mid-training.
    sampler: str = "ddpm"

    def __post_init__(self):
        from repro.actors import normalize_sampler
        if normalize_sampler(self.sampler) == "distilled":
            raise ValueError(
                "stream training collects with the online actor; "
                "sampler='distilled' needs a student head that only exists "
                "after training (use ddpm or ddim:K)")
        if self.rounds < 0:
            raise ValueError(f"rounds must be >= 0, got {self.rounds}")
        if self.windows_per_round < 1:
            raise ValueError(f"windows_per_round must be >= 1, got "
                             f"{self.windows_per_round}")
        if self.streams < 1:
            raise ValueError(f"streams must be >= 1, got {self.streams}")
        if self.rate_scale <= 0.0:
            raise ValueError(f"rate_scale must be > 0, got "
                             f"{self.rate_scale}")


class StreamTrainResult(NamedTuple):
    state: Any                    # SAC.TrainState | PPO.PPOState
    history: List[Dict]           # one row per round (training + QoS)
    stream: StreamResult          # run-level QoS summary + final carry


# ----------------------------------------------------------------------
def resolve_cells(ecfg: EV.EnvConfig, scenario: Optional[Scenario],
                  curriculum: Optional[Sequence[Scenario]],
                  rate_scale: float = 1.0
                  ) -> List[Tuple[str, Any, TraceConfig]]:
    """Scenario cells -> [(name, arrival process, TraceConfig)] for the
    curriculum task source. Every cell must share the training `ecfg` (one
    compiled rollout program serves them all); a missing arrival process
    means Poisson at the cell's configured rate; `rate_scale` scales every
    process's intensity uniformly."""
    if curriculum and scenario:
        raise ValueError("pass either scenario= or curriculum=, not both")
    cells = list(curriculum) if curriculum else None
    if cells is None:
        sc = scenario
        if sc is None:
            base = paper_rate_for(ecfg.num_servers)
            sc = Scenario(
                name=f"poisson-{ecfg.num_servers}srv",
                ecfg=ecfg,
                tcfg=TraceConfig(num_tasks=ecfg.max_tasks, arrival_rate=base,
                                 max_servers=ecfg.num_servers,
                                 num_models=ecfg.num_models))
        cells = [sc]
    out = []
    for sc in cells:
        if sc.ecfg != ecfg:
            raise ValueError(
                f"cell {sc.name!r} has a different EnvConfig than the "
                "training env; build cells with "
                "scenarios.training_curriculum(ecfg)")
        tc = sc.tcfg
        if tc.num_tasks != ecfg.max_tasks:
            tc = dataclasses.replace(tc, num_tasks=ecfg.max_tasks)
        proc = sc.arrival if sc.arrival is not None else PoissonArrivals(
            tc.arrival_rate)
        out.append((sc.name, scale_rate(proc, rate_scale), tc))
    return out


def _make_runner(ecfg, cells, key, stcfg: StreamTrainConfig, exec_spec,
                 policy, params):
    from repro.api.backends import rollout_fn_for
    from repro.api.specs import ExecSpec
    k_src, k_stream = jax.random.split(key)
    source = CurriculumTaskSource([(proc, tc) for _, proc, tc in cells],
                                  k_src, num_streams=stcfg.streams,
                                  chunk_size=stcfg.chunk_size)
    scfg = StreamConfig(
        num_windows=stcfg.rounds * stcfg.windows_per_round,
        num_streams=stcfg.streams,
        max_steps_per_window=stcfg.max_steps_per_window,
        max_carry=stcfg.max_carry, resp_sla=stcfg.resp_sla,
        chunk_size=stcfg.chunk_size,
        faults=getattr(exec_spec, "faults", None),
        placement=getattr(exec_spec, "placement", None))
    rollout = rollout_fn_for(exec_spec or ExecSpec())
    tracer = tracer_for(getattr(exec_spec, "trace", None))
    runner = StreamRunner(ecfg, policy, params, source, k_stream, scfg,
                          rollout_fn=rollout, tracer=tracer)
    return source, runner


def _round_row(r: int, cell_name: str, ragg: MX.StreamAggregator,
               runner: StreamRunner, returns: List[float], n_new: int,
               n_upd: int) -> Dict:
    row = {"round": r, "cell": cell_name,
           "transitions": n_new, "updates": n_upd,
           "episode_return_mean": float(np.mean(returns)),
           "backlog": runner.backlog()}
    rs = ragg.summary()
    row.update({k: rs[k] for k in QOS_KEYS})
    return row


def _publish_round(row: Dict, algo: str) -> None:
    """Round row -> unified metrics registry gauges (eat_train_*); the
    registry snapshot is what `TraceConfig.metrics_path` exports."""
    MET.publish_summary(row, prefix="eat_train",
                        labels={"algo": algo, "cell": str(row["cell"])})


def _log_row(tag: str, row: Dict) -> None:
    print(f"[{tag} round {row['round']:4d}] cell={row['cell']:<12s} "
          f"R={row['episode_return_mean']:8.2f} "
          f"p99={row['latency_p99']:8.1f}s "
          f"viol={row['qos_violation_rate']:.3f} "
          f"drop={row['drop_rate']:.3f} backlog={row['backlog']:4d} "
          f"buf/pool={row.get('buffer_size', row['transitions']):6d}")


# ----------------------------------------------------------------------
def train_stream_sac(ecfg: EV.EnvConfig, acfg: AG.AgentConfig,
                     scfg: SAC.SACConfig,
                     stcfg: StreamTrainConfig = StreamTrainConfig(), *,
                     scenario: Optional[Scenario] = None,
                     curriculum: Optional[Sequence[Scenario]] = None,
                     seed: int = 0, exec_spec=None, callback=None,
                     transition_hook=None) -> StreamTrainResult:
    """SAC (paper Algorithm 2) trained from windowed streaming rollouts.

    Per round: pick a curriculum cell (host RNG decoupled from the network
    init — `sac.host_rng`), advance `windows_per_round` stream windows with
    the current policy (uniform exploration until the buffer reaches
    `scfg.warmup_steps`, then the diffusion/Gaussian actor), push the valid
    transitions into the replay buffer, and run the per-step update
    schedule over the new experience. Backlog, clock, and server occupancy
    persist across rounds — under `rate_scale > 1` the agent learns to
    schedule a queue it can never fully drain.

    Replay transitions keep the env's own done flag at the window's final
    step (the layout is bitwise-identical to episodic `collect_batch` — the
    parity guarantee tests rely on it), so the TD target treats the seam as
    terminal; the truncation bias this introduces is one bootstrap term per
    window, bounded by gamma and washed out by the off-policy buffer.

    `transition_hook(round_idx, flat)` (flat = the replay-layout arrays
    from `sac.flatten_valid_transitions`) observes every window's collected
    batch — the stream-train benchmark uses it to assert bitwise-identical
    collection across execution backends.
    """
    key = jax.random.PRNGKey(seed)
    rng = SAC.host_rng(key)
    key, k0, k_run = jax.random.split(key, 3)
    ts = SAC.init_train_state(k0, ecfg, acfg)
    buffer = ReplayBuffer(scfg.buffer_capacity, ecfg.obs_shape,
                          ecfg.action_dim)
    cells = resolve_cells(ecfg, scenario, curriculum, stcfg.rate_scale)
    source, runner = _make_runner(ecfg, cells, k_run, stcfg, exec_spec,
                                  SAC.warmup_policy(ecfg), {})
    history: List[Dict] = []
    for r in range(stcfg.rounds):
        ci = int(rng.integers(len(cells))) if len(cells) > 1 else 0
        source.set_cell(ci)
        warmup = buffer.size < scfg.warmup_steps
        policy = (SAC.warmup_policy(ecfg) if warmup
                  else ACT.actor_policy(ecfg, acfg,
                                        sampler=stcfg.sampler))
        params = {} if warmup else ts.actor
        ragg = MX.StreamAggregator(ecfg.num_servers, ecfg.q_min,
                                   stcfg.resp_sla)
        n_new, returns = 0, []
        with runner.tracer.span("train_round", cat="train", algo="sac",
                                round=r, cell=cells[ci][0],
                                warmup=bool(warmup)):
            for _ in range(stcfg.windows_per_round):
                wres = runner.run_window(policy=policy, params=params,
                                         collect=True)
                flat = SAC.flatten_valid_transitions(wres.transitions)
                with runner.tracer.span("replay_push", cat="train",
                                        n=int(len(flat[2]))):
                    buffer.add_batch(*flat)
                n_new += len(flat[2])
                if transition_hook is not None:
                    transition_hook(r, flat)
                ragg.update(wres.stats)
                returns.append(wres.record["episode_return_mean"])
            with runner.tracer.span("gradient_update", cat="train",
                                    algo="sac", new_transitions=int(n_new)):
                ts, key, n_upd = SAC.run_update_schedule(
                    ts, buffer, rng, key, n_new, ecfg=ecfg, acfg=acfg,
                    scfg=scfg, max_updates=stcfg.max_updates_per_round)
        row = _round_row(r, cells[ci][0], ragg, runner, returns, n_new,
                         n_upd)
        row.update(warmup=bool(warmup), buffer_size=buffer.size)
        history.append(row)
        _publish_round(row, "sac")
        runner.tracer.write()
        if callback:
            callback(r, row, ts)
        if stcfg.log_every and r % stcfg.log_every == 0:
            _log_row("sac", row)
    return StreamTrainResult(state=ts, history=history,
                             stream=runner.result())


# ----------------------------------------------------------------------
def train_stream_ppo(ecfg: EV.EnvConfig, pcfg: PPO.PPOConfig,
                     stcfg: StreamTrainConfig = StreamTrainConfig(), *,
                     scenario: Optional[Scenario] = None,
                     curriculum: Optional[Sequence[Scenario]] = None,
                     seed: int = 0, exec_spec=None, callback=None,
                     transition_hook=None) -> StreamTrainResult:
    """PPO trained from windowed streaming rollouts.

    Per round: collect `windows_per_round` on-policy windows, compute GAE
    per stream over each window's valid prefix — bootstrapping past the
    window seam with the critic's value of the final `next_obs` (the seam
    is a truncation, not a terminal state) — pool everything into one
    batch, and run the clipped-surrogate epochs.
    """
    key = jax.random.PRNGKey(seed)
    rng = SAC.host_rng(key)
    key, k0, k_run = jax.random.split(key, 3)
    st = PPO.init_ppo(k0, ecfg)
    policy = PPO.ppo_policy(ecfg)
    cells = resolve_cells(ecfg, scenario, curriculum, stcfg.rate_scale)
    source, runner = _make_runner(ecfg, cells, k_run, stcfg, exec_spec,
                                  policy, st.params)
    history: List[Dict] = []
    for r in range(stcfg.rounds):
        ci = int(rng.integers(len(cells))) if len(cells) > 1 else 0
        source.set_cell(ci)
        ragg = MX.StreamAggregator(ecfg.num_servers, ecfg.q_min,
                                   stcfg.resp_sla)
        datas, returns, n_new = [], [], 0
        with runner.tracer.span("train_round", cat="train", algo="ppo",
                                round=r, cell=cells[ci][0]):
            for _ in range(stcfg.windows_per_round):
                wres = runner.run_window(params=st.params, collect=True)
                tr = wres.transitions
                if transition_hook is not None:
                    transition_hook(r, SAC.flatten_valid_transitions(tr))
                with runner.tracer.span("gae_pool", cat="train"):
                    lens = np.asarray(tr.valid).sum(axis=1)
                    nobs = np.asarray(tr.next_obs)
                    last_nobs = nobs[np.arange(len(lens)),
                                     np.maximum(lens - 1, 0).astype(int)]
                    last_vals = np.asarray(PPO.value_of(st.params,
                                                        jnp.asarray(last_nobs)))
                    last_vals = np.where(lens > 0, last_vals, 0.0)
                    data = PPO.pool_gae(tr, pcfg, last_values=last_vals)
                datas.append(data)
                n_new += len(data["adv"])
                ragg.update(wres.stats)
                returns.append(wres.record["episode_return_mean"])
            pooled = {k: np.concatenate([d[k] for d in datas])
                      for k in datas[0]}
            with runner.tracer.span("gradient_update", cat="train",
                                    algo="ppo", new_transitions=int(n_new)):
                st, n_upd = PPO.run_ppo_epochs(
                    st, pooled, rng, ecfg, pcfg,
                    max_updates=stcfg.max_updates_per_round)
        row = _round_row(r, cells[ci][0], ragg, runner, returns, n_new,
                         n_upd)
        history.append(row)
        _publish_round(row, "ppo")
        runner.tracer.write()
        if callback:
            callback(r, row, st)
        if stcfg.log_every and r % stcfg.log_every == 0:
            _log_row("ppo", row)
    return StreamTrainResult(state=st, history=history,
                             stream=runner.result())
