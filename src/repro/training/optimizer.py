"""Adam + weight decay + grad clipping + schedules (no optax offline).

Functional optimizer in the optax style: init(params) -> state;
apply(grads, state, params, lr) -> (updates, state).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam_init(params) -> AdamState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     mu=jax.tree_util.tree_map(z, params),
                     nu=jax.tree_util.tree_map(z, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adam_update(grads, state: AdamState, params, lr,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0) -> Tuple[Any, AdamState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        u = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (-lr * u).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    outs = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    return updates, AdamState(step=step, mu=mu, nu=nu)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


def cosine_schedule(step, base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(1.0, warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
