"""LM pretraining loop (the training substrate used by examples/train_lm.py).

Single-host, pjit-on-debug-mesh when >1 device is available; Adam + cosine
schedule + grad clipping + periodic checkpointing. Works for every arch in
the zoo (reduced configs on CPU).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.checkpoint import save_checkpoint
from repro.common.config import ArchConfig
from repro.models.zoo import build_model
from repro.training.data import DataConfig, MarkovTokens
from repro.training.optimizer import (adam_init, adam_update, apply_updates,
                                      clip_by_global_norm, cosine_schedule)


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 50
    total_steps: int = 300
    max_grad_norm: float = 1.0
    weight_decay: float = 0.01
    log_every: int = 20
    ckpt_every: int = 0          # 0 = only final
    ckpt_dir: Optional[str] = None


def make_train_step(model, tcfg: TrainConfig):
    @jax.jit
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
        lr = cosine_schedule(opt_state.step, tcfg.lr, tcfg.warmup,
                             tcfg.total_steps)
        updates, opt_state = adam_update(grads, opt_state, params, lr,
                                         weight_decay=tcfg.weight_decay)
        params = apply_updates(params, updates)
        return params, opt_state, loss, gnorm

    return train_step


def train_lm(cfg: ArchConfig, tcfg: TrainConfig, dcfg: DataConfig,
             seed: int = 0, verbose: bool = True):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adam_init(params)
    data = MarkovTokens(dcfg)
    step_fn = make_train_step(model, tcfg)
    history = []
    t0 = time.time()
    for step, batch in enumerate(data):
        if step >= tcfg.total_steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, loss, gnorm = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.total_steps - 1:
            history.append({"step": step, "loss": float(loss),
                            "grad_norm": float(gnorm),
                            "elapsed": time.time() - t0})
            if verbose:
                print(f"[train step {step:4d}] loss={float(loss):.4f} "
                      f"gnorm={float(gnorm):.2f} ({time.time()-t0:.1f}s)")
        if tcfg.ckpt_dir and tcfg.ckpt_every and step and step % tcfg.ckpt_every == 0:
            save_checkpoint(tcfg.ckpt_dir, step, params)
    if tcfg.ckpt_dir:
        save_checkpoint(tcfg.ckpt_dir, tcfg.total_steps, params)
    return params, history
