"""Synthetic token pipeline for the LM-training substrate.

A seeded Markov-chain token stream: each vocab id has a small set of likely
successors, so a model can actually reduce loss below the unigram entropy
(gives the train_lm example a meaningful learning curve without external
datasets, which are unavailable offline).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Dict

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 1024
    seq_len: int = 256
    batch_size: int = 8
    branching: int = 4          # successors per token
    temperature: float = 0.7
    seed: int = 0


class MarkovTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, b = cfg.vocab_size, cfg.branching
        self.successors = rng.integers(0, v, size=(v, b))
        logits = rng.normal(size=(v, b)) / cfg.temperature
        p = np.exp(logits - logits.max(1, keepdims=True))
        self.probs = p / p.sum(1, keepdims=True)
        self.rng = rng

    def sample_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s = cfg.batch_size, cfg.seq_len
        toks = np.zeros((b, s + 1), np.int64)
        toks[:, 0] = self.rng.integers(0, cfg.vocab_size, size=b)
        for t in range(s):
            cur = toks[:, t]
            choice = np.array([self.rng.choice(cfg.branching, p=self.probs[c])
                               for c in cur])
            toks[:, t + 1] = self.successors[cur, choice]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.sample_batch()
