"""Consistency distillation of the diffusion actor: the T-step teacher
chain compressed into one student forward pass.

The EAT actor pays T denoiser calls per scheduling decision; the chain is
a deterministic map (x_T, f_s) -> x_0 once its PRNG path is fixed.
Following the consistency-model recipe ("Accelerating AIGC Services with
Latent Action Diffusion Scheduling in Edge Networks", PAPERS.md), we train
a denoiser-shaped student g(x_T, T, f_s) to regress the FROZEN teacher
chain's output on the exact (x_T, f_s) pairing inference will see:

* observations come from rolling the teacher policy itself (so the state
  distribution matches deployment — `collect_obs`);
* the teacher target is the DETERMINISTIC probability-flow chain — the
  full-grid DDIM (eta = 0, K = T) run of the same denoiser
  (`actors.samplers.chain_sample(kind="ddim", K=T)`). The stochastic DDPM
  chain injects fresh posterior noise at every step, which no one-call
  student can reproduce (the regression would bottom out at the chain's
  conditional variance); the PF-ODE endpoint is a deterministic function
  of (x_T, f_s), so the student can fit it arbitrarily well;
* per sample, a decision-level chain key `kd` fixes both the teacher's
  x_T and the student's (the same ``split(kd)[0]`` draw —
  `actors.samplers.distilled_sample` replays it at inference), so a
  perfectly-distilled student is action-identical to the deterministic
  DDIM teacher on every decision key;
* plain MSE on tanh-bounded x_0, Adam on the student only — encoder and
  sigma head are shared with (copied from) the teacher, so f_s and the
  exploration head are untouched.

    params2, hist = distill_actor(key, teacher_params, ecfg, acfg)
    rp = resolve(PolicySpec("eat", params=params2, sampler="distilled"), ecfg)

The returned params dict is the teacher's plus ``"student"`` — exactly
what ``PolicySpec("eat", sampler="distilled")`` expects.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors import samplers as SMP
from repro.actors.policies import actor_policy, init_student
from repro.core import agent as AG
from repro.core import diffusion as DF
from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.workload import TraceConfig, make_trace
from repro.training.optimizer import (adam_init, adam_update, apply_updates)


@dataclass(frozen=True)
class DistillConfig:
    steps: int = 400              # gradient steps
    batch: int = 256              # samples per step
    lr: float = 1e-3
    dataset: int = 4096           # (obs, kd) pairs distilled over
    noise_per_obs: int = 4        # fresh x_T draws per collected obs
    collect_episodes: int = 8     # teacher rollouts that supply the obs
    collect_steps: Optional[int] = None   # decision budget per rollout
    log_every: int = 0

    def __post_init__(self):
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.dataset < 1 or self.batch < 1:
            raise ValueError("dataset and batch must be >= 1")


def collect_obs(key, teacher_params, ecfg: EV.EnvConfig,
                acfg: AG.AgentConfig, episodes: int = 8,
                num_steps: Optional[int] = None) -> jnp.ndarray:
    """Observations from the teacher's own induced state distribution:
    `episodes` deterministic teacher rollouts, valid steps only, flattened
    to (N, 3, E+l)."""
    k_tr, k_run = jax.random.split(key)
    tcfg = TraceConfig(num_tasks=ecfg.max_tasks, max_servers=ecfg.num_servers,
                       num_models=ecfg.num_models)
    traces = jax.vmap(lambda k: make_trace(k, tcfg))(
        jax.random.split(k_tr, episodes))
    policy = actor_policy(ecfg, acfg, deterministic=True, sampler="ddpm")
    res = RO.batch_rollout(ecfg, traces, policy, teacher_params,
                           jax.random.split(k_run, episodes),
                           num_steps=num_steps, collect=True)
    tr = res.transitions
    valid = np.asarray(tr.valid).reshape(-1)
    obs = np.asarray(tr.obs).reshape((-1,) + tr.obs.shape[2:])[valid]
    return jnp.asarray(obs)


@functools.partial(jax.jit, static_argnames=("ecfg", "acfg"))
def _teacher_targets(teacher_params, obs, kds, *, ecfg: EV.EnvConfig,
                     acfg: AG.AgentConfig):
    """Frozen-teacher supervision for a batch of (obs, chain key) pairs:
    f_s, the deterministic full-grid DDIM chain's x_0 (the PF-ODE
    endpoint), and the student's input x_T (the chain's own first draw —
    `chain_sample`'s kx)."""
    sched = DF.vp_schedule(acfg.T)

    def one(o, kd):
        f_s = AG._encode(teacher_params, acfg, ecfg, o)
        x0 = SMP.chain_sample(teacher_params["denoiser"], sched, f_s, kd,
                              ecfg.action_dim, kind="ddim", K=acfg.T,
                              impl="ref")
        kx, _ = jax.random.split(kd)
        x_T = jax.random.normal(kx, (ecfg.action_dim,))
        return f_s, x0, x_T

    return jax.vmap(one)(obs, kds)


@functools.partial(jax.jit, static_argnames=("acfg", "lr"))
def _student_step(student, opt, f_s, x0, x_T, *, acfg: AG.AgentConfig,
                  lr: float):
    T = acfg.T

    def loss_fn(sp):
        pred = DF.denoise_eps(sp, x_T, jnp.full(x_T.shape[:-1], T), f_s)
        return jnp.mean(jnp.square(pred - x0))

    loss, grads = jax.value_and_grad(loss_fn)(student)
    upd, opt = adam_update(grads, opt, student, lr)
    return apply_updates(student, upd), opt, loss


def distill_actor(key, teacher_params, ecfg: EV.EnvConfig,
                  acfg: AG.AgentConfig,
                  dcfg: DistillConfig = DistillConfig(), *,
                  obs: Optional[jnp.ndarray] = None, tracer=None
                  ) -> Tuple[Dict, List[Dict]]:
    """Distill the frozen teacher chain into a one-call student head.

    Returns (params, history): `params` is the teacher dict plus the
    trained ``"student"``; `history` rows carry (step, loss). `obs`
    overrides the self-collected observation set (any (N, 3, E+l) array).
    """
    if acfg.policy != "diffusion":
        raise ValueError(
            f"distillation needs a diffusion teacher; variant "
            f"{acfg.variant!r} is Gaussian")
    k_obs, k_data, k_init, k_train = jax.random.split(key, 4)
    if obs is None:
        obs = collect_obs(k_obs, teacher_params, ecfg, acfg,
                          episodes=dcfg.collect_episodes,
                          num_steps=dcfg.collect_steps)
    n_obs = int(obs.shape[0])
    if n_obs == 0:
        raise ValueError("no observations to distill over")

    # dataset: sample obs rows, one fresh chain key per (obs, draw) pair
    n = min(dcfg.dataset, n_obs * dcfg.noise_per_obs)
    ko, kk = jax.random.split(k_data)
    rows = jax.random.randint(ko, (n,), 0, n_obs)
    kds = jax.vmap(jax.random.fold_in, (None, 0))(kk, jnp.arange(n))
    f_s, x0, x_T = _teacher_targets(teacher_params, obs[rows], kds,
                                    ecfg=ecfg, acfg=acfg)

    student = init_student(k_init, ecfg, acfg)
    opt = adam_init(student)
    history: List[Dict] = []
    span = (tracer.span if tracer is not None
            else (lambda *a, **k: _NULL_SPAN))
    with span("distill", cat="train", steps=dcfg.steps, samples=n):
        for s in range(dcfg.steps):
            kb = jax.random.fold_in(k_train, s)
            idx = jax.random.randint(kb, (min(dcfg.batch, n),), 0, n)
            student, opt, loss = _student_step(
                student, opt, f_s[idx], x0[idx], x_T[idx], acfg=acfg,
                lr=dcfg.lr)
            if dcfg.log_every and s % dcfg.log_every == 0:
                row = {"step": s, "loss": float(loss)}
                history.append(row)
                print(f"[distill {s:4d}] loss={row['loss']:.5f}")
    history.append({"step": dcfg.steps - 1, "loss": float(loss)})
    out = dict(teacher_params)
    out["student"] = student
    return out, history


class _Null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


_NULL_SPAN = _Null()
