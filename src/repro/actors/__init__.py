"""repro.actors — the unified compiled-inference (actor) layer.

One `ActorProgram` per (EnvConfig, policy callable) owns every compiled
view of policy inference — the per-decision jitted program the serving
backend and the latency probe share, and the vmapped view the fused
rollout scan consumes. `actor_policy` is the one door to the EAT actor
with its sampler family ("ddpm" | "ddim:K" | "distilled"); the registry
(`PolicySpec(..., sampler=...)`) resolves through it, so Simulator,
StreamRunner, stream training, and serving all pick up a sampler choice
with no per-layer changes. See docs/actors.md.
"""
from repro.actors.policies import actor_policy, init_student
from repro.actors.program import ActorProgram, actor_program
from repro.actors.samplers import (chain_sample, ddim_coeffs, ddim_taus,
                                   ddpm_coeffs, distilled_sample,
                                   normalize_sampler, parse_sampler)

__all__ = [
    "ActorProgram", "actor_program",
    "actor_policy", "init_student",
    "parse_sampler", "normalize_sampler",
    "ddpm_coeffs", "ddim_coeffs", "ddim_taus",
    "chain_sample", "distilled_sample",
]
