"""The unified EAT actor door: one cached factory for every sampler.

`actor_policy(ecfg, acfg, deterministic, sampler)` builds (and caches) the
rollout-protocol callable for the diffusion/Gaussian actor:

* ``sampler="ddpm"`` (default) — the paper's full T-step chain via
  `agent.actor_sample`. The closure body is exactly the pre-refactor
  `core.sac.actor_policy` body, and `sac.actor_policy` now delegates here,
  so the default actor is the SAME cached callable object everywhere —
  compiled-program caches (jit statics) keep hitting, and results stay
  bitwise-identical to the pre-refactor path on every backend
  (`make actor-smoke` gates this).
* ``sampler="ddim:K"`` / ``"distilled"`` — the fast samplers
  (`actors.samplers`) produce the action mean; the sigma head, Gaussian
  exploration, and clipping replicate `agent.actor_sample`'s tail on the
  same (kd, ks) key split, so swapping samplers changes only how the mean
  is computed.

Fast samplers require a diffusion variant ("eat"/"eat-a"); the Gaussian
ablations have no denoiser to stride or distill. "distilled" additionally
expects ``params["student"]`` — a denoiser-shaped head trained by
`training.distill` (or fresh via `init_student`, flagged untrained by the
registry).

Every returned callable carries ``policy.sampler`` (normalized label) for
telemetry attribution — serving decision spans, stream window spans, and
the metrics registry label decisions per sampler with it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.actors import samplers as SMP
from repro.core import agent as AG
from repro.core import diffusion as DF
from repro.core import env as EV


def actor_policy(ecfg: EV.EnvConfig, acfg: AG.AgentConfig,
                 deterministic: bool = False, sampler: str = "ddpm"):
    """Diffusion/Gaussian actor as a batch_rollout policy; actor weights
    are the traced `params`, so training updates never recompile. The
    callable is cached on (ecfg, acfg, deterministic, normalized sampler)."""
    return _build_policy(ecfg, acfg, bool(deterministic),
                         SMP.normalize_sampler(sampler))


@functools.lru_cache(maxsize=None)
def _build_policy(ecfg, acfg, deterministic, sampler):
    kind, K = SMP.parse_sampler(sampler)
    if kind != "ddpm" and acfg.policy != "diffusion":
        raise ValueError(
            f"sampler {sampler!r} needs a diffusion actor; variant "
            f"{acfg.variant!r} is Gaussian — only 'ddpm' applies")
    sched = DF.vp_schedule(acfg.T)

    if kind == "ddpm":
        def policy(params, key, trace, state, obs):
            a, _, _, _ = AG.actor_sample(params, acfg, ecfg, sched, obs, key,
                                         deterministic=deterministic)
            return AG.to_env_action(a), {"agent_action": a}
    else:
        def policy(params, key, trace, state, obs):
            kd, ks = jax.random.split(key)
            f_s = AG._encode(params, acfg, ecfg, obs)
            if kind == "ddim":
                mean = SMP.chain_sample(params["denoiser"], sched, f_s, kd,
                                        ecfg.action_dim, kind="ddim", K=K)
            else:
                mean = SMP.distilled_sample(params["student"], f_s, kd,
                                            ecfg.action_dim, acfg.T)
            log_sigma = jnp.clip(
                mean @ params["sigma_head"]["w"] + params["sigma_head"]["b"],
                acfg.log_sigma_min, acfg.log_sigma_max)
            eps = jax.random.normal(ks, mean.shape)
            a = mean if deterministic else mean + jnp.exp(log_sigma) * eps
            a = jnp.clip(a, -1.0, 1.0)
            return AG.to_env_action(a), {"agent_action": a}

    policy.sampler = sampler
    return policy


def init_student(key, ecfg: EV.EnvConfig, acfg: AG.AgentConfig):
    """Fresh distilled-student head: denoiser-shaped (same input layout —
    concat(x, t_emb, f_s) — and the same tanh-bounded output), so the
    student reuses the fused `denoiser_step` kernel unchanged."""
    feat_dim = ecfg.obs_shape[1]
    return DF.init_denoiser(key, ecfg.action_dim, feat_dim, acfg.hidden)
