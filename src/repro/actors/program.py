"""ActorProgram: the one compiled-inference owner per (env, policy).

Before this layer, every consumer of a rollout-protocol policy re-derived
and re-cached its own compiled program: the serving backend jitted a
key-split + forward (`_policy_prog`), the fused rollout scan re-built
`jax.vmap(policy, ...)` per trace, the decision-latency probe jitted an
ad-hoc lambda per call, and the registry handed out bare callables.
`actor_program(ecfg, policy)` now owns all of those views:

* ``act(trace, state, obs, key, params)`` — ONE jitted per-decision
  program: split the carried key, run the actor, return
  (key', action, extras). Exactly the serving backend's decision seam; the
  latency probe (`telemetry.profile.profile_policy`) measures this same
  program, so BENCH_decision_latency numbers and serving's decision spans
  describe literally the same XLA executable.
* ``vmapped`` — the batch-axis view `vmap(policy, (None, 0, 0, 0, 0))`
  the fused rollout scan consumes.
* ``policy`` — the raw protocol callable (a static jit argument: identity
  IS the compiled-program cache key, which is why programs are cached per
  (ecfg, policy) and policies come from lru-cached factories).
* ``sampler`` — the policy's sampler label when it carries one
  (`actors.policies` stamps it), for telemetry span/metric attribution.

Per-shape compilation is jit's own cache: one `ActorProgram` serves every
batch shape its consumers throw at it.
"""
from __future__ import annotations

import functools

import jax


class ActorProgram:
    """Compiled inference views of one rollout-protocol policy on one env.

    Build via `actor_program(ecfg, policy)` — the lru-cached factory is
    what guarantees one program (and one set of compiled executables) per
    (env config, policy callable).
    """

    def __init__(self, ecfg, policy):
        self.ecfg = ecfg
        self.policy = policy
        self.sampler = getattr(policy, "sampler", None)
        self._act = jax.jit(self._split_act)
        self._vmapped = None

    def _split_act(self, trace, state, obs, key, params):
        key, k_act = jax.random.split(key)
        action, extras = self.policy(params, k_act, trace, state, obs)
        return key, action, extras

    def act(self, trace, state, obs, key, params):
        """One decision at the serving seam: split the carried key, run the
        actor. Returns (key', action, extras)."""
        return self._act(trace, state, obs, key, params)

    @property
    def vmapped(self):
        """The fused-scan view: `vmap(policy, (None, 0, 0, 0, 0))` (shared
        params, batched key/trace/state/obs)."""
        if self._vmapped is None:
            self._vmapped = jax.vmap(self.policy,
                                     in_axes=(None, 0, 0, 0, 0))
        return self._vmapped

    def __repr__(self):
        s = f", sampler={self.sampler!r}" if self.sampler else ""
        return (f"ActorProgram({getattr(self.policy, '__name__', 'policy')}"
                f"{s})")


@functools.lru_cache(maxsize=None)
def actor_program(ecfg, policy) -> ActorProgram:
    """The shared compiled-inference layer: one `ActorProgram` per
    (EnvConfig, policy callable), cached for the process lifetime."""
    return ActorProgram(ecfg, policy)
