"""Sampler family for the diffusion actor: one set of denoiser weights,
three ways to turn them into an action mean.

* ``"ddpm"`` — the paper's full T-step reverse chain
  (`core.diffusion.reverse_sample`); the bitwise-canonical default.
* ``"ddim:K"`` — deterministic DDIM (eta = 0) over a strided subset of K
  timesteps. Zero retraining: the same eps-network is queried at K ≪ T
  indices, so decision latency drops ~T/K at a small quality cost.
* ``"distilled"`` — a consistency-distilled student head (one
  denoiser-shaped MLP call, trained by `training.distill` to regress the
  frozen teacher chain's x_0 from the same (x_T, f_s) pair). One forward
  pass per decision.

Both fast samplers run through the affine chain executors in
`kernels/denoiser` — step j: x <- c_x[j] x + c_e[j] eps + c_n[j] noise —
so the DDPM posterior and the DDIM update share one kernel; only the
(K,)-coefficient vectors built here differ. PRNG convention mirrors
`agent.actor_sample`/`diffusion.reverse_sample` exactly: the caller's
chain key `kd` splits into (kx, kn); x_T is drawn from kx — teacher and
student therefore see the SAME x_T for a given decision key, which is what
makes deterministic-mode distillation parity meaningful.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diffusion as DF
from repro.kernels.denoiser import ops as KOPS

SAMPLER_KINDS = ("ddpm", "ddim", "distilled")


def parse_sampler(sampler: Optional[str]) -> Tuple[str, Optional[int]]:
    """"ddpm" | "ddim:K" | "distilled" -> (kind, K). None means "ddpm"."""
    if sampler is None:
        return "ddpm", None
    s = str(sampler).strip().lower()
    if s in ("ddpm", "distilled"):
        return s, None
    if s.startswith("ddim:"):
        try:
            K = int(s.split(":", 1)[1])
        except ValueError:
            raise ValueError(
                f"bad ddim sampler {sampler!r}: expected 'ddim:K' with "
                "integer K") from None
        if K < 1:
            raise ValueError(f"ddim step count must be >= 1, got {K}")
        return "ddim", K
    raise ValueError(
        f"unknown sampler {sampler!r}; choose 'ddpm', 'ddim:K' or "
        "'distilled'")


def normalize_sampler(sampler: Optional[str]) -> str:
    kind, K = parse_sampler(sampler)
    return f"ddim:{K}" if kind == "ddim" else kind


# ----------------------------------------------------------------------
# affine chain coefficients (step j of K denoises timestep index idx[j])
def ddpm_coeffs(sched: DF.DiffusionSchedule):
    """Full-chain DDPM posterior (Eq. 10/12) as affine coefficients.

    Returns (coef_x, coef_e, coef_n, t_in), each (T,), ordered j = 0..T-1
    over timestep indices i = T-1..0; `t_in = i + 1` is the integer fed to
    the timestep embedding (matching `reverse_sample`)."""
    T = sched.betas.shape[0]
    i = jnp.arange(T - 1, -1, -1)
    beta = sched.betas[i]
    alpha = sched.alphas[i]
    abar = sched.alpha_bars[i]
    abar_prev = jnp.where(i > 0, sched.alpha_bars[jnp.maximum(i - 1, 0)],
                          1.0)
    coef_x = 1.0 / jnp.sqrt(alpha)
    coef_e = -(beta / jnp.sqrt(1.0 - abar)) / jnp.sqrt(alpha)
    var = beta * (1.0 - abar_prev) / (1.0 - abar)
    coef_n = jnp.where(i > 0, jnp.sqrt(jnp.maximum(var, 1e-12)), 0.0)
    return coef_x, coef_e, coef_n, i + 1


def ddim_taus(T: int, K: int) -> np.ndarray:
    """K strided timestep indices, descending T-1 .. 0.

    Evenly spaced with floor; for K <= T consecutive values differ by
    >= (T-1)/(K-1) >= 1, so the floors are strictly decreasing."""
    if not 1 <= K <= T:
        raise ValueError(f"ddim step count must be in [1, T={T}], got {K}")
    if K == 1:
        return np.array([T - 1], dtype=np.int64)
    return np.floor(np.linspace(T - 1, 0, K)).astype(np.int64)


def ddim_coeffs(sched: DF.DiffusionSchedule, K: int):
    """Deterministic DDIM (eta = 0) over the strided subset:

        x_prev = sqrt(abar_prev) * x0_pred + sqrt(1 - abar_prev) * eps,
        x0_pred = (x - sqrt(1 - abar) * eps) / sqrt(abar)

    expanded into the shared affine-chain form. coef_n is identically 0 —
    the chain is noise-free, which is what serving's deterministic mode
    relies on. The final step (idx 0) uses abar_prev = 1: x = x0_pred."""
    T = int(sched.betas.shape[0])
    idx = ddim_taus(T, K)
    abar = sched.alpha_bars[idx]
    nxt = np.concatenate([idx[1:], [0]])
    abar_prev = jnp.where(jnp.arange(K) < K - 1, sched.alpha_bars[nxt], 1.0)
    sq_ab = jnp.sqrt(abar)
    sq_abp = jnp.sqrt(abar_prev)
    coef_x = sq_abp / sq_ab
    coef_e = jnp.sqrt(1.0 - abar_prev) - sq_abp * jnp.sqrt(1.0 - abar) / sq_ab
    coef_n = jnp.zeros((K,), sched.betas.dtype)
    return coef_x, coef_e, coef_n, jnp.asarray(idx) + 1


# ----------------------------------------------------------------------
def chain_sample(denoiser_params, sched: DF.DiffusionSchedule, f_s, key,
                 action_dim: int, *, kind: str = "ddpm",
                 K: Optional[int] = None, impl: str = "auto",
                 t_dim: int = 16):
    """Action mean x_0 via the fused affine chain. Drop-in for
    `diffusion.reverse_sample` (same key semantics: key -> (kx, kn), x_T
    from kx, posterior noise from kn) with selectable schedule."""
    if kind == "ddpm":
        cx, ce, cn, t_in = ddpm_coeffs(sched)
    elif kind == "ddim":
        if K is None:
            raise ValueError("kind='ddim' needs K")
        cx, ce, cn, t_in = ddim_coeffs(sched, K)
    else:
        raise ValueError(f"chain kind must be ddpm|ddim, got {kind!r}")
    Ks = int(t_in.shape[0])
    batch_shape = f_s.shape[:-1]
    kx, kn = jax.random.split(key)
    x = jax.random.normal(kx, batch_shape + (action_dim,))
    noises = (jax.random.normal(kn, (Ks,) + batch_shape + (action_dim,))
              if kind == "ddpm"
              else jnp.zeros((Ks,) + batch_shape + (action_dim,)))
    tembs = DF.timestep_embedding(t_in, t_dim)
    return KOPS.denoise_chain(denoiser_params, x, noises, f_s, tembs,
                              cx, ce, cn, impl=impl)


def distilled_sample(student_params, f_s, key, action_dim: int, T: int, *,
                     impl: str = "auto", t_dim: int = 16):
    """One student forward: x_0 = student(x_T, T, f_s), tanh-bounded.

    Key semantics mirror `reverse_sample`: kd -> (kx, kn), x_T from kx (kn
    unused), so the student consumes the exact x_T the teacher chain would
    have started from — `training.distill` trains on that pairing."""
    batch_shape = f_s.shape[:-1]
    kx, _ = jax.random.split(key)
    x = jax.random.normal(kx, batch_shape + (action_dim,))
    i = jnp.full(batch_shape, T)
    if KOPS.resolve_impl(impl) == "ref":
        return DF.denoise_eps(student_params, x, i, f_s, t_dim)
    return KOPS.denoise_eps_fused(student_params, x, i, f_s, t_dim)
