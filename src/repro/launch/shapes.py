"""The four assigned input shapes + per-(arch, shape) applicability rules."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.common.config import ArchConfig

SLIDING_WINDOW_LONG = 16384     # window used by dense archs for long_500k


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def adapt_config(cfg: ArchConfig, shape: ShapeSpec) -> Optional[ArchConfig]:
    """Returns the (possibly shape-adapted) config, or None if the pair is
    skipped (recorded in DESIGN.md §4)."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return None          # enc-dec: no 500k decode story (DESIGN.md)
        if not cfg.is_subquadratic():
            # dense/moe/vlm archs: sliding-window variant (sub-quadratic)
            return dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_LONG)
    return cfg


def pair_list():
    """All (arch_name, shape_name) baseline pairs (skips excluded)."""
    from repro.common.config import ASSIGNED_ARCHS, get_config
    out = []
    for a in ASSIGNED_ARCHS:
        for s in SHAPES.values():
            if adapt_config(get_config(a), s) is not None:
                out.append((a, s.name))
    return out
