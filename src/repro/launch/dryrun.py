import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out artifacts/dryrun

The XLA_FLAGS line above MUST run before any jax import: jax locks the
device count at first initialisation.
"""
import argparse
import json
import time
import traceback

import jax

from repro.common.config import ASSIGNED_ARCHS, get_config
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, adapt_config
from repro.launch.steps import build_case, lower_case


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference), N = active params."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token


def run_one(arch: str, shape_name: str, mesh_kind: str, **case_kw):
    shape = SHAPES[shape_name]
    base_cfg = get_config(arch)
    if adapt_config(base_cfg, shape) is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "total_s": 0.0,
                "reason": "pair skipped per DESIGN.md §4 (enc-dec @ 500k)"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": n_dev}
    t0 = time.time()
    try:
        case = build_case(base_cfg, shape, mesh, **case_kw)
        lowered = lower_case(case)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        analysis = HA.analyze_compiled(compiled)
        rec.update(analysis)
        mf = model_flops(case.cfg, shape)
        rec["model_flops_global"] = mf
        per_dev = analysis["hlo_flops"]
        rec["model_flops_per_device"] = mf / n_dev
        rec["useful_flop_ratio"] = (mf / n_dev) / per_dev if per_dev else 0.0
        rec["status"] = "ok"
        print(compiled.memory_analysis())
        ca = HA.cost_dict(compiled)
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches for train shapes")
    ap.add_argument("--tp-inference", action="store_true",
                    help="replicate weights over the data axis for "
                         "prefill/decode (tensor-parallel only, no per-step "
                         "FSDP all-gathers)")
    ap.add_argument("--resume", action="store_true",
                    help="skip pairs whose artifact JSON already has status ok/skipped")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                tag = f"{arch}__{shape}__{mk}"
                path = os.path.join(args.out, tag + ".json")
                if args.resume and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        results.append(prev)
                        print(f"--- {tag}: cached ({prev['status']})", flush=True)
                        continue
                print(f"=== {tag} ===", flush=True)
                rec = run_one(arch, shape, mk,
                              remat=not args.no_remat,
                              microbatches=args.microbatches,
                              tp_inference=args.tp_inference)
                results.append(rec)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                status = rec["status"]
                extra = (f" flops/dev={rec.get('hlo_flops', 0):.3e}"
                         f" coll={rec.get('collective_bytes', 0):.3e}B"
                         f" bottleneck={rec.get('bottleneck', '-')}"
                         if status == "ok" else rec.get("error", ""))
                print(f"--- {tag}: {status} ({rec['total_s']}s){extra}",
                      flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\n{n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} errors / {len(results)} cases")
    return results


if __name__ == "__main__":
    main()
