"""Step builders + input_specs for the multi-pod dry-run.

For every (architecture x input shape x mesh) this module produces:
  * the step function (train_step / prefill_step / decode_step),
  * ShapeDtypeStruct stand-ins for every input (no device allocation),
  * in/out NamedShardings assembled from the partition rules.

Sharding policy (baseline; §Perf iterates on this):
  * batch over the data-parallel axes (pod, data) when divisible;
  * weights FSDP: d_model over `data`, wide dim over `model`;
  * decode KV caches: sequence dim over every mesh axis not used by the
    batch (flash-decoding style sharded softmax) — this is the TPU mapping
    of the paper's DistriFusion patch parallelism;
  * train/prefill activations: batch-sharded, full sequence per device.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import ArchConfig
from repro.launch.shapes import ShapeSpec, adapt_config
from repro.models.zoo import Model, build_model
from repro.sharding.context import activation_sharding
from repro.sharding.specs import batch_spec, cache_rules, tree_shardings
from repro.training.optimizer import adam_init, adam_update, apply_updates


class Case(NamedTuple):
    fn: Any                     # the step callable
    arg_structs: Tuple          # ShapeDtypeStructs to .lower(*args)
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    cfg: ArchConfig


def _repl(mesh):
    return NamedSharding(mesh, P())


def _tree_repl(tree, mesh):
    return jax.tree_util.tree_map(lambda _: _repl(mesh), tree)


def batch_structs(cfg: ArchConfig, batch: int, seq: int) -> Dict:
    out = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    return out


def _batch_shardings(structs: Dict, mesh: Mesh, dp) -> Dict:
    out = {}
    for k, v in structs.items():
        spec = [dp] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape
    (the public entry the assignment asks for)."""
    if shape.kind == "train":
        b = batch_structs(cfg, shape.global_batch, shape.seq_len)
        b["labels"] = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                           jnp.int32)
        return b
    if shape.kind == "prefill":
        return batch_structs(cfg, shape.global_batch, shape.seq_len)
    return {"token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}


# ----------------------------------------------------------------------
def build_case(arch_cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, *,
               lr: float = 1e-4, compute_dtype=jnp.bfloat16,
               remat: bool = True, seq_shard_activations: bool = False,
               microbatches: int = 1, tp_inference: bool = False,
               param_dtype=jnp.float32) -> Optional[Case]:
    cfg = adapt_config(arch_cfg, shape)
    if cfg is None:
        return None
    model = build_model(cfg)
    dp = batch_spec(mesh, shape.global_batch)
    seq_ax = "model" if seq_shard_activations and shape.seq_len % mesh.shape["model"] == 0 else None
    act_sh = NamedSharding(mesh, P(dp if dp else None, seq_ax, None))
    moe_sh = None
    if cfg.moe is not None and cfg.moe.num_experts % mesh.shape["model"] == 0:
        moe_sh = NamedSharding(mesh, P(dp if dp else None, "model", None, None))

    def pin_activations(fn):
        """Arm the activation-sharding constraints while tracing the step."""
        def wrapped(*args):
            with activation_sharding(act_sh, moe_sh):
                return fn(*args)
        return wrapped

    params_struct = jax.eval_shape(
        functools.partial(model.init, dtype=param_dtype), jax.random.PRNGKey(0))
    prules = None
    if tp_inference and shape.kind != "train":
        # §Perf iteration (decode): tensor-parallel-only weights — replicate
        # over the `data`/`pod` axes so serving steps never pay per-step
        # FSDP all-gathers. Weight residency grows n_data-fold but stays
        # far under HBM for every assigned arch (<= 6.5 GB for jamba-52b).
        from repro.sharding.specs import PARAM_RULES
        prules = [(pat, tuple(None if e in ("data", "pod") else e
                              for e in entries))
                  for pat, entries in PARAM_RULES]
    param_sh = (tree_shardings(params_struct, mesh, rules=prules)
                if prules else tree_shardings(params_struct, mesh))

    if shape.kind == "train":
        bstructs = input_specs(cfg, shape)
        b_sh = _batch_shardings(bstructs, mesh, dp)
        opt_struct = jax.eval_shape(adam_init, params_struct)
        opt_sh = tree_shardings(opt_struct, mesh)

        def grad_of(params, mb):
            def loss_fn(p):
                loss, metrics = model.loss(p, mb, compute_dtype=compute_dtype,
                                           remat=remat)
                return loss, metrics
            return jax.value_and_grad(loss_fn, has_aux=True)(params)

        def train_step(params, opt_state, batch):
            if microbatches <= 1:
                (loss, metrics), grads = grad_of(params, batch)
            else:
                # gradient accumulation (§Perf iteration 4): scan over
                # microbatches so activation working sets scale with
                # B/microbatches; grads accumulate in f32 at param sharding.
                def split(v):
                    b = v.shape[0]
                    return v.reshape(microbatches, b // microbatches,
                                     *v.shape[1:])
                mbs = {k: split(v) for k, v in batch.items()}

                def acc_fn(carry, mb):
                    g_acc, l_acc = carry
                    (loss, metrics), g = grad_of(params, mb)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, b_: a + b_.astype(a.dtype), g_acc, g)
                    return (g_acc, l_acc + loss), metrics

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), mstack = jax.lax.scan(
                    acc_fn, (g0, jnp.zeros((), jnp.float32)), mbs)
                grads = jax.tree_util.tree_map(
                    lambda g: g / microbatches, grads)
                loss = loss / microbatches
                metrics = jax.tree_util.tree_map(lambda m: m[-1], mstack)
            updates, opt_state = adam_update(grads, opt_state, params, lr)
            params = apply_updates(params, updates)
            return params, opt_state, loss, metrics

        loss_struct, metrics_struct = jax.eval_shape(
            lambda p, b: model.loss(p, b, compute_dtype=compute_dtype,
                                    remat=remat),
            params_struct, bstructs)
        return Case(
            fn=pin_activations(train_step),
            arg_structs=(params_struct, opt_struct, bstructs),
            in_shardings=(param_sh, opt_sh, b_sh),
            out_shardings=(param_sh, opt_sh, _repl(mesh),
                           _tree_repl(metrics_struct, mesh)),
            donate_argnums=(0, 1),
            cfg=cfg,
        )

    # inference cases ---------------------------------------------------
    seq_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.shape and a not in (dp if isinstance(dp, tuple) else (dp,)))
    crules = cache_rules(dp if dp else None, seq_axes if seq_axes else None)
    cache_struct = jax.eval_shape(
        functools.partial(model.make_cache, shape.global_batch, shape.seq_len,
                          jnp.bfloat16))
    cache_sh = tree_shardings(cache_struct, mesh, rules=crules)

    if shape.kind == "prefill":
        bstructs = input_specs(cfg, shape)
        b_sh = _batch_shardings(bstructs, mesh, dp)

        def prefill_step(params, batch, cache):
            # capacity-bounded MoE dispatch at scale (dropless would cost
            # e/k-times the expert FLOPs on a 32k prompt)
            return model.prefill(params, batch, cache,
                                 compute_dtype=compute_dtype,
                                 moe_dropless=False)

        logits_struct, _ = jax.eval_shape(prefill_step, params_struct,
                                          bstructs, cache_struct)
        return Case(
            fn=pin_activations(prefill_step),
            arg_structs=(params_struct, bstructs, cache_struct),
            in_shardings=(param_sh, b_sh, cache_sh),
            out_shardings=(NamedSharding(mesh, P(dp if dp else None)), cache_sh),
            donate_argnums=(2,),
            cfg=cfg,
        )

    # decode
    tok_struct = input_specs(cfg, shape)["token"]
    tok_sh = NamedSharding(mesh, P(dp if dp else None, None))

    def decode_step(params, cache, token):
        # s=1: capacity == dropless (each token hits k distinct experts)
        return model.decode(params, cache, token, compute_dtype=compute_dtype,
                            moe_dropless=False)

    return Case(
        fn=pin_activations(decode_step),
        arg_structs=(params_struct, cache_struct, tok_struct),
        in_shardings=(param_sh, cache_sh, tok_sh),
        out_shardings=(NamedSharding(mesh, P(dp if dp else None)), cache_sh),
        donate_argnums=(1,),
        cfg=cfg,
    )


def lower_case(case: Case):
    jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                     out_shardings=case.out_shardings,
                     donate_argnums=case.donate_argnums)
    return jitted.lower(*case.arg_structs)
