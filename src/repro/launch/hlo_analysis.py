"""Extract roofline terms from a compiled dry-run artifact.

cost_analysis() gives PER-DEVICE HLO FLOPs / bytes accessed (verified: a
512-way sharded matmul reports 1/512 of the global FLOPs). Collective bytes
are not in cost_analysis, so we parse the optimized HLO text and sum the
output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (a standard per-device bytes-moved proxy).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by collectives, by op kind."""
    out: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        ty, op = m.group(1), m.group(2)
        b = _shape_bytes(ty)
        out[op] = out.get(op, 0.0) + b
        count[op] = count.get(op, 0) + 1
    out["total"] = sum(v for k, v in out.items())
    out["ops"] = sum(count.values())
    return out


def roofline_terms(cost: Dict, coll: Dict, *, num_links: int = 4) -> Dict:
    """Three roofline terms in seconds (per device / per chip)."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    bytes_coll = float(coll.get("total", 0.0))
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_hbm / HBM_BW
    t_coll = bytes_coll / (ICI_BW * num_links)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll, "hlo_flops": flops,
             "hlo_bytes": bytes_hbm, "collective_bytes": bytes_coll}
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    terms["bottleneck"] = dominant.replace("_s", "")
    return terms


def cost_dict(compiled) -> Dict:
    """compiled.cost_analysis() version shim: jax <= 0.4.x returns a list of
    per-program dicts, newer jax returns the dict directly."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze_compiled(compiled) -> Dict:
    cost = cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    out = roofline_terms(cost, coll)
    out["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
    }
    out["peak_device_bytes"] = (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes)
    out["collectives"] = {k: v for k, v in coll.items()}
    return out
