"""Production meshes. TARGET: TPU v5e pods — 256 chips (16x16) per pod,
2 pods = 512 chips for the multi-pod dry-run.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def _make_mesh(shape, axes):
    """Version-compat mesh constructor (jax 0.4.x .. current)."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh exists but predates axis_types
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    import numpy as np
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (uses however many host devices exist)."""
    return _make_mesh((data, model), ("data", "model"))


def make_data_mesh(devices: int = 0, axis: str = "data"):
    """1-D data-parallel mesh over `devices` local devices (0 = all).

    This is the mesh the `repro.api` sharded execution backend shards the
    rollout batch/stream axis over (`api/backends.py`); on CPU CI it is
    driven with XLA_FLAGS=--xla_force_host_platform_device_count=N.
    """
    n = int(devices) or jax.local_device_count()
    return _make_mesh((n,), (axis,))
