"""Post-hoc augmentation: add analytic roofline terms to dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.augment_roofline [--out artifacts/dryrun]

Computes the analytic model (repro.launch.roofline) for every saved dry-run
JSON and merges the ``a_*`` fields in place. No recompilation — the analytic
terms depend only on (config, shape, mesh), which is the point: they correct
the scan-body-counted-once bias of ``cost_analysis`` (see roofline.py).
"""
from __future__ import annotations

import argparse
import json
import os

from repro.common.config import get_config
from repro.launch.roofline import analytic_terms
from repro.launch.shapes import SHAPES, adapt_config

MESH_DEVS = {"single": 256, "multi": 512}


def dp_degree_for(shape_name: str, mesh: str) -> int:
    b = SHAPES[shape_name].global_batch
    full = 16 * (2 if mesh == "multi" else 1)
    while full > 1 and b % full:
        full //= 2
    return max(full, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    n = 0
    for fn in sorted(os.listdir(args.out)):
        if not fn.endswith(".json"):
            continue
        path = os.path.join(args.out, fn)
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        shape = SHAPES[rec["shape"]]
        cfg = adapt_config(get_config(rec["arch"]), shape)
        terms = analytic_terms(cfg, shape, MESH_DEVS[rec["mesh"]],
                               dp_degree_for(rec["shape"], rec["mesh"]))
        rec.update(terms)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        n += 1
    print(f"augmented {n} artifacts with analytic roofline terms")


if __name__ == "__main__":
    main()
