"""CLI serving launcher — wraps the edge-serving engine.

    PYTHONPATH=src python -m repro.launch.serve --servers 4 \
        --archs qwen2-1.5b,tinyllama-1.1b --tasks 12 --policy eat

Equivalent to examples/serve_cluster.py (the annotated walk-through) but
runnable as a module from anywhere in the repo.
"""
from __future__ import annotations

import os
import runpy
import sys


def main():
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    script = os.path.join(repo, "examples", "serve_cluster.py")
    sys.argv[0] = script
    runpy.run_path(script, run_name="__main__")


if __name__ == "__main__":
    main()
