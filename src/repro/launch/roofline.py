"""Analytic roofline model per (arch x shape x mesh).

WHY THIS EXISTS: XLA's ``compiled.cost_analysis()`` counts a ``lax.scan`` /
``while`` body ONCE, not x trip-count (verified: an 8-layer and a 2-layer
model report identical FLOPs). Our models scan over stacked layer params
and chunk attention with an inner loop, so the HLO-measured terms
underestimate per-step work by ~num_layers (and by ~num_kv_blocks inside
attention). The measured terms remain useful for *relative* comparisons
between sharding variants (same loop structure), but the absolute roofline
table is derived from this analytic model, cross-checked against the
measured terms (see EXPERIMENTS.md §Roofline).

All terms are PER DEVICE, in seconds, for one step of the given shape on
TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, 4 ICI links x 50 GB/s/link, 16 GB).
"""
from __future__ import annotations

from typing import Dict

from repro.common.config import ArchConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.shapes import ShapeSpec

HBM_PER_CHIP = 16e9            # v5e
BF16 = 2
F32 = 4


def _layer_kinds(cfg: ArchConfig):
    """(n_attn_layers, n_ssm_layers, n_moe_layers, n_dense_ffn_layers)."""
    n_attn = n_ssm = n_moe = n_ffn = 0
    for i in range(cfg.num_layers):
        if cfg.layer_pattern == "attn":
            is_attn = True
        elif cfg.layer_pattern == "jamba":
            is_attn = (i % cfg.attn_period) == (cfg.attn_period - 1)
        else:
            is_attn = False
        if is_attn:
            n_attn += 1
        elif cfg.ssm is not None:
            n_ssm += 1
        if cfg.moe is not None and (i % cfg.moe.layer_period) == 0:
            n_moe += 1
        elif cfg.d_ff:
            n_ffn += 1
    return n_attn, n_ssm, n_moe, n_ffn


def analytic_terms(cfg: ArchConfig, shape: ShapeSpec, n_dev: int,
                   dp_degree: int) -> Dict[str, float]:
    """Three roofline terms from first principles.

    dp_degree: how many ways the global batch is data-sharded (the weights
    are sharded over all n_dev devices, FSDP-style).
    """
    B, S = shape.global_batch, shape.seq_len
    d, hd = cfg.d_model, cfg.resolved_head_dim
    n_attn, n_ssm, n_moe, n_ffn = _layer_kinds(cfg)
    N_act = cfg.param_count(active_only=True)
    N_tot = cfg.param_count(active_only=False)
    P_b = N_tot * BF16                     # bf16 compute copy
    B_loc = max(1, B // max(dp_degree, 1))

    # effective attention context (sliding window caps it)
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S

    # ---- compute FLOPs (global, then /n_dev) --------------------------
    attn_dim = cfg.num_heads * hd
    if shape.kind == "train":
        flops = 6.0 * N_act * B * S
        # causal attention scores+values, fwd+bwd (x3)
        flops += 3.0 * n_attn * 4.0 * B * S * ctx * 0.5 * attn_dim / max(S / max(ctx, 1), 1) ** 0
        tokens_desc = B * S
    elif shape.kind == "prefill":
        flops = 2.0 * N_act * B * S
        flops += n_attn * 4.0 * B * S * ctx * 0.5 * attn_dim
        tokens_desc = B * S
    else:  # decode: one token against a ctx-long KV
        flops = 2.0 * N_act * B
        flops += n_attn * 4.0 * B * ctx * attn_dim
        tokens_desc = B
    t_compute = flops / n_dev / PEAK_FLOPS_BF16

    # ---- HBM traffic (per device) --------------------------------------
    kv_bytes_layer = B * ctx * 2 * cfg.num_kv_heads * hd * BF16   # K+V
    if shape.kind == "train":
        # params: read fwd + read bwd + write grads (bf16) + Adam m/v rw (f32)
        param_traffic = N_tot * (3 * BF16 + 4 * F32)
        # activations under remat: write+read layer boundaries, ~3 passes
        act = cfg.num_layers * B_loc * S * d * BF16 * 6
        logits = B_loc * S * cfg.padded_vocab * F32 * 2
        bytes_dev = param_traffic / n_dev + act + logits
    elif shape.kind == "prefill":
        param_traffic = N_tot * BF16
        act = cfg.num_layers * B_loc * S * d * BF16 * 4
        kv = n_attn * kv_bytes_layer / n_dev * 2          # write + re-read
        bytes_dev = param_traffic / n_dev + act + kv
    else:
        param_traffic = N_tot * BF16                      # every weight once
        kv = n_attn * kv_bytes_layer / n_dev              # read full cache
        bytes_dev = param_traffic / n_dev + kv + B_loc * d * cfg.num_layers * BF16 * 4
    t_memory = bytes_dev / HBM_BW

    # ---- collective traffic (per device) -------------------------------
    # FSDP: all-gather weights fwd (+bwd for train) + reduce-scatter grads.
    shard_b = P_b / n_dev
    if shape.kind == "train":
        coll = shard_b * (2 + 1) + shard_b * 2            # AG fwd+bwd, RS+AG opt
    else:
        coll = shard_b                                    # AG weights once
    # decode with sequence-sharded KV: per-token partial-softmax reduce
    if shape.kind == "decode":
        coll += n_attn * B * attn_dim * BF16 * 2
    t_coll = coll / (ICI_BW * 4)

    terms = {
        "a_compute_s": t_compute, "a_memory_s": t_memory,
        "a_collective_s": t_coll,
        "a_flops_dev": flops / n_dev, "a_bytes_dev": bytes_dev,
        "a_coll_bytes_dev": coll,
        "model_flops_global": (6.0 if shape.kind == "train" else 2.0)
        * N_act * tokens_desc,
        "params_total": N_tot, "params_active": N_act,
    }
    dom = max(("a_compute_s", "a_memory_s", "a_collective_s"),
              key=lambda k: terms[k])
    terms["a_bottleneck"] = dom[2:].replace("_s", "")
    # weights-fit check: bf16 copy + f32 master + 2xf32 Adam per device
    if shape.kind == "train":
        resident = N_tot * (BF16 + 3 * F32) / n_dev
    else:
        resident = N_tot * BF16 / n_dev + (
            _layer_kinds(cfg)[0] * kv_bytes_layer / n_dev)
    terms["a_resident_bytes_dev"] = resident
    terms["a_fits_hbm"] = bool(resident < HBM_PER_CHIP * 0.9)
    return terms
