"""CLI training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        [--reduced] [--steps 100] [--batch 8] [--seq 256] [--ckpt-dir DIR]

Runs the pretraining substrate (Adam + cosine + grad-clip + checkpointing)
on the selected architecture. On this CPU container use ``--reduced`` (the
smoke-scale variant); on a real TPU mesh the same step functions lower via
``repro.launch.steps`` (see dryrun.py for the production-mesh path).
"""
from __future__ import annotations

import argparse

from repro.common.config import ASSIGNED_ARCHS, get_config
from repro.training.data import DataConfig
from repro.training.train_loop import TrainConfig, train_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup=max(5, args.steps // 10),
                       ckpt_dir=args.ckpt_dir)
    dcfg = DataConfig(vocab_size=min(cfg.vocab_size, 2048),
                      seq_len=args.seq, batch_size=args.batch,
                      seed=args.seed)
    _params, history = train_lm(cfg, tcfg, dcfg, seed=args.seed)
    print(f"final loss {history[-1]['loss']:.4f} "
          f"({history[0]['loss']:.4f} at step 0)")


if __name__ == "__main__":
    main()
