"""Parameter pytree helpers: init, counting, dtype casting, tree paths."""
from __future__ import annotations

import math
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Params = Any  # nested dict of jnp arrays


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, params)


def tree_paths(params: Params) -> Dict[str, Any]:
    """Flatten to {'a/b/c': leaf} path dict (for partition-rule matching)."""
    out = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out["/".join(keys)] = leaf
    return out


def map_with_paths(fn: Callable[[str, Any], Any], params: Params) -> Params:
    """tree_map with 'a/b/c' path string passed to fn."""
    def _fn(path, leaf):
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        return fn("/".join(keys), leaf)
    return jax.tree_util.tree_map_with_path(_fn, params)


# ------------------------------------------------------------------
# initializers (functional, explicit rng splitting)
def normal_init(key, shape, dtype=jnp.float32, stddev=0.02):
    return (stddev * jax.random.normal(key, shape)).astype(dtype)


def lecun_init(key, shape, dtype=jnp.float32, fan_in_axis=-2):
    fan_in = shape[fan_in_axis] if len(shape) >= 2 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Stateful convenience splitter for init code (host-side only)."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub
