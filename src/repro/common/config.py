"""Configuration system: architecture configs + registry.

Every assigned architecture gets a module in ``repro.configs`` that builds an
:class:`ArchConfig` with the exact dimensions from its source paper/model card
and registers it under its public id (e.g. ``--arch tinyllama-1.1b``).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    # d_ff of each expert (may differ from the dense d_ff)
    expert_d_ff: int
    # apply MoE every `period` layers (1 = every layer, 2 = alternate)
    layer_period: int = 1
    # load-balancing auxiliary loss coefficient
    aux_loss_coef: float = 0.01
    # router jitter for training
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM / xLSTM cell dims."""
    state_dim: int = 16          # N (per-channel state)
    conv_width: int = 4
    expand: int = 2              # inner dim = expand * d_model
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)
    # xLSTM: number of mLSTM heads
    mlstm_heads: int = 4


@dataclass(frozen=True)
class ArchConfig:
    """One schedulable AIGC service / model family instance."""
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    activation: str = "silu"     # silu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 1 << 20
    # attention variant: 0 = full; >0 = sliding window size (tokens)
    sliding_window: int = 0
    # mixture of experts (None = dense FFN)
    moe: Optional[MoEConfig] = None
    # ssm/hybrid params
    ssm: Optional[SSMConfig] = None
    # layer pattern: "attn" | "mamba" | "jamba" | "xlstm"
    layer_pattern: str = "attn"
    # hybrid (jamba): attention layer every `attn_period` layers
    attn_period: int = 8
    # encoder-decoder (whisper): number of encoder layers consumed as a stub
    encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub: none | audio | vision
    frontend: str = "none"
    # vision/audio stub shapes (frames/patches, produced by input_specs())
    frontend_tokens: int = 0
    frontend_dim: int = 0
    # citation (source paper / model card)
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so embeddings shard on 16-way axes."""
        return _round_up(self.vocab_size, 256)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def is_recurrent(self) -> bool:
        return self.layer_pattern in ("mamba", "xlstm")

    def is_subquadratic(self) -> bool:
        """True if long-context decode is supported natively or via window."""
        return self.layer_pattern in ("mamba", "xlstm", "jamba") or self.sliding_window > 0

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        vocab = min(self.vocab_size, 1024)
        if self.vocab_size % 256 and vocab % 256 == 0:
            vocab -= 24  # preserve the "vocab needs padding" property
        num_heads = min(self.num_heads, 4)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        while num_heads % num_kv:
            num_kv -= 1
        d_model = min(self.d_model, 256)
        head_dim = min(self.resolved_head_dim, d_model // num_heads)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(4, self.moe.num_experts),
                experts_per_token=min(2, self.moe.experts_per_token),
                expert_d_ff=min(128, self.moe.expert_d_ff))
        if self.layer_pattern == "jamba":
            layers = self.attn_period
        elif self.layer_pattern == "xlstm":
            layers = 4
        elif self.moe is not None and self.moe.layer_period > 1:
            layers = self.moe.layer_period
        else:
            layers = 2
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=layers,
            encoder_layers=min(self.encoder_layers, 2),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            moe=moe,
            vocab_size=vocab,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            frontend_dim=d_model if self.frontend != "none" else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )

    # parameter count (embedding + per-layer), used by the latency table
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.activation == "geglu":
            n_ffn_dense = 3 * d * self.d_ff
        else:
            n_ffn_dense = 3 * d * self.d_ff  # gate/up/down (llama-style)
        total = 0
        for i in range(self.num_layers):
            if self.layer_pattern == "attn":
                is_attn = True
            elif self.layer_pattern == "jamba":
                is_attn = (i % self.attn_period) == (self.attn_period - 1)
            else:
                is_attn = False
            if is_attn:
                total += n_attn
            elif self.ssm is not None:
                inner = self.ssm.expand * d
                total += 2 * d * inner + inner * (2 * self.ssm.state_dim + 2) + inner * d
            if self.moe is not None and (i % self.moe.layer_period) == 0:
                e = self.moe.experts_per_token if active_only else self.moe.num_experts
                total += e * 3 * d * self.moe.expert_d_ff + d * self.moe.num_experts
            elif self.d_ff:
                total += n_ffn_dense
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return total


# ----------------------------------------------------------------------
# registry
_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}

ASSIGNED_ARCHS: Tuple[str, ...] = (
    "jamba-v0.1-52b",
    "tinyllama-1.1b",
    "whisper-small",
    "gemma-7b",
    "olmoe-1b-7b",
    "llama3.2-3b",
    "qwen2-1.5b",
    "internvl2-1b",
    "qwen3-moe-30b-a3b",
    "xlstm-125m",
)


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def _load_all():
    # import the configs package, which registers everything
    importlib.import_module("repro.configs")


def get_config(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return get_config(name[: -len("-reduced")]).reduced()
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> List[str]:
    _load_all()
    return sorted(_REGISTRY)
