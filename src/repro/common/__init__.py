from repro.common.config import ArchConfig, MoEConfig, SSMConfig, get_config, list_configs, register, ASSIGNED_ARCHS  # noqa: F401
