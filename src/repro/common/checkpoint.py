"""Minimal pytree checkpointing (npz-based; no orbax available offline).

Layout: <dir>/<step>/arrays.npz + treedef.json (path list). Atomic-ish via
tmp rename. Used by both the LM trainer and the RL trainer.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.common.pytree import tree_paths


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = tree_paths(tree)
    flat = {k: np.asarray(v) for k, v in flat.items()}
    treedef = jax.tree_util.tree_structure(tree)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "treedef.json"), "w") as f:
            json.dump({"step": step, "treedef": str(treedef), "keys": sorted(flat)}, f)
        final = os.path.join(directory, str(step))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d) for d in os.listdir(directory) if d.isdigit()]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, target: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of `target` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    data = np.load(os.path.join(directory, str(step), "arrays.npz"))
    flat_target = tree_paths(target)
    leaves = []
    for k in flat_target:
        if k not in data:
            raise KeyError(f"checkpoint missing key {k}")
        leaves.append(data[k])
    treedef = jax.tree_util.tree_structure(target)
    ordered_keys = list(flat_target.keys())
    return jax.tree_util.tree_unflatten(treedef, [data[k] for k in ordered_keys])
