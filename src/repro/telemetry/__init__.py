"""repro.telemetry — structured tracing, unified metrics, decision profiling.

The observability substrate every layer reports through:

* `trace` — span-based tracer emitting Chrome trace-event JSON
  (perfetto-loadable) + JSONL, recorded only at jit boundaries; zero
  overhead when disabled. Front door: ``ExecSpec(trace=TraceConfig(...))``.
* `metrics` — one labelled counters/gauges/histograms registry that the
  stream aggregator, serving pool, and streaming trainers publish into;
  Prometheus text + JSONL snapshot export.
* `profile` — per-decision policy-inference latency (the diffusion
  actor's K-denoise-step cost vs greedy/fifo), split from env-advance and
  executor wall time.
* `schema` — the machine-readable trace schema + dependency-free
  validator CI gates emitted files with.
"""
from repro.telemetry.metrics import (DEFAULT_EDGES, Counter, Gauge,
                                     Histogram, LatencyHistogram,
                                     MetricsRegistry, default_registry,
                                     parse_prometheus, publish_counters,
                                     publish_summary)
from repro.telemetry.profile import (DECISION_EDGES, DecisionProfile,
                                     profile_policy)
from repro.telemetry.schema import (KNOWN_SPANS, TRACE_SCHEMA,
                                    assert_valid_trace, span_durations,
                                    validate_trace)
from repro.telemetry.trace import (NULL_TRACER, TraceConfig, Tracer,
                                   jax_profile, reset_tracers, tracer_for)

__all__ = [
    "TraceConfig", "Tracer", "NULL_TRACER", "tracer_for", "reset_tracers",
    "jax_profile",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "LatencyHistogram",
    "DEFAULT_EDGES", "default_registry",
    "parse_prometheus", "publish_summary", "publish_counters",
    "DecisionProfile", "profile_policy", "DECISION_EDGES",
    "KNOWN_SPANS", "TRACE_SCHEMA", "validate_trace", "assert_valid_trace",
    "span_durations",
]
