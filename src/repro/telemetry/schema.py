"""Machine-readable schema for emitted traces, plus a built-in validator.

`TRACE_SCHEMA` is a JSON-Schema (draft-07 subset) document describing the
Chrome trace-event files the tracer writes; `validate_trace` enforces it
without external dependencies (the container has no `jsonschema`), so CI
(`make trace-smoke`) and `tests/test_telemetry.py` can gate every emitted
file. `KNOWN_SPANS` is the contract documented in
`docs/telemetry_schema.md`: every span name the stack emits, one place.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Tuple

#: every span name the stack emits -> (category, emitting layer)
KNOWN_SPANS: Dict[str, Tuple[str, str]] = {
    # Simulator (repro.api.simulator)
    "run":              ("run",      "api.Simulator"),
    "resolve_policy":   ("run",      "api.Simulator"),
    "episodic_rollout": ("rollout",  "api.Simulator"),
    "profile_decisions": ("profile", "api.Simulator"),
    # streaming engine (repro.traffic.stream.StreamRunner)
    "window":           ("stream",   "traffic.StreamRunner"),
    "build_window":     ("stream",   "traffic.StreamRunner"),
    "window_rollout":   ("rollout",  "traffic.StreamRunner"),
    "window_seam":      ("stream",   "traffic.StreamRunner"),
    "fault_requeue":    ("stream",   "traffic.StreamRunner"),
    # streaming trainers (repro.training.stream_train)
    "train_round":      ("train",    "training.stream_train"),
    "replay_push":      ("train",    "training.stream_train"),
    "gae_pool":         ("train",    "training.stream_train"),
    "gradient_update":  ("train",    "training.stream_train"),
    # serving backend (repro.serving.backend / executor)
    "decision":         ("serving",  "serving.ServingRollout"),
    "env_advance":      ("serving",  "serving.ServingRollout"),
    "wall_patch":       ("serving",  "serving.ServingRollout"),
    "execute_task":     ("serving",  "serving.ServingRollout"),
    "model_load":       ("serving",  "serving.ServingRollout"),
    "executor_warmup":  ("serving",  "serving.ServingRollout"),
    "prefill":          ("serving",  "serving.ModelExecutor"),
    "decode":           ("serving",  "serving.ModelExecutor"),
    # serving fault tolerance (repro.serving.backend)
    "executor_retry":   ("serving",  "serving.ServingRollout"),
    "executor_degrade": ("serving",  "serving.ServingRollout"),
    # slow-timescale placement (repro.placement / serving.backend)
    "placement_decide": ("placement", "placement.PlacementManager"),
    "prefetch":         ("placement", "serving.ServingRollout"),
    "evict":            ("placement", "serving.ServingRollout"),
}

_EVENT_SCHEMA = {
    "type": "object",
    "required": ["name", "cat", "ph", "ts", "pid", "tid"],
    "properties": {
        "name": {"type": "string", "minLength": 1},
        "cat": {"type": "string", "minLength": 1},
        "ph": {"enum": ["X", "i", "C"]},
        "ts": {"type": "number", "minimum": 0},
        "dur": {"type": "number", "minimum": 0},
        "pid": {"type": "integer"},
        "tid": {"type": "integer"},
        "args": {"type": "object"},
        "s": {"enum": ["t", "p", "g"]},
    },
}

TRACE_SCHEMA = {
    "$schema": "http://json-schema.org/draft-07/schema#",
    "title": "repro telemetry trace (Chrome trace-event JSON)",
    "type": "object",
    "required": ["traceEvents", "otherData"],
    "properties": {
        "traceEvents": {"type": "array", "items": _EVENT_SCHEMA},
        "displayTimeUnit": {"type": "string"},
        "otherData": {
            "type": "object",
            "required": ["schema_version"],
            "properties": {
                "schema_version": {"type": "integer", "minimum": 1},
                "epoch_unix_s": {"type": "number"},
            },
        },
    },
}

_TYPES = {"object": dict, "array": list, "string": str, "integer": int,
          "number": (int, float), "boolean": bool}


def _check(doc, schema, path: str, errors: List[str]) -> None:
    """Minimal draft-07 checker for exactly the constructs TRACE_SCHEMA
    uses: type, enum, required, properties, items, minimum, minLength."""
    if "enum" in schema:
        if doc not in schema["enum"]:
            errors.append(f"{path}: {doc!r} not in {schema['enum']}")
        return
    t = schema.get("type")
    if t:
        py = _TYPES[t]
        ok = isinstance(doc, py) and not (t in ("integer", "number")
                                          and isinstance(doc, bool))
        if not ok:
            errors.append(f"{path}: expected {t}, got {type(doc).__name__}")
            return
    if t == "object":
        for req in schema.get("required", ()):
            if req not in doc:
                errors.append(f"{path}: missing required key {req!r}")
        for k, sub in schema.get("properties", {}).items():
            if k in doc:
                _check(doc[k], sub, f"{path}.{k}", errors)
    elif t == "array":
        items = schema.get("items")
        if items:
            for i, el in enumerate(doc):
                _check(el, items, f"{path}[{i}]", errors)
    elif t == "string":
        if len(doc) < schema.get("minLength", 0):
            errors.append(f"{path}: string shorter than "
                          f"{schema['minLength']}")
    elif t in ("integer", "number"):
        if "minimum" in schema and doc < schema["minimum"]:
            errors.append(f"{path}: {doc} < minimum {schema['minimum']}")


def validate_events(doc: dict, *, strict_names: bool = False) -> List[str]:
    """Validate a loaded trace document; returns a list of problems
    (empty = valid). `strict_names=True` additionally requires every span
    name to appear in `KNOWN_SPANS` — the repo's own emitters must pass
    it; third-party spans need not."""
    errors: List[str] = []
    _check(doc, TRACE_SCHEMA, "$", errors)
    if strict_names and not errors:
        for i, ev in enumerate(doc["traceEvents"]):
            if ev["ph"] == "C":
                continue                      # counters are free-form
            if ev["name"] not in KNOWN_SPANS:
                errors.append(f"$.traceEvents[{i}]: unknown span name "
                              f"{ev['name']!r} (add it to KNOWN_SPANS + "
                              "docs/telemetry_schema.md)")
    return errors


def validate_trace(path: str, *, strict_names: bool = False) -> List[str]:
    """Validate a trace file (Chrome JSON or JSONL sidecar)."""
    if path.endswith(".jsonl"):
        with open(path) as f:
            events = [json.loads(line) for line in f if line.strip()]
        doc = {"traceEvents": events, "otherData": {"schema_version": 1}}
    else:
        with open(path) as f:
            doc = json.load(f)
    return validate_events(doc, strict_names=strict_names)


def assert_valid_trace(path: str, *, strict_names: bool = False) -> None:
    errors = validate_trace(path, strict_names=strict_names)
    if errors:
        raise ValueError(f"invalid trace {path}:\n  " + "\n  ".join(errors))


def span_durations(events: Iterable[dict]) -> Dict[str, Dict[str, float]]:
    """Aggregate complete-span events -> {name: {count, total_s, mean_s,
    self_total_s}}. `self_total_s` subtracts the time spent in directly
    nested spans (depth + containment), so a per-phase breakdown sums to
    ~the root span instead of double-counting parents."""
    spans = [e for e in events if e.get("ph") == "X"]
    out: Dict[str, Dict[str, float]] = {}
    for e in spans:
        rec = out.setdefault(e["name"], {"count": 0, "total_s": 0.0,
                                         "self_total_s": 0.0})
        rec["count"] += 1
        rec["total_s"] += e["dur"] / 1e6
        child = 0.0
        d = e.get("args", {}).get("depth")
        if d is not None:
            for c in spans:
                if (c is not e and c.get("args", {}).get("depth") == d + 1
                        and c["ts"] >= e["ts"]
                        and c["ts"] + c.get("dur", 0.0)
                        <= e["ts"] + e["dur"]):
                    child += c["dur"] / 1e6
        rec["self_total_s"] += max(e["dur"] / 1e6 - child, 0.0)
    for rec in out.values():
        rec["mean_s"] = rec["total_s"] / max(rec["count"], 1)
    return out
