"""Unified metrics registry: counters / gauges / histograms with labels.

Every layer that already computes run statistics — the streaming QoS
aggregator (`traffic.metrics.StreamAggregator`), the serving pool ledger
(`ServerPool.counters()`), the streaming trainers' per-round history rows —
publishes into ONE registry under a common naming scheme, and the registry
exports two ways:

* Prometheus text exposition format (``to_prometheus()`` /
  ``write_prometheus(path)``) — scrape-ready, histogram buckets in the
  standard ``_bucket{le=...}`` / ``_sum`` / ``_count`` convention;
* JSONL snapshots (``write_jsonl(path)``) — one metric sample per line,
  machine-diffable across PRs.

Naming scheme (see docs/telemetry_schema.md): ``eat_<layer>_<quantity>``
with layers ``stream`` (QoS aggregates), ``serving`` (pool/executor),
``train`` (per-round trainer telemetry), ``decision`` (policy-inference
latency). Labels carry the low-cardinality dimensions (policy, backend,
cell, algo); values are plain floats.

Publishing is pure host-side dict arithmetic — it never touches compiled
code, so metrics are byte-identical whether tracing is on or off.
"""
from __future__ import annotations

import json
import math
import re
import threading
import time
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

# 60 log-spaced bins across 0.1 s .. 1e5 s, plus underflow/overflow slots —
# the QoS response-latency range this simulator spans (re-exported by
# `traffic.metrics`, its historical home).
DEFAULT_EDGES = np.geomspace(1e-1, 1e5, 61).astype(np.float32)


class LatencyHistogram:
    """Fixed-bin streaming histogram with percentile estimation.

    Slot semantics (matching `np.searchsorted(edges, v)` /
    `traffic.metrics.bucketize_counts`): slot 0 is the underflow,
    holding values in (-inf, edges[0]]; slot i >= 1 holds
    (edges[i-1], edges[i]]; the last slot is the overflow
    (> edges[-1]).

    Percentiles interpolate linearly inside the resolved slot.
    Sub-range resolution at the extremes is bounded by the edges:

    * the underflow slot interpolates over [0, edges[0]] — values below
      edges[0] are reported no finer than that sub-range (callers whose
      data can sit far below edges[0] should pick tighter edges, e.g.
      `telemetry.profile.DECISION_EDGES` for decision latencies);
    * the overflow slot clamps to edges[-1] (the histogram cannot know
      how far past the top edge the mass sits — pair with an exact
      running max, as `StreamAggregator` does);
    * q == 0 resolves to the lower edge of the first *occupied* slot
      (it used to report 0.0 regardless of where the data sat).
    """

    def __init__(self, edges: Optional[np.ndarray] = None):
        self.edges = np.asarray(DEFAULT_EDGES if edges is None else edges,
                                np.float64)
        self.counts = np.zeros(len(self.edges) + 1, np.int64)

    @property
    def total(self) -> int:
        return int(self.counts.sum())

    def add_counts(self, counts) -> None:
        self.counts += np.asarray(counts, np.int64)

    def add_values(self, values) -> None:
        idx = np.searchsorted(self.edges, np.asarray(values, np.float64))
        np.add.at(self.counts, idx, 1)

    def percentile(self, q: float) -> float:
        """q in [0, 1]; linear interpolation inside the resolved slot
        (see the class docstring for the underflow/overflow sub-range
        behaviour at the extremes)."""
        total = self.total
        if total == 0:
            return float("nan")
        target = q * total
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        if self.counts[i] == 0:
            # only reachable at target == 0 (q == 0) with empty leading
            # slots: resolve to the first occupied slot's lower edge
            # instead of interpolating from an empty one
            i = int(np.argmax(self.counts > 0))
            return float(self.edges[i - 1] if i >= 1 else 0.0)
        lo = self.edges[i - 1] if i >= 1 else 0.0
        hi = self.edges[i] if i < len(self.edges) else self.edges[-1]
        prev = cum[i - 1] if i >= 1 else 0
        frac = (target - prev) / max(int(self.counts[i]), 1)
        return float(lo + np.clip(frac, 0.0, 1.0) * (hi - lo))


LabelSet = Tuple[Tuple[str, str], ...]


def _labelset(labels: Optional[Mapping[str, str]]) -> LabelSet:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(ls: LabelSet, extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    items = ls + extra
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


class Counter:
    """Monotone accumulator per label set."""
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.values: Dict[LabelSet, float] = {}

    def inc(self, value: float = 1.0,
            labels: Optional[Mapping[str, str]] = None) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        ls = _labelset(labels)
        self.values[ls] = self.values.get(ls, 0.0) + float(value)


class Gauge:
    """Last-value metric per label set."""
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.values: Dict[LabelSet, float] = {}

    def set(self, value: float,
            labels: Optional[Mapping[str, str]] = None) -> None:
        self.values[_labelset(labels)] = float(value)


class Histogram:
    """Fixed-bin histogram per label set (`LatencyHistogram` underneath),
    exported in the Prometheus cumulative-bucket convention."""
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 edges: Optional[np.ndarray] = None):
        self.name, self.help = name, help
        self.edges = np.asarray(DEFAULT_EDGES if edges is None else edges,
                                np.float64)
        self.values: Dict[LabelSet, LatencyHistogram] = {}
        self.sums: Dict[LabelSet, float] = {}

    def _hist(self, ls: LabelSet) -> LatencyHistogram:
        h = self.values.get(ls)
        if h is None:
            h = self.values[ls] = LatencyHistogram(self.edges)
            self.sums[ls] = 0.0
        return h

    def observe(self, value: float,
                labels: Optional[Mapping[str, str]] = None) -> None:
        ls = _labelset(labels)
        self._hist(ls).add_values([value])
        self.sums[ls] += float(value)

    def observe_counts(self, counts, approx_sum: float = 0.0,
                       labels: Optional[Mapping[str, str]] = None) -> None:
        """Fold pre-binned device-side counts (e.g. a window's latency
        histogram row); `approx_sum` keeps the `_sum` series meaningful."""
        ls = _labelset(labels)
        self._hist(ls).add_counts(counts)
        self.sums[ls] += float(approx_sum)

    def percentile(self, q: float,
                   labels: Optional[Mapping[str, str]] = None) -> float:
        ls = _labelset(labels)
        return self._hist(ls).percentile(q) if ls in self.values \
            else float("nan")


class MetricsRegistry:
    """Name -> metric, with typed creation and full-registry export."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  edges: Optional[np.ndarray] = None) -> Histogram:
        return self._get(Histogram, name, help, edges=edges)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- export --------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict]:
        """{name: {"kind", "help", "samples": {label-string: value}}} —
        histograms expand into bucket/sum/count sample series."""
        out: Dict[str, Dict] = {}
        for name, m in sorted(self._metrics.items()):
            samples: Dict[str, float] = {}
            if isinstance(m, Histogram):
                for ls, h in m.values.items():
                    # slot i of LatencyHistogram covers (edges[i-1],
                    # edges[i]] with slot 0 the underflow, so the
                    # cumulative prometheus bucket le=edges[i] is
                    # sum(counts[:i+1]); the overflow slot only shows in
                    # le="+Inf" (= total), per the exposition convention.
                    cum = 0
                    for i, edge in enumerate(h.edges):
                        cum += int(h.counts[i])
                        samples[f"{name}_bucket" + _fmt_labels(
                            ls, (("le", repr(float(edge))),))] = float(cum)
                    samples[f"{name}_bucket"
                            + _fmt_labels(ls, (("le", "+Inf"),))] = \
                        float(h.total)
                    samples[f"{name}_sum" + _fmt_labels(ls)] = m.sums[ls]
                    samples[f"{name}_count" + _fmt_labels(ls)] = \
                        float(h.total)
            else:
                for ls, v in m.values.items():
                    samples[name + _fmt_labels(ls)] = v
            out[name] = {"kind": m.kind, "help": m.help, "samples": samples}
        return out

    def to_prometheus(self) -> str:
        lines: List[str] = []
        for name, rec in self.snapshot().items():
            if rec["help"]:
                lines.append(f"# HELP {name} {rec['help']}")
            lines.append(f"# TYPE {name} {rec['kind']}")
            for series, v in rec["samples"].items():
                lines.append(f"{series} {v:.17g}")
        return "\n".join(lines) + "\n"

    def write_prometheus(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_prometheus())
        return path

    def write_jsonl(self, path: str) -> str:
        ts = time.time()
        with open(path, "w") as f:
            for name, rec in self.snapshot().items():
                for series, v in rec["samples"].items():
                    f.write(json.dumps({"ts": ts, "metric": name,
                                        "series": series, "kind": rec["kind"],
                                        "value": v}) + "\n")
        return path


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^}]*\})?\s+(?P<value>[^\s]+)$")


def parse_prometheus(text: str) -> Dict[str, float]:
    """Prometheus text -> {series-string: value}. Round-trips
    `to_prometheus()` output exactly (label order is canonical there)."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable prometheus sample: {line!r}")
        out[m.group("name") + (m.group("labels") or "")] = \
            float(m.group("value"))
    return out


# ----------------------------------------------------------------------
# the process-wide default registry (consumers may still build their own)
_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _DEFAULT


# -- publishers ---------------------------------------------------------
def publish_summary(summary: Mapping[str, object], *, prefix: str,
                    labels: Optional[Mapping[str, str]] = None,
                    registry: Optional[MetricsRegistry] = None) -> None:
    """Flat scalar summary dict -> gauges `<prefix>_<key>{labels}`.
    Non-numeric values are skipped (they belong in labels, not samples)."""
    reg = registry or default_registry()
    for k, v in summary.items():
        if isinstance(v, bool) or not isinstance(v, (int, float, np.number)):
            continue
        if not math.isfinite(float(v)):
            continue
        reg.gauge(f"{prefix}_{k}").set(float(v), labels=labels)


def publish_counters(counters: Mapping[str, object], *, prefix: str,
                     labels: Optional[Mapping[str, str]] = None,
                     registry: Optional[MetricsRegistry] = None) -> None:
    """Monotone ledger dict (e.g. `ServerPool.counters()`) -> gauges with
    the counter naming suffix `_total` (the source resets per run, so the
    registry records the latest run total rather than accumulating)."""
    reg = registry or default_registry()
    for k, v in counters.items():
        if isinstance(v, (int, float, np.number)) and not isinstance(v, bool):
            reg.gauge(f"{prefix}_{k}_total").set(float(v), labels=labels)
