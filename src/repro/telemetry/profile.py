"""Decision-latency profiling: how long does the scheduler take to decide?

EAT's QoS accounting (Eq. 4a) treats the scheduler itself as free, but the
diffusion actor pays K denoise steps per decision — at high arrival rates
that inference cost, not env throughput, bounds the achievable line rate
("Accelerating AIGC Services with Latent Action Diffusion", PAPERS.md).
This module measures it:

* `DecisionProfile` — streaming histograms (`LatencyHistogram` on
  decision-scaled log edges) of the three per-decision phases the serving
  backend can split at its jit boundaries: `policy` (inference),
  `env_advance` (mirror decision step), `executor` (real model work).
* `profile_policy` — the standalone probe: wall-clocks one scheduling
  decision (state -> action) of any rollout-protocol policy on a
  representative (trace, state, obs), one jitted program per policy,
  compile excluded. `benchmarks/bench_decision_latency.py` sweeps it over
  the registry; `Simulator` runs it post-run when
  `TraceConfig(profile_decisions=True)` and folds the percentiles into
  the result summary / sweep rows.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.telemetry.metrics import LatencyHistogram

# decision latencies live in microseconds..seconds, two decades below the
# QoS response-latency edges — ~10 log-bins per decade across 1e-6..1e2 s
DECISION_EDGES = np.geomspace(1e-6, 1e2, 81).astype(np.float64)

PHASES = ("policy", "env_advance", "executor")


class DecisionProfile:
    """Per-phase streaming latency histograms with percentile summaries."""

    def __init__(self):
        self.hists: Dict[str, LatencyHistogram] = {
            p: LatencyHistogram(DECISION_EDGES) for p in PHASES}
        self.sums: Dict[str, float] = {p: 0.0 for p in PHASES}

    def observe(self, phase: str, seconds: float) -> None:
        self.hists[phase].add_values([seconds])
        self.sums[phase] += float(seconds)

    def counts(self, phase: str) -> int:
        return self.hists[phase].total

    def summary(self) -> Dict[str, float]:
        """Flat scalars: `<phase>_latency_{p50,p95,p99,mean}_s` + counts,
        with the policy phase doubled under the headline `decision_*`
        names every consumer keys on."""
        out: Dict[str, float] = {}
        for p in PHASES:
            h = self.hists[p]
            if h.total == 0:
                continue
            out[f"{p}_latency_p50_s"] = h.percentile(0.50)
            out[f"{p}_latency_p95_s"] = h.percentile(0.95)
            out[f"{p}_latency_p99_s"] = h.percentile(0.99)
            out[f"{p}_latency_mean_s"] = self.sums[p] / h.total
            out[f"{p}_decisions"] = float(h.total)
        for k in ("p50", "p95", "p99", "mean"):
            src = f"policy_latency_{k}_s"
            if src in out:
                out[f"decision_latency_{k}_s"] = out[src]
        return out


# ----------------------------------------------------------------------
def profile_policy(ecfg, policy, params, key, *, trace=None, state=None,
                   iters: int = 50, warmup: int = 2,
                   batch: int = 0) -> Dict[str, float]:
    """Wall-clock `iters` single decisions of one rollout-protocol policy.

    The probe runs the shared actor layer's per-decision program
    (`repro.actors.actor_program(ecfg, policy).act` — the key split +
    actor forward at the serving backend's jit boundary), so the measured
    executable is literally the one a serving decision pays per arriving
    task. No env step, no executor. Returns
    `decision_latency_{p50,p95,p99,mean}_s` (+ `_n`, + `sampler` when the
    policy carries a sampler label).

    ``batch > 0`` measures the batched view instead — the `vmapped`
    program the fused rollout scan pays per decision step across `batch`
    envs (trace/state/obs broadcast). Single-decision timings on small
    nets are floored by host dispatch; the batched probe is where a
    cheaper sampler's compute saving is visible, so latency gates compare
    samplers at batch scale.
    """
    import jax
    import jax.numpy as jnp

    from repro.actors.program import actor_program
    from repro.core import env as EV
    from repro.core.workload import TraceConfig, make_trace

    if trace is None:
        trace = make_trace(jax.random.PRNGKey(0),
                           TraceConfig(num_tasks=ecfg.max_tasks,
                                       max_servers=ecfg.num_servers,
                                       num_models=ecfg.num_models))
    if state is None:
        state = EV.reset(ecfg)
    _, obs = EV.reset_view(ecfg, trace, state)

    aprog = actor_program(ecfg, policy)
    if batch > 0:
        bcast = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda x: jnp.broadcast_to(x, (batch,) + jnp.shape(x)), t)
        btrace, bstate = bcast(trace), bcast(state)
        bobs = jnp.broadcast_to(obs, (batch,) + obs.shape)
        vp = jax.jit(aprog.vmapped)

        def run(p, k):
            return vp(p, jax.random.split(k, batch), btrace, bstate, bobs)[0]
    else:
        run = lambda p, k: aprog.act(trace, state, obs, k, p)[1]  # noqa: E731
    jax.block_until_ready(run(params, key))          # compile
    for _ in range(warmup):
        jax.block_until_ready(run(params, key))

    hist = LatencyHistogram(DECISION_EDGES)
    total = 0.0
    for i in range(iters):
        k = jax.random.fold_in(key, i)
        t0 = time.perf_counter()
        jax.block_until_ready(run(params, k))
        dt = time.perf_counter() - t0
        hist.add_values([dt])
        total += dt
    out = {
        "decision_latency_p50_s": hist.percentile(0.50),
        "decision_latency_p95_s": hist.percentile(0.95),
        "decision_latency_p99_s": hist.percentile(0.99),
        "decision_latency_mean_s": total / max(iters, 1),
        "decision_latency_n": float(iters),
    }
    if batch > 0:
        out["decision_batch"] = float(batch)
    if aprog.sampler:
        out["sampler"] = aprog.sampler
    return out
