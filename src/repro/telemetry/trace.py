"""Span-based structured tracing for the whole stack.

One `Tracer` per run collects host-side *spans* — named wall-clock
intervals opened at jit boundaries (window seam, policy decision, env
advance, model load, prefill, decode, replay push, gradient update) — and
writes them as Chrome trace-event JSON (loadable in perfetto /
chrome://tracing) plus a line-per-event JSONL sidecar. Spans are recorded
strictly OUTSIDE compiled code: the tracer never enters a `jit`-traced
region, so enabling it cannot perturb a single compiled program, and with
`TraceConfig(enabled=False)` (the default) every call site hits the
shared `NULL_TRACER` no-op — zero allocations, zero behavioural change
(`tests/test_telemetry.py` pins summaries bitwise-identical on vs off).

The front door is `ExecSpec(trace=TraceConfig(enabled=True, path=...))`:
`Simulator`, `StreamRunner`, `train_stream_sac/ppo`, and the serving
backend all resolve the SAME `TraceConfig` to the SAME `Tracer` (live
tracers are cached per config), so one run emits one trace file no matter
how many layers touch it.

    with tracer.span("window", window=w):
        ...host work wrapping one jitted window rollout...
    tracer.write()          # idempotent full rewrite; safe to call often

Span names and their argument keys are documented in
`docs/telemetry_schema.md`; `telemetry.schema.validate_trace` checks an
emitted file against the machine-readable schema.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

#: schema version stamped into every trace file (bump on breaking changes)
TRACE_SCHEMA_VERSION = 1


@dataclass(frozen=True)
class TraceConfig:
    """Declarative tracing knobs, hashable so it can ride on `ExecSpec`.

    * ``enabled`` — master switch; False (default) resolves to the no-op
      `NULL_TRACER` everywhere.
    * ``path`` — Chrome trace JSON output (default ``trace.json``); the
      JSONL sidecar lands next to it as ``<path>.jsonl``.
    * ``jsonl`` — also write the JSONL sidecar (one event per line).
    * ``metrics_path`` — when set, consumers snapshot the unified metrics
      registry here (Prometheus text; ``<path>.jsonl`` gets the JSONL
      snapshot) at run end.
    * ``profile_decisions`` — time per-decision policy inference after a
      `Simulator.run` (`telemetry.profile`) and surface p50/p95/p99 in
      the result summary/sweep rows.
    * ``profile_iters`` — decisions timed by the profiler probe.
    * ``jax_profiler_dir`` — opt-in `jax.profiler.start_trace` capture
      directory (device-side profile alongside the host-span trace).
    """
    enabled: bool = False
    path: str = "trace.json"
    jsonl: bool = True
    metrics_path: Optional[str] = None
    profile_decisions: bool = False
    profile_iters: int = 50
    jax_profiler_dir: Optional[str] = None


class _NullSpan:
    """No-op context manager shared by every disabled call site."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every operation is a constant-time no-op."""
    enabled = False
    config: Optional[TraceConfig] = None

    def span(self, name: str, cat: str = "phase", **args):
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "phase", **args) -> None:
        pass

    def counter(self, name: str, value: float, **args) -> None:
        pass

    def write(self) -> Optional[str]:
        return None


NULL_TRACER = NullTracer()


class _Span:
    __slots__ = ("tracer", "name", "cat", "args", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: Dict):
        self.tracer, self.name, self.cat, self.args = tracer, name, cat, args

    def __enter__(self):
        self.depth = self.tracer._enter()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        self.tracer._exit(self.name, self.cat, self.t0, dur, self.depth,
                          self.args)
        return False


class Tracer:
    """Collects spans/instants/counters; writes Chrome JSON + JSONL.

    Events are buffered on the host (a 10^5-span run is a few MB) and the
    output files are fully rewritten on every `write()` — callers flush at
    natural boundaries (run end, round end) and a crash mid-run still
    leaves the last consistent file behind.
    """

    enabled = True

    def __init__(self, config: TraceConfig):
        self.config = config
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._epoch = time.time()
        self._depth = 0
        self._lock = threading.Lock()
        self._pid = os.getpid()

    # -- recording -----------------------------------------------------
    def span(self, name: str, cat: str = "phase", **args) -> _Span:
        """Context manager: one complete ("X") event on exit."""
        return _Span(self, name, cat, args)

    def _enter(self) -> int:
        with self._lock:
            d = self._depth
            self._depth += 1
        return d

    def _exit(self, name, cat, t0, dur, depth, args) -> None:
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": (t0 - self._t0) * 1e6, "dur": dur * 1e6,
              "pid": self._pid, "tid": 0, "args": dict(args, depth=depth)}
        with self._lock:
            self._depth -= 1
            self.events.append(ev)

    def instant(self, name: str, cat: str = "phase", **args) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": (time.perf_counter() - self._t0) * 1e6,
              "pid": self._pid, "tid": 0, "args": args}
        with self._lock:
            self.events.append(ev)

    def counter(self, name: str, value: float, **args) -> None:
        ev = {"name": name, "cat": "counter", "ph": "C",
              "ts": (time.perf_counter() - self._t0) * 1e6,
              "pid": self._pid, "tid": 0,
              "args": dict(args, value=float(value))}
        with self._lock:
            self.events.append(ev)

    # -- output --------------------------------------------------------
    def _ordered(self) -> List[Dict[str, Any]]:
        # completion order == append order; presentation order is by start
        # time so nesting reads top-down in the file and in `trace_summary`
        return sorted(self.events, key=lambda e: e["ts"])

    def write(self) -> str:
        """(Re)write the trace files; returns the Chrome JSON path."""
        path = self.config.path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        events = self._ordered()
        doc = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "schema_version": TRACE_SCHEMA_VERSION,
                "epoch_unix_s": self._epoch,
            },
        }
        with open(path, "w") as f:
            json.dump(doc, f)
        if self.config.jsonl:
            with open(path + ".jsonl", "w") as f:
                for ev in events:
                    f.write(json.dumps(ev) + "\n")
        return path


# ----------------------------------------------------------------------
# one live tracer per TraceConfig: every layer that threads the same
# config (Simulator, StreamRunner, trainers, serving backend) shares one
# event buffer, hence one trace file per run.
_LIVE: Dict[TraceConfig, Tracer] = {}
_LIVE_LOCK = threading.Lock()


def tracer_for(config: Optional[TraceConfig]):
    """Resolve a TraceConfig to a tracer (NULL_TRACER when disabled)."""
    if config is None or not config.enabled:
        return NULL_TRACER
    with _LIVE_LOCK:
        t = _LIVE.get(config)
        if t is None:
            t = _LIVE[config] = Tracer(config)
        return t


def reset_tracers() -> None:
    """Drop every cached live tracer (tests; fresh files per scenario)."""
    with _LIVE_LOCK:
        _LIVE.clear()


# ----------------------------------------------------------------------
class jax_profile:
    """Opt-in device-side capture: wraps a region in
    `jax.profiler.start_trace(dir)` when `TraceConfig.jax_profiler_dir`
    is set (and tracing is enabled), no-op otherwise."""

    def __init__(self, config: Optional[TraceConfig]):
        self._dir = (config.jax_profiler_dir
                     if config is not None and config.enabled else None)

    def __enter__(self):
        if self._dir:
            import jax
            jax.profiler.start_trace(self._dir)
        return self

    def __exit__(self, *exc):
        if self._dir:
            import jax
            jax.profiler.stop_trace()
        return False
