"""The 10 assigned architecture configs must match the assignment table exactly."""
import pytest

from repro.common.config import ASSIGNED_ARCHS, get_config, list_configs

# (layers, d_model, heads, kv, d_ff, vocab, family)
EXPECTED = {
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, "hybrid"),
    "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000, "dense"),
    "whisper-small": (12, 768, 12, 12, 3072, 51865, "audio"),
    "gemma-7b": (28, 3072, 16, 16, 24576, 256000, "dense"),
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304, "moe"),
    "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256, "dense"),
    "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936, "dense"),
    "internvl2-1b": (24, 896, 14, 2, 4864, 151655, "vlm"),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936, "moe"),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304, "ssm"),
}

MOE_SPECS = {
    "jamba-v0.1-52b": (16, 2),
    "olmoe-1b-7b": (64, 8),
    "qwen3-moe-30b-a3b": (128, 8),
}


def test_all_assigned_registered():
    known = set(list_configs())
    assert set(ASSIGNED_ARCHS) <= known


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_exact_dims(name):
    cfg = get_config(name)
    layers, d, h, kv, ff, vocab, fam = EXPECTED[name]
    assert cfg.num_layers == layers
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == vocab
    assert cfg.family == fam
    assert cfg.source  # every config cites its source


@pytest.mark.parametrize("name", sorted(MOE_SPECS))
def test_moe_specs(name):
    cfg = get_config(name)
    e, k = MOE_SPECS[name]
    assert cfg.moe is not None
    assert cfg.moe.num_experts == e
    assert cfg.moe.experts_per_token == k


def test_special_attributes():
    assert get_config("gemma-7b").head_dim == 256
    assert get_config("gemma-7b").activation == "geglu"
    assert get_config("qwen2-1.5b").qkv_bias
    assert get_config("whisper-small").encoder_layers == 12
    assert get_config("jamba-v0.1-52b").attn_period == 8
    assert get_config("internvl2-1b").frontend == "vision"
    assert get_config("xlstm-125m").layer_pattern == "xlstm"


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_padded_vocab_shardable(name):
    cfg = get_config(name)
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    assert cfg.d_model % 16 == 0  # shards on the 16-way axes


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_reduced_variant_bounds(name):
    r = get_config(name).reduced()
    assert r.d_model <= 512
    assert r.num_layers <= 8
    if r.moe is not None:
        assert r.moe.num_experts <= 4


def test_param_counts_plausible():
    # sanity: headline sizes within 2x of the public numbers
    assert 30e9 < get_config("jamba-v0.1-52b").param_count() < 80e9
    assert 0.6e9 < get_config("tinyllama-1.1b").param_count() < 1.6e9
    assert 5e9 < get_config("gemma-7b").param_count() < 12e9
    assert 4e9 < get_config("olmoe-1b-7b").param_count() < 9e9
    active = get_config("olmoe-1b-7b").param_count(active_only=True)
    assert active < 2.5e9  # ~1B active
