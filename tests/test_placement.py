"""Two-timescale model placement (`repro.placement`, ISSUE 9).

The contracts under test:

* **Off means off.** ``placement=None`` and ``PlacementSpec.none()`` produce
  bitwise-identical summaries AND final carries on the fused and serving
  backends — placement applies on the host carry between windows, so an
  inactive spec changes no compiled program and no result.
* **Same arrivals.** An active placement policy sees the exact arrival
  stream the placement-free run sees (`tasks_injected` parity): the slow
  timescale rewrites idle-server caches, never demand.
* **Ledger conservation.** The streaming seam ledger balances with
  placement active, with and without fault injection.
* **Fault interaction.** Placement under an aggressive `FaultSpec` stays
  deterministic and conserves both ledgers; a cold restart wipes placed
  caches through the same decision-step wipe that covers carried ones.
* **Planner semantics.** Whole synthetic gangs only (the env's reuse test
  needs complete idle gangs), keep-before-bind, cheapest-first binding,
  busy servers untouched, seam-convention gang labels.
* **Pool tie-break.** `ServerPool.pick_fresh` prefers arch-matching idle
  servers among equally fragmented candidates — and reproduces the
  historical order exactly when no arch is given.
"""
import dataclasses
import re

import jax
import numpy as np
import pytest

from repro import api
from repro.core import env as EV
from repro.core.scenarios import Scenario, zipf_probs
from repro.core.workload import TraceConfig
from repro.faults import FaultSpec
from repro.placement import (DemandStats, PlacementManager, PlacementSpec,
                             known_policies, placement_active, plan_gangs,
                             plan_stream, prior_weights)
from repro.serving.pool import LogicalServer, ServerPool

ECFG = EV.EnvConfig(num_servers=4, max_tasks=8, num_models=3)
TCFG = TraceConfig(num_tasks=8, arrival_rate=2.0, max_servers=4,
                   num_models=3, model_probs=zipf_probs(3))
CELL = Scenario(name="placement-test-cell", ecfg=ECFG, tcfg=TCFG)

SERVE_ECFG = EV.EnvConfig(num_servers=4, max_tasks=8)
SERVE_CELL = Scenario(name="placement-serve-cell", ecfg=SERVE_ECFG,
                      tcfg=TraceConfig(num_tasks=8, arrival_rate=2.0,
                                       max_servers=4))
MIRROR = api.ExecSpec(backend="serving", serving_execute=False)

_MEASURED = re.compile(
    r"(_latency_(p\d+|mean)_s$|_decisions$|^decision_latency_n$"
    r"|measured_busy|^wall_s$)")


def _det(summary):
    """The deterministic slice of a summary (drop wall-clock noise)."""
    return {k: v for k, v in summary.items()
            if isinstance(v, (int, float, bool)) and not _MEASURED.search(k)}


def _wl(cell=CELL, **kw):
    kw.setdefault("streams", 2)
    kw.setdefault("num_windows", 3)
    kw.setdefault("window_tasks", 8)
    return api.WorkloadSpec.streaming(cell, **kw)


def _run(wl, spec, key=None):
    sim = api.Simulator(wl, spec)
    return sim.run(api.PolicySpec("greedy"), key or jax.random.PRNGKey(0))


# ------------------------------------------------------------ spec
def test_spec_validation():
    assert set(known_policies()) >= {"none", "static", "lfu", "forecast"}
    assert PlacementSpec.none().active is False
    assert PlacementSpec(policy="lfu").active is True
    assert placement_active(None) is False
    assert placement_active(PlacementSpec.none()) is False
    assert placement_active(PlacementSpec(policy="forecast")) is True
    with pytest.raises(ValueError, match="policy"):
        PlacementSpec(policy="nope")
    with pytest.raises(ValueError, match="interval"):
        PlacementSpec(policy="lfu", interval=0)
    with pytest.raises(ValueError, match="ewma_alpha"):
        PlacementSpec(policy="forecast", ewma_alpha=0.0)
    with pytest.raises(ValueError, match="model_probs"):
        PlacementSpec(policy="static", model_probs=(-1.0, 2.0))
    # frozen + hashable: the ExecSpec contract
    hash(PlacementSpec(policy="lfu", model_probs=(0.5, 0.5)))


def test_manager_rejects_inactive_spec():
    with pytest.raises(ValueError, match="active spec"):
        PlacementManager(PlacementSpec.none(), ECFG)


def test_simulator_rejects_episodic_placement():
    wl = api.WorkloadSpec.episodic(CELL, batch=2)
    with pytest.raises(ValueError, match="streaming-only"):
        api.Simulator(wl, api.ExecSpec(placement=PlacementSpec(policy="lfu")))


# ------------------------------------------------------------ stats
def test_demand_stats_binning_and_accessors():
    st = DemandStats(1, 2, (1, 2, 4, 8))
    model = np.array([[0, 0, 1, 0, -1]])
    c = np.array([[1, 3, 4, 8, 2]])      # c=3 bins DOWN to the 2-slot
    st.observe(model, c)
    last = st.last(0)
    assert last[0, 0] == 1 and last[0, 1] == 1 and last[0, 3] == 1
    assert last[1, 2] == 1
    assert last.sum() == 4               # model=-1 ignored
    # single window: EWMA == last; seasonal(period<=1) == last
    np.testing.assert_array_equal(st.ewma(0, 0.5), last)
    np.testing.assert_array_equal(st.seasonal(0, 1, 0), last)
    st.observe(np.zeros((1, 5), int), np.ones((1, 5), int))
    ew = st.ewma(0, 0.5)
    assert ew[0, 0] == 0.5 * 5 + 0.5 * 1  # alpha*new + (1-alpha)*old
    # phase 0 of period 2 picks only the first window
    np.testing.assert_array_equal(st.seasonal(0, 2, 0), last)


def test_policies_return_demand_weights():
    st = DemandStats(1, 3, (1, 2, 4, 8))
    spec = PlacementSpec(policy="forecast", model_probs=(0.7, 0.2, 0.1))
    from repro.placement.policies import get_placement_policy
    # before any observation: every policy falls back to the static prior
    prior = prior_weights(spec, 3, st.c_support)
    for name in ("static", "lfu", "forecast"):
        w = get_placement_policy(name)(spec, st, 0)
        assert w.shape == (3, 4) and (w >= 0).all()
        np.testing.assert_allclose(w, prior)
    # flash crowd on model 2: the trend boost outranks the EWMA baseline
    st.observe(np.zeros((1, 4), int), np.full((1, 4), 2))
    st.observe(np.full((1, 8), 2), np.full((1, 8), 2))
    w = get_placement_policy("forecast")(spec, st, 0)
    assert w[2, 1] > w[0, 1]


# ------------------------------------------------------------ planner
def test_plan_gangs_tracks_demand_and_capacity():
    w = np.array([[4.0, 0.0], [1.0, 0.0]])
    gangs = plan_gangs(w, capacity=6, c_support=(1, 2))
    assert sum(c for _, c in gangs) <= 6
    n0 = sum(1 for m, _ in gangs if m == 0)
    n1 = sum(1 for m, _ in gangs if m == 1)
    assert n0 > n1 >= 1                  # credit-halving shares capacity
    capped = plan_gangs(w, 6, (1, 2), max_gangs_per_cell=1)
    assert sum(1 for m, _ in capped if m == 0) == 1


def test_plan_stream_binds_cheapest_first():
    # 4 idle broken servers: s1 already holds model 0 (hit), s0/s3 empty,
    # s2 holds model 1 (evict) -> a (0, 2)-gang binds to {s1, s0}
    idle = np.ones(4, bool)
    model = np.array([-1, 0, 1, -1], np.int32)
    gang = np.full(4, -1, np.int32)
    size = np.zeros(4, np.int32)
    w = np.zeros((2, 2))
    w[0, 1] = 1.0                        # demand: one gang of (m=0, c=2)
    sp = plan_stream(w, idle, model, gang, size, (1, 2), K=8,
                     max_gangs_per_cell=1)
    placed = np.flatnonzero(sp.gang_size == 2)
    assert set(placed) == {0, 1}
    assert sp.counters["evictions"] == 0
    assert sp.counters["prefetches"] == 1        # only s0 changed model
    assert not sp.prefetch[1]                    # s1 was already warm
    # seam-convention label: K + min(member index)
    assert sp.gang[0] == sp.gang[1] == 8 + 0


def test_plan_stream_keeps_existing_gangs_and_skips_busy():
    # s0+s1: a complete idle gang already matching (m=1, c=2); s2 busy
    idle = np.array([True, True, False, True])
    model = np.array([1, 1, 0, -1], np.int32)
    gang = np.array([5, 5, 7, -1], np.int32)
    size = np.array([2, 2, 1, 0], np.int32)
    w = np.zeros((2, 2))
    w[1, 1] = 1.0
    sp = plan_stream(w, idle, model, gang, size, (1, 2), K=8,
                     max_gangs_per_cell=1)
    assert sp.counters["gangs_kept"] == 1
    assert sp.counters["prefetches"] == sp.counters["evictions"] == 0
    np.testing.assert_array_equal(sp.model, model)    # zero churn
    np.testing.assert_array_equal(sp.gang, gang)
    # busy server untouched even under heavy demand
    w[0, 0] = 10.0
    sp2 = plan_stream(w, idle, model, gang, size, (1, 2), K=8)
    assert sp2.model[2] == 0 and sp2.gang[2] == 7 and sp2.gang_size[2] == 1


# ------------------------------------------------------------ identity
def test_placement_none_bitwise_identical_fused():
    """None vs PlacementSpec.none() vs the pre-placement default: same
    summary, same final carry, byte for byte (fused backend)."""
    base = _run(_wl(), api.ExecSpec(backend="fused"))
    off = _run(_wl(), api.ExecSpec(backend="fused",
                                   placement=PlacementSpec.none()))
    assert _det(base.summary) == _det(off.summary)
    a = jax.tree_util.tree_map(np.asarray, base.raw.final_carry)
    b = jax.tree_util.tree_map(np.asarray, off.raw.final_carry)
    jax.tree_util.tree_map(np.testing.assert_array_equal, a, b)
    assert base.raw.placement_counters == off.raw.placement_counters == {}


def test_placement_none_bitwise_identical_serving():
    wl = _wl(SERVE_CELL, streams=1, num_windows=2)
    base = _run(wl, MIRROR)
    off = _run(wl, dataclasses.replace(MIRROR,
                                       placement=PlacementSpec.none()))
    assert _det(base.summary) == _det(off.summary)
    a = jax.tree_util.tree_map(np.asarray, base.raw.final_carry)
    b = jax.tree_util.tree_map(np.asarray, off.raw.final_carry)
    jax.tree_util.tree_map(np.testing.assert_array_equal, a, b)


# ------------------------------------------------------------ active runs
@pytest.mark.parametrize("policy", ["static", "lfu", "forecast"])
def test_active_placement_same_arrivals_and_ledger(policy):
    """An active policy sees the placement-free arrival stream (injection
    parity), balances the seam ledger, and reports its decision ledger."""
    base = _run(_wl(), api.ExecSpec(backend="fused"))
    res = _run(_wl(), api.ExecSpec(
        backend="fused", placement=PlacementSpec(policy=policy)))
    s = res.summary
    assert s["tasks_injected"] == base.summary["tasks_injected"]
    assert s["tasks_injected"] == (
        s["tasks_scheduled"] + s["tasks_dropped"]
        + s["tasks_failed_pending_retry"] + s["tasks_leftover"])
    pc = res.raw.placement_counters
    assert pc["placement_decisions"] == 3       # one decision per seam
    assert pc["placement_gangs_planned"] > 0
    assert set(pc["per_model"]) == {0, 1, 2}
    for row in pc["per_model"].values():
        assert 0.0 <= row["cold_start_rate"] <= 1.0


def test_placement_interval_skips_seams():
    res = _run(_wl(num_windows=4), api.ExecSpec(
        backend="fused", placement=PlacementSpec(policy="lfu", interval=2)))
    # seams after windows 0..3; only (w+1) % 2 == 0 decides -> w=1, w=3
    assert res.raw.placement_counters["placement_decisions"] == 2


def test_placement_deterministic():
    spec = api.ExecSpec(backend="fused",
                        placement=PlacementSpec(policy="forecast"))
    r1, r2 = _run(_wl(), spec), _run(_wl(), spec)
    assert _det(r1.summary) == _det(r2.summary)
    assert r1.raw.placement_counters == r2.raw.placement_counters


def test_serving_prefetch_and_warm_hits():
    """Real-weight pre-warm: `apply_placement` evicts displaced weights,
    prefetches the planned models off the timed path, and the resulting
    gang satisfies the pool's reuse test — a warm hit, not a cold load."""
    from repro.placement import PlacementDecision, StreamPlacement
    from repro.serving.backend import ServingRollout
    ro = ServingRollout(4, execute=False)
    ro.pool.servers[2].model_name = "stale-arch"   # displaced by the plan
    ro.pool.servers[2].params = object()
    arch = ro._arch_of(0)
    sp = StreamPlacement(
        model=np.array([0, 0, 0, -1], np.int32),
        gang=np.array([8, 8, 8, -1], np.int32),
        gang_size=np.array([3, 3, 3, 0], np.int32),
        prefetch=np.array([True, True, True, False]),
        evict=np.array([False, False, True, False]),
        counters={})
    ro.apply_placement(PlacementDecision(0, [sp], {}))
    assert ro.placement_counters() == {"placement_weight_prefetches": 3,
                                       "placement_weight_evictions": 1}
    for i in range(3):
        s = ro.pool.servers[i]
        assert s.model_name == arch and s.params is not None
    # the placed gang is a complete idle gang: the reuse test finds it
    gang = ro.pool.find_reusable_gang(arch, 3, now=0.0)
    assert gang is not None and {s.sid for s in gang} == {0, 1, 2}
    # already-warm servers are skipped: re-planning the same layout (the
    # planner emits no evictions against an unchanged state) loads nothing
    again = sp._replace(evict=np.zeros(4, bool))
    ro.apply_placement(PlacementDecision(1, [again], {}))
    assert ro.placement_prefetches == 3
    # pinned key set: placement counters must NOT leak into pool.counters()
    assert set(ro.pool.counters()) == {"model_loads", "model_reuses"}


# ------------------------------------------------------------ faults
CHAOS = FaultSpec(seed=2, mtbf=60.0, mttr=15.0, straggler_prob=0.3,
                  straggler_factor=3.0, max_retries=3, backoff_base=2.0,
                  backoff_cap=20.0, retry_deadline=600.0)


def test_placement_under_faults_conserves_both_ledgers():
    """Cold restarts wipe placed caches through the decision-step wipe:
    chaos + placement stays deterministic, conserves the stream ledger,
    and keeps fault arrivals identical to the placement-free chaos run."""
    spec = api.ExecSpec(backend="fused", faults=CHAOS,
                        placement=PlacementSpec(policy="lfu"))
    res = _run(_wl(num_windows=4), spec)
    s = res.summary
    assert s["tasks_failed"] > 0                  # chaos actually fired
    assert res.raw.placement_counters["placement_decisions"] > 0
    assert s["tasks_injected"] == (
        s["tasks_scheduled"] + s["tasks_dropped"]
        + s["tasks_failed_pending_retry"] + s["tasks_leftover"])
    assert s["tasks_dropped"] == (s["tasks_dropped_shed"]
                                  + s["tasks_dropped_retry_exhausted"])
    base = _run(_wl(num_windows=4),
                api.ExecSpec(backend="fused", faults=CHAOS))
    assert s["tasks_injected"] == base.summary["tasks_injected"]
    assert s["tasks_failed"] == base.summary["tasks_failed"]
    rep = _run(_wl(num_windows=4), spec)
    assert _det(res.summary) == _det(rep.summary)
    assert res.raw.placement_counters == rep.raw.placement_counters


def test_cold_restart_wipes_stale_placement():
    """A placed cache on a crashed server must not survive the restart:
    under chaos the placement run's reuse economics can differ from the
    fault-free placement run (wiped caches reload), while the placement
    DECISION ledger — which only sees demand — stays identical."""
    place = PlacementSpec(policy="lfu")
    faulty = _run(_wl(num_windows=4),
                  api.ExecSpec(backend="fused", faults=CHAOS,
                               placement=place))
    clean = _run(_wl(num_windows=4),
                 api.ExecSpec(backend="fused", placement=place))
    pf, pc = (faulty.raw.placement_counters, clean.raw.placement_counters)
    assert pf["placement_decisions"] == pc["placement_decisions"]
    # chaos cost tasks: the wipe forces reloads the clean run never pays
    assert faulty.summary["tasks_scheduled"] <= clean.summary["tasks_scheduled"]


# ------------------------------------------------------------ pool
def _pool(rows):
    """rows: (model_name, gang, gang_size, busy_until) per server."""
    p = ServerPool(len(rows))
    for s, (m, g, gs, b) in zip(p.servers, rows):
        s.model_name, s.gang, s.gang_size, s.busy_until = m, g, gs, b
    return p


def test_pick_fresh_prefers_arch_matches():
    p = _pool([("a", -1, 0, 0.0), ("b", -1, 0, 0.0),
               (None, -1, 0, 0.0), ("b", -1, 0, 0.0)])
    gang = p.pick_fresh(2, 0.0, arch="b")
    assert [s.sid for s in gang] == [1, 3]
    # no arch: the exact historical sid order
    assert [s.sid for s in p.pick_fresh(2, 0.0)] == [0, 1]


def test_pick_fresh_arch_never_outranks_fragmentation():
    # s0+s1: intact idle gang holding "b"; s2 empty; s3 holds "b" but its
    # gang partner s4 is busy (broken gang). Even hunting for "b", intact
    # gangs are still broken LAST: the warm broken server then the empty
    # one win, and the intact pair survives.
    p = _pool([("b", 9, 2, 0.0), ("b", 9, 2, 0.0),
               (None, -1, 0, 0.0), ("b", 3, 2, 0.0), ("b", 3, 2, 99.0)])
    gang = p.pick_fresh(2, 0.0, arch="b")
    assert [s.sid for s in gang] == [3, 2]
