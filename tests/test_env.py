"""Environment invariants: unit + hypothesis property tests on the MDP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import timemodel as TM
from repro.core.env import EnvConfig, observe, reset, step, episode_metrics
from repro.core.quality import quality_of
from repro.core.workload import TraceConfig, make_trace

ECFG = EnvConfig(num_servers=4, max_tasks=12, queue_window=4)
TC = TraceConfig(num_tasks=12, arrival_rate=0.05, max_servers=4)


def _trace(seed=0):
    return make_trace(jax.random.PRNGKey(seed), TC)


def _rollout(actions, trace, ecfg=ECFG):
    """Apply a fixed list of actions; returns trajectory of (state, info)."""
    state = reset(ecfg)
    traj = []
    for a in actions:
        state, obs, r, done, info = step(ecfg, trace, state, jnp.asarray(a))
        traj.append((state, float(r), bool(done), info))
        if done:
            break
    return traj


def test_observation_shape_and_ranges():
    trace = _trace()
    state = reset(ECFG)
    obs = observe(ECFG, trace, state)
    assert obs.shape == ECFG.obs_shape
    assert np.all(np.asarray(obs[0, : ECFG.num_servers]) == 1.0)  # all idle


def test_eq6_layout():
    """Row semantics of the Eq.-6 matrix."""
    trace = _trace()
    state = reset(ECFG)
    # advance time past first arrival
    state = state._replace(time=trace["arr_time"][0] + 1.0)
    obs = np.asarray(observe(ECFG, trace, state))
    E = ECFG.num_servers
    assert obs[0, E] > 0          # waiting time of the first task
    assert obs[1, E] == float(trace["c"][0]) / 8.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_gang_invariants(seed):
    """For random policies: gang members idle at schedule time, steps in
    bounds, tasks scheduled at most once, conservation of tasks."""
    trace = _trace(seed % 50)
    rng = np.random.default_rng(seed)
    state = reset(ECFG)
    scheduled_ids = []
    for _ in range(80):
        t_before = float(state.time)
        free_before = np.asarray(state.server_free_at)
        a = rng.uniform(size=ECFG.action_dim).astype(np.float32)
        state, obs, r, done, info = step(ECFG, trace, state, jnp.asarray(a))
        if bool(info["scheduled"]):
            k = int(info["task"])
            assert k not in scheduled_ids          # at most once
            scheduled_ids.append(k)
            s = int(info["steps"])
            assert ECFG.s_min <= s <= ECFG.s_max   # step bounds
            # gang servers were idle before scheduling
            c_k = int(trace["c"][k])
            changed = np.where(np.asarray(state.server_free_at) != free_before)[0]
            assert len(changed) == c_k
            assert np.all(free_before[changed] <= t_before + 1e-5)
        if bool(done):
            break
    st_ = np.asarray(state.task_status)
    assert np.sum(st_ >= 1) == len(scheduled_ids)   # conservation


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_reuse_skips_init(seed):
    """finish - start == exec_time (+init iff reload)."""
    trace = _trace(seed % 20)
    rng = np.random.default_rng(seed)
    state = reset(ECFG)
    for _ in range(80):
        a = rng.uniform(size=ECFG.action_dim).astype(np.float32)
        state, _, _, done, info = step(ECFG, trace, state, jnp.asarray(a))
        if bool(info["scheduled"]):
            k = int(info["task"])
            c = np.asarray(trace["c"])[k]
            s = int(np.asarray(state.task_steps)[k])
            dur = float(np.asarray(state.task_finish)[k]
                        - np.asarray(state.task_start)[k])
            exec_t = float(TM.exec_time(jnp.asarray(c), jnp.asarray(s)))
            init_t = float(TM.init_time(jnp.asarray(c)))
            if int(np.asarray(state.task_reload)[k]):
                np.testing.assert_allclose(dur, exec_t + init_t, rtol=1e-5)
            else:
                np.testing.assert_allclose(dur, exec_t, rtol=1e-5)
        if bool(done):
            break


def test_noop_advances_time():
    trace = _trace()
    state = reset(ECFG)
    noop = jnp.asarray([1.0, 0.5] + [0.0] * ECFG.queue_window)
    state2, _, r, _, info = step(ECFG, trace, state, noop)
    assert not bool(info["scheduled"])
    assert float(r) == 0.0
    assert float(state2.time) > float(state.time)


def test_schedule_keeps_time():
    trace = _trace()
    state = reset(ECFG)
    # advance until a task is queued
    noop = jnp.asarray([1.0, 0.5] + [0.0] * ECFG.queue_window)
    for _ in range(10):
        state, _, _, _, _ = step(ECFG, trace, state, noop)
        if float(state.time) >= float(trace["arr_time"][0]):
            break
    t = float(state.time)
    act = jnp.asarray([0.0, 0.5, 1.0] + [0.0] * (ECFG.queue_window - 1))
    state2, _, r, _, info = step(ECFG, trace, state, act)
    if bool(info["scheduled"]):
        assert float(state2.time) == t   # scheduling does not advance time
        assert float(r) > 0


def test_infeasible_when_servers_busy():
    """c_k larger than idle count -> no schedule."""
    ecfg = EnvConfig(num_servers=2, max_tasks=4, queue_window=4)
    tc = TraceConfig(num_tasks=4, arrival_rate=1.0, max_servers=2,
                     c_support=(2,), c_probs=(1.0,))
    trace = make_trace(jax.random.PRNGKey(0), tc)
    state = reset(ecfg)
    noop = jnp.asarray([1.0, 0.5, 0, 0, 0, 0], jnp.float32)
    act = jnp.asarray([0.0, 0.5, 1.0, 0, 0, 0], jnp.float32)
    for _ in range(6):
        state, _, _, _, _ = step(ecfg, trace, state, noop)
    state, _, _, _, i1 = step(ecfg, trace, state, act)
    assert bool(i1["scheduled"])            # 2 idle -> ok
    state, _, _, _, i2 = step(ecfg, trace, state, act)
    assert not bool(i2["scheduled"])        # all busy now


def test_reward_structure():
    """Reward = alpha*q - lambda*I + reciprocal time term (bounded)."""
    trace = _trace()
    state = reset(ECFG)
    noop = jnp.asarray([1.0, 0.5] + [0.0] * ECFG.queue_window)
    while float(state.time) < float(trace["arr_time"][0]):
        state, _, _, _, _ = step(ECFG, trace, state, noop)
    act = jnp.asarray([0.0, 1.0, 1.0] + [0.0] * (ECFG.queue_window - 1))
    _, _, r, _, info = step(ECFG, trace, state, act)
    assert bool(info["scheduled"])
    q = float(info["quality"])
    assert q == pytest.approx(float(quality_of(50)), abs=0.02)
    assert 0 < float(r) < ECFG.alpha_q * 0.3 + 10


def test_metrics_keys():
    trace = _trace()
    state = reset(ECFG)
    m = episode_metrics(ECFG, trace, state)
    for k in ("avg_quality", "avg_response", "reload_rate", "avg_steps"):
        assert k in m
