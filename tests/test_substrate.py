"""Optimizer / checkpoint / data / sharding-rule substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.training.data import DataConfig, MarkovTokens
from repro.training.optimizer import (adam_init, adam_update, apply_updates,
                                      clip_by_global_norm, cosine_schedule)


def test_adam_matches_reference():
    """One Adam step on a scalar against hand math."""
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.5])}
    st = adam_init(p)
    upd, st = adam_update(g, st, p, lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    # m=0.05 -> mhat=0.5 ; v=0.00025/0.001 -> vhat=0.25 ; u = 0.5/(0.5+eps)=~1
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.1], rtol=1e-4)
    p2 = apply_updates(p, upd)
    np.testing.assert_allclose(np.asarray(p2["w"]), [1.9], rtol=1e-4)


def test_adam_converges_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = adam_init(p)
    for _ in range(400):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        upd, st = adam_update(g, st, p, lr=0.05)
        p = apply_updates(p, upd)
    assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    total = jnp.sqrt(clipped["a"] ** 2 + clipped["b"] ** 2)
    np.testing.assert_allclose(float(total[0]), 1.0, rtol=1e-5)


def test_cosine_schedule():
    assert float(cosine_schedule(0, 1.0, 10, 100)) == 0.0
    assert float(cosine_schedule(10, 1.0, 10, 100)) == pytest.approx(1.0)
    assert float(cosine_schedule(100, 1.0, 10, 100)) == pytest.approx(0.1, abs=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 7, tree)
    assert latest_step(d) == 7
    restored = restore_checkpoint(d, tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_markov_data_learnable_structure():
    cfg = DataConfig(vocab_size=64, seq_len=32, batch_size=4, branching=2)
    data = MarkovTokens(cfg)
    b = data.sample_batch()
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    # successors constrained to the branching table
    ok = 0
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t, row_l):
            ok += l in data.successors[t]
    assert ok == 4 * 32


# -------------------------------------------------------------- sharding
def test_partition_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    from repro.sharding.specs import spec_for

    mesh = make_debug_mesh(1, 1)
    assert spec_for("embed/table", (1024, 256), mesh) == P("model", "data")
    assert spec_for("periods/blk0_attn/wq/w", (4, 256, 512), mesh) == \
        P(None, "data", "model")
    assert spec_for("periods/blk0_moe/gate", (4, 16, 256, 64), mesh) == \
        P(None, "model", "data")   # trailing None trimmed
    assert spec_for("periods/norm0_mix/scale", (4, 256), mesh) == P()


def test_partition_divisibility_degrades():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    from repro.sharding.specs import spec_for
    # fake a 16-wide axis via mesh shape check: use debug mesh (1,1): always divides
    mesh = make_debug_mesh(1, 1)
    # odd vocab still maps (axis size 1 divides everything on debug mesh)
    assert spec_for("embed/table", (51865, 768), mesh) == P("model", "data")


def test_batch_spec_degrades():
    from repro.launch.mesh import make_debug_mesh
    from repro.sharding.specs import batch_spec
    mesh = make_debug_mesh(1, 1)
    assert batch_spec(mesh, 16) == ("data",)
    # batch=1 divides a 1-wide axis, so it stays
    assert batch_spec(mesh, 1) == ("data",)
