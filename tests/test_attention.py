"""flash_attention_jnp (XLA path + custom flash backward) vs naive oracle,
including hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (decode_attention, flash_attention_jnp,
                                    simple_attention)


def _rand(key, *shape):
    return jax.random.normal(key, shape)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 24)])
def test_forward_matches_oracle(causal, window):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], 2, 65, 8, 32)
    k = _rand(ks[1], 2, 65, 4, 32)
    v = _rand(ks[2], 2, 65, 4, 32)
    o = flash_attention_jnp(q, k, v, causal=causal, window=window,
                            q_block=16, k_block=32)
    oref = simple_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16)])
def test_gradient_matches_oracle(causal, window):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], 1, 48, 4, 16)
    k = _rand(ks[1], 1, 48, 2, 16)
    v = _rand(ks[2], 1, 48, 2, 16)

    def f(impl):
        def g(q, k, v):
            return jnp.sum(jnp.tanh(impl(q, k, v)))
        return jax.grad(g, argnums=(0, 1, 2))(q, k, v)

    g1 = f(lambda q, k, v: flash_attention_jnp(
        q, k, v, causal=causal, window=window, q_block=16, k_block=16))
    g2 = f(lambda q, k, v: simple_attention(q, k, v, causal=causal,
                                            window=window))
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(1, 70),
    t=st.integers(1, 70),
    kv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 3]),
    hd=st.sampled_from([8, 16]),
    causal=st.booleans(),
)
def test_property_shapes(s, t, kv, g, hd, causal):
    if causal:
        t = s  # causal masks assume aligned positions
    key = jax.random.PRNGKey(s * 1000 + t)
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], 1, s, kv * g, hd)
    k = _rand(ks[1], 1, t, kv, hd)
    v = _rand(ks[2], 1, t, kv, hd)
    o = flash_attention_jnp(q, k, v, causal=causal, q_block=16, k_block=16)
    oref = simple_attention(q, k, v, causal=causal)
    assert o.shape == q.shape
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=3e-5, atol=3e-5)


def test_decode_matches_full():
    """decode_attention on the last position == full attention last row."""
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    b, t, h, kvh, hd = 2, 20, 6, 2, 16
    q_full = _rand(ks[0], b, t, h, hd)
    k = _rand(ks[1], b, t, kvh, hd)
    v = _rand(ks[2], b, t, kvh, hd)
    full = simple_attention(q_full, k, v, causal=True)
    dec = decode_attention(q_full[:, -1:], k, v, t)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_ring_window():
    """Ring cache with window w must equal plain windowed decode."""
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 3)
    b, kvh, hd, w = 1, 2, 8, 8
    t_total = 13  # cache has seen 13 tokens, ring size 8
    q = _rand(ks[0], b, 1, 4, hd)
    k_all = _rand(ks[1], b, t_total, kvh, hd)
    v_all = _rand(ks[2], b, t_total, kvh, hd)
    # plain windowed: last w entries
    ref = decode_attention(q, k_all, v_all, t_total, window=w)
    # ring layout: entry i lives at i % w
    ring_k = jnp.zeros((b, w, kvh, hd))
    ring_v = jnp.zeros((b, w, kvh, hd))
    for i in range(t_total):
        ring_k = ring_k.at[:, i % w].set(k_all[:, i])
        ring_v = ring_v.at[:, i % w].set(v_all[:, i])
    out = decode_attention(q, ring_k, ring_v, t_total, window=w, ring=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
