"""Serving layer: pool gang semantics, real execution (KV sizing,
patch-parallel prefill), engine QoS schema, Eq.-6 observation parity."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import env as EV
from repro.serving.engine import Request, ServingEngine
from repro.serving.executor import ModelExecutor, chunkable
from repro.serving.pool import LogicalServer, ServerPool


def _req(rid, arch="tinyllama-1.1b", c=2, t=0.0, prompt_len=8,
         max_new_tokens=4):
    rng = np.random.default_rng(rid)
    return Request(rid=rid, arch=arch, prompt=rng.integers(0, 1000, prompt_len),
                   patches=c, arrive_t=t, max_new_tokens=max_new_tokens)


def _random_policy(engine, rng):
    a = rng.uniform(size=2 + engine.l).astype(np.float32)
    a[0] = 0.0  # always try to execute in tests
    return a


# ---------------------------------------------------------------- engine
def test_engine_serves_requests():
    eng = ServingEngine(num_servers=2, archs=["tinyllama-1.1b"], queue_window=4,
                        reduced=True, time_dilation=1.0, s_min=2, s_max=4)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(_req(i, c=2))
    for _ in range(12):
        if not eng.queue:
            break
        eng.try_schedule(_random_policy(eng, rng))
    m = eng.qos_summary()
    assert m["tasks_scheduled"] == 3
    assert all(r.tokens is not None and len(r.tokens) == r.steps
               for r in eng.done)
    assert m["avg_quality"] > 0
    assert m["wall_clock"] is False       # virtual (Table-VI) time mode


def test_engine_model_reuse():
    """Same service, same gang size -> second task reuses the loaded model."""
    eng = ServingEngine(num_servers=2, archs=["tinyllama-1.1b"], queue_window=4,
                        reduced=True, time_dilation=1.0, s_min=2, s_max=2)
    rng = np.random.default_rng(0)
    eng.submit(_req(0, c=2))
    r0 = eng.try_schedule(_random_policy(eng, rng))
    assert r0 is not None and not r0.reused
    # wait for the gang to go idle
    eng.clock = max(s.busy_until for s in eng.pool.servers) + 1
    eng.submit(_req(1, c=2, t=eng.clock))
    r1 = eng.try_schedule(_random_policy(eng, rng))
    assert r1 is not None and r1.reused
    assert eng.pool.load_count == 2      # only the first gang loaded
    m = eng.qos_summary()
    assert m["model_loads"] == 2 and m["model_reuses"] == 1
    assert m["cold_start_rate"] == pytest.approx(0.5)


def test_engine_gang_infeasible():
    eng = ServingEngine(num_servers=2, archs=["tinyllama-1.1b"], queue_window=4,
                        reduced=True, time_dilation=1.0)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, arch="tinyllama-1.1b",
                       prompt=np.arange(8), patches=4, arrive_t=0.0))
    out = eng.try_schedule(_random_policy(eng, rng))
    assert out is None                    # 4 patches > 2 servers
    assert len(eng.queue) == 1


def test_engine_qos_summary_stream_schema():
    """Engine QoS rows use the shared StreamAggregator schema, so real and
    simulated runs drop into one comparison table."""
    eng = ServingEngine(num_servers=2, archs=["tinyllama-1.1b"], queue_window=4,
                        reduced=True, time_dilation=1.0, s_min=2, s_max=4)
    rng = np.random.default_rng(0)
    for i in range(2):
        eng.submit(_req(i, c=1))
    for _ in range(8):
        if not eng.queue:
            break
        eng.try_schedule(_random_policy(eng, rng))
    m = eng.qos_summary(resp_sla=1e6)
    for key in ("latency_p50", "latency_p95", "latency_p99", "latency_mean",
                "qos_violation_rate", "drop_rate", "cold_start_rate",
                "reuse_rate", "utilization", "goodput_per_s", "avg_quality"):
        assert key in m, key
    assert m["tasks_injected"] == 2 and m["tasks_scheduled"] == 2
    assert m["qos_violation_rate_latency"] == 0.0
    assert np.isfinite(m["latency_p50"]) and m["latency_p50"] > 0


def test_engine_metrics_deprecated_but_working():
    eng = ServingEngine(num_servers=2, archs=["tinyllama-1.1b"], queue_window=4,
                        reduced=True, time_dilation=1.0, s_min=2, s_max=2)
    rng = np.random.default_rng(0)
    eng.submit(_req(0, c=2))
    eng.try_schedule(_random_policy(eng, rng))
    with pytest.deprecated_call():
        m = eng.metrics()
    assert m["completed"] == 1 and m["loads"] == 2


def test_engine_observation_matches_eq6():
    eng = ServingEngine(num_servers=3, archs=["tinyllama-1.1b", "qwen2-1.5b"],
                        queue_window=2, reduced=True, time_dilation=1.0)
    eng.submit(_req(0, c=1))
    obs = eng.observe()
    assert obs.shape == (3, 3 + 2)
    assert np.all(obs[0, :3] == 1.0)      # all idle
    assert obs[1, 3] == pytest.approx(1 / 8)   # c_k row


def test_engine_observation_parity_with_simulated_env():
    """Pool-derived and simulated Eq.-6 observations are the same array on
    matched state: build the simulated EnvState the engine's pool/queue
    describe by hand and compare against `engine.observe()`."""
    archs = ["tinyllama-1.1b", "qwen2-1.5b"]
    eng = ServingEngine(num_servers=3, archs=archs, queue_window=2,
                        reduced=True, time_dilation=1.0)
    # server 0 busy until t=30 with arch 1, servers 1-2 idle with arch 0
    eng.clock = 12.0
    s0, s1, s2 = eng.pool.servers
    s0.model_name, s0.busy_until, s0.gang, s0.gang_size = archs[1], 30.0, 7, 1
    s1.model_name, s1.gang, s1.gang_size = archs[0], 3, 2
    s2.model_name, s2.gang, s2.gang_size = archs[0], 3, 2
    eng.submit(_req(0, arch=archs[0], c=2, t=2.0))
    eng.submit(_req(1, arch=archs[1], c=1, t=9.0))

    cfg = EV.EnvConfig(num_servers=3, queue_window=2, max_tasks=2,
                       num_models=2)
    trace = {"arr_time": np.asarray([2.0, 9.0], np.float32),
             "c": np.asarray([2, 1], np.int32),
             "model": np.asarray([0, 1], np.int32),
             "noise": np.zeros(2, np.float32)}
    state = EV.reset(cfg)._replace(
        time=np.float32(12.0),
        server_free_at=np.asarray([30.0, 0.0, 0.0], np.float32),
        server_model=np.asarray([1, 0, 0], np.int32),
        server_gang=np.asarray([7, 3, 3], np.int32),
        server_gang_size=np.asarray([1, 2, 2], np.int32))
    sim_obs = np.asarray(EV.observe(cfg, {k: np.asarray(v) for k, v
                                          in trace.items()}, state))
    np.testing.assert_array_equal(eng.observe(), sim_obs)


# ---------------------------------------------------------------- pool
def _pool(n):
    return ServerPool(n)


def _assign(pool, sids, arch, gang, size, busy=0.0):
    for sid in sids:
        s = pool.servers[sid]
        s.model_name, s.gang, s.gang_size, s.busy_until = arch, gang, size, busy
        s.params = object()


def test_pool_find_reusable_gang_exact_match():
    pool = _pool(4)
    _assign(pool, [0, 1], "a", gang=5, size=2)
    _assign(pool, [2, 3], "a", gang=7, size=2)
    pool.servers[3].busy_until = 10.0          # gang 7 broken: member busy
    got = pool.find_reusable_gang("a", 2, now=0.0)
    assert got is not None and {s.sid for s in got} == {0, 1}
    # size must match exactly — a 2-gang never serves a 1-patch task
    assert pool.find_reusable_gang("a", 1, now=0.0) is None
    # arch must match
    assert pool.find_reusable_gang("b", 2, now=0.0) is None
    # re-assigning one member breaks the gang for good
    pool.servers[1].gang = 9
    assert pool.find_reusable_gang("a", 2, now=0.0) is None
    # ...but once both of gang 7's members are idle it matches again
    pool.servers[3].busy_until = 0.0
    got = pool.find_reusable_gang("a", 2, now=0.0)
    assert got is not None and {s.sid for s in got} == {2, 3}


def test_pool_pick_fresh_fragmentation_ordering():
    pool = _pool(6)
    _assign(pool, [0, 1], "a", gang=1, size=2)      # intact, small
    _assign(pool, [2, 3, 4], "a", gang=2, size=3)   # intact, big
    # server 5 never gang-assigned: free real estate, consumed first
    got = pool.pick_fresh(2, now=0.0)
    assert [s.sid for s in got] == [5, 0]   # free first, then smallest intact
    # a busy member breaks gang 2: its idle remnants sort before intact gangs
    pool.servers[2].busy_until = 10.0
    got = pool.pick_fresh(3, now=0.0)
    assert [s.sid for s in got] == [3, 4, 5]
    # not enough idle servers -> None
    assert pool.pick_fresh(6, now=0.0) is None


def test_pool_counter_economics_interleaved_gangs():
    """Load/reuse ledger under interleaved gangs via the engine: loads count
    per *server* (a c=2 cold gang costs 2), reuses per *task*."""
    eng = ServingEngine(num_servers=4, archs=["tinyllama-1.1b"],
                        queue_window=4, reduced=True, time_dilation=1.0,
                        s_min=2, s_max=2)
    rng = np.random.default_rng(0)
    eng.submit(_req(0, c=2))
    eng.try_schedule(_random_policy(eng, rng))      # cold: +2 loads
    eng.submit(_req(1, c=1, t=eng.clock))
    eng.try_schedule(_random_policy(eng, rng))      # cold c=1 on s2/s3: +1
    assert (eng.pool.load_count, eng.pool.reuse_count) == (3, 0)
    eng.clock = max(s.busy_until for s in eng.pool.servers) + 1
    eng.submit(_req(2, c=2, t=eng.clock))
    eng.try_schedule(_random_policy(eng, rng))      # reuse the c=2 gang
    assert (eng.pool.load_count, eng.pool.reuse_count) == (3, 1)
    assert eng.pool.counters() == {"model_loads": 3, "model_reuses": 1}
    eng.pool.reset()
    assert eng.pool.counters() == {"model_loads": 0, "model_reuses": 0}
    assert all(s.params is None and s.gang == -1 for s in eng.pool.servers)


# ---------------------------------------------------------------- executor
def test_executor_kv_capacity_steps_beyond_max_new_tokens():
    """Regression: the scheduler may pick more inference steps than the
    request's max_new_tokens; the KV cache must be sized by the max of the
    two (the legacy engine sized by max_new_tokens alone and overflowed)."""
    ex = ModelExecutor(reduced=True)
    params = ex.init_params("tinyllama-1.1b", jax.random.PRNGKey(0))
    prompt = np.arange(1, 9, dtype=np.int32)
    steps = 12
    toks_small = ex.generate("tinyllama-1.1b", params, prompt, 1, steps,
                             max_new_tokens=4)       # steps > max_new_tokens
    toks_big = ex.generate("tinyllama-1.1b", params, prompt, 1, steps,
                           max_new_tokens=64)        # oversized cache
    assert len(toks_small) == steps
    # a silently clamped/overflowing cache would corrupt late-step attention:
    # capacity must not change the generation
    np.testing.assert_array_equal(toks_small, toks_big)


def test_executor_chunked_c1_parity():
    """The patch-parallel (chunk-batched) prefill with c=1 is bitwise-
    identical to the unchunked path."""
    ex = ModelExecutor(reduced=True)
    params = ex.init_params("tinyllama-1.1b", jax.random.PRNGKey(1))
    prompt = np.arange(1, 13, dtype=np.int32)
    a = ex.generate("tinyllama-1.1b", params, prompt, 1, 6,
                    force_chunked=True)
    b = ex.generate("tinyllama-1.1b", params, prompt, 1, 6,
                    force_chunked=False)
    np.testing.assert_array_equal(a, b)


def test_executor_patch_parallel_prefill_executes():
    """c>1 actually batches the prompt chunks (the legacy path computed the
    chunks and threw them away): uneven prompts left-pad, decode proceeds
    from the merged cache."""
    ex = ModelExecutor(reduced=True)
    assert chunkable(ex.model("tinyllama-1.1b").cfg)
    params = ex.init_params("tinyllama-1.1b", jax.random.PRNGKey(2))
    prompt = np.arange(1, 11, dtype=np.int32)        # len 10, c=4 -> pad 2
    toks = ex.generate("tinyllama-1.1b", params, prompt, 4, 5)
    assert len(toks) == 5
    assert np.all(toks >= 0) and np.all(toks < ex.model(
        "tinyllama-1.1b").cfg.vocab_size)


def test_latency_table_scales():
    from repro.serving.latency_table import arch_scales, env_model_scales
    s = arch_scales()
    assert s["jamba-v0.1-52b"] > s["tinyllama-1.1b"]
    scales = env_model_scales()
    assert len(scales) == 10
    assert all(0.25 <= x <= 8.0 for x in scales)
