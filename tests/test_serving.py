"""End-to-end serving engine: real model execution, gang allocation, reuse."""
import numpy as np
import pytest

from repro.serving.engine import Request, ServingEngine


def _req(rid, arch="tinyllama-1.1b", c=2, t=0.0, prompt_len=8):
    rng = np.random.default_rng(rid)
    return Request(rid=rid, arch=arch, prompt=rng.integers(0, 1000, prompt_len),
                   patches=c, arrive_t=t, max_new_tokens=4)


def _random_policy(engine, rng):
    a = rng.uniform(size=2 + engine.l).astype(np.float32)
    a[0] = 0.0  # always try to execute in tests
    return a


def test_engine_serves_requests():
    eng = ServingEngine(num_servers=2, archs=["tinyllama-1.1b"], queue_window=4,
                        reduced=True, time_dilation=1.0, s_min=2, s_max=4)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(_req(i, c=2))
    for _ in range(12):
        if not eng.queue:
            break
        eng.try_schedule(_random_policy(eng, rng))
    m = eng.metrics()
    assert m["completed"] == 3
    assert all(r.tokens is not None and len(r.tokens) == r.steps
               for r in eng.done)
    assert m["avg_quality"] > 0


def test_engine_model_reuse():
    """Same service, same gang size -> second task reuses the loaded model."""
    eng = ServingEngine(num_servers=2, archs=["tinyllama-1.1b"], queue_window=4,
                        reduced=True, time_dilation=1.0, s_min=2, s_max=2)
    rng = np.random.default_rng(0)
    eng.submit(_req(0, c=2))
    r0 = eng.try_schedule(_random_policy(eng, rng))
    assert r0 is not None and not r0.reused
    # wait for the gang to go idle
    eng.clock = max(s.busy_until for s in eng.pool.servers) + 1
    eng.submit(_req(1, c=2, t=eng.clock))
    r1 = eng.try_schedule(_random_policy(eng, rng))
    assert r1 is not None and r1.reused
    assert eng.pool.load_count == 2      # only the first gang loaded
    assert eng.metrics()["reload_rate"] == 0.5


def test_engine_gang_infeasible():
    eng = ServingEngine(num_servers=2, archs=["tinyllama-1.1b"], queue_window=4,
                        reduced=True, time_dilation=1.0)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, arch="tinyllama-1.1b",
                       prompt=np.arange(8), patches=4, arrive_t=0.0))
    out = eng.try_schedule(_random_policy(eng, rng))
    assert out is None                    # 4 patches > 2 servers
    assert len(eng.queue) == 1


def test_engine_observation_matches_eq6():
    eng = ServingEngine(num_servers=3, archs=["tinyllama-1.1b", "qwen2-1.5b"],
                        queue_window=2, reduced=True, time_dilation=1.0)
    eng.submit(_req(0, c=1))
    obs = eng.observe()
    assert obs.shape == (3, 3 + 2)
    assert np.all(obs[0, :3] == 1.0)      # all idle
    assert obs[1, 3] == pytest.approx(1 / 8)   # c_k row


def test_latency_table_scales():
    from repro.serving.latency_table import arch_scales, env_model_scales
    s = arch_scales()
    assert s["jamba-v0.1-52b"] > s["tinyllama-1.1b"]
    scales = env_model_scales()
    assert len(scales) == 10
    assert all(0.25 <= x <= 8.0 for x in scales)
