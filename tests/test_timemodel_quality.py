"""Latency model vs the paper's Table VI; quality proxy vs Tables II/IX."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import timemodel as TM
from repro.core.quality import quality_of, quality_penalty


def test_table_vi_values():
    np.testing.assert_allclose(float(TM.init_time(jnp.asarray(1))), 33.5)
    np.testing.assert_allclose(float(TM.init_time(jnp.asarray(2))), 31.9)
    np.testing.assert_allclose(float(TM.init_time(jnp.asarray(4))), 35.0)
    np.testing.assert_allclose(
        float(TM.exec_time(jnp.asarray(1), jnp.asarray(20))), 0.53 * 20)
    np.testing.assert_allclose(
        float(TM.exec_time(jnp.asarray(2), jnp.asarray(20))), 0.29 * 20)
    np.testing.assert_allclose(
        float(TM.exec_time(jnp.asarray(4), jnp.asarray(17))), 0.20 * 17,
        rtol=1e-6)


def test_patch_acceleration_monotonic():
    """Table I: more patches -> faster per-step time."""
    ts = [float(TM.exec_time(jnp.asarray(c), jnp.asarray(20)))
          for c in (1, 2, 4, 8)]
    assert ts == sorted(ts, reverse=True)
    accel = ts[0] / np.asarray(ts)
    assert accel[1] == pytest.approx(1.8, rel=0.05)   # paper: x1.8
    assert accel[2] == pytest.approx(3.1, rel=0.2)    # paper: x3.1 (2.65 in VI)
    assert accel[3] == pytest.approx(4.9, rel=0.25)   # paper: x4.9


def test_quality_calibration():
    """Anchors: ~0.24 at 17-18 steps, ~0.25 at 20, saturating ~0.27-0.285."""
    assert float(quality_of(18)) == pytest.approx(0.24, abs=0.015)
    assert float(quality_of(20)) == pytest.approx(0.251, abs=0.01)
    assert float(quality_of(50)) == pytest.approx(0.283, abs=0.01)
    assert float(quality_of(10)) < float(quality_of(20)) < float(quality_of(40))


def test_quality_penalty():
    assert float(quality_penalty(0.20, 0.23, 2.0)) == 2.0
    assert float(quality_penalty(0.25, 0.23, 2.0)) == 0.0


def test_predict_remaining():
    with_init = float(TM.predict_remaining(jnp.asarray(2), jnp.asarray(10),
                                           jnp.asarray(False)))
    without = float(TM.predict_remaining(jnp.asarray(2), jnp.asarray(10),
                                         jnp.asarray(True)))
    assert with_init == pytest.approx(without + 31.9)
