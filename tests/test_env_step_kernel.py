"""Fused env-step parity: the Pallas kernel (interpret mode), the jnp
reference, and the pre-refactor compositional `env.step` must produce
bitwise-identical state / reward / done / queue / observation on randomized
EnvStates — including carried-gang labels in [K, K+E) (the streaming seam
relabeling), cold and warm servers, and multi-model configs — and the fused
rollout / streaming engines must reproduce the unfused ones exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env as EV
from repro.core import rollout as RO
from repro.core import scenarios as SC
from repro.core.workload import TraceConfig, make_trace, make_trace_batch
from repro.kernels.env_step import ops as EK
from repro.traffic import (PoissonArrivals, ProcessTaskSource, StreamConfig,
                           run_stream)


def _cfg(E, num_models=1):
    ms = tuple([1.0, 0.5, 2.0][:num_models]) if num_models > 1 else ()
    return EV.EnvConfig(num_servers=E, max_tasks=2 * E + 4, queue_window=4,
                        num_models=num_models, model_scale=ms)


def _tc(ecfg):
    return TraceConfig(num_tasks=ecfg.max_tasks, arrival_rate=0.2,
                       max_servers=ecfg.num_servers,
                       num_models=ecfg.num_models)


def _random_state(rng, ecfg, trace):
    """A semi-consistent EnvState: warm/cold servers, intact and broken
    gangs, labels from both the in-episode range [0, K) and the carried
    range [K, K+E), tasks in every status."""
    E, K = ecfg.num_servers, ecfg.max_tasks
    t = np.float32(rng.uniform(0.0, 60.0))
    free = np.where(rng.random(E) < 0.5, 0.0,
                    t + rng.uniform(-20.0, 40.0, E)).astype(np.float32)
    gang = -np.ones(E, np.int64)
    gsize = np.zeros(E, np.int64)
    model = -np.ones(E, np.int64)
    # place a few gangs; labels may come from the carried range [K, K+E)
    servers = rng.permutation(E)
    i = 0
    while i < E and rng.random() < 0.8:
        c = int(rng.choice([1, 2, 4, 8]))
        c = min(c, E - i)
        label = int(rng.integers(0, K + E))
        m = int(rng.integers(0, max(ecfg.num_models, 1)))
        members = servers[i:i + c]
        # sometimes break the gang: report a wrong size on purpose
        size = c if rng.random() < 0.8 else int(rng.integers(1, 9))
        gang[members] = label
        gsize[members] = size
        model[members] = m
        i += c
    status = rng.choice([0, 0, 1, 2], K)
    tstart = np.where(status >= 1, rng.uniform(0, t, K), 0).astype(np.float32)
    tfin = np.where(status >= 1, tstart + rng.uniform(1, 50, K),
                    0).astype(np.float32)
    return EV.EnvState(
        time=jnp.asarray(t),
        server_free_at=jnp.asarray(free),
        server_model=jnp.asarray(model, jnp.int32),
        server_gang=jnp.asarray(gang, jnp.int32),
        server_gang_size=jnp.asarray(gsize, jnp.int32),
        task_status=jnp.asarray(status, jnp.int32),
        task_start=jnp.asarray(tstart),
        task_finish=jnp.asarray(tfin),
        task_steps=jnp.asarray(rng.integers(0, 50, K), jnp.int32),
        task_quality=jnp.asarray(rng.uniform(0, 0.3, K), jnp.float32),
        task_reload=jnp.asarray(rng.integers(0, 2, K), jnp.int32),
        steps_taken=jnp.asarray(int(rng.integers(0, 100)), jnp.int32),
    )


def _b1(tree):
    return jax.tree_util.tree_map(lambda x: x[None], tree)


def _assert_tree_equal(a, b, ctx):
    fa = a._asdict() if hasattr(a, "_asdict") else a
    fb = b._asdict() if hasattr(b, "_asdict") else b
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=f"{ctx}: field {k}")


# ---------------------------------------------------------------- per-step
@pytest.mark.parametrize("E,num_models", [(4, 1), (8, 1), (16, 1), (8, 3)])
def test_fused_step_matches_legacy_on_random_states(E, num_models):
    """Kernel (interpret) == jnp ref == pre-refactor step, bitwise, over
    randomized states and actions (schedule, no-op, infeasible)."""
    ecfg = _cfg(E, num_models)
    rng = np.random.default_rng(E * 100 + num_models)
    for trial in range(12):
        trace = make_trace(jax.random.PRNGKey(trial), _tc(ecfg))
        state = _random_state(rng, ecfg, trace)
        statics = EV.decision_statics(ecfg, trace)
        qv = EV.visible_queue(ecfg, trace, state)
        a = rng.uniform(size=ecfg.action_dim).astype(np.float32)
        if trial % 3 == 0:
            a[0] = 0.1          # force a schedule attempt
        a = jnp.asarray(a)
        ns_l, obs_l, r_l, d_l, _ = EV.step(ecfg, trace, state, a)
        q2_l = EV.visible_queue(ecfg, trace, ns_l)
        for impl in ("ref", "pallas"):
            ns_f, q_f, obs_f, r_f, d_f = EK.env_step_fused(
                ecfg, _b1(statics), _b1(state), a[None], _b1(qv), impl=impl)
            ctx = f"E={E} nm={num_models} trial={trial} impl={impl}"
            _assert_tree_equal(ns_l, jax.tree_util.tree_map(
                lambda x: x[0], ns_f), ctx)
            _assert_tree_equal(q2_l, jax.tree_util.tree_map(
                lambda x: x[0], q_f), ctx + " queue")
            np.testing.assert_array_equal(np.asarray(obs_l),
                                          np.asarray(obs_f[0]), ctx)
            assert float(r_l) == float(r_f[0]), ctx
            assert bool(d_l) == bool(d_f[0]), ctx


def test_fused_step_carried_gang_reuse():
    """A complete idle gang with a carried label in [K, K+E) must be reused
    identically by all three implementations (no reload)."""
    ecfg = _cfg(4)
    K = ecfg.max_tasks
    tc = TraceConfig(num_tasks=K, arrival_rate=100.0, max_servers=4,
                     c_support=(2,), c_probs=(1.0,))
    trace = make_trace(jax.random.PRNGKey(0), tc)
    state = EV.reset(ecfg)._replace(
        time=jnp.float32(1.0),
        server_gang=jnp.asarray([K + 1, K + 1, -1, -1], jnp.int32),
        server_gang_size=jnp.asarray([2, 2, 0, 0], jnp.int32),
        server_model=jnp.asarray([0, 0, -1, -1], jnp.int32),
    )
    a = jnp.asarray([0.0, 0.5, 1.0, 0.0, 0.0, 0.0], jnp.float32)
    ns_l, _, r_l, _, info = EV.step(ecfg, trace, state, a)
    assert bool(info["scheduled"]) and bool(info["reuse"])
    qv = EV.visible_queue(ecfg, trace, state)
    statics = EV.decision_statics(ecfg, trace)
    for impl in ("ref", "pallas"):
        ns_f, _, _, r_f, _ = EK.env_step_fused(
            ecfg, _b1(statics), _b1(state), a[None], _b1(qv), impl=impl)
        _assert_tree_equal(ns_l, jax.tree_util.tree_map(lambda x: x[0], ns_f),
                           impl)
        assert float(r_l) == float(r_f[0])
        # the reused servers kept the carried label and skipped the reload
        assert int(np.asarray(ns_f.task_reload[0]).sum()) == 0


# ---------------------------------------------------------------- rollouts
@pytest.mark.parametrize("policy_fn", [RO.uniform_policy, RO.greedy_policy,
                                       RO.fifo_policy],
                         ids=["random", "greedy", "fifo"])
def test_fused_rollout_matches_unfused(policy_fn):
    ecfg = EV.EnvConfig(num_servers=4, max_tasks=8, queue_window=4,
                        max_steps=96)
    tc = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)
    traces = make_trace_batch(jax.random.PRNGKey(3), tc, 4)
    keys = jax.random.split(jax.random.PRNGKey(4), 4)
    pol = policy_fn(ecfg)
    a = RO.batch_rollout(ecfg, traces, pol, {}, keys, fused=False,
                         collect=True)
    for impl in ("ref", "pallas"):
        b = RO.batch_rollout(ecfg, traces, pol, {}, keys, fused=True,
                             collect=True, fused_impl=impl)
        for k in a.metrics:
            np.testing.assert_array_equal(np.asarray(a.metrics[k]),
                                          np.asarray(b.metrics[k]),
                                          err_msg=f"{impl} metric {k}")
        _assert_tree_equal(a.final_state, b.final_state, impl)
        for fld in ("obs", "action", "reward", "next_obs", "done", "valid"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a.transitions, fld)),
                np.asarray(getattr(b.transitions, fld)),
                err_msg=f"{impl} transitions {fld}")


def test_fused_scenario_grid_bitwise():
    """Representative scenario cells (distinct E / rates / gang mixes / a
    multi-model cell): fused and unfused episode metrics are bitwise equal,
    so every existing scenario result stays reproducible on the fused
    engine. The full default grid runs under -m slow."""
    cells = [SC._make("tiny-4srv", 4, 0.05, num_tasks=8),
             SC.cold_start_heavy(4),
             SC.multi_model_mix(num_servers=4, num_models=2,
                                model_scale=(1.0, 0.5))]
    for sc in cells:
        _assert_scenario_parity(sc, num_steps=128)


@pytest.mark.slow
def test_fused_full_default_grid_bitwise():
    """Acceptance: the full `scenarios.default_grid()` produces
    bitwise-identical episode metrics on fused vs unfused engines."""
    for sc in SC.default_grid():
        _assert_scenario_parity(sc, num_steps=256)


def _assert_scenario_parity(sc, num_steps):
    key = jax.random.PRNGKey(7)
    pol = RO.uniform_policy(sc.ecfg)
    a = SC.run_scenario(sc, pol, key, batch=2, num_steps=num_steps)
    # run_scenario goes through batch_rollout(fused default); force both
    from repro.core.workload import make_trace_batch as _mtb
    k_trace, k_run = jax.random.split(key)
    if sc.arrival is None:
        traces = _mtb(k_trace, sc.tcfg, 2)
    else:
        traces = SC.make_scenario_trace_batch(k_trace, sc, 2)
    keys = jax.random.split(k_run, 2)
    ra = RO.batch_rollout(sc.ecfg, traces, pol, {}, keys, fused=False,
                          num_steps=num_steps)
    rb = RO.batch_rollout(sc.ecfg, traces, pol, {}, keys, fused=True,
                          num_steps=num_steps)
    for k in ra.metrics:
        np.testing.assert_array_equal(np.asarray(ra.metrics[k]),
                                      np.asarray(rb.metrics[k]),
                                      err_msg=f"{sc.name}: {k}")
    _assert_tree_equal(ra.final_state, rb.final_state, sc.name)


# ---------------------------------------------------------------- streaming
def test_fused_stream_matches_unfused_across_seams():
    """Multi-window streaming (carried gangs relabelled into [K, K+E),
    backlog carry, clock rebase) is bitwise-identical on the fused engine:
    same summaries, same per-window ledgers, same final carry state."""
    ecfg = EV.EnvConfig(num_servers=4, max_tasks=16, queue_window=4,
                        max_steps=64)
    tc = TraceConfig(num_tasks=16, arrival_rate=0.3, max_servers=4)

    def run(fused):
        src = ProcessTaskSource(PoissonArrivals(0.3), tc,
                                jax.random.PRNGKey(0), num_streams=2)
        return run_stream(ecfg, RO.fifo_policy(ecfg), {}, src,
                          jax.random.PRNGKey(1),
                          StreamConfig(num_windows=5, num_streams=2,
                                       fused=fused))

    a, b = run(False), run(True)
    assert a.summary == b.summary
    assert a.per_window == b.per_window
    _assert_tree_equal(a.final_carry, b.final_carry, "final_carry")


def test_stream_config_fused_default_on():
    assert StreamConfig().fused is True
    assert dataclasses.replace(StreamConfig(), fused=False).fused is False
