"""Dry-run machinery: input_specs, pair skip rules, and one real
lower+compile on the production mesh (slow; subprocess for device count)."""
import json
import os
import subprocess
import sys

import pytest

from repro.common.config import ASSIGNED_ARCHS, get_config
from repro.launch.shapes import SHAPES, adapt_config, pair_list


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_pair_skips():
    pairs = pair_list()
    # whisper long_500k is the single skipped pair (DESIGN.md §4)
    assert ("whisper-small", "long_500k") not in pairs
    assert len(pairs) == 39


def test_long500k_dense_gets_window():
    cfg = adapt_config(get_config("llama3.2-3b"), SHAPES["long_500k"])
    assert cfg.sliding_window == 16384
    # subquadratic archs keep native attention
    cfg2 = adapt_config(get_config("jamba-v0.1-52b"), SHAPES["long_500k"])
    assert cfg2.sliding_window == 0


def test_input_specs_structs():
    from repro.launch.steps import input_specs
    cfg = get_config("internvl2-1b")
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    assert sp["image_embeds"].shape == (256, 256, 896)
    spd = input_specs(cfg, SHAPES["decode_32k"])
    assert spd["token"].shape == (128, 1)


@pytest.mark.slow
def test_dryrun_one_case_subprocess():
    """Real 512-device lower+compile via the CLI (proves the entry point)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "xlstm-125m", "--shape", "decode_32k", "--mesh", "multi",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=500)
    assert "1 ok" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.load(open("/tmp/dryrun_test/xlstm-125m__decode_32k__multi.json"))
    assert rec["status"] == "ok"
    assert rec["devices"] == 512
    assert rec["hlo_flops"] > 0


def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import collective_bytes, roofline_terms
    hlo = """
      %ar = f32[256,4096]{1,0} all-reduce(%x), replica_groups={}
      %ag.1 = bf16[16,128]{1,0} all-gather(%y), dimensions={0}
      %aa = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b)
    """
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 256 * 4096 * 4
    assert cb["all-gather"] == 16 * 128 * 2
    assert cb["all-to-all"] == 2 * 8 * 8 * 4
    assert cb["ops"] == 3
    terms = roofline_terms({"flops": 197e12, "bytes accessed": 819e9}, cb)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(1.0)
    assert terms["bottleneck"] in ("compute", "memory", "collective")
