"""Pallas kernels (interpret=True on CPU) vs pure-jnp oracles, swept over
shapes and dtypes (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.denoiser.ops import denoise_eps_fused
from repro.kernels.denoiser.ref import denoiser_ref
from repro.kernels.flash_attention.ops import attention as pallas_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ops import selective_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("b,s,t,h,kv,hd,causal,win", [
    (2, 64, 64, 4, 2, 32, True, 0),
    (1, 100, 100, 4, 4, 16, True, 0),
    (2, 32, 96, 8, 4, 64, False, 0),
    (1, 128, 128, 4, 2, 32, True, 48),
    (1, 17, 33, 2, 1, 8, False, 0),
])
def test_flash_attention_kernel(b, s, t, h, kv, hd, causal, win):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, t, kv, hd))
    v = jax.random.normal(ks[2], (b, t, kv, hd))
    o = pallas_attention(q, k, v, causal=causal, window=win,
                         block_q=32, block_k=32)
    oref = attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                         causal=causal, window=win).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 32)).astype(dtype)
    o = pallas_attention(q, k, v, causal=True, block_q=32, block_k=32)
    oref = attention_ref(q.swapaxes(1, 2).astype(jnp.float32),
                         k.swapaxes(1, 2).astype(jnp.float32),
                         v.swapaxes(1, 2).astype(jnp.float32),
                         causal=True).swapaxes(1, 2)
    assert o.dtype == dtype
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(oref),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------- ssm scan
@pytest.mark.parametrize("B,S,I,N,bs,bi", [
    (2, 32, 64, 16, 16, 64),
    (1, 100, 96, 8, 16, 32),
    (2, 64, 300, 16, 64, 256),
    (1, 7, 16, 4, 8, 16),
])
def test_ssm_scan_kernel(B, S, I, N, bs, bi):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, I)))
    a = -jnp.exp(jax.random.normal(ks[1], (I, N)))
    bm = jax.random.normal(ks[2], (B, S, N))
    cm = jax.random.normal(ks[3], (B, S, N))
    x = jax.random.normal(ks[4], (B, S, I))
    h0 = jax.random.normal(ks[5], (B, I, N))
    y, hT = selective_scan(dt, a, bm, cm, x, h0, block_s=bs, block_i=bi)
    yr, hTr = ssm_scan_ref(dt, a, bm, cm, x, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), rtol=2e-5, atol=2e-5)


def test_ssm_scan_zero_h0_default():
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    B, S, I, N = 1, 16, 32, 8
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, I)))
    a = -jnp.exp(jax.random.normal(ks[1], (I, N)))
    bm = jax.random.normal(ks[2], (B, S, N))
    cm = jax.random.normal(ks[3], (B, S, N))
    x = jax.random.normal(ks[4], (B, S, I))
    y, _ = selective_scan(dt, a, bm, cm, x)
    yr, _ = ssm_scan_ref(dt, a, bm, cm, x, jnp.zeros((B, I, N)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- denoiser
@pytest.mark.parametrize("batch,a_dim,f_dim", [(1, 10, 12), (33, 10, 16), (128, 6, 20)])
def test_denoiser_kernel(batch, a_dim, f_dim):
    from repro.core.diffusion import init_denoiser, denoise_eps
    p = init_denoiser(jax.random.PRNGKey(1), action_dim=a_dim, feat_dim=f_dim)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (batch, a_dim))
    i = jnp.full((batch,), 3)
    f = jax.random.normal(key, (batch, f_dim))
    out = denoise_eps_fused(p, x, i, f)
    ref = denoise_eps(p, x, i, f)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_denoiser_kernel_matches_layers_ref():
    from repro.core.diffusion import init_denoiser, timestep_embedding
    p = init_denoiser(jax.random.PRNGKey(4), action_dim=8, feat_dim=8)
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 8))
    i = jnp.full((16,), 1)
    f = jax.random.normal(jax.random.PRNGKey(6), (16, 8))
    inp = jnp.concatenate([x, timestep_embedding(i, 16), f], axis=-1)
    l = p["layers"]
    ref = denoiser_ref(inp, l[0]["w"], l[0]["b"], l[1]["w"], l[1]["b"],
                       l[2]["w"], l[2]["b"])
    out = denoise_eps_fused(p, x, i, f)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)
