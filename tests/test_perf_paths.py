"""Regression tests for the §Perf code paths (EXPERIMENTS.md):

  * chunk-fused mamba scan: chunk size must not change the output;
  * scatter-free MoE combine / set-scatter dispatch: exact match against a
    straightforward scatter-add reference;
  * microbatched train step: identical loss/grads to the monolithic step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.common.config import get_config
from repro.models import blocks as B
from repro.models.zoo import build_model


# ----------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [1, 7, 16, 64, 1000])
def test_mamba_chunk_size_invariance(chunk):
    cfg = get_config("jamba-v0.1-52b").reduced()
    scfg = cfg.ssm
    key = jax.random.PRNGKey(0)
    p = B.init_mamba(key, cfg, scfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 33, cfg.d_model),
                          jnp.float32) * 0.1
    inner = scfg.expand * cfg.d_model
    h0 = jnp.zeros((2, inner, scfg.state_dim), jnp.float32)
    ref, href, _ = B._mamba_full(p, cfg, scfg, x, h0, chunk=33)
    out, hout, _ = B._mamba_full(p, cfg, scfg, x, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(href), np.asarray(hout),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_grads_flow():
    cfg = get_config("jamba-v0.1-52b").reduced()
    scfg = cfg.ssm
    p = B.init_mamba(jax.random.PRNGKey(0), cfg, scfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model)) * 0.1

    def loss(p):
        return jnp.sum(B.mamba_train(p, cfg, scfg, x, chunk=4) ** 2)

    g = jax.grad(loss)(p)
    norms = [float(jnp.linalg.norm(v)) for v in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0.0


# ----------------------------------------------------------------------
def _moe_reference(p, cfg, mcfg, x, cap):
    """Straightforward scatter-add dispatch/combine (the pre-§Perf path)."""
    import math
    b, s, d = x.shape
    e, k = mcfg.num_experts, mcfg.experts_per_token
    logits = (x @ p["router"]["w"].astype(x.dtype)
              + p["router"].get("b", 0)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    def group(xg, topi_g, topw_g):
        flat_e = topi_g.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - onehot,
                                  flat_e[:, None], 1)[:, 0]
        keep = pos < cap
        pos_c = jnp.minimum(pos, cap - 1)
        tok = jnp.repeat(jnp.arange(s), k)
        src = jnp.where(keep[:, None], xg[tok], 0)
        buf = jnp.zeros((e, cap, d), xg.dtype).at[flat_e, pos_c].add(src)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["up"])
        oe = jnp.einsum("ecf,efd->ecd", h, p["down"])
        gathered = oe[flat_e, pos_c] * (topw_g.reshape(-1) * keep)[:, None]
        return jnp.zeros((s, d), x.dtype).at[tok].add(
            gathered.astype(x.dtype))

    return jax.vmap(group)(x, topi, topw)


def test_moe_scatterfree_matches_scatter_add_reference():
    cfg = get_config("olmoe-1b-7b").reduced()
    mcfg = cfg.moe
    p = B.init_moe(jax.random.PRNGKey(0), cfg, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model),
                          jnp.float32) * 0.3
    y, _aux = B.moe_apply(p, cfg, mcfg, x, capacity_factor=1.25)
    import math
    cap = max(1, min(12, int(math.ceil(
        12 * mcfg.experts_per_token / mcfg.num_experts * 1.25))))
    ref = _moe_reference(p, cfg, mcfg, x, cap)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 17), seed=st.integers(0, 2**31 - 1))
def test_moe_dropless_token_order_invariance(s, seed):
    """Property: with dropless dispatch, permuting tokens permutes outputs."""
    cfg = get_config("olmoe-1b-7b").reduced()
    mcfg = cfg.moe
    p = B.init_moe(jax.random.PRNGKey(0), cfg, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, s, cfg.d_model)) * 0.3
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), s)
    y, _ = B.moe_apply(p, cfg, mcfg, x, dropless=True)
    yp, _ = B.moe_apply(p, cfg, mcfg, x[:, perm], dropless=True)
    np.testing.assert_allclose(np.asarray(y[:, perm]), np.asarray(yp),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
def test_microbatched_train_step_matches_monolithic():
    import os
    from jax.sharding import Mesh
    from repro.launch.shapes import ShapeSpec
    from repro.launch.steps import build_case
    from repro.launch.mesh import make_debug_mesh

    cfg = get_config("tinyllama-1.1b").reduced()
    shape = ShapeSpec("tiny_train", "train", 32, 4)
    mesh = make_debug_mesh(1, 1)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, size=(4, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.training.optimizer import adam_init
    opt = adam_init(params)

    outs = {}
    for mb in (1, 4):
        case = build_case(cfg, shape, mesh, microbatches=mb, remat=False)
        p2, o2, loss, _metrics = case.fn(
            jax.tree_util.tree_map(jnp.copy, params),
            jax.tree_util.tree_map(jnp.copy, opt), batch)
        outs[mb] = (float(loss), p2)
    assert np.isclose(outs[1][0], outs[4][0], rtol=2e-3)
    l1 = jax.tree_util.tree_leaves(outs[1][1])
    l4 = jax.tree_util.tree_leaves(outs[4][1])
    worst = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(l1, l4))
    assert worst < 5e-3, f"param divergence {worst}"
