"""Batched rollout engine: host-loop parity, determinism, scenario grids,
and the batched experience-collection paths of SAC / PPO."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import rollout as RO
from repro.core import scenarios as SC
from repro.core.env import EnvConfig
from repro.core.replay import ReplayBuffer
from repro.core.workload import (TraceConfig, make_trace, make_trace_batch,
                                 stack_traces)

ECFG = EnvConfig(num_servers=4, max_tasks=8, queue_window=4, max_steps=128)
TC = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)


def _trace(seed=0):
    return make_trace(jax.random.PRNGKey(seed), TC)


def _batch_of_one(trace):
    return jax.tree_util.tree_map(lambda x: x[None], trace)


# ------------------------------------------------------------ parity
def test_batch_matches_host_loop_random():
    """Same (trace, policy, key) => bitwise-identical metrics incl. return."""
    trace = _trace()
    key = jax.random.PRNGKey(42)
    host = BL.evaluate_policy(ECFG, trace,
                              lambda k, s, o: BL.random_policy(k, ECFG), key)
    batch = BL.evaluate_policy_batch(ECFG, _batch_of_one(trace),
                                     RO.uniform_policy(ECFG), key[None])
    for k, v in host.items():
        assert float(batch[k][0]) == v, k


def test_batch_matches_host_loop_greedy():
    trace = _trace(1)
    key = jax.random.PRNGKey(7)
    host = BL.evaluate_policy(ECFG, trace,
                              lambda k, s, o: BL.greedy_act(ECFG, trace, s),
                              key)
    batch = BL.evaluate_policy_batch(ECFG, _batch_of_one(trace),
                                     RO.greedy_policy(ECFG), key[None])
    # state-derived metrics are bitwise; the return accumulation may differ
    # by a float32 ulp (greedy's candidate reduction under double-vmap)
    for k, v in host.items():
        if k == "episode_return":
            np.testing.assert_allclose(float(batch[k][0]), v, rtol=1e-6)
        else:
            assert float(batch[k][0]) == v, k


def test_batch_rows_match_single_episodes():
    """Row b of a B-episode batch == an independent B=1 rollout."""
    traces = make_trace_batch(jax.random.PRNGKey(3), TC, 3)
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    batch = BL.evaluate_policy_batch(ECFG, traces, RO.uniform_policy(ECFG),
                                     keys)
    for b in range(3):
        tr_b = jax.tree_util.tree_map(lambda x, b=b: x[b], traces)
        single = BL.evaluate_policy_batch(ECFG, _batch_of_one(tr_b),
                                          RO.uniform_policy(ECFG),
                                          keys[b][None])
        for k in batch:
            assert batch[k][b] == single[k][0], k


def test_batch_rollout_deterministic():
    traces = make_trace_batch(jax.random.PRNGKey(5), TC, 4)
    keys = jax.random.split(jax.random.PRNGKey(6), 4)
    r1 = BL.evaluate_policy_batch(ECFG, traces, RO.uniform_policy(ECFG), keys)
    r2 = BL.evaluate_policy_batch(ECFG, traces, RO.uniform_policy(ECFG), keys)
    for k in r1:
        np.testing.assert_array_equal(r1[k], r2[k])


# ------------------------------------------------------------ transitions
def test_collect_transitions_shapes_and_validity():
    traces = make_trace_batch(jax.random.PRNGKey(8), TC, 2)
    keys = jax.random.split(jax.random.PRNGKey(9), 2)
    res = RO.batch_rollout(ECFG, traces, RO.uniform_policy(ECFG), {}, keys,
                           collect=True)
    tr = res.transitions
    T = ECFG.max_steps
    assert tr.obs.shape == (2, T) + ECFG.obs_shape
    assert tr.action.shape == (2, T, ECFG.action_dim)
    valid = np.asarray(tr.valid)
    lens = np.asarray(res.metrics["episode_len"])
    # valid is a prefix of exactly episode_len steps
    np.testing.assert_array_equal(valid.sum(axis=1), lens)
    for b in range(2):
        assert np.all(valid[b, :int(lens[b])])
    # rewards are zeroed past the end; return telescopes over valid steps
    rew = np.asarray(tr.reward)
    assert np.all(rew[~valid] == 0.0)
    np.testing.assert_allclose(rew.sum(axis=1),
                               np.asarray(res.metrics["episode_return"]),
                               rtol=1e-6)


def test_stack_traces_matches_make_trace_batch():
    stacked = stack_traces([_trace(0), _trace(1)])
    for k, v in stacked.items():
        assert v.shape[0] == 2
        np.testing.assert_array_equal(np.asarray(v[1]),
                                      np.asarray(_trace(1)[k]))


# ------------------------------------------------------------ scenarios
def test_scenario_grid_runs():
    scs = [SC._make("tiny-4srv", 4, 0.05, num_tasks=8),
           SC.cold_start_heavy(4)]
    res = SC.run_grid(scs, RO.uniform_policy, jax.random.PRNGKey(0), batch=4)
    assert [r["scenario"] for r in res] == ["tiny-4srv", "coldstart-4srv"]
    for r in res:
        assert r["episode_return"].shape == (4,)
        assert np.isfinite(r["mean_episode_return"])
        assert 0.0 <= r["mean_reload_rate"] <= 1.0


def test_default_grid_covers_paper_axes():
    names = [s.name for s in SC.default_grid()]
    assert {"paper-4srv", "paper-8srv", "paper-12srv"} <= set(names)
    assert any(n.startswith("rate-8srv") for n in names)
    assert any(n.startswith("multimodel") for n in names)
    assert any(n.startswith("coldstart") for n in names)


def test_multimodel_scenario_rollout():
    sc = SC.multi_model_mix(num_servers=4, num_models=2,
                            model_scale=(1.0, 0.5))
    m = SC.run_scenario(sc, RO.uniform_policy(sc.ecfg),
                        jax.random.PRNGKey(1), batch=3)
    assert m["episode_return"].shape == (3,)
    assert np.isfinite(m["mean_avg_response"])


# ------------------------------------------------------------ RL consumers
def test_sac_collect_batch_fills_buffer():
    from repro.core import agent as AG
    from repro.core import sac as SAC
    buffer = ReplayBuffer(10_000, ECFG.obs_shape, ECFG.action_dim)
    traces = make_trace_batch(jax.random.PRNGKey(11), TC, 3)
    keys = jax.random.split(jax.random.PRNGKey(12), 3)
    metrics, n = SAC.collect_batch(ECFG, AG.AgentConfig(variant="eat-da"),
                                   None, traces, keys, buffer, warmup=True)
    assert n == int(np.asarray(metrics["episode_len"]).sum())
    assert buffer.size == n > 0
    # stored agent-space actions live in [-1, 1]
    assert np.all(np.abs(buffer.action[:n]) <= 1.0)
    batch = buffer.sample(np.random.default_rng(0), 16)
    assert batch["obs"].shape == (16,) + ECFG.obs_shape


def test_replay_add_batch_ring_wraps():
    buf = ReplayBuffer(8, (2, 2), 3)
    obs = np.arange(12 * 4, dtype=np.float32).reshape(12, 2, 2)
    act = np.zeros((12, 3), np.float32)
    rew = np.arange(12, dtype=np.float32)
    buf.add_batch(obs[:5], act[:5], rew[:5], obs[:5], np.zeros(5))
    assert buf.size == 5 and buf.ptr == 5
    buf.add_batch(obs[5:], act[5:], rew[5:], obs[5:], np.ones(7))
    assert buf.size == 8 and buf.ptr == 4
    # newest 8 rewards (4..11) live in the ring
    assert set(buf.reward.tolist()) == set(range(4, 12))


@pytest.mark.slow
def test_ppo_batched_training_runs():
    from repro.core import ppo as PPO
    ecfg = EnvConfig(num_servers=4, max_tasks=6, queue_window=4, max_steps=96)
    tc = TraceConfig(num_tasks=6, arrival_rate=0.05, max_servers=4)
    st, hist = PPO.train_ppo(ecfg, PPO.PPOConfig(epochs=1, minibatches=2),
                             lambda k: make_trace(k, tc), num_episodes=4,
                             seed=0, log_every=0, num_envs=2)
    assert len(hist) == 4
    assert int(st.step) > 0
    assert all(np.isfinite(h["episode_return"]) for h in hist)
