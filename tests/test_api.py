"""Unified simulation facade (`repro.api`): policy registry resolution and
weight provenance, the checkpoint-restore door, the registry x backend
smoke grid (every registered policy through episodic AND streaming
simulation on the reference / fused / sharded backends with identical
summaries; sharded bitwise vs fused), and the deprecated pre-facade
wrappers. Run under XLA_FLAGS=--xla_force_host_platform_device_count=8
(CI `sharded-parity` job / `make test-sharded`) the sharded backend uses a
real multi-device mesh."""
import dataclasses

import jax
import numpy as np
import pytest

import repro.api as api
from repro.core import scenarios as SC
from repro.core.env import EnvConfig
from repro.core.workload import TraceConfig

# tiny cell so the full registry x mode x backend grid stays cheap
ECFG = EnvConfig(num_servers=4, max_tasks=8, queue_window=4, max_steps=24)
TCFG = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)
CELL = SC.Scenario(name="api-test-cell", ecfg=ECFG, tcfg=TCFG)

# cheap builder options for the expensive-to-resolve policies
OPTS = {
    "eat": {"variant": "eat-da", "T": 2},
    "ppo": {},
    "genetic": {"population": 8, "generations": 2, "parents": 4,
                "seq_len": 24},
    "harmony": {"memory_size": 8, "improvisations": 8, "improv_batch": 4,
                "seq_len": 24},
}


def _spec(name):
    return api.PolicySpec(name, options=OPTS.get(name, {}))


def _summary_arrays(summary):
    return {k: np.asarray(v) for k, v in summary.items()
            if not isinstance(v, str)}


# ------------------------------------------------------- registry
def test_registry_covers_all_schedulers():
    names = api.available_policies()
    for expected in ("random", "fifo", "greedy", "eat", "ppo", "genetic",
                     "harmony"):
        assert expected in names
    with pytest.raises(ValueError):
        api.resolve("oracle", ECFG)
    assert api.policy_kind("greedy") == "baseline"
    assert api.policy_kind("eat") == "learned"
    assert api.policy_kind("harmony") == "offline"


def test_baselines_resolve_trained_without_weights():
    for name in ("random", "fifo", "greedy"):
        rp = api.resolve(name, ECFG)
        assert rp.trained and rp.params == {} and rp.kind == "baseline"


def test_learned_policy_fresh_weights_flagged_untrained():
    """The PR-4 bugfix: no checkpoint/params -> trained=False + warning."""
    for name in ("eat", "ppo"):
        with pytest.warns(api.UntrainedPolicyWarning):
            rp = api.resolve(_spec(name), ECFG)
        assert rp.trained is False


def test_learned_policy_with_params_is_trained_and_silent(recwarn):
    with pytest.warns(api.UntrainedPolicyWarning):
        fresh = api.resolve(_spec("ppo"), ECFG)
    recwarn.clear()
    rp = api.resolve(api.PolicySpec("ppo", params=fresh.params), ECFG)
    assert rp.trained is True
    assert not [w for w in recwarn
                if issubclass(w.category, api.UntrainedPolicyWarning)]


def test_offline_policy_requires_workload_context():
    with pytest.raises(ValueError):
        api.resolve(_spec("genetic"), ECFG)   # no trace_fn, no Simulator


# ------------------------------------------------------- checkpoint door
def test_checkpoint_restore_roundtrip(tmp_path):
    from repro.common.checkpoint import save_checkpoint
    with pytest.warns(api.UntrainedPolicyWarning):
        fresh = api.resolve(_spec("ppo"), ECFG)
    bumped = jax.tree_util.tree_map(lambda x: x + 1.0, fresh.params)
    save_checkpoint(str(tmp_path), 3, bumped)
    rp = api.resolve(api.PolicySpec("ppo", checkpoint=str(tmp_path)), ECFG)
    assert rp.trained is True
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        rp.params, bumped)


def test_restore_from_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        api.restore_params(str(tmp_path), {"w": np.zeros(2)})


# ------------------------------------------------------- exec backends
def test_exec_spec_validation():
    with pytest.raises(ValueError):
        api.ExecSpec(backend="gpu-magic")
    with pytest.raises(ValueError):
        api.WorkloadSpec(scenario=CELL, mode="sideways")


def test_resolve_shards_gcd_degrade():
    ndev = api.device_count()
    spec = api.ExecSpec(backend="sharded")
    assert api.resolve_shards(8 * ndev, spec) == ndev
    assert api.resolve_shards(1, spec) == 1
    with pytest.raises(ValueError):
        api.resolve_shards(8, api.ExecSpec(backend="sharded",
                                           mesh_devices=ndev + 1))


# ------------------------------------------------------- registry x backend
@pytest.mark.parametrize("name", ["random", "fifo", "greedy", "eat", "ppo",
                                  "genetic", "harmony"])
def test_registry_backend_grid(name):
    """Every registered policy runs through episodic AND streaming
    simulation on all batch-parallel simulated backends with identical
    summary metrics (sharded parity bitwise vs fused). The serving
    backend is one physical cluster (B=1) and has its own parity suite
    in tests/test_serving_backend.py."""
    key = jax.random.PRNGKey(7)
    workloads = {
        "episodic": api.WorkloadSpec.episodic(CELL, batch=8, num_steps=16),
        "streaming": api.WorkloadSpec.streaming(CELL, streams=8,
                                                num_windows=2),
    }
    for mode, wl in workloads.items():
        results = {}
        for backend in api.SIM_BACKENDS:
            sim = api.Simulator(wl, api.ExecSpec(backend=backend))
            if name in ("eat", "ppo"):      # fresh weights -> flagged
                with pytest.warns(api.UntrainedPolicyWarning):
                    results[backend] = sim.run(_spec(name), key)
                assert results[backend].trained is False
            else:
                results[backend] = sim.run(_spec(name), key)
                assert results[backend].trained is True
        base = _summary_arrays(results["fused"].summary)
        for backend in ("reference", "sharded"):
            other = _summary_arrays(results[backend].summary)
            assert base.keys() == other.keys()
            for k in base:
                np.testing.assert_array_equal(
                    base[k], other[k],
                    err_msg=f"{name}/{mode}/{backend}/{k}")
        if mode == "episodic":   # per-episode arrays bitwise, sharded/ref
            for backend in ("reference", "sharded"):
                for k, v in results["fused"].metrics.items():
                    np.testing.assert_array_equal(
                        v, results[backend].metrics[k],
                        err_msg=f"{name}/episodic/{backend}/{k}")


def test_sharded_collect_transitions_bitwise():
    """Training consumers collect transitions; the sharded backend must
    return the identical stacked (B, T, ...) trajectory."""
    wl = api.WorkloadSpec.episodic(CELL, batch=8, num_steps=12, collect=True)
    key = jax.random.PRNGKey(11)
    tf = api.Simulator(wl, api.ExecSpec(backend="fused")).run("random", key)
    ts = api.Simulator(wl, api.ExecSpec(backend="sharded")).run("random", key)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        tf.raw.transitions, ts.raw.transitions)


def test_sharded_fault_parity_bitwise():
    """An active FaultSpec must not break sharded/fused/reference parity:
    the fault trace columns ride the same P(axis) batch sharding as the
    rest of the trace, so episodic summaries and per-episode metrics stay
    bitwise-identical across backends."""
    from repro.faults import FaultSpec
    spec = FaultSpec(seed=5, mtbf=60.0, mttr=20.0, straggler_prob=0.2,
                     straggler_factor=3.0, max_retries=2)
    wl = api.WorkloadSpec.episodic(CELL, batch=8, num_steps=16)
    key = jax.random.PRNGKey(21)
    results = {
        b: api.Simulator(wl, api.ExecSpec(backend=b, faults=spec)).run(
            "greedy", key)
        for b in ("fused", "reference", "sharded")}
    assert float(np.sum(results["fused"].metrics["num_failed"])) > 0, \
        "chaos spec injected no failures — fault trace not attached?"
    base = _summary_arrays(results["fused"].summary)
    for backend in ("reference", "sharded"):
        other = _summary_arrays(results[backend].summary)
        assert base.keys() == other.keys()
        for k in base:
            np.testing.assert_array_equal(base[k], other[k],
                                          err_msg=f"faults/{backend}/{k}")
        for k, v in results["fused"].metrics.items():
            np.testing.assert_array_equal(
                v, results[backend].metrics[k],
                err_msg=f"faults/{backend}/metrics/{k}")


def test_sharded_uses_multi_device_mesh_when_available():
    """Under the CI sharded-parity job (8 forced host devices) the grid
    above must actually exercise a multi-device mesh."""
    ndev = api.device_count()
    assert api.resolve_shards(8 * ndev,
                              api.ExecSpec(backend="sharded")) == ndev


# ------------------------------------------------------- training consumers
def test_sac_collect_on_sharded_backend_matches_fused():
    from repro.core import agent as AG
    from repro.core import sac as SAC
    from repro.core.replay import ReplayBuffer
    from repro.core.workload import make_trace_batch
    acfg = AG.AgentConfig(variant="eat-da", T=2)
    actor = AG.init_actor(jax.random.PRNGKey(0), ECFG, acfg)
    traces = make_trace_batch(jax.random.PRNGKey(1), TCFG, 4)
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    out = {}
    for backend in ("fused", "sharded"):
        buf = ReplayBuffer(4096, ECFG.obs_shape, ECFG.action_dim)
        m, n = SAC.collect_batch(ECFG, acfg, actor, traces, keys, buf,
                                 exec_spec=api.ExecSpec(backend=backend))
        out[backend] = (n, {k: np.asarray(v) for k, v in m.items()})
    assert out["fused"][0] == out["sharded"][0]
    for k in out["fused"][1]:
        np.testing.assert_array_equal(out["fused"][1][k],
                                      out["sharded"][1][k])


# ------------------------------------------------------- sweep rows
def test_sweep_row_carries_provenance_and_backend():
    from repro.traffic.stream import StreamConfig
    from repro.traffic.sweep import run_cell
    row = run_cell(CELL, "fifo", jax.random.PRNGKey(0),
                   stream=StreamConfig(num_windows=2, num_streams=2),
                   exec_spec=api.ExecSpec(backend="fused"))
    assert row["trained"] is True
    assert row["exec_backend"] == "fused"
    assert row["cell"] == "api-test-cell"
    assert row["tasks_injected"] == (row["tasks_scheduled"]
                                     + row["tasks_dropped"]
                                     + row["tasks_leftover"])


# ------------------------------------------------------- deprecated doors
def test_make_policy_wrapper_warns_and_delegates():
    from repro.traffic import policies as TP
    with pytest.warns(DeprecationWarning, match="repro.api"):
        policy, params = TP.make_policy("greedy", ECFG)
    assert params == {}
    assert policy is api.resolve("greedy", ECFG).policy


def test_evaluate_policy_batch_wrapper_warns_and_matches():
    from repro.core import baselines as BL
    from repro.core import rollout as RO
    from repro.core.workload import make_trace_batch
    traces = make_trace_batch(jax.random.PRNGKey(3), TCFG, 4)
    keys = jax.random.split(jax.random.PRNGKey(4), 4)
    with pytest.warns(DeprecationWarning, match="repro.api"):
        old = BL.evaluate_policy_batch(ECFG, traces,
                                       RO.uniform_policy(ECFG), keys)
    new = api.evaluate_batch(ECFG, traces, "random", keys)
    for k in old:
        np.testing.assert_array_equal(old[k], new[k])
