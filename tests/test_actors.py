"""The unified actor layer (`repro.actors`): fused K-step denoiser chain
parity vs the ref oracle, the DDIM / distilled fast samplers, ActorProgram
caching and the migrated consumer doors, registry sampler plumbing, and
consistency distillation (`training.distill`)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro import actors as ACT
from repro.actors import samplers as SMP
from repro.core import agent as AG
from repro.core import diffusion as DF
from repro.core import sac as SAC
from repro.core import scenarios as SC
from repro.core.env import EnvConfig
from repro.core.workload import TraceConfig
from repro.kernels.denoiser import ops as KOPS
from repro.kernels.denoiser import ref as KREF

ECFG = EnvConfig(num_servers=4, max_tasks=8, queue_window=4, max_steps=24)
TCFG = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)
CELL = SC.Scenario(name="actors-test-cell", ecfg=ECFG, tcfg=TCFG)
# mlp encoder + diffusion policy: the cheapest variant with a denoiser
ACFG = AG.AgentConfig(variant="eat-a", T=4, hidden=32)


def _chain_inputs(key, B, A, F, K, t_dim=16):
    ks = jax.random.split(key, 8)
    p = DF.init_denoiser(ks[0], A, F, hidden=24)
    x = jax.random.normal(ks[1], (B, A))
    noises = jax.random.normal(ks[2], (K, B, A))
    f_s = jax.random.normal(ks[3], (B, F))
    tembs = DF.timestep_embedding(jnp.arange(K) + 1, t_dim)
    cx = 1.0 + 0.1 * jax.random.normal(ks[4], (K,))
    ce = 0.1 * jax.random.normal(ks[5], (K,))
    cn = 0.1 * jax.random.uniform(ks[6], (K,))
    return p, x, noises, f_s, tembs, cx, ce, cn


# ------------------------------------------------------------ chain kernel
@pytest.mark.parametrize("B,A,F,K", [
    (9, 3, 12, 10),
    (5, 5, 7, 5),
    (4, 4, 20, 1),
    (130, 3, 12, 4),   # batch spills over one 128-row block
])
def test_chain_kernel_bitwise_vs_ref_oracle(B, A, F, K):
    """Pallas whole-chain kernel (interpret mode) is BITWISE against the
    jnp chain oracle — the _pin armor blocks FMA contraction."""
    p, x, noises, f_s, tembs, cx, ce, cn = _chain_inputs(
        jax.random.PRNGKey(K * 131 + A), B, A, F, K)
    ref = KOPS.denoise_chain(p, x, noises, f_s, tembs, cx, ce, cn,
                             impl="ref")
    ker = KOPS.denoise_chain(p, x, noises, f_s, tembs, cx, ce, cn,
                             impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


def test_chain_ref_single_step_matches_denoiser_ref():
    """K=1, cx=0, ce=1, cn=0 reduces the chain to tanh of one eps call."""
    p, x, noises, f_s, tembs, *_ = _chain_inputs(
        jax.random.PRNGKey(7), 6, 3, 10, 1)
    w = [(l["w"], l["b"]) for l in p["layers"]]
    inp = jnp.concatenate([x, jnp.broadcast_to(tembs[0], (6, 16)), f_s], -1)
    eps = KREF.denoiser_ref(inp, *w[0], *w[1], *w[2])
    out = KREF.denoiser_chain_ref(
        x, noises, f_s, tembs, jnp.zeros((1,)), jnp.ones((1,)),
        jnp.zeros((1,)), *w[0], *w[1], *w[2])
    # allclose, not bitwise: the standalone eps call compiles in a separate
    # XLA program whose fusion choices may differ at the ulp level
    np.testing.assert_allclose(np.asarray(out), np.tanh(np.asarray(eps)),
                               rtol=1e-6, atol=1e-6)


def test_chain_with_ddpm_coeffs_matches_reverse_sample():
    """The affine-chain DDPM path reproduces `diffusion.reverse_sample`
    on the same PRNG path (allclose — the coefficient algebra is
    refactored, not transcribed)."""
    T, A, F = 6, 3, 12
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    p = DF.init_denoiser(ks[0], A, F, hidden=24)
    sched = DF.vp_schedule(T)
    f_s = jax.random.normal(ks[1], (F,))
    want = DF.reverse_sample(p, sched, f_s, ks[2], A)
    got = SMP.chain_sample(p, sched, f_s, ks[2], A, kind="ddpm", impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_denoise_eps_fused_rejects_wrong_layer_count():
    """Regression: the fused op used to silently index layers[0..2] —
    non-3-layer denoisers must fail loudly, not compute garbage."""
    A, F = 3, 8
    p3 = DF.init_denoiser(jax.random.PRNGKey(0), A, F, hidden=16)
    x = jnp.zeros((2, A))
    i = jnp.full((2,), 4)
    f_s = jnp.zeros((2, F))
    for n in (2, 4):
        bad = {"layers": (p3["layers"] * 2)[:n]}
        with pytest.raises(ValueError, match="exactly 3 MLP layers"):
            KOPS.denoise_eps_fused(bad, x, i, f_s)
    with pytest.raises(ValueError, match="layers"):
        KOPS.denoise_eps_fused({"w": jnp.zeros(())}, x, i, f_s)
    # the chain executor validates through the same door
    with pytest.raises(ValueError, match="exactly 3 MLP layers"):
        KOPS.denoise_chain({"layers": p3["layers"][:2]}, x,
                           jnp.zeros((1, 2, A)), f_s,
                           DF.timestep_embedding(jnp.array([1]), 16),
                           jnp.ones((1,)), jnp.ones((1,)), jnp.zeros((1,)))


# ------------------------------------------------------------ samplers
def test_parse_and_normalize_sampler():
    assert SMP.parse_sampler(None) == ("ddpm", None)
    assert SMP.parse_sampler("ddpm") == ("ddpm", None)
    assert SMP.parse_sampler("ddim:5") == ("ddim", 5)
    assert SMP.parse_sampler("DDIM:3") == ("ddim", 3)
    assert SMP.parse_sampler("distilled") == ("distilled", None)
    assert SMP.normalize_sampler(None) == "ddpm"
    assert SMP.normalize_sampler("ddim:7") == "ddim:7"
    for bad in ("ddim", "ddim:x", "ddim:0", "euler"):
        with pytest.raises(ValueError):
            SMP.parse_sampler(bad)


def test_ddim_taus_strided_and_monotone():
    for T, K in [(10, 1), (10, 5), (10, 10), (7, 3), (100, 4)]:
        taus = SMP.ddim_taus(T, K)
        assert taus.shape == (K,)
        assert taus[0] == T - 1
        if K > 1:
            assert taus[-1] == 0
            assert (np.diff(taus) < 0).all()
    with pytest.raises(ValueError):
        SMP.ddim_taus(5, 6)


def test_ddim_full_grid_matches_probability_flow():
    """K=T DDIM visits every timestep; coefficients are finite and the
    terminal step maps x0_pred through exactly (coef_n == 0 throughout)."""
    sched = DF.vp_schedule(8)
    cx, ce, cn, t_in = SMP.ddim_coeffs(sched, 8)
    assert np.asarray(t_in).tolist() == list(range(8, 0, -1))
    np.testing.assert_array_equal(np.asarray(cn), 0.0)
    assert np.isfinite(np.asarray(cx)).all()
    assert np.isfinite(np.asarray(ce)).all()
    # last step: abar_prev = 1 -> coef_x = 1/sqrt(abar_0)
    np.testing.assert_allclose(
        np.asarray(cx)[-1], 1.0 / np.sqrt(np.asarray(sched.alpha_bars)[0]),
        rtol=1e-6)


def test_gaussian_variant_rejects_fast_samplers():
    gcfg = AG.AgentConfig(variant="eat-da", T=4)
    with pytest.raises(ValueError, match="Gaussian"):
        ACT.actor_policy(ECFG, gcfg, sampler="ddim:2")
    with pytest.raises(ValueError, match="Gaussian"):
        ACT.actor_policy(ECFG, gcfg, sampler="distilled")
    # default ddpm label is fine on Gaussian variants (it routes to
    # actor_sample, which handles both policy families)
    assert ACT.actor_policy(ECFG, gcfg).sampler == "ddpm"


# ------------------------------------------------------------ actor layer
def test_sac_actor_policy_is_the_actors_door():
    """The historical door returns the SAME cached callable object — jit
    caches keyed on policy identity keep hitting across both imports."""
    a = SAC.actor_policy(ECFG, ACFG)
    b = ACT.actor_policy(ECFG, ACFG, sampler="ddpm")
    c = ACT.actor_policy(ECFG, ACFG)
    assert a is b is c
    assert a.sampler == "ddpm"
    det = SAC.actor_policy(ECFG, ACFG, deterministic=True)
    assert det is ACT.actor_policy(ECFG, ACFG, deterministic=True)
    assert det is not a


def test_actor_program_cached_and_samples():
    policy = ACT.actor_policy(ECFG, ACFG, deterministic=True)
    prog = ACT.actor_program(ECFG, policy)
    assert prog is ACT.actor_program(ECFG, policy)
    assert prog.sampler == "ddpm"
    assert prog.policy is policy

    from repro.core import env as EV
    from repro.core.workload import make_trace
    params = AG.init_actor(jax.random.PRNGKey(0), ECFG, ACFG)
    trace = make_trace(jax.random.PRNGKey(1), TCFG)
    state = EV.reset(ECFG)
    obs = EV.observe(ECFG, trace, state)
    key = jax.random.PRNGKey(2)
    key2, action, extras = prog.act(trace, state, obs, key, params)
    assert "agent_action" in extras
    # the seam splits the carried key exactly once
    np.testing.assert_array_equal(np.asarray(key2),
                                  np.asarray(jax.random.split(key)[0]))


def test_policy_prog_door_is_deprecated():
    from repro.serving import backend as SB
    policy = ACT.actor_policy(ECFG, ACFG, deterministic=True)
    with pytest.warns(DeprecationWarning, match="actor_program"):
        act = SB._policy_prog(ECFG, policy)
    # bound methods compare equal iff same function on the same program
    assert act == ACT.actor_program(ECFG, policy).act


# ------------------------------------------------------------ registry
def _eat_spec(sampler=None, **opts):
    opts.setdefault("acfg", ACFG)
    return api.PolicySpec("eat", options=opts, sampler=sampler)


def _resolve_quiet(spec):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", api.UntrainedPolicyWarning)
        return api.resolve(spec, ECFG)


def test_registry_plumbs_sampler_and_program():
    for sampler, want in [(None, "ddpm"), ("ddim:2", "ddim:2"),
                          ("distilled", "distilled")]:
        rp = _resolve_quiet(_eat_spec(sampler))
        assert rp.meta["sampler"] == want
        assert rp.policy.sampler == want
        assert rp.program is ACT.actor_program(ECFG, rp.policy)
        assert rp.program.sampler == want
    # legacy options key still works; spec.sampler wins over it
    rp = _resolve_quiet(api.PolicySpec(
        "eat", options={"acfg": ACFG, "sampler": "ddim:2"}))
    assert rp.meta["sampler"] == "ddim:2"
    rp = _resolve_quiet(api.PolicySpec(
        "eat", options={"acfg": ACFG, "sampler": "ddim:2"},
        sampler="ddim:3"))
    assert rp.meta["sampler"] == "ddim:3"


def test_distilled_needs_student_weights():
    # fresh resolve injects an (untrained) student head
    rp = _resolve_quiet(_eat_spec("distilled"))
    assert "student" in rp.params and rp.trained is False
    # explicit weights without one fail loudly
    teacher = AG.init_actor(jax.random.PRNGKey(0), ECFG, ACFG)
    with pytest.raises(ValueError, match="student"):
        api.resolve(api.PolicySpec("eat", params=teacher,
                                   options={"acfg": ACFG},
                                   sampler="distilled"), ECFG)


def _run_quiet(wl, exec_spec, spec, key):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", api.UntrainedPolicyWarning)
        return api.Simulator(wl, exec_spec).run(spec, key)


@pytest.mark.parametrize("sampler", ["ddim:2", "distilled"])
def test_fast_samplers_run_through_simulator(sampler):
    wl = api.WorkloadSpec.episodic(CELL, batch=3)
    res = _run_quiet(wl, api.ExecSpec(), _eat_spec(sampler),
                     jax.random.PRNGKey(0))
    assert res.summary["sampler"] == sampler
    assert np.isfinite(res.summary["mean_episode_return"])


@pytest.mark.parametrize("sampler", ["ddim:2", "distilled"])
def test_fast_sampler_deterministic_parity_fused_vs_serving(sampler):
    """Deterministic serving (virtual time, mirror mode) is bitwise with
    the fused backend under both fast samplers — the contract serving's
    sampler swap relies on."""
    wl = api.WorkloadSpec.streaming(CELL, streams=1, num_windows=2,
                                    window_tasks=8, max_steps_per_window=16)
    spec = _eat_spec(sampler, deterministic=True)
    key = jax.random.PRNGKey(4)
    rf = _run_quiet(wl, api.ExecSpec(backend="fused"), spec, key)
    rs = _run_quiet(wl, api.ExecSpec(backend="serving",
                                     serving_execute=False), spec, key)
    skip = {"model_loads", "model_reuses", "tasks_executed", "wall_clock"}
    for k, a in rf.summary.items():
        if k in skip or isinstance(a, str):
            continue
        np.testing.assert_equal(rs.summary[k], a, err_msg=k)
    assert rs.summary["sampler"] == sampler


def test_stream_runner_swap_updates_program():
    from repro.traffic import (PoissonArrivals, ProcessTaskSource,
                               StreamConfig)
    from repro.traffic.stream import StreamRunner
    p_ddpm = ACT.actor_policy(ECFG, ACFG, deterministic=True)
    p_ddim = ACT.actor_policy(ECFG, ACFG, deterministic=True,
                              sampler="ddim:2")
    params = AG.init_actor(jax.random.PRNGKey(0), ECFG, ACFG)
    src = ProcessTaskSource(PoissonArrivals(0.05), TCFG,
                            jax.random.PRNGKey(0), num_streams=2)
    runner = StreamRunner(ECFG, p_ddpm, params, src, jax.random.PRNGKey(1),
                          StreamConfig(num_streams=2,
                                       max_steps_per_window=8))
    assert runner.program.sampler == "ddpm"
    assert runner.program is ACT.actor_program(ECFG, p_ddpm)
    runner.run_window(policy=p_ddim)
    assert runner.policy is p_ddim
    assert runner.program is ACT.actor_program(ECFG, p_ddim)
    assert runner.program.sampler == "ddim:2"


# ------------------------------------------------------------ distillation
def test_distill_reduces_loss_and_tracks_teacher():
    from repro.training.distill import DistillConfig, distill_actor
    teacher = AG.init_actor(jax.random.PRNGKey(0), ECFG, ACFG)
    obs = jax.random.normal(jax.random.PRNGKey(1), (64,) + ECFG.obs_shape)
    dcfg = DistillConfig(steps=300, batch=128, dataset=512, noise_per_obs=16,
                         log_every=100)
    params, hist = distill_actor(jax.random.PRNGKey(2), teacher, ECFG, ACFG,
                                 dcfg, obs=obs)
    assert "student" in params
    assert params["denoiser"] is teacher["denoiser"]
    assert hist[-1]["loss"] < 0.5 * hist[0]["loss"]

    # the distilled policy's deterministic actions approach the teacher's
    # PF-ODE (full-grid DDIM) endpoint on UNSEEN decision keys, far
    # closer than an untrained student
    sched = DF.vp_schedule(ACFG.T)
    f_s = AG._encode(teacher, ACFG, ECFG, obs[0])
    untrained = ACT.init_student(jax.random.PRNGKey(5), ECFG, ACFG)
    errs, errs_fresh = [], []
    for i in range(32):
        kd = jax.random.fold_in(jax.random.PRNGKey(9), i)
        want = SMP.chain_sample(teacher["denoiser"], sched, f_s, kd,
                                ECFG.action_dim, kind="ddim", K=ACFG.T,
                                impl="ref")
        got = SMP.distilled_sample(params["student"], f_s, kd,
                                   ECFG.action_dim, ACFG.T, impl="ref")
        fresh = SMP.distilled_sample(untrained, f_s, kd, ECFG.action_dim,
                                     ACFG.T, impl="ref")
        errs.append(float(jnp.mean(jnp.abs(got - want))))
        errs_fresh.append(float(jnp.mean(jnp.abs(fresh - want))))
    assert np.mean(errs) < 0.6 * np.mean(errs_fresh)


def test_distill_rejects_gaussian_teacher():
    from repro.training.distill import distill_actor
    gcfg = AG.AgentConfig(variant="eat-da", T=4)
    teacher = AG.init_actor(jax.random.PRNGKey(0), ECFG, gcfg)
    with pytest.raises(ValueError, match="Gaussian"):
        distill_actor(jax.random.PRNGKey(1), teacher, ECFG, gcfg)
