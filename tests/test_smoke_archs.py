"""Per-architecture smoke tests (assignment deliverable f): reduced variant,
one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ASSIGNED_ARCHS, get_config
from repro.models.zoo import build_model
from repro.training.optimizer import adam_init, adam_update, apply_updates


def _batch(cfg, key, b=2, s=16):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (b, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one adam step
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    opt = adam_init(params)
    upd, opt = adam_update(grads, opt, params, 1e-3)
    params2 = apply_updates(params, upd)
    loss2, _ = model.loss(params2, batch)
    assert np.isfinite(float(loss2))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_prefill_decode_shapes(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    batch = _batch(cfg, jax.random.PRNGKey(1), b=b, s=s)
    cache = model.make_cache(b, 32)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    logits2, cache = model.decode(params, cache, tok)
    assert logits2.shape == (b, 1, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    expected = s + 1 + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert int(cache["pos"]) == expected
