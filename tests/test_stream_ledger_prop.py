"""Property test: the streaming seam ledger is conserved for *every*
window/carry/fault configuration, not just the hand-picked ones.

    injected == scheduled + dropped + failed_pending_retry + leftover

with dropped = shed + retry-exhausted. Two drivers share one core check:

* a Hypothesis property (`hypothesis` ships in requirements-dev.txt but not
  in the minimal container, so it is `importorskip`'d), and
* a seeded-RNG fallback sweep that always runs, drawing the same parameter
  space from `np.random.default_rng` so tier-1 keeps randomized coverage
  even without Hypothesis installed.
"""
import jax
import numpy as np
import pytest

from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.workload import TraceConfig
from repro.faults import FaultSpec
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.stream import ProcessTaskSource, StreamConfig, run_stream


def check_ledger(*, windows: int, streams: int, K: int, max_carry,
                 fault_seed: int, mtbf: float, max_retries: int,
                 rate: float, key_seed: int) -> None:
    ecfg = EV.EnvConfig(num_servers=4, queue_window=4, max_tasks=K,
                        time_limit=600.0, max_steps=8 * K)
    faults = None
    if mtbf > 0.0:
        faults = FaultSpec(seed=fault_seed, mtbf=mtbf, mttr=30.0,
                           straggler_prob=0.2, max_retries=max_retries,
                           backoff_base=2.0, backoff_cap=20.0,
                           retry_deadline=300.0)
    key = jax.random.PRNGKey(key_seed)
    src = ProcessTaskSource(PoissonArrivals(rate=rate),
                            TraceConfig(num_tasks=K), key,
                            num_streams=streams)
    scfg = StreamConfig(num_windows=windows, num_streams=streams,
                        max_carry=max_carry, resp_sla=120.0, faults=faults)
    res = run_stream(ecfg, RO.greedy_policy(ecfg), None, src, key, scfg)
    s = res.summary
    assert s["tasks_injected"] == (
        s["tasks_scheduled"] + s["tasks_dropped"]
        + s["tasks_failed_pending_retry"] + s["tasks_leftover"]), s
    assert s["tasks_dropped"] == (s["tasks_dropped_shed"]
                                  + s["tasks_dropped_retry_exhausted"]), s
    for k in ("tasks_scheduled", "tasks_dropped", "tasks_leftover",
              "tasks_failed_pending_retry", "tasks_failed", "tasks_retried"):
        assert s.get(k, 0) >= 0, (k, s)


def _draw(rng):
    mtbf = float(rng.choice([0.0, 40.0, 120.0, 300.0]))
    return dict(
        windows=int(rng.integers(1, 5)),
        streams=int(rng.integers(1, 4)),
        K=int(rng.choice([8, 12, 16])),
        max_carry=(None if rng.random() < 0.5
                   else int(rng.integers(0, 9))),
        fault_seed=int(rng.integers(0, 1000)),
        mtbf=mtbf,
        max_retries=int(rng.integers(0, 4)),
        rate=float(rng.choice([0.05, 0.2, 1.0])),
        key_seed=int(rng.integers(0, 1000)),
    )


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_ledger_conserved_seeded_sweep(seed):
    """Fallback sweep (no external deps): 6 random configs per tier-1 run."""
    check_ledger(**_draw(np.random.default_rng(seed)))


def test_ledger_conserved_hypothesis():
    """The same invariant under Hypothesis' adversarial shrinking search."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=12, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(
        windows=st.integers(1, 4),
        streams=st.integers(1, 3),
        K=st.sampled_from([8, 12, 16]),
        max_carry=st.one_of(st.none(), st.integers(0, 8)),
        fault_seed=st.integers(0, 999),
        mtbf=st.sampled_from([0.0, 40.0, 120.0, 300.0]),
        max_retries=st.integers(0, 3),
        rate=st.sampled_from([0.05, 0.2, 1.0]),
        key_seed=st.integers(0, 999),
    )
    def prop(**kw):
        check_ledger(**kw)

    prop()
