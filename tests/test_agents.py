"""EAT agent variants: encoder, diffusion policy, SAC update, PPO update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agent as AG
from repro.core import diffusion as DF
from repro.core.env import EnvConfig
from repro.core.networks import attention_encode, init_attention_encoder
from repro.core.sac import SACConfig, init_train_state, update_step

ECFG = EnvConfig(num_servers=4, max_tasks=8, queue_window=4)


def test_attention_encoder_shapes():
    p = init_attention_encoder(jax.random.PRNGKey(0), 3, 8, d_attn=16)
    s = jax.random.normal(jax.random.PRNGKey(1), (3, 8))
    f = attention_encode(p, s)
    assert f.shape == (8,)
    # batched
    sb = jax.random.normal(jax.random.PRNGKey(2), (5, 3, 8))
    fb = attention_encode(p, sb)
    assert fb.shape == (5, 8)
    np.testing.assert_allclose(np.asarray(fb[0]),
                               np.asarray(attention_encode(p, sb[0])),
                               rtol=1e-6)


def test_attention_softmax_rows():
    """Eq. 9: attention weights rows sum to 1 (implicitly via softmax) —
    verify permutation equivariance of the encoding."""
    p = init_attention_encoder(jax.random.PRNGKey(0), 3, 6)
    s = jax.random.normal(jax.random.PRNGKey(1), (3, 6))
    f = attention_encode(p, s)
    perm = jnp.asarray([1, 0, 2, 3, 4, 5])
    f2 = attention_encode(p, s[:, perm])
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f[perm]), rtol=1e-5, atol=1e-6)


def test_vp_schedule():
    sched = DF.vp_schedule(10)
    assert sched.betas.shape == (10,)
    assert np.all(np.asarray(sched.betas) > 0)
    assert np.all(np.asarray(sched.betas) < 1)
    assert float(sched.alpha_bars[-1]) < 0.05   # near-pure noise at i = T


@pytest.mark.parametrize("variant", list(AG.VARIANTS))
def test_actor_sample_bounds(variant):
    acfg = AG.AgentConfig(variant=variant, T=5)
    params = AG.init_actor(jax.random.PRNGKey(0), ECFG, acfg)
    sched = DF.vp_schedule(acfg.T)
    obs = jax.random.normal(jax.random.PRNGKey(1), ECFG.obs_shape)
    a, mean, log_sigma, ent = AG.actor_sample(params, acfg, ECFG, sched, obs,
                                              jax.random.PRNGKey(2))
    assert a.shape == (ECFG.action_dim,)
    assert np.all(np.abs(np.asarray(a)) <= 1.0)
    assert np.all(np.abs(np.asarray(mean)) <= 1.0)
    assert np.isfinite(float(ent))
    env_a = AG.to_env_action(a)
    assert np.all((np.asarray(env_a) >= 0) & (np.asarray(env_a) <= 1))


def test_entropy_formula():
    """H = 0.5 sum log(2 pi e sigma^2) (Eq. 14)."""
    acfg = AG.AgentConfig(variant="eat-da")
    params = AG.init_actor(jax.random.PRNGKey(0), ECFG, acfg)
    sched = DF.vp_schedule(acfg.T)
    obs = jax.random.normal(jax.random.PRNGKey(1), ECFG.obs_shape)
    _, _, log_sigma, ent = AG.actor_sample(params, acfg, ECFG, sched, obs,
                                           jax.random.PRNGKey(2))
    expect = 0.5 * np.sum(np.log(2 * np.pi * np.e) + 2 * np.asarray(log_sigma))
    np.testing.assert_allclose(float(ent), expect, rtol=1e-5)


def test_diffusion_reverse_differentiable():
    acfg = AG.AgentConfig(variant="eat", T=4)
    params = AG.init_actor(jax.random.PRNGKey(0), ECFG, acfg)
    sched = DF.vp_schedule(acfg.T)
    obs = jax.random.normal(jax.random.PRNGKey(1), ECFG.obs_shape)

    def f(p):
        a, _, _, _ = AG.actor_sample(p, acfg, ECFG, sched, obs,
                                     jax.random.PRNGKey(2))
        return jnp.sum(a)

    g = jax.grad(f)(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("variant", ["eat", "eat-da"])
def test_sac_update_step(variant):
    acfg = AG.AgentConfig(variant=variant, T=3)
    scfg = SACConfig(batch_size=16)
    ts = init_train_state(jax.random.PRNGKey(0), ECFG, acfg)
    rng = np.random.default_rng(0)
    batch = {
        "obs": jnp.asarray(rng.normal(size=(16,) + ECFG.obs_shape), jnp.float32),
        "action": jnp.asarray(rng.uniform(-1, 1, size=(16, ECFG.action_dim)),
                              jnp.float32),
        "reward": jnp.asarray(rng.normal(size=(16,)), jnp.float32),
        "next_obs": jnp.asarray(rng.normal(size=(16,) + ECFG.obs_shape),
                                jnp.float32),
        "done": jnp.zeros((16,), jnp.float32),
    }
    ts2, m = update_step(ts, batch, jax.random.PRNGKey(1), ecfg=ECFG,
                         acfg=acfg, scfg=scfg)
    assert np.isfinite(float(m["critic_loss"]))
    assert np.isfinite(float(m["actor_loss"]))
    # target nets moved toward the online nets (soft update)
    t0 = jax.tree_util.tree_leaves(ts.target1)[0]
    t1 = jax.tree_util.tree_leaves(ts2.target1)[0]
    assert not np.allclose(np.asarray(t0), np.asarray(t1))
    assert int(ts2.step) == 1


def test_ppo_update():
    from repro.core.ppo import PPOConfig, init_ppo, ppo_act, ppo_update
    st = init_ppo(jax.random.PRNGKey(0), ECFG)
    obs = jax.random.normal(jax.random.PRNGKey(1), ECFG.obs_shape)
    a, logp, v = ppo_act(st.params, obs, jax.random.PRNGKey(2), ecfg=ECFG)
    assert a.shape == (ECFG.action_dim,)
    rng = np.random.default_rng(0)
    batch = {
        "obs": jnp.asarray(rng.normal(size=(32,) + ECFG.obs_shape), jnp.float32),
        "action": jnp.asarray(rng.uniform(-1, 1, size=(32, ECFG.action_dim)),
                              jnp.float32),
        "logp": jnp.asarray(rng.normal(size=(32,)), jnp.float32),
        "adv": jnp.asarray(rng.normal(size=(32,)), jnp.float32),
        "ret": jnp.asarray(rng.normal(size=(32,)), jnp.float32),
    }
    st2, m = ppo_update(st, batch, ecfg=ECFG, pcfg=PPOConfig())
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) >= 0
