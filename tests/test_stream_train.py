"""Streaming training subsystem (`repro.training.stream_train`) and its
satellite bugfixes: bitwise collection parity between a single-window
stream and episodic `collect_batch` on every execution backend, the cached
jitted env step (compile-count regression), host-RNG decoupling from the
network-init seed, drop-aware shed accounting, the curriculum task source,
and SAC/PPO stream-training smoke runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecSpec, Simulator, WorkloadSpec, rollout_fn_for
from repro.core import agent as AG
from repro.core import ppo as PPO
from repro.core import rollout as RO
from repro.core import sac as SAC
from repro.core.env import EnvConfig
from repro.core.replay import ReplayBuffer
from repro.core.scenarios import (Scenario, curriculum_picker,
                                  training_curriculum)
from repro.core.workload import TraceConfig, make_trace_batch
from repro.traffic import (CurriculumTaskSource, PoissonArrivals,
                           ProcessTaskSource, StreamConfig, StreamRunner,
                           TraceTaskSource, run_stream, scale_rate)
from repro.training import stream_train as ST

ECFG = EnvConfig(num_servers=4, max_tasks=8, queue_window=4, max_steps=32)
TC = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)
ACFG = AG.AgentConfig(variant="eat-da", T=2)


def _buffer_arrays(buf, n):
    return (buf.obs[:n], buf.action[:n], buf.reward[:n], buf.next_obs[:n],
            buf.done[:n])


# ------------------------------------------------- collection parity
@pytest.mark.parametrize("backend", ["reference", "fused", "sharded"])
def test_single_window_collection_matches_episodic(backend):
    """A one-window stream collection from a fresh carry pushes bitwise-
    identical replay-buffer transitions to episodic `collect_batch` on the
    same traces — on every execution backend (the stream derives window 0's
    keys as split(fold_in(key, 0), B), which the episodic reference
    reproduces explicitly)."""
    B = 4
    key = jax.random.PRNGKey(3)
    traces = make_trace_batch(jax.random.PRNGKey(1), TC, B)
    actor = SAC.init_train_state(jax.random.PRNGKey(2), ECFG, ACFG).actor
    spec = ExecSpec(backend=backend)

    buf_ep = ReplayBuffer(4096, ECFG.obs_shape, ECFG.action_dim)
    ep_keys = jax.random.split(jax.random.fold_in(key, 0), B)
    _, n_ep = SAC.collect_batch(ECFG, ACFG, actor, traces, ep_keys, buf_ep,
                                exec_spec=spec)

    buf_st = ReplayBuffer(4096, ECFG.obs_shape, ECFG.action_dim)
    runner = StreamRunner(
        ECFG, SAC.actor_policy(ECFG, ACFG), actor,
        TraceTaskSource(jax.tree_util.tree_map(np.asarray, traces)), key,
        StreamConfig(num_windows=1, num_streams=B,
                     max_steps_per_window=ECFG.max_steps),
        rollout_fn=rollout_fn_for(spec))
    wres = runner.run_window(collect=True)
    n_st = SAC.push_transitions(buf_st, wres.transitions)

    assert n_ep == n_st > 0
    for a, b in zip(_buffer_arrays(buf_ep, n_ep), _buffer_arrays(buf_st, n_st)):
        np.testing.assert_array_equal(a, b)


def test_collection_identical_across_backends():
    """The flattened window transitions are bitwise-identical between the
    reference, fused, and sharded backends."""
    B = 4
    flats = {}
    for backend in ("reference", "fused", "sharded"):
        src = ProcessTaskSource(PoissonArrivals(0.3), TC,
                                jax.random.PRNGKey(5), num_streams=B)
        runner = StreamRunner(ECFG, SAC.warmup_policy(ECFG), {}, src,
                              jax.random.PRNGKey(6),
                              StreamConfig(num_windows=2, num_streams=B),
                              rollout_fn=rollout_fn_for(
                                  ExecSpec(backend=backend)))
        flats[backend] = [SAC.flatten_valid_transitions(
            runner.run_window(collect=True).transitions) for _ in range(2)]
    for backend in ("fused", "sharded"):
        for fa, fb in zip(flats["reference"], flats[backend]):
            for a, b in zip(fa, fb):
                np.testing.assert_array_equal(a, b)


# ------------------------------------------------- jit-cache regression
def test_env_step_compiles_once_across_traces():
    """`seed_with_demonstrations` / `run_episode` share one compiled env
    step per (ecfg, shape): the trace is a traced argument, not a closure
    constant (the old code compiled a fresh program every episode)."""
    from repro.core.workload import make_trace
    SAC._jit_env_step.clear_cache()
    buf = ReplayBuffer(4096, ECFG.obs_shape, ECFG.action_dim)
    SAC.seed_with_demonstrations(buf, ECFG, lambda k: make_trace(k, TC),
                                 jax.random.PRNGKey(0), episodes=3)
    assert SAC._jit_env_step._cache_size() == 1
    actor = SAC.init_train_state(jax.random.PRNGKey(1), ECFG, ACFG).actor
    SAC.run_episode(ECFG, make_trace(jax.random.PRNGKey(2), TC), actor,
                    ACFG, jax.random.PRNGKey(3))
    assert SAC._jit_env_step._cache_size() == 1


# ------------------------------------------------- host-RNG decoupling
def test_host_rng_decoupled_from_seed():
    """The training host RNG must not mirror np.random.default_rng(seed)
    (which would couple curriculum-cell sampling to the PRNGKey(seed)
    network init), and distinct seeds must give distinct streams."""
    draws = lambda rng: rng.integers(0, 1000, size=16).tolist()  # noqa: E731
    host0 = draws(SAC.host_rng(jax.random.PRNGKey(0)))
    assert host0 != draws(np.random.default_rng(0))
    assert host0 != draws(SAC.host_rng(jax.random.PRNGKey(1)))
    assert host0 == draws(SAC.host_rng(jax.random.PRNGKey(0)))  # reproducible


def test_distinct_seeds_give_distinct_curriculum_sequences():
    cells = training_curriculum(ECFG)
    def seq(seed):
        pick = curriculum_picker(ECFG, cells)
        rng = SAC.host_rng(jax.random.PRNGKey(seed))
        return [pick(rng)[0] for _ in range(24)]
    assert seq(0) != seq(1)


# ------------------------------------------------- shed accounting
def test_forced_shedding_accounting():
    """With max_carry forced low under overload, shed tasks must appear in
    conservation, drop_rate, and the drop-inclusive violation/goodput
    rates."""
    src = ProcessTaskSource(PoissonArrivals(0.8), TC, jax.random.PRNGKey(7),
                            num_streams=2)
    # overload + a step budget too small to drain the window: backlog grows
    # past max_carry=1 every seam, forcing the shed path
    res = run_stream(ECFG, RO.uniform_policy(ECFG), {}, src,
                     jax.random.PRNGKey(8),
                     StreamConfig(num_windows=6, num_streams=2, max_carry=1,
                                  max_steps_per_window=10))
    s, t = res.summary, res.aggregator.totals
    assert s["tasks_dropped"] > 0
    assert (s["tasks_injected"]
            == s["tasks_scheduled"] + s["tasks_dropped"]
            + s["tasks_leftover"])
    resolved = t["n_sched"] + t["n_dropped"]
    assert s["tasks_resolved"] == resolved
    assert s["drop_rate"] == pytest.approx(t["n_dropped"] / resolved)
    # drops are QoS failures: the headline rate counts them...
    assert s["qos_violation_rate"] == pytest.approx(
        (t["n_viol"] + t["n_dropped"]) / resolved)
    assert s["qos_violation_rate"] >= s["drop_rate"]
    assert s["qos_violation_rate_latency"] >= s["drop_rate"]
    # ...and served-within-QoS + violated partitions the resolved tasks
    assert s["goodput_rate"] + s["qos_violation_rate"] == pytest.approx(1.0)
    # the drop-exclusive view is still available
    assert s["qos_violation_rate_scheduled"] == pytest.approx(
        t["n_viol"] / t["n_sched"])
    # per-window ledger: last window's backlog is carried or shed, and
    # carried + injected fill exactly the window slots
    for prev, w in zip(res.per_window, res.per_window[1:]):
        assert prev["leftover"] == w["carried"] + w["dropped"]
    for w in res.per_window:
        assert w["carried"] + w["injected"] == 2 * ECFG.max_tasks


# ------------------------------------------------- curriculum source
def test_curriculum_source_switches_cells_on_shared_clock():
    fast, slow = PoissonArrivals(20.0), PoissonArrivals(0.02)
    src = CurriculumTaskSource([(fast, TC), (slow, TC)],
                               jax.random.PRNGKey(9), num_streams=1,
                               chunk_size=64)
    a = src.take(0, 64)["arr_time"]
    src.set_cell(1)
    b = src.take(0, 64)["arr_time"]
    both = np.concatenate([a, b])
    assert (np.diff(both) >= 0).all()          # one continuous clock
    assert np.diff(b).mean() > 20 * np.diff(a).mean()
    with pytest.raises(ValueError):
        src.set_cell(2)
    with pytest.raises(ValueError):
        CurriculumTaskSource([], jax.random.PRNGKey(0))


def test_scale_rate_scales_intensity():
    assert scale_rate(PoissonArrivals(0.1), 2.0).rate == pytest.approx(0.2)
    assert scale_rate(PoissonArrivals(0.1), 1.0).rate == pytest.approx(0.1)
    from repro.traffic import FlashCrowdArrivals, MMPPArrivals
    m = scale_rate(MMPPArrivals(rates=(0.02, 0.3)), 3.0)
    assert m.rates == pytest.approx((0.06, 0.9))
    f = scale_rate(FlashCrowdArrivals(base_rate=0.05, spike_rate=0.5), 2.0)
    assert (f.base_rate, f.spike_rate) == pytest.approx((0.1, 1.0))
    with pytest.raises(ValueError):
        scale_rate(PoissonArrivals(0.1), -1.0)


def test_resolve_cells_validates_ecfg():
    other = EnvConfig(num_servers=8, max_tasks=8, queue_window=4)
    with pytest.raises(ValueError):
        ST.resolve_cells(ECFG, None, training_curriculum(other))
    cells = ST.resolve_cells(ECFG, None, training_curriculum(ECFG),
                             rate_scale=2.0)
    assert len(cells) >= 4
    names = [n for n, _, _ in cells]
    assert "coldstart" in names and "bursty" in names


# ------------------------------------------------- trainers
def test_stream_train_config_validation():
    with pytest.raises(ValueError):
        ST.StreamTrainConfig(windows_per_round=0)
    with pytest.raises(ValueError):
        ST.StreamTrainConfig(streams=0)
    with pytest.raises(ValueError):
        ST.StreamTrainConfig(rate_scale=0.0)
    with pytest.raises(ValueError):
        ST.StreamTrainConfig(rounds=-1)
    assert ST.StreamTrainConfig(rounds=0).rounds == 0   # bench round-0 probe


def test_train_stream_sac_smoke():
    stcfg = ST.StreamTrainConfig(rounds=3, streams=2, rate_scale=2.0,
                                 max_updates_per_round=1)
    scfg = SAC.SACConfig(warmup_steps=16, batch_size=32)
    res = ST.train_stream_sac(ECFG, ACFG, scfg, stcfg, seed=0)
    assert len(res.history) == 3
    for row in res.history:
        assert np.isfinite(row["episode_return_mean"])
        for k in ST.QOS_KEYS:
            assert k in row
    assert res.history[-1]["buffer_size"] > 0
    assert res.history[-1]["updates"] >= 1          # past warmup, trained
    assert res.stream.summary["tasks_injected"] > 0


def test_train_stream_sac_curriculum_cells():
    cells = training_curriculum(ECFG)
    stcfg = ST.StreamTrainConfig(rounds=4, streams=2,
                                 max_updates_per_round=0)
    scfg = SAC.SACConfig(warmup_steps=100_000)      # collect-only
    res = ST.train_stream_sac(ECFG, ACFG, scfg, stcfg, curriculum=cells,
                              seed=1)
    names = {n for n, _, _ in ST.resolve_cells(ECFG, None, cells)}
    assert {row["cell"] for row in res.history} <= names
    assert all(row["warmup"] for row in res.history)


def test_pool_gae_seam_bootstrap_survives_window_done():
    """Providing `last_values` marks the row's end as a window-seam
    truncation: the env's done flag on the final valid step (raised when
    the window drains or hits its budget) must NOT zero the critic
    bootstrap."""
    T, gamma = 3, 0.9
    pcfg = PPO.PPOConfig(gamma=gamma, gae_lambda=1.0)
    ones = np.ones((1, T), np.float32)
    tr = RO.Transitions(
        obs=np.zeros((1, T, 3, 8), np.float32),
        action=np.zeros((1, T, 6), np.float32),
        reward=ones.copy(),
        next_obs=np.zeros((1, T, 3, 8), np.float32),
        done=np.asarray([[0.0, 0.0, 1.0]], np.float32),   # env done at seam
        valid=np.ones((1, T), bool),
        extras={"agent_action": np.zeros((1, T, 6), np.float32),
                "logp": ones.copy(),
                "value": 0.5 * ones.copy()})
    term = PPO.pool_gae(tr, pcfg)                          # terminal: no boot
    seam = PPO.pool_gae(tr, pcfg, last_values=np.asarray([2.0]))
    assert seam["ret"][-1] == pytest.approx(term["ret"][-1] + gamma * 2.0)


def test_train_stream_ppo_smoke():
    stcfg = ST.StreamTrainConfig(rounds=2, streams=2)
    res = ST.train_stream_ppo(ECFG, PPO.PPOConfig(epochs=1, minibatches=2),
                              stcfg, seed=0)
    assert len(res.history) == 2
    assert all(np.isfinite(r["episode_return_mean"]) for r in res.history)
    assert res.history[0]["transitions"] > 0


# ------------------------------------------------- api passthrough
def test_workloadspec_streaming_collect_returns_transitions():
    cell = Scenario(name="collect-cell", ecfg=ECFG, tcfg=TC)
    sim = Simulator(WorkloadSpec.streaming(cell, streams=2, num_windows=3,
                                           collect=True))
    res = sim.run("random", jax.random.PRNGKey(0))
    tr = res.raw.transitions
    assert isinstance(tr, list) and len(tr) == 3
    for w in tr:
        assert w.obs.shape[0] == 2                  # (B, T, ...) per window
        assert w.valid.shape == w.reward.shape
    # collect off (the default) keeps the result lean
    lean = Simulator(WorkloadSpec.streaming(cell, streams=2, num_windows=1))
    assert lean.run("random", jax.random.PRNGKey(0)).raw.transitions is None
