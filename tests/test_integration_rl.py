"""Integration: short EAT training run completes and schedules all tasks."""
import jax
import pytest

from repro.core.agent import AgentConfig
from repro.core.env import EnvConfig
from repro.core.sac import SACConfig, train
from repro.core.workload import TraceConfig, make_trace


@pytest.mark.slow
def test_eat_short_training_run():
    ecfg = EnvConfig(num_servers=4, max_tasks=6, queue_window=4, max_steps=128)
    tc = TraceConfig(num_tasks=6, arrival_rate=0.05, max_servers=4)
    ts, hist = train(ecfg, AgentConfig(variant="eat", T=4),
                     SACConfig(batch_size=32, warmup_steps=32,
                               updates_per_step=1),
                     lambda k: make_trace(k, tc), num_episodes=2, log_every=0)
    assert len(hist) == 2
    assert all(h["num_scheduled"] >= 1 for h in hist)
    assert int(ts.step) > 0


@pytest.mark.slow
def test_eat_da_short_training_run():
    ecfg = EnvConfig(num_servers=4, max_tasks=6, queue_window=4, max_steps=128)
    tc = TraceConfig(num_tasks=6, arrival_rate=0.05, max_servers=4)
    ts, hist = train(ecfg, AgentConfig(variant="eat-da"),
                     SACConfig(batch_size=32, warmup_steps=32),
                     lambda k: make_trace(k, tc), num_episodes=2, log_every=0)
    assert len(hist) == 2
