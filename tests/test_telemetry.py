"""Telemetry subsystem: tracing, metrics registry, decision profiling.

The contract under test: (1) emitted traces validate against the
machine-readable schema and nest deterministically for a fixed seed;
(2) tracing is observability, not physics — every number a run produces is
bitwise-identical with tracing on vs off; (3) the Prometheus exposition
round-trips; (4) `LatencyHistogram.percentile` boundary semantics
(underflow slot, q=0, overflow clamp); (5) executor warmup moves XLA
compilation out of the serving backend's timed region.
"""
import json
import time

import jax
import numpy as np
import pytest

from repro import api
from repro.core import env as EV
from repro.core.scenarios import Scenario
from repro.core.workload import TraceConfig as WorkloadTraceConfig
from repro.telemetry import (DECISION_EDGES, NULL_TRACER, DecisionProfile,
                             LatencyHistogram, MetricsRegistry, TraceConfig,
                             default_registry, parse_prometheus,
                             profile_policy, reset_tracers, span_durations,
                             tracer_for, validate_trace)
from repro.telemetry.schema import KNOWN_SPANS, validate_events

ECFG = EV.EnvConfig(num_servers=4, max_tasks=8)
TCFG = WorkloadTraceConfig(num_tasks=8, arrival_rate=2.0, max_servers=4)
CELL = Scenario(name="telemetry-cell", ecfg=ECFG, tcfg=TCFG)


@pytest.fixture(autouse=True)
def _fresh_telemetry():
    reset_tracers()
    default_registry().clear()
    yield
    reset_tracers()
    default_registry().clear()


def _wl(streams=2, windows=2):
    return api.WorkloadSpec.streaming(CELL, streams=streams,
                                      num_windows=windows, window_tasks=8,
                                      max_steps_per_window=16)


def _run(spec, policy="fifo", key=0):
    sim = api.Simulator(_wl(), spec)
    return sim.run(policy, jax.random.PRNGKey(key))


# ------------------------------------------------------------ tracing
def test_trace_validates_against_schema(tmp_path):
    path = str(tmp_path / "trace.json")
    spec = api.ExecSpec(trace=TraceConfig(enabled=True, path=path))
    _run(spec)
    assert validate_trace(path, strict_names=True) == []
    assert validate_trace(path + ".jsonl", strict_names=True) == []
    doc = json.load(open(path))
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"run", "window", "build_window", "window_rollout",
            "window_seam"} <= names
    assert names - {"backlog"} <= set(KNOWN_SPANS)


def test_span_nesting_deterministic_for_fixed_seed(tmp_path):
    seqs = []
    for tag in ("a", "b"):
        reset_tracers()
        path = str(tmp_path / f"trace_{tag}.json")
        spec = api.ExecSpec(trace=TraceConfig(enabled=True, path=path))
        _run(spec, key=7)
        doc = json.load(open(path))
        seqs.append([(e["name"], e["args"].get("depth"))
                     for e in doc["traceEvents"] if e["ph"] == "X"])
    assert seqs[0] == seqs[1]
    # spans nest: every window-phase span sits under its window span
    depths = {n: d for n, d in seqs[0]}
    assert depths["window"] > depths["run"]
    assert depths["build_window"] > depths["window"]


def test_tracing_is_bitwise_invisible(tmp_path):
    """Summaries (and therefore every downstream number) are identical
    with tracing enabled vs disabled — observability cannot perturb."""
    r_off = _run(api.ExecSpec(), key=3)
    reset_tracers()
    default_registry().clear()
    path = str(tmp_path / "trace.json")
    r_on = _run(api.ExecSpec(trace=TraceConfig(enabled=True, path=path)),
                key=3)
    assert set(r_off.summary) == set(r_on.summary)
    for k, v in r_off.summary.items():
        if isinstance(v, float):
            np.testing.assert_equal(v, r_on.summary[k], err_msg=k)
        else:
            assert v == r_on.summary[k], k


def test_one_tracer_per_config(tmp_path):
    cfg = TraceConfig(enabled=True, path=str(tmp_path / "t.json"))
    assert tracer_for(cfg) is tracer_for(cfg)
    assert tracer_for(TraceConfig()) is NULL_TRACER
    assert tracer_for(None) is NULL_TRACER


def test_span_durations_and_counters(tmp_path):
    cfg = TraceConfig(enabled=True, path=str(tmp_path / "t.json"))
    tr = tracer_for(cfg)
    with tr.span("outer", cat="phase"):
        with tr.span("inner", cat="phase"):
            time.sleep(0.002)
        tr.counter("backlog", 3.0)
    tr.write()
    assert validate_events(json.load(open(cfg.path))) == []
    d = span_durations(json.load(open(cfg.path))["traceEvents"])
    assert d["outer"]["count"] == d["inner"]["count"] == 1
    assert d["outer"]["total_s"] >= d["inner"]["total_s"]
    # self time excludes the contained child span
    assert d["outer"]["self_total_s"] <= d["outer"]["total_s"]


# ------------------------------------------------------------ metrics
def test_metrics_registry_prometheus_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("eat_test_events_total").inc(3, labels={"cell": "a"})
    reg.gauge("eat_test_backlog").set(7.5)
    h = reg.histogram("eat_test_latency_seconds", edges=DECISION_EDGES)
    for v in (1e-5, 3e-4, 0.02, 0.02, 5.0, 1e3):
        h.observe(v)
    text = reg.to_prometheus()
    parsed = parse_prometheus(text)
    flat = {}
    for rec in reg.snapshot().values():
        flat.update(rec["samples"])
    assert parsed == flat
    # bucket convention: cumulative, +Inf equals count
    assert parsed['eat_test_latency_seconds_bucket{le="+Inf"}'] == 6.0
    assert parsed["eat_test_latency_seconds_count"] == 6.0
    assert parsed["eat_test_latency_seconds_sum"] == pytest.approx(1005.04031)


def test_metrics_registry_kind_conflict():
    reg = MetricsRegistry()
    reg.counter("eat_x")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("eat_x")


def test_run_publishes_into_default_registry():
    _run(api.ExecSpec())
    snap = default_registry().snapshot()
    assert "eat_stream_latency_p99" in snap
    assert "eat_stream_latency_seconds" in snap
    sample = next(iter(snap["eat_stream_latency_p99"]["samples"]))
    assert 'policy="fifo"' in sample and 'backend="fused"' in sample


def test_metrics_identical_tracing_on_vs_off(tmp_path):
    _run(api.ExecSpec(), key=5)
    off = default_registry().snapshot()
    reset_tracers()
    default_registry().clear()
    spec = api.ExecSpec(trace=TraceConfig(
        enabled=True, path=str(tmp_path / "t.json"),
        metrics_path=str(tmp_path / "metrics.prom")))
    _run(spec, key=5)
    on = default_registry().snapshot()
    assert off == on
    # and the exported file parses back to the same samples
    parsed = parse_prometheus(open(str(tmp_path / "metrics.prom")).read())
    flat = {}
    for rec in on.values():
        flat.update(rec["samples"])
    assert parsed == flat


# ------------------------------------------------------------ percentiles
def test_percentile_underflow_slot_interpolates_from_zero():
    h = LatencyHistogram(np.asarray([1.0, 2.0, 4.0]))
    h.add_values([0.5, 0.5])          # both in the underflow slot (-inf, 1]
    assert 0.0 < h.percentile(0.5) <= 1.0
    assert h.percentile(1.0) == 1.0   # upper edge of the underflow slot


def test_percentile_q0_resolves_first_occupied_slot():
    h = LatencyHistogram(np.asarray([1.0, 2.0, 4.0]))
    h.add_values([3.0, 3.5])          # slot (2, 4] only
    assert h.percentile(0.0) == 2.0   # lower edge of the occupied slot
    h2 = LatencyHistogram(np.asarray([1.0, 2.0, 4.0]))
    h2.add_values([0.2])
    assert h2.percentile(0.0) == 0.0  # underflow slot: lower bound 0


def test_percentile_boundary_values_land_in_closed_upper_slot():
    h = LatencyHistogram(np.asarray([1.0, 2.0, 4.0]))
    h.add_values([1.0, 2.0, 4.0])     # exactly on the edges: slots 0,1,2
    assert np.array_equal(h.counts, [1, 1, 1, 0])
    assert h.percentile(1.0) == 4.0


def test_percentile_overflow_clamps_to_top_edge():
    h = LatencyHistogram(np.asarray([1.0, 2.0, 4.0]))
    h.add_values([100.0, 200.0])
    assert h.percentile(0.5) == 4.0
    assert h.percentile(1.0) == 4.0


def test_percentile_empty_is_nan():
    assert np.isnan(LatencyHistogram().percentile(0.5))


# ------------------------------------------------------------ profiling
def test_profile_policy_reports_percentiles():
    out = profile_policy(ECFG, *_fifo(), jax.random.PRNGKey(0), iters=5)
    assert out["decision_latency_n"] == 5.0
    assert 0 < out["decision_latency_p50_s"] <= out["decision_latency_p99_s"]


def _fifo():
    rp = api.registry.resolve("fifo", ECFG)
    return rp.policy, rp.params


def test_decision_profile_summary_keys():
    p = DecisionProfile()
    for _ in range(4):
        p.observe("policy", 1e-3)
        p.observe("env_advance", 2e-3)
    s = p.summary()
    assert s["policy_decisions"] == 4.0
    assert s["decision_latency_p50_s"] == s["policy_latency_p50_s"]
    assert "executor_latency_p50_s" not in s   # no executor observations


def test_simulator_profile_decisions_knob(tmp_path):
    spec = api.ExecSpec(trace=TraceConfig(
        enabled=True, path=str(tmp_path / "t.json"),
        profile_decisions=True, profile_iters=4))
    res = _run(spec)
    assert res.summary["decision_latency_n"] == 4.0
    assert "decision_latency_p99_s" in res.row()


# ------------------------------------------------------------ warmup
def test_executor_warmup_memoizes_shape_buckets():
    from repro.serving.executor import ModelExecutor
    ex = ModelExecutor(reduced=True)
    assert ex.warm("tinyllama-1.1b", 8, 1, 4, 8) is True
    assert ex.warm("tinyllama-1.1b", 8, 1, 4, 8) is False
    # same capacity bucket (steps/max_new_tokens round to the same cache)
    assert ex.shape_key("tinyllama-1.1b", 8, 1, 4, 8) == \
        ex.shape_key("tinyllama-1.1b", 8, 1, 6, 8)
    assert ex.warm("tinyllama-1.1b", 8, 1, 6, 8) is False


def test_executor_warmup_removes_first_task_compile_cost():
    """After `warm`, the first timed generate is steady-state work, not an
    XLA compile: it must be far cheaper than a cold executor's first call
    and comparable to its own steady state."""
    from repro.serving.executor import ModelExecutor
    arch, prompt = "tinyllama-1.1b", np.arange(8, dtype=np.int32)

    cold = ModelExecutor(reduced=True)
    params = cold.init_params(arch, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    cold.generate(arch, params, prompt, 1, 4, 8)
    t_cold = time.perf_counter() - t0

    warm = ModelExecutor(reduced=True)
    warm.warm(arch, 8, 1, 4, 8)
    t0 = time.perf_counter()
    warm.generate(arch, params, prompt, 1, 4, 8)
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm.generate(arch, params, prompt, 1, 4, 8)
    t_steady = time.perf_counter() - t0

    assert t_first < t_cold / 5, (t_first, t_cold)
    assert t_first < max(20 * t_steady, 0.05), (t_first, t_steady)


def test_serving_warmup_defaults_follow_wall_clock():
    from repro.serving.backend import serving_rollout
    on = serving_rollout(api.ExecSpec(backend="serving",
                                      serving_wall_clock=True))
    off = serving_rollout(api.ExecSpec(backend="serving"))
    forced = serving_rollout(api.ExecSpec(backend="serving",
                                          serving_warmup=True))
    assert on._ensure(4).warmup is True
    assert off._ensure(4).warmup is False
    assert forced._ensure(4).warmup is True


def test_serving_mirror_run_reports_decision_profile():
    wl = api.WorkloadSpec.streaming(CELL, streams=1, num_windows=1,
                                    window_tasks=8, max_steps_per_window=12)
    sim = api.Simulator(wl, api.ExecSpec(backend="serving",
                                         serving_execute=False))
    res = sim.run("fifo", jax.random.PRNGKey(0))
    assert res.summary["policy_decisions"] > 0
    assert res.summary["decision_latency_p50_s"] > 0
    snap = default_registry().snapshot()
    assert "eat_serving_model_loads_total" in snap
