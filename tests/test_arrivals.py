"""Arrival processes: empirical rates vs configured means, replay
round-trip, chunked statefulness, and the scenario/workload bridges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.traffic.arrivals import (DiurnalArrivals, FlashCrowdArrivals,
                                    MMPPArrivals, PoissonArrivals,
                                    ReplayArrivals, generate_trace,
                                    make_process)


def _empirical_rate(proc, n=4000, seed=0, chunks=4):
    """Long-run arrivals/second measured over `chunks` sequential chunks
    (exercises state threading across chunk boundaries)."""
    state = proc.init(jax.random.PRNGKey(seed))
    total = 0.0
    for _ in range(chunks):
        state, gaps = proc.sample(state, n // chunks)
        total += float(jnp.sum(gaps))
    return n / total


def test_poisson_empirical_rate():
    proc = PoissonArrivals(rate=0.1)
    assert _empirical_rate(proc) == pytest.approx(0.1, rel=0.08)


def test_mmpp_empirical_rate_and_burstiness():
    proc = MMPPArrivals(rates=(0.02, 0.3), switch=0.05)
    assert _empirical_rate(proc, n=8000) == pytest.approx(proc.mean_rate(),
                                                          rel=0.15)
    # bursty: squared coefficient of variation of gaps well above the
    # exponential's 1.0
    state = proc.init(jax.random.PRNGKey(3))
    _, gaps = proc.sample(state, 8000)
    g = np.asarray(gaps)
    assert np.var(g) / np.mean(g) ** 2 > 1.5


def test_diurnal_empirical_rate_and_phase():
    proc = DiurnalArrivals(base_rate=0.1, amplitude=0.6, period=2000.0)
    assert _empirical_rate(proc, n=8000) == pytest.approx(0.1, rel=0.15)
    # more arrivals land in the sinusoid's peak half-period than the trough
    state = proc.init(jax.random.PRNGKey(1))
    _, gaps = proc.sample(state, 8000)
    t = np.cumsum(np.asarray(gaps))
    phase = np.mod(t, proc.period) / proc.period
    peak = np.sum(phase < 0.5)          # sin > 0 on the first half
    trough = np.sum(phase >= 0.5)
    assert peak > 1.3 * trough


def test_flash_crowd_rate_and_spikes():
    proc = FlashCrowdArrivals(base_rate=0.05, spike_rate=0.5,
                              period=2000.0, spike_duration=200.0)
    assert _empirical_rate(proc, n=8000) == pytest.approx(proc.mean_rate(),
                                                          rel=0.15)
    state = proc.init(jax.random.PRNGKey(2))
    _, gaps = proc.sample(state, 6000)
    t = np.cumsum(np.asarray(gaps))
    in_spike = np.mod(t, proc.period) < proc.spike_duration
    # 10% of the time at 10x the rate -> roughly half the arrivals
    assert 0.3 < np.mean(in_spike) < 0.75


def test_replay_round_trip():
    arr = np.asarray([3.0, 5.5, 9.0, 20.0, 21.5], np.float32)
    proc = ReplayArrivals(times=arr)
    state = proc.init(jax.random.PRNGKey(0))
    state, gaps = proc.sample(state, 5)
    np.testing.assert_allclose(np.cumsum(np.asarray(gaps)), arr, rtol=1e-6)
    # wrap-around continues monotonically with the configured span
    _, gaps2 = proc.sample(state, 5)
    t2 = arr[-1] + np.cumsum(np.asarray(gaps2))
    span = arr[-1] * (len(arr) + 1) / len(arr)
    np.testing.assert_allclose(t2, arr + span, rtol=1e-5)
    assert proc.mean_rate() == pytest.approx(len(arr) / span)


def test_replay_split_chunks_match_one_shot():
    arr = np.cumsum(np.random.default_rng(0).exponential(10.0, 12)).astype(
        np.float32)
    proc = ReplayArrivals(times=arr)
    s = proc.init(jax.random.PRNGKey(0))
    s, g1 = proc.sample(s, 7)
    s, g2 = proc.sample(s, 5)
    whole = proc.sample(proc.init(jax.random.PRNGKey(0)), 12)[1]
    np.testing.assert_allclose(np.concatenate([g1, g2]), whole, rtol=1e-6)


def test_bursty_scenario_offers_paper_mean_load():
    """The MMPP cell's long-run rate must match the Poisson reference, so
    bursty-vs-poisson comparisons isolate burstiness from mean load."""
    from repro.core.scenarios import bursty_traffic
    from repro.core.workload import paper_rate_for
    sc = bursty_traffic(8, burst_factor=3.0)
    assert sc.arrival.mean_rate() == pytest.approx(paper_rate_for(8),
                                                   rel=1e-6)
    hot, quiet = max(sc.arrival.rates), min(sc.arrival.rates)
    assert hot / quiet == pytest.approx(9.0, rel=1e-6)


def test_replay_stagger_desyncs_streams():
    arr = np.cumsum(np.full(32, 5.0)).astype(np.float32)
    proc = ReplayArrivals(times=arr, stagger=True)
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    starts = {int(proc.init(k)[0]) for k in keys}
    assert len(starts) > 1          # streams start at distinct phases
    # a staggered stream still emits positive gaps through the wrap
    s = proc.init(keys[0])
    _, gaps = proc.sample(s, 64)
    assert np.all(np.asarray(gaps) > 0)


def test_make_process_registry():
    assert isinstance(make_process("poisson", rate=0.2), PoissonArrivals)
    assert isinstance(make_process("mmpp"), MMPPArrivals)
    with pytest.raises(ValueError):
        make_process("fractal")


def test_generate_trace_schema():
    from repro.core.workload import TraceConfig
    tc = TraceConfig(num_tasks=16, arrival_rate=0.1, max_servers=4)
    trace = generate_trace(jax.random.PRNGKey(0), PoissonArrivals(0.1), tc)
    assert set(trace) == {"arr_time", "c", "model", "noise"}
    assert trace["arr_time"].shape == (16,)
    arr = np.asarray(trace["arr_time"])
    assert np.all(np.diff(arr) >= 0) and arr[0] > 0
    assert np.all(np.asarray(trace["c"]) <= 4)


def test_scenario_arrival_field_rollout():
    from repro.core import rollout as RO
    from repro.core import scenarios as SC
    sc = SC.bursty_traffic(4)
    sc = SC.Scenario(name=sc.name,
                     ecfg=SC.EV.EnvConfig(num_servers=4, max_tasks=8,
                                          queue_window=4, max_steps=64),
                     tcfg=SC.TraceConfig(num_tasks=8, arrival_rate=0.05,
                                         max_servers=4),
                     arrival=sc.arrival)
    m = SC.run_scenario(sc, RO.uniform_policy(sc.ecfg), jax.random.PRNGKey(0),
                        batch=2)
    assert m["episode_return"].shape == (2,)
    assert np.isfinite(m["mean_avg_response"])
