"""Model-level integration: decode path == full forward, vocab padding,
sliding window, MoE aux loss, hybrid/xlstm recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import get_config
from repro.models import lm as LM
from repro.models.zoo import build_model


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "olmoe-1b-7b",
                                  "jamba-v0.1-52b", "xlstm-125m"])
def test_decode_matches_full_forward(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 17), 0, cfg.vocab_size)
    # dropless MoE oracle: slicing-invariant (capacity dispatch drops
    # different assignments for s=17 vs s=16, so it cannot be the oracle)
    full, _ = LM.lm_logits(params, cfg, toks, moe_dropless=True)
    cache = model.make_cache(2, 32, dtype=jnp.float32)
    lg_pre, cache = model.prefill(params, {"tokens": toks[:, :16]}, cache,
                                  compute_dtype=jnp.float32)
    lg_dec, _ = model.decode(params, cache, toks[:, 16:17],
                             compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]), np.asarray(full[:, 15]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]), np.asarray(full[:, 16]),
                               rtol=1e-4, atol=1e-4)


def test_multi_step_decode_consistency():
    """Greedy decode token-by-token == argmax of teacher-forced logits."""
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    cache = model.make_cache(1, 24, dtype=jnp.float32)
    lg, cache = model.prefill(params, {"tokens": toks}, cache,
                              compute_dtype=jnp.float32)
    seq = [int(jnp.argmax(lg[0, -1, : cfg.vocab_size]))]
    for _ in range(4):
        lg, cache = model.decode(params, cache,
                                 jnp.asarray([[seq[-1]]], jnp.int32),
                                 compute_dtype=jnp.float32)
        seq.append(int(jnp.argmax(lg[0, -1, : cfg.vocab_size])))
    # teacher-forced check of the first generated continuation
    ctx = jnp.concatenate([toks, jnp.asarray([seq[:-1]], jnp.int32)], axis=1)
    full, _ = LM.lm_logits(params, cfg, ctx)
    for i, tok in enumerate(seq[1:]):
        assert int(jnp.argmax(full[0, 8 + i, : cfg.vocab_size])) == tok


def test_vocab_padding_masked():
    cfg = dataclasses.replace(get_config("whisper-small").reduced())
    assert cfg.padded_vocab > cfg.vocab_size
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = {"frames": jax.random.normal(jax.random.PRNGKey(1),
                                     (1, cfg.frontend_tokens, cfg.d_model)),
         "tokens": jnp.ones((1, 4), jnp.int32)}
    cache = model.make_cache(1, 8, dtype=jnp.float32)
    logits, _ = model.prefill(params, b, cache, compute_dtype=jnp.float32)
    pad_logits = np.asarray(logits[0, 0, cfg.vocab_size:])
    assert np.all(pad_logits < -1e20)


def test_sliding_window_matches_full_short_seq():
    """window >= seq -> identical logits to full attention."""
    base = get_config("tinyllama-1.1b").reduced()
    cfg_w = dataclasses.replace(base, sliding_window=64)
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, base.vocab_size)
    full, _ = LM.lm_logits(params, base, toks)
    win, _ = LM.lm_logits(params, cfg_w, toks)
    np.testing.assert_allclose(np.asarray(full), np.asarray(win),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_ring_decode():
    """Dense arch with ring cache decodes beyond the window without error
    and differs from the prefix-only result (stale entries overwritten)."""
    base = get_config("qwen2-1.5b").reduced()
    cfg = dataclasses.replace(base, sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.make_cache(1, 8, dtype=jnp.float32)   # ring of size 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab_size)
    lg, cache = model.prefill(params, {"tokens": toks}, cache,
                              compute_dtype=jnp.float32)
    for i in range(6):  # decode past the window
        lg, cache = model.decode(params, cache, jnp.asarray([[i + 1]]),
                                 compute_dtype=jnp.float32)
        assert np.all(np.isfinite(np.asarray(lg, np.float32)))
    assert int(cache["pos"]) == 12


def test_moe_aux_loss_positive_and_bounded():
    cfg = get_config("olmoe-1b-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size),
             "labels": jnp.ones((2, 16), jnp.int32)}
    loss, metrics = model.loss(params, batch)
    aux = float(metrics["aux"])
    assert aux > 0
    assert aux < 1.0   # aux_coef-scaled load-balance loss is small


def test_jamba_period_structure():
    from repro.models.lm import period_spec
    cfg = get_config("jamba-v0.1-52b")
    spec = period_spec(cfg)
    assert len(spec) == 8
    assert spec[7][0] == "attn"                     # 1 attention per 8
    assert all(m == "mamba" for m, _ in spec[:7])   # 7 mamba
    assert sum(1 for _, f in spec if f == "moe") == 4  # MoE every other layer


def test_xlstm_states_update():
    cfg = get_config("xlstm-125m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.make_cache(1, 8, dtype=jnp.float32)
    c0 = np.asarray(jax.tree_util.tree_leaves(cache["periods"])[0]).copy()
    _, cache2 = model.prefill(params, {"tokens": jnp.ones((1, 4), jnp.int32)},
                              cache, compute_dtype=jnp.float32)
    c1 = np.asarray(jax.tree_util.tree_leaves(cache2["periods"])[0])
    assert not np.allclose(c0, c1)
