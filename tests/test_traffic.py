"""Streaming traffic engine: single-window parity with the episodic
batched rollout, task conservation across window seams, QoS telemetry
sanity, and the curriculum training hook."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rollout as RO
from repro.core.env import EnvConfig
from repro.core.workload import TraceConfig, make_trace
from repro.traffic import (LatencyHistogram, PoissonArrivals,
                           ProcessTaskSource, StreamConfig, TraceTaskSource,
                           run_stream)

ECFG = EnvConfig(num_servers=4, max_tasks=32, queue_window=4, max_steps=128)
TC = TraceConfig(num_tasks=32, arrival_rate=0.05, max_servers=4)


def _b1(tree):
    return jax.tree_util.tree_map(lambda x: x[None], tree)


# ------------------------------------------------------- parity
@pytest.mark.parametrize("policy_fn", [RO.uniform_policy, RO.greedy_policy,
                                       RO.fifo_policy],
                         ids=["random", "greedy", "fifo"])
def test_single_window_stream_matches_episodic(policy_fn):
    """A one-window stream over the exact episodic trace reproduces the
    episodic batch_rollout metrics (acceptance: 32-task trace)."""
    trace = make_trace(jax.random.PRNGKey(0), TC)
    policy = policy_fn(ECFG)
    base_key = jax.random.PRNGKey(42)
    # the stream derives window w's keys as split(fold_in(key, w), B)
    ref_keys = jax.random.split(jax.random.fold_in(base_key, 0), 1)
    ref = RO.batch_rollout(ECFG, _b1(trace), policy, {}, ref_keys)

    res = run_stream(ECFG, policy, {}, TraceTaskSource(_b1(trace)), base_key,
                     StreamConfig(num_windows=1, num_streams=1,
                                  max_steps_per_window=ECFG.max_steps))
    s = res.summary
    m = {k: float(np.asarray(v)[0]) for k, v in ref.metrics.items()}
    assert s["tasks_scheduled"] == int(m["num_scheduled"])
    assert s["tasks_completed_in_window"] == int(m["num_done"])
    np.testing.assert_allclose(s["avg_quality"], m["avg_quality"], rtol=1e-6)
    np.testing.assert_allclose(s["latency_mean"], m["avg_response"],
                               rtol=1e-6)
    np.testing.assert_allclose(s["cold_start_rate"], m["reload_rate"],
                               rtol=1e-6)
    np.testing.assert_allclose(s["avg_steps"], m["avg_steps"], rtol=1e-6)
    np.testing.assert_allclose(res.per_window[0]["episode_return_mean"],
                               m["episode_return"], rtol=1e-6)


def test_single_window_stream_covers_all_tasks():
    trace = make_trace(jax.random.PRNGKey(5), TC)
    res = run_stream(ECFG, RO.greedy_policy(ECFG), {},
                     TraceTaskSource(_b1(trace)), jax.random.PRNGKey(7),
                     StreamConfig(num_windows=1, num_streams=1,
                                  max_steps_per_window=ECFG.max_steps))
    assert res.summary["tasks_injected"] == TC.num_tasks
    assert (res.summary["tasks_scheduled"]
            + res.summary["tasks_leftover"]) == TC.num_tasks


# ------------------------------------------------------- conservation
@pytest.mark.parametrize("rate,policy_fn", [(0.05, RO.fifo_policy),
                                            (0.5, RO.uniform_policy)],
                         ids=["light-fifo", "overload-random"])
def test_multi_window_task_conservation(rate, policy_fn):
    """No task is lost or duplicated at window seams: every injected task is
    scheduled, dropped (overload shedding), or still queued at the end."""
    ecfg = EnvConfig(num_servers=4, max_tasks=16, queue_window=4,
                     max_steps=64)
    tc = TraceConfig(num_tasks=16, arrival_rate=rate, max_servers=4)
    src = ProcessTaskSource(PoissonArrivals(rate), tc, jax.random.PRNGKey(0),
                            num_streams=3)
    res = run_stream(ecfg, policy_fn(ecfg), {}, src, jax.random.PRNGKey(1),
                     StreamConfig(num_windows=6, num_streams=3))
    s = res.summary
    assert s["tasks_injected"] > 0
    assert (s["tasks_injected"]
            == s["tasks_scheduled"] + s["tasks_dropped"]
            + s["tasks_leftover"]), s
    # per-window ledger: injected fills exactly the non-carried slots
    for w in res.per_window:
        assert 0 <= w["leftover"] <= 3 * 16
        assert w["injected"] + w["dropped"] >= 0


def test_stream_carries_backlog_not_resets():
    """Under overload the carried state raises later windows' latency —
    seams must not silently reset waiting time or server occupancy."""
    ecfg = EnvConfig(num_servers=4, max_tasks=16, queue_window=4,
                     max_steps=64)
    tc = TraceConfig(num_tasks=16, arrival_rate=0.5, max_servers=4)
    src = ProcessTaskSource(PoissonArrivals(0.5), tc, jax.random.PRNGKey(2),
                            num_streams=2)
    res = run_stream(ecfg, RO.fifo_policy(ecfg), {}, src,
                     jax.random.PRNGKey(3),
                     StreamConfig(num_windows=8, num_streams=2))
    # offered load >> capacity: response times must climb across windows
    assert (res.per_window[-1]["mean_latency"]
            > 2.0 * res.per_window[0]["mean_latency"] > 0.0)


def test_truncated_windows_carry_leftovers():
    """A step budget too small to drain the window forces unscheduled tasks
    across the seam; they must reappear (conservation) and eventually age."""
    ecfg = EnvConfig(num_servers=4, max_tasks=16, queue_window=4,
                     max_steps=64)
    tc = TraceConfig(num_tasks=16, arrival_rate=0.2, max_servers=4)
    src = ProcessTaskSource(PoissonArrivals(0.2), tc, jax.random.PRNGKey(8),
                            num_streams=2)
    res = run_stream(ecfg, RO.uniform_policy(ecfg), {}, src,
                     jax.random.PRNGKey(9),
                     StreamConfig(num_windows=6, num_streams=2,
                                  max_steps_per_window=12))
    s = res.summary
    assert sum(w["leftover"] for w in res.per_window) > 0
    assert (s["tasks_injected"]
            == s["tasks_scheduled"] + s["tasks_dropped"]
            + s["tasks_leftover"])


# ------------------------------------------------------- telemetry
def test_summary_telemetry_sanity():
    ecfg = EnvConfig(num_servers=4, max_tasks=16, queue_window=4,
                     max_steps=64)
    tc = TraceConfig(num_tasks=16, arrival_rate=0.05, max_servers=4)
    src = ProcessTaskSource(PoissonArrivals(0.05), tc, jax.random.PRNGKey(4),
                            num_streams=2)
    s = run_stream(ecfg, RO.greedy_policy(ecfg), {}, src,
                   jax.random.PRNGKey(5),
                   StreamConfig(num_windows=4, num_streams=2)).summary
    assert s["latency_p50"] <= s["latency_p95"] <= s["latency_p99"]
    assert s["latency_p99"] <= s["latency_max"] + 1e-6
    assert 0.0 <= s["qos_violation_rate"] <= 1.0
    assert 0.0 <= s["cold_start_rate"] <= 1.0
    assert s["utilization"] >= 0.0
    assert s["goodput_per_s"] <= s["throughput_per_s"] + 1e-9
    assert s["sim_seconds"] > 0


def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    assert np.isnan(h.percentile(0.5))
    vals = np.geomspace(1.0, 1000.0, 500)
    h.add_values(vals)
    assert h.total == 500
    for q in (0.5, 0.95, 0.99):
        exact = np.percentile(vals, 100 * q)
        est = h.percentile(q)
        assert est == pytest.approx(exact, rel=0.35)   # log-bin resolution
    assert h.percentile(0.5) <= h.percentile(0.99)


def test_trace_source_exhaustion_raises():
    trace = make_trace(jax.random.PRNGKey(0), TC)
    src = TraceTaskSource(_b1(trace))
    src.take(0, 30)
    with pytest.raises(ValueError):
        src.take(0, 3)


# ------------------------------------------------------- policy adapters
def test_policy_adapter_names():
    from repro.traffic.policies import available_policies, make_policy
    for name in ("random", "fifo", "greedy"):
        policy, params = make_policy(name, ECFG)
        assert params == {}
    with pytest.raises(ValueError):
        make_policy("oracle", ECFG)
    assert "eat" in available_policies()


def test_eat_adapter_streams():
    from repro.core.agent import AgentConfig
    from repro.traffic.policies import make_policy
    ecfg = EnvConfig(num_servers=4, max_tasks=8, queue_window=4, max_steps=32)
    tc = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)
    policy, params = make_policy("eat", ecfg,
                                 acfg=AgentConfig(variant="eat-da", T=2))
    src = ProcessTaskSource(PoissonArrivals(0.05), tc, jax.random.PRNGKey(6),
                            num_streams=2)
    s = run_stream(ecfg, policy, params, src, jax.random.PRNGKey(7),
                   StreamConfig(num_windows=2, num_streams=2)).summary
    assert s["tasks_injected"] == (s["tasks_scheduled"] + s["tasks_dropped"]
                                   + s["tasks_leftover"])


# ------------------------------------------------------- curriculum
def test_training_curriculum_cells_share_ecfg():
    from repro.core.scenarios import training_curriculum
    cells = training_curriculum(ECFG)
    assert len(cells) >= 4
    assert all(sc.ecfg == ECFG for sc in cells)
    names = [sc.name for sc in cells]
    assert "coldstart" in names and "bursty" in names


def test_sac_train_with_curriculum_smoke():
    from repro.core import agent as AG
    from repro.core import sac as SAC
    from repro.core.scenarios import training_curriculum
    ecfg = EnvConfig(num_servers=4, max_tasks=6, queue_window=4, max_steps=48)
    cells = training_curriculum(ecfg)
    # warmup high enough that no gradient update compiles (collect-only)
    scfg = SAC.SACConfig(warmup_steps=100_000)
    ts, hist = SAC.train(ecfg, AG.AgentConfig(variant="eat-da", T=2), scfg,
                         None, num_episodes=4, seed=0, log_every=0,
                         num_envs=2, curriculum=cells)
    assert len(hist) == 4
    assert all(np.isfinite(h["episode_return"]) for h in hist)


def test_curriculum_rejects_mismatched_ecfg():
    from repro.core.scenarios import curriculum_picker, training_curriculum
    other = EnvConfig(num_servers=8, max_tasks=6, queue_window=4)
    cells = training_curriculum(other)
    with pytest.raises(ValueError):
        curriculum_picker(ECFG, cells)
