"""Baseline schedulers: greedy, random, meta-heuristics, sequence rollouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core.env import EnvConfig, episode_metrics, reset, step
from repro.core.workload import TraceConfig, make_trace, paper_rate_for

ECFG = EnvConfig(num_servers=4, max_tasks=8, queue_window=4, max_steps=256)
TC = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)


def _trace(seed=0):
    return make_trace(jax.random.PRNGKey(seed), TC)


def test_paper_rates():
    assert paper_rate_for(4) == 0.05
    assert paper_rate_for(8) == 0.1
    assert paper_rate_for(12) == 0.15


def test_trace_properties():
    trace = _trace()
    arr = np.asarray(trace["arr_time"])
    assert np.all(np.diff(arr) > 0)                  # strictly increasing
    assert set(np.asarray(trace["c"])) <= {1, 2, 4}  # clipped to 4 servers


def test_greedy_prefers_quality():
    """Greedy maximises immediate reward -> near-max steps (paper §VI.B.3)."""
    trace = _trace()
    m = BL.evaluate_policy(
        ECFG, trace, lambda k, s, o: BL.greedy_act(ECFG, trace, s),
        jax.random.PRNGKey(0))
    assert m["num_scheduled"] == 8
    assert m["avg_steps"] > 0.8 * ECFG.s_max


def test_greedy_beats_random_return():
    trace = _trace(3)
    rng_key = jax.random.PRNGKey(0)
    g = BL.evaluate_policy(ECFG, trace,
                           lambda k, s, o: BL.greedy_act(ECFG, trace, s),
                           rng_key)
    r = BL.evaluate_policy(ECFG, trace,
                           lambda k, s, o: BL.random_policy(k, ECFG), rng_key)
    assert g["episode_return"] >= r["episode_return"]


def test_rollout_sequence_deterministic():
    trace = _trace()
    seq = jax.random.uniform(jax.random.PRNGKey(1), (64, ECFG.action_dim))
    r1, s1 = BL.rollout_sequence(ECFG, trace, seq)
    r2, s2 = BL.rollout_sequence(ECFG, trace, seq)
    assert float(r1) == float(r2)
    np.testing.assert_array_equal(np.asarray(s1.task_status),
                                  np.asarray(s2.task_status))


def test_genetic_improves_fitness():
    trace = _trace()
    gcfg = BL.GeneticConfig(population=8, generations=3, parents=3, seq_len=48)
    key = jax.random.PRNGKey(0)
    # initial random population fitness
    pop0 = jax.random.uniform(key, (8, 48, ECFG.action_dim))
    fits0 = jax.vmap(lambda s: BL.rollout_sequence(ECFG, trace, s)[0])(pop0)
    _, best = BL.genetic_schedule(key, ECFG, trace, gcfg)
    assert float(best) >= float(jnp.max(fits0)) - 1e-5


def test_harmony_returns_valid_sequence():
    trace = _trace()
    hcfg = BL.HarmonyConfig(memory_size=6, improvisations=4, seq_len=32)
    seq, fit = BL.harmony_schedule(jax.random.PRNGKey(0), ECFG, trace, hcfg)
    assert seq.shape == (32, ECFG.action_dim)
    assert np.all((np.asarray(seq) >= 0) & (np.asarray(seq) <= 1))
    assert np.isfinite(float(fit))
