"""Deterministic fault injection: cross-implementation parity, ledger
conservation, retry/backoff semantics, and serving-layer fault tolerance.

The contract under test (docs/faults.md):

* faults OFF (``faults=None`` or ``FaultSpec.none()``) is *bitwise-identical*
  to the pre-fault code on every backend — the fault branch keys off the
  presence of the ``f_*`` trace columns, so a fault-free trace compiles the
  exact pre-existing program;
* faults ON produce the *same* results on the legacy compositional step,
  the jnp reference, and the Pallas kernel (and hence on the
  reference/fused/sharded backends);
* the streaming ledger stays conserved under crashes and retries:
  ``injected == scheduled + dropped + failed_pending_retry + leftover``;
* the serving executor retries transient faults, degrades the last attempt,
  and its fault state fully resets between Simulator runs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.workload import TraceConfig, make_trace
from repro.faults import (FAULT_COLS, ExecFaultInjector, FaultSpec,
                          FaultTimeline, fault_horizon, faults_active,
                          retry_backoff)
from repro.kernels.env_step import ops as EK
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.stream import ProcessTaskSource, StreamConfig, run_stream

CHAOS = FaultSpec(seed=1, mtbf=150.0, mttr=40.0, straggler_prob=0.2,
                  straggler_factor=3.0, max_retries=3, backoff_base=2.0,
                  backoff_cap=30.0, retry_deadline=1000.0)


def _cfg(E, num_models=1):
    ms = tuple([1.0, 0.5, 2.0][:num_models]) if num_models > 1 else ()
    return EV.EnvConfig(num_servers=E, max_tasks=2 * E + 4, queue_window=4,
                        num_models=num_models, model_scale=ms)


def _tc(ecfg):
    return TraceConfig(num_tasks=ecfg.max_tasks, arrival_rate=0.2,
                       max_servers=ecfg.num_servers,
                       num_models=ecfg.num_models)


def _fault_trace(ecfg, spec, seed=0, stream=0):
    """One episodic trace with window-0 fault columns attached."""
    trace = dict(make_trace(jax.random.PRNGKey(seed), _tc(ecfg)))
    tl = FaultTimeline(spec, ecfg.num_servers, stream + 1)
    fa = tl.window_arrays(0, np.zeros(stream + 1, np.float64),
                          fault_horizon(ecfg.time_limit, spec))
    trace.update({k: jnp.asarray(np.asarray(v)[stream]) for k, v in
                  fa.items()})
    return trace


def _b1(tree):
    return jax.tree_util.tree_map(lambda x: x[None], tree)


def _assert_tree_equal(a, b, ctx):
    fa = a._asdict() if hasattr(a, "_asdict") else a
    fb = b._asdict() if hasattr(b, "_asdict") else b
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=f"{ctx}: field {k}")


# ---------------------------------------------------------------- spec
def test_fault_spec_activity():
    assert not faults_active(None)
    assert not faults_active(FaultSpec.none())
    assert not FaultSpec.none().active
    assert FaultSpec(mtbf=100.0).active
    assert FaultSpec(straggler_prob=0.1).active
    assert FaultSpec(exec_error_prob=0.1).active
    assert FaultSpec(exec_timeout_s=5.0).active
    assert FaultSpec.chaos().active
    # hashable: it rides on the (hashable) ExecSpec into program caches
    hash(FaultSpec.chaos())


def test_retry_backoff_caps():
    spec = FaultSpec(backoff_base=2.0, backoff_cap=30.0)
    assert retry_backoff(spec, 1) == 2.0
    assert retry_backoff(spec, 2) == 4.0
    assert retry_backoff(spec, 4) == 16.0
    assert retry_backoff(spec, 10) == 30.0       # capped


# ---------------------------------------------------------------- timeline
def test_fault_timeline_deterministic_and_pruned():
    spec = FaultSpec(seed=7, mtbf=50.0, mttr=10.0, straggler_prob=0.3)
    a = FaultTimeline(spec, 4, 2)
    b = FaultTimeline(spec, 4, 2)
    t0 = np.zeros(2, np.float64)
    fa = a.window_arrays(0, t0, 400.0)
    fb = b.window_arrays(0, t0, 400.0)
    for k in FAULT_COLS:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=k)
    # advancing the epoch prunes fully-past intervals: every kept interval
    # must still overlap [t0, inf) after rebasing
    t1 = np.full(2, 200.0)
    fc = a.window_arrays(1, t1, 400.0)
    de = np.asarray(fc["f_down_end"])
    fin = np.isfinite(de)
    assert np.all(de[fin] > 0.0), "fully-past down intervals leaked"
    # a different seed moves the outage schedule
    c = FaultTimeline(dataclasses.replace(spec, seed=8), 4, 2)
    fc2 = c.window_arrays(0, t0, 400.0)
    assert not all(np.array_equal(np.asarray(fa[k]), np.asarray(fc2[k]))
                   for k in FAULT_COLS)


# ---------------------------------------------------------------- per-step
@pytest.mark.parametrize("E,num_models", [(4, 1), (8, 3)])
def test_fault_step_three_way_parity(E, num_models):
    """Legacy compositional step == jnp ref == Pallas kernel, bitwise,
    on randomized states under active fault columns."""
    ecfg = _cfg(E, num_models)
    spec = FaultSpec(seed=E, mtbf=60.0, mttr=15.0, straggler_prob=0.4,
                     straggler_factor=3.0)
    rng = np.random.default_rng(E * 10 + num_models)
    saw_fail = False
    for trial in range(8):
        trace = _fault_trace(ecfg, spec, seed=trial)
        state = EV.reset(ecfg)._replace(
            time=jnp.float32(rng.uniform(0.0, 60.0)))
        statics = EV.decision_statics(ecfg, trace)
        for col in FAULT_COLS:
            assert col in statics
        qv = EV.visible_queue(ecfg, trace, state)
        a = jnp.asarray(rng.uniform(size=ecfg.action_dim).astype(np.float32))
        ns_l, obs_l, r_l, d_l, info = EV.step(ecfg, trace, state, a)
        saw_fail |= bool(np.asarray(info.get("failed", False)))
        q2_l = EV.visible_queue(ecfg, trace, ns_l)
        for impl in ("ref", "pallas"):
            ns_f, q_f, obs_f, r_f, d_f = EK.env_step_fused(
                ecfg, _b1(statics), _b1(state), a[None], _b1(qv), impl=impl)
            ctx = f"E={E} nm={num_models} trial={trial} impl={impl}"
            _assert_tree_equal(ns_l, jax.tree_util.tree_map(
                lambda x: x[0], ns_f), ctx)
            _assert_tree_equal(q2_l, jax.tree_util.tree_map(
                lambda x: x[0], q_f), ctx + " queue")
            np.testing.assert_array_equal(np.asarray(obs_l),
                                          np.asarray(obs_f[0]), ctx)
            assert float(r_l) == float(r_f[0]), ctx
            assert bool(d_l) == bool(d_f[0]), ctx


def test_fault_rollout_backend_parity():
    """reference == fused(ref) == fused(pallas) episodic rollouts, bitwise,
    under active faults — and at least one task actually fails."""
    ecfg = EV.EnvConfig(num_servers=4, max_tasks=8, queue_window=4,
                        max_steps=96)
    spec = FaultSpec(seed=3, mtbf=60.0, mttr=20.0, straggler_prob=0.3)
    tc = TraceConfig(num_tasks=8, arrival_rate=0.05, max_servers=4)
    B = 4
    traces = jax.vmap(lambda k: make_trace(k, tc))(
        jax.random.split(jax.random.PRNGKey(3), B))
    tl = FaultTimeline(spec, 4, B)
    traces = dict(traces)
    traces.update(tl.window_arrays(0, np.zeros(B, np.float64),
                                   fault_horizon(ecfg.time_limit, spec)))
    keys = jax.random.split(jax.random.PRNGKey(4), B)
    pol = RO.greedy_policy(ecfg)
    a = RO.batch_rollout(ecfg, traces, pol, {}, keys, fused=False)
    assert "num_failed" in a.metrics
    assert float(np.sum(np.asarray(a.metrics["num_failed"]))) > 0
    for impl in ("ref", "pallas"):
        b = RO.batch_rollout(ecfg, traces, pol, {}, keys, fused=True,
                             fused_impl=impl)
        for k in a.metrics:
            np.testing.assert_array_equal(np.asarray(a.metrics[k]),
                                          np.asarray(b.metrics[k]),
                                          err_msg=f"{impl} metric {k}")
        _assert_tree_equal(a.final_state, b.final_state, impl)


def test_down_server_blocks_selection_and_obs():
    """While a server is inside a down interval it is masked out of the
    availability observation and cannot join a gang."""
    ecfg = _cfg(4)
    trace = dict(make_trace(jax.random.PRNGKey(0), _tc(ecfg)))
    E, F = 4, 2
    ds = np.full((E, F), np.inf, np.float32)
    de = np.full((E, F), np.inf, np.float32)
    ds[:, 0], de[:, 0] = 0.0, 1e6          # every server down, forever
    trace["f_down_start"] = jnp.asarray(ds)
    trace["f_down_end"] = jnp.asarray(de)
    trace["f_slow"] = jnp.ones((E,), jnp.float32)
    trace["f_cold"] = jnp.zeros((1,), jnp.float32)
    state = EV.reset(ecfg)
    obs = EV.observe(ecfg, trace, state)
    # availability block of the observation must read all-down
    ns, _, r, _, info = EV.step(
        ecfg, trace, state,
        jnp.asarray([0.0, 0.5, 1.0, 0.0, 0.0, 0.0], jnp.float32))
    assert not bool(info["scheduled"])
    assert float(np.asarray(obs).sum()) < float(
        np.asarray(EV.observe(ecfg, {k: v for k, v in trace.items()
                                     if not k.startswith("f_")},
                              state)).sum())


# ---------------------------------------------------------------- stream
def _stream_run(faults, seed=0, windows=6, streams=2, K=16, E=8):
    ecfg = EV.EnvConfig(num_servers=E, queue_window=4, max_tasks=K,
                        time_limit=600.0, max_steps=256)
    tc = TraceConfig(num_tasks=K)
    key = jax.random.PRNGKey(seed)
    src = ProcessTaskSource(PoissonArrivals(rate=0.2), tc, key,
                            num_streams=streams)
    scfg = StreamConfig(num_windows=windows, num_streams=streams,
                        resp_sla=120.0, faults=faults)
    return run_stream(ecfg, RO.greedy_policy(ecfg), None, src, key, scfg)


def test_stream_faults_none_bitwise_identical():
    base = _stream_run(None)
    none = _stream_run(FaultSpec.none())
    assert set(base.summary) == set(none.summary)
    for k in base.summary:
        assert base.summary[k] == none.summary[k], (
            k, base.summary[k], none.summary[k])
    assert none.fault_counters == {}


def test_stream_fault_ledger_conserved_and_deterministic():
    a = _stream_run(CHAOS)
    s = a.summary
    assert s["tasks_injected"] == (
        s["tasks_scheduled"] + s["tasks_dropped"]
        + s["tasks_failed_pending_retry"] + s["tasks_leftover"]), s
    assert s["tasks_dropped"] == (s["tasks_dropped_shed"]
                                  + s["tasks_dropped_retry_exhausted"])
    assert s["tasks_failed"] > 0, "chaos spec produced no crashes"
    assert s["tasks_retried"] > 0
    b = _stream_run(CHAOS)
    for k in s:
        assert s[k] == b.summary[k], (k, s[k], b.summary[k])
    assert a.fault_counters == b.fault_counters
    assert a.fault_counters["tasks_pending_retry"] == \
        s["tasks_failed_pending_retry"]


def test_stream_fault_records_in_per_window():
    res = _stream_run(CHAOS, windows=4)
    for rec in res.per_window:
        for key in ("failed", "retried", "failed_dropped", "pending_retry"):
            assert key in rec, key
        assert rec["failed"] >= 0


# ---------------------------------------------------------------- serving
def test_exec_fault_injector_deterministic():
    spec = FaultSpec(seed=5, exec_error_prob=0.5)
    a, b = ExecFaultInjector(spec), ExecFaultInjector(spec)

    def draw(inj, n=64):
        outs = []
        for _ in range(n):
            try:
                inj.maybe_fail("generate")
                outs.append(0)
            except Exception:
                outs.append(1)
        return outs

    seq = draw(a)
    assert draw(b) == seq
    assert 0 < sum(seq) < 64
    a.reset()
    assert draw(a) == seq                      # reset restores the stream
    assert a.counters()["exec_errors_injected"] == sum(seq)
    off = ExecFaultInjector(None)
    assert not off.enabled
    off.maybe_fail("generate")                 # no-op, never raises


def test_server_pool_fault_ledger_resets():
    from repro.serving.pool import ServerPool
    pool = ServerPool(4)
    assert set(pool.counters()) == {"model_loads", "model_reuses"}
    pool.exec_failures, pool.exec_retries = 3, 2
    pool.exec_degraded, pool.exec_gave_up, pool.crashed_tasks = 1, 1, 5
    assert pool.fault_counters()["exec_failures"] == 3
    pool.reset()
    assert all(v == 0 for v in pool.fault_counters().values())
    assert all(v == 0 for v in pool.counters().values())


def test_serving_fault_state_isolated_between_runs():
    """Satellite regression: a Simulator sweep must not leak fault/backoff
    state between runs — same key, same spec => identical fault ledgers."""
    from repro.api.simulator import Simulator
    from repro.api.specs import ExecSpec, PolicySpec, WorkloadSpec
    from repro.core import scenarios as SC
    sc = SC.poisson_scenario(num_servers=4, rate=2.0)
    wl = WorkloadSpec.streaming(sc, streams=1, num_windows=2, window_tasks=8)
    spec = FaultSpec(seed=3, mtbf=60.0, mttr=15.0, exec_error_prob=0.6,
                     exec_max_attempts=2, max_retries=2)
    sim = Simulator(wl, ExecSpec(backend="serving", serving_execute=True,
                                 faults=spec))
    key = jax.random.PRNGKey(0)
    r1 = sim.run(PolicySpec("greedy"), key)
    fc1 = dict(sim._rollout.fault_counters())
    r2 = sim.run(PolicySpec("greedy"), key)
    fc2 = dict(sim._rollout.fault_counters())
    assert fc1 == fc2, (fc1, fc2)    # reset() cleared pool + injector state
    assert r1.summary["tasks_injected"] == r2.summary["tasks_injected"]
    # executor warm memos may persist (compiled programs stay valid) but the
    # failure/backoff ledger must start from zero each run
    sim._rollout.reset()
    assert all(v == 0 for v in sim._rollout.fault_counters().values())
