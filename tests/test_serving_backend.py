"""The serving execution backend: `ExecSpec(backend="serving")` drives the
real cluster through the unified `repro.api` / `traffic.stream` /
`training.stream_train` seams.

Parity contract: in virtual time (`serving_wall_clock=False`) the serving
backend's decision process — metrics, final carry, collected transitions —
is bitwise-identical to the fused simulator on the same (workload, policy,
key); real model execution rides along without perturbing the MDP. Wall-
clock mode replaces the Table-VI latencies with measured seconds.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro import api
from repro.core import agent as AG
from repro.core import env as EV
from repro.core import sac as SAC
from repro.core.scenarios import Scenario
from repro.core.workload import TraceConfig

ECFG = EV.EnvConfig(num_servers=4, max_tasks=8)
TCFG = TraceConfig(num_tasks=8, arrival_rate=2.0, max_servers=4)
CELL = Scenario(name="serve-test-cell", ecfg=ECFG, tcfg=TCFG)
ACFG = AG.AgentConfig(variant="eat-da", T=2)

MIRROR = api.ExecSpec(backend="serving", serving_execute=False)
REAL = api.ExecSpec(backend="serving", serving_archs=("tinyllama-1.1b",),
                    serving_prompt_len=8, serving_max_new_tokens=8)


def _wl(**kw):
    kw.setdefault("streams", 1)
    kw.setdefault("num_windows", 2)
    kw.setdefault("window_tasks", 8)
    kw.setdefault("max_steps_per_window", 16)
    return api.WorkloadSpec.streaming(CELL, **kw)


def _run(wl, spec, policy, key):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", api.UntrainedPolicyWarning)
        return api.Simulator(wl, spec).run(policy, key)


# ------------------------------------------------------------ validation
def test_serving_backend_registered():
    assert "serving" in api.BACKENDS
    assert api.ExecSpec(backend="serving").backend == "serving"


def test_serving_rejects_multi_stream_workloads():
    with pytest.raises(ValueError, match="ONE physical cluster"):
        api.Simulator(_wl(streams=2), MIRROR)
    from repro.serving.runner import ServingStreamRunner
    from repro.traffic.stream import StreamConfig
    with pytest.raises(ValueError, match="num_streams=1"):
        ServingStreamRunner(ECFG, None, {}, None, jax.random.PRNGKey(0),
                            StreamConfig(num_streams=2))


def test_serving_runner_requires_serving_rollout_fn():
    from repro.api.backends import rollout_fn_for
    from repro.serving.runner import ServingStreamRunner
    from repro.traffic.stream import StreamConfig
    with pytest.raises(ValueError, match="serving rollout fn"):
        ServingStreamRunner(ECFG, None, {}, None, jax.random.PRNGKey(0),
                            StreamConfig(num_streams=1),
                            rollout_fn=rollout_fn_for(api.ExecSpec()))


# ------------------------------------------------------------ parity
@pytest.mark.parametrize("policy", ["greedy", "fifo", "random"])
def test_virtual_time_parity_with_fused_backend(policy):
    """Multi-window streaming summary + final carry, serving vs fused."""
    key = jax.random.PRNGKey(0)
    rf = _run(_wl(), api.ExecSpec(backend="fused"), policy, key)
    rs = _run(_wl(), MIRROR, policy, key)
    skip = {"model_loads", "model_reuses", "tasks_executed", "wall_clock"}
    for k, a in rf.summary.items():
        b = rs.summary[k]
        if k in skip:
            continue
        if isinstance(a, float):
            np.testing.assert_equal(b, a, err_msg=k)
        else:
            assert a == b, (k, a, b)
    fc_f = jax.tree_util.tree_map(np.asarray, rf.raw.final_carry)
    fc_s = jax.tree_util.tree_map(np.asarray, rs.raw.final_carry)
    jax.tree_util.tree_map(np.testing.assert_array_equal, fc_f, fc_s)


def test_collected_transitions_bitwise_match_fused():
    """collect=True: serving-collected windows flatten to the exact replay
    layout and bitwise-match the fused backend's collection."""
    key = jax.random.PRNGKey(3)
    wl = _wl(collect=True)
    rf = _run(wl, api.ExecSpec(backend="fused"), "eat", key)
    rs = _run(wl, MIRROR, "eat", key)
    assert len(rf.raw.transitions) == len(rs.raw.transitions) == 2
    for tf, ts in zip(rf.raw.transitions, rs.raw.transitions):
        ff = SAC.flatten_valid_transitions(tf)
        fs = SAC.flatten_valid_transitions(ts)
        for name, a, b in zip(("obs", "action", "reward", "next_obs",
                               "done"), ff, fs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


def test_pool_economics_accrue_in_mirror_mode():
    r = _run(_wl(num_windows=3), MIRROR, "greedy", jax.random.PRNGKey(0))
    assert r.summary["tasks_executed"] == r.summary["tasks_scheduled"] > 0
    assert r.summary["model_loads"] > 0
    assert r.summary["wall_clock"] is False


def test_simulator_resets_pool_between_runs():
    sim = api.Simulator(_wl(), MIRROR)
    r1 = _run(_wl(), MIRROR, "greedy", jax.random.PRNGKey(0))
    ra = sim.run("greedy", jax.random.PRNGKey(0))
    rb = sim.run("greedy", jax.random.PRNGKey(0))
    assert ra.summary["model_loads"] == rb.summary["model_loads"] \
        == r1.summary["model_loads"]


# ------------------------------------------------------------ real execution
def test_real_execution_stream():
    """A multi-window Poisson stream on reduced real models end to end:
    every scheduled task runs actual prefill+decode, QoS rows come back in
    the shared StreamAggregator schema, checkpoint-restored policies work."""
    r = _run(_wl(), REAL, "greedy", jax.random.PRNGKey(0))
    assert r.summary["tasks_executed"] == r.summary["tasks_scheduled"] > 0
    assert r.summary["model_loads"] > 0
    for k in ("latency_p50", "latency_p95", "latency_p99",
              "qos_violation_rate", "goodput_per_s", "cold_start_rate",
              "utilization"):
        assert k in r.summary, k
    # virtual time: QoS numbers identical to the pure simulator's
    rf = _run(_wl(), api.ExecSpec(backend="fused"), "greedy",
              jax.random.PRNGKey(0))
    assert r.summary["latency_p50"] == rf.summary["latency_p50"]


def test_real_execution_with_checkpoint_restored_policy(tmp_path):
    from repro.common.checkpoint import save_checkpoint
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", api.UntrainedPolicyWarning)
        fresh = api.resolve(
            api.PolicySpec("eat", options={"acfg": ACFG}), ECFG)
    save_checkpoint(str(tmp_path), 1, fresh.params)
    spec = api.PolicySpec("eat", checkpoint=str(tmp_path),
                          options={"acfg": ACFG})
    r = _run(_wl(num_windows=1), REAL, spec, jax.random.PRNGKey(1))
    assert r.trained is True
    assert r.summary["tasks_executed"] >= 0   # stream completed


def test_wall_clock_mode_measures_latency():
    spec = dataclasses.replace(REAL, serving_wall_clock=True)
    r = _run(_wl(num_windows=1), spec, "greedy", jax.random.PRNGKey(0))
    assert r.summary["wall_clock"] is True
    assert r.summary["tasks_executed"] > 0
    assert r.summary["measured_busy_mean_s"] > 0
    # measured CPU latencies are far from the Table-VI edge-GPU model
    rv = _run(_wl(num_windows=1), REAL, "greedy", jax.random.PRNGKey(0))
    assert r.summary["latency_mean"] != rv.summary["latency_mean"]


# ------------------------------------------------------------ training
def test_train_stream_sac_on_serving_backend():
    """>=1 fine-tune round on serving-collected transitions, with the
    collected batches bitwise-identical to the fused backend's."""
    from repro.training import stream_train as ST
    scfg = SAC.SACConfig(warmup_steps=4, batch_size=8)
    stcfg = ST.StreamTrainConfig(rounds=2, streams=1,
                                 max_steps_per_window=12,
                                 max_updates_per_round=2)
    flats = {}

    def train(spec):
        seen = []
        res = ST.train_stream_sac(
            ECFG, ACFG, scfg, stcfg, scenario=CELL, seed=0, exec_spec=spec,
            transition_hook=lambda r, flat: seen.append(
                [np.asarray(x) for x in flat]))
        return res, seen

    res_s, flats["serving"] = train(MIRROR)
    res_f, flats["fused"] = train(api.ExecSpec(backend="fused"))
    assert len(res_s.history) == 2
    assert res_s.history[0]["warmup"] is True      # round 0 fills the buffer
    assert res_s.history[1]["warmup"] is False     # round 1 fine-tunes actor
    assert res_s.history[1]["updates"] > 0
    for fs, ff in zip(flats["serving"], flats["fused"]):
        for name, a, b in zip(("obs", "action", "reward", "next_obs",
                               "done"), fs, ff):
            np.testing.assert_array_equal(a, b, err_msg=name)
