"""Training substrate: loss decreases on learnable synthetic data."""
import dataclasses

import pytest

from repro.common.config import get_config
from repro.training.data import DataConfig
from repro.training.train_loop import TrainConfig, train_lm


@pytest.mark.slow
def test_lm_loss_decreases():
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b").reduced(),
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256)
    tcfg = TrainConfig(lr=1e-3, warmup=5, total_steps=40, log_every=10)
    dcfg = DataConfig(vocab_size=256, seq_len=64, batch_size=4, branching=2)
    _, history = train_lm(cfg, tcfg, dcfg, verbose=False)
    first, last = history[0]["loss"], history[-1]["loss"]
    assert last < first - 0.5, (first, last)


def test_checkpointing_during_training(tmp_path):
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=128)
    tcfg = TrainConfig(lr=1e-3, warmup=2, total_steps=6, log_every=5,
                       ckpt_dir=str(tmp_path / "ck"))
    dcfg = DataConfig(vocab_size=128, seq_len=32, batch_size=2)
    params, _ = train_lm(cfg, tcfg, dcfg, verbose=False)
    from repro.common.checkpoint import latest_step, restore_checkpoint
    assert latest_step(str(tmp_path / "ck")) == 6
    restored = restore_checkpoint(str(tmp_path / "ck"), params)
    import jax, numpy as np
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
