"""Serve the stream: a Poisson arrival stream scheduled onto the REAL
serving cluster through the unified facade.

    PYTHONPATH=src python examples/serve_stream.py [--policy eat|greedy|fifo|random]
        [--servers 4] [--windows 3] [--window-tasks 8] [--rate 2.0]
        [--archs tinyllama-1.1b] [--wall-clock] [--checkpoint DIR]

One spec triple drives everything:

    Simulator(WorkloadSpec.streaming(cell, streams=1, ...),
              ExecSpec(backend="serving", ...)).run(PolicySpec(name), key)

Every scheduling decision advances the shared env decision step on a mirror
of the physical pool; every scheduled task REALLY loads weights (or reuses
a warm gang) and runs patch-parallel prefill + greedy decode on reduced-
config zoo models. Default virtual time keeps Table-VI latency economics
(the decision process is bitwise-identical to the fused simulator —
`tests/test_serving_backend.py` pins this); `--wall-clock` feeds measured
execution seconds into latencies, rewards, and observations instead.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import api
from repro.core import agent as AG
from repro.core import env as EV
from repro.core.scenarios import Scenario
from repro.core.workload import TraceConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="greedy",
                    choices=["eat", "greedy", "fifo", "random"])
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--windows", type=int, default=3)
    ap.add_argument("--window-tasks", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--archs", default="tinyllama-1.1b")
    ap.add_argument("--wall-clock", action="store_true")
    ap.add_argument("--checkpoint", default=None,
                    help="restore EAT actor weights from a saved run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ecfg = EV.EnvConfig(num_servers=args.servers,
                        max_tasks=args.window_tasks)
    cell = Scenario(
        name=f"poisson-{args.servers}srv",
        ecfg=ecfg,
        tcfg=TraceConfig(num_tasks=args.window_tasks,
                         arrival_rate=args.rate,
                         max_servers=args.servers))
    wl = api.WorkloadSpec.streaming(
        cell, streams=1, num_windows=args.windows,
        window_tasks=args.window_tasks,
        max_steps_per_window=4 * args.window_tasks)
    spec = api.ExecSpec(backend="serving",
                        serving_archs=tuple(args.archs.split(",")),
                        serving_wall_clock=args.wall_clock,
                        serving_prompt_len=8, serving_max_new_tokens=8,
                        serving_seed=args.seed)
    options = ({"acfg": AG.AgentConfig(variant="eat-da", T=2)}
               if args.policy == "eat" else {})
    pol = api.PolicySpec(args.policy, checkpoint=args.checkpoint,
                         options=options)

    res = api.Simulator(wl, spec).run(pol, jax.random.PRNGKey(args.seed))
    s = res.summary
    mode = "wall-clock" if s["wall_clock"] else "virtual (Table-VI)"
    print(f"\npolicy={res.policy} trained={res.trained} time={mode}")
    print(f"windows={args.windows} injected={s['tasks_injected']} "
          f"scheduled={s['tasks_scheduled']} executed={s['tasks_executed']} "
          f"dropped={s['tasks_dropped']}")
    print(f"latency p50/p95/p99 = {s['latency_p50']:.2f}/"
          f"{s['latency_p95']:.2f}/{s['latency_p99']:.2f}s  "
          f"violation={s['qos_violation_rate']:.3f}  "
          f"goodput={s['goodput_per_s']:.3f}/s")
    print(f"model loads={s['model_loads']} reuses={s['model_reuses']} "
          f"cold-start rate={s['cold_start_rate']:.2f} "
          f"utilization={s['utilization']:.2f}")
    if args.wall_clock and "measured_busy_mean_s" in s:
        print(f"measured busy mean = {s['measured_busy_mean_s']:.3f}s/task")


if __name__ == "__main__":
    main()
