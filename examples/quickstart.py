"""Quickstart — the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Build an assigned architecture (reduced smoke variant), run one training
   step and a short prefill+decode.
2. Run the EAT scheduler (attention encoder + diffusion policy) for a few
   decisions on the simulated edge cluster.
"""
import jax
import jax.numpy as jnp

from repro.common.config import get_config
from repro.models.zoo import build_model
from repro.core import agent as AG
from repro.core import env as EV
from repro.core import sac as SAC
from repro.core.workload import TraceConfig, make_trace

# ---- 1. a schedulable AIGC service (one of the 10 assigned archs) -------
cfg = get_config("qwen2-1.5b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

batch = {"tokens": jnp.ones((2, 32), jnp.int32),
         "labels": jnp.ones((2, 32), jnp.int32)}
loss, metrics = jax.jit(model.loss)(params, batch)
print(f"[train] {cfg.name}: loss={float(loss):.3f} "
      f"(vocab {cfg.vocab_size}, {cfg.num_layers} layers)")

cache = model.make_cache(1, 64, jnp.float32)
logits, cache = model.prefill(params, {"tokens": jnp.ones((1, 8), jnp.int32)},
                              cache, compute_dtype=jnp.float32)
tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], -1).astype(jnp.int32)
for _ in range(4):
    logits, cache = model.decode(params, cache, tok,
                                 compute_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], -1).astype(jnp.int32)
print(f"[serve] prefill 8 tokens + decode 4: last token id {int(tok[0, 0])}")

# ---- 2. the paper's contribution: EAT scheduling on an edge cluster -----
ecfg = EV.EnvConfig(num_servers=4)
acfg = AG.AgentConfig(variant="eat")          # attention + diffusion policy
trace = make_trace(jax.random.PRNGKey(1), TraceConfig(max_servers=4,
                                                      arrival_rate=0.05))
actor = AG.init_actor(jax.random.PRNGKey(2), ecfg, acfg)

state = EV.reset(ecfg)
obs = EV.observe(ecfg, trace, state)
key = jax.random.PRNGKey(3)
for step in range(8):
    key, k = jax.random.split(key)
    a = SAC.policy_act(actor, obs, k, ecfg=ecfg, acfg=acfg)
    state, obs, r, done, info = EV.step(ecfg, trace, state,
                                        AG.to_env_action(a))
    print(f"[eat ] t={float(state.time):7.1f}s "
          f"scheduled={bool(info['scheduled'])} reward={float(r):.2f}")
    if bool(done):
        break
m = EV.episode_metrics(ecfg, trace, state)
print(f"[eat ] scheduled {int(m['num_scheduled'])} tasks, "
      f"avg quality {float(m['avg_quality']):.3f}")
