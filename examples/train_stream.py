"""Train in the stream: SAC/PPO from windowed streaming rollouts.

    PYTHONPATH=src python examples/train_stream.py                 # SAC
    PYTHONPATH=src python examples/train_stream.py --algo ppo
    PYTHONPATH=src python examples/train_stream.py \
        --curriculum --rate-scale 2.0 --backend sharded \
        --rounds 64 --streams 8

Each round advances one (or more) windows of an open-loop arrival stream —
backlog, clock, and server occupancy carried across the seam — collects the
transitions, and runs gradient updates, so the agent trains on the backlog
distribution it induces rather than on fresh episode resets
(`repro.training.stream_train`). `--rate-scale > 1` trains under sustained
overload; `--curriculum` cycles the arrival-process cells (rate sweep,
cold-start-heavy, MMPP bursts, flash crowds) through one continuous stream
clock. `--backend sharded` splits the stream axis over the local device
mesh (bitwise-identical collection; on CPU force devices with
XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
from __future__ import annotations

import argparse
import json

from repro.api import BACKENDS, ExecSpec
from repro.core import agent as AG
from repro.core import ppo as PPO
from repro.core import sac as SAC
from repro.core.env import EnvConfig
from repro.core.scenarios import training_curriculum
from repro.training import stream_train as ST


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="sac", choices=("sac", "ppo"))
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--window-tasks", type=int, default=32,
                    help="tasks per window per stream (= env max_tasks)")
    ap.add_argument("--streams", type=int, default=4,
                    help="parallel streams (the sharded batch axis)")
    ap.add_argument("--rounds", type=int, default=32)
    ap.add_argument("--windows-per-round", type=int, default=1)
    ap.add_argument("--rate-scale", type=float, default=1.5,
                    help="arrival-intensity multiplier (>1 = sustained "
                         "overload, the streaming regime)")
    ap.add_argument("--curriculum", action="store_true",
                    help="cycle arrival cells (rates/coldstart/bursty/"
                         "flashcrowd) instead of one Poisson cell")
    ap.add_argument("--variant", default="eat",
                    help="SAC actor variant: eat|eat-a|eat-d|eat-da")
    ap.add_argument("--diffusion-steps", type=int, default=10)
    ap.add_argument("--warmup-steps", type=int, default=256)
    ap.add_argument("--max-updates-per-round", type=int, default=-1,
                    help="cap gradient updates per round (0 = collect-only, "
                         "-1 = no cap; matches StreamTrainConfig semantics)")
    ap.add_argument("--backend", default="fused", choices=BACKENDS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the per-round history rows as JSON")
    args = ap.parse_args()

    ecfg = EnvConfig(num_servers=args.servers, max_tasks=args.window_tasks)
    stcfg = ST.StreamTrainConfig(
        rounds=args.rounds, windows_per_round=args.windows_per_round,
        streams=args.streams, rate_scale=args.rate_scale,
        max_updates_per_round=(None if args.max_updates_per_round < 0
                               else args.max_updates_per_round),
        log_every=1)
    curriculum = training_curriculum(ecfg) if args.curriculum else None
    exec_spec = ExecSpec(backend=args.backend)

    if args.algo == "sac":
        acfg = AG.AgentConfig(variant=args.variant, T=args.diffusion_steps)
        scfg = SAC.SACConfig(warmup_steps=args.warmup_steps)
        res = ST.train_stream_sac(ecfg, acfg, scfg, stcfg,
                                  curriculum=curriculum, seed=args.seed,
                                  exec_spec=exec_spec)
    else:
        res = ST.train_stream_ppo(ecfg, PPO.PPOConfig(), stcfg,
                                  curriculum=curriculum, seed=args.seed,
                                  exec_spec=exec_spec)

    s = res.stream.summary
    print(f"\n=== run summary ({args.algo}, backend={args.backend}) ===")
    for k in ("tasks_injected", "tasks_scheduled", "tasks_dropped",
              "latency_p95", "latency_p99", "qos_violation_rate",
              "drop_rate", "goodput_per_s", "utilization"):
        print(f"  {k:24s} {s[k]}")
    if res.history:
        first, last = res.history[0], res.history[-1]
        print(f"  return round0 -> final   {first['episode_return_mean']:.2f} "
              f"-> {last['episode_return_mean']:.2f}")
        print(f"  violation round0 -> final {first['qos_violation_rate']:.3f} "
              f"-> {last['qos_violation_rate']:.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": res.history, "summary": s}, f, indent=1)
        print(f"history -> {args.out}")


if __name__ == "__main__":
    main()
