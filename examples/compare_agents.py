"""Fig. 5 — training curves of the DRL schedulers (EAT vs ablations vs PPO).

    PYTHONPATH=src python examples/compare_agents.py --episodes 15 \
        --servers 8 --variants eat,eat-da,ppo

Trains each variant on the 8-server simulated cluster at the paper's
arrival rate (experience collected from ``--num-envs`` parallel envs via the
batched rollout engine), dumps reward / episode-length curves to
``artifacts/training_curves.json`` (paper Fig. 5a/5c: EAT trends above the
ablations; Fig. 5b: diffusion-policy variants converge to shorter episodes),
then evaluates every trained policy — plus Random/Greedy — on ``--eval-batch``
held-out traces in one jitted program per policy.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.api import BACKENDS, ExecSpec, PolicySpec, evaluate_batch
from repro.core import agent as AG
from repro.core import ppo as PPO
from repro.core import sac as SAC
from repro.core.env import EnvConfig
from repro.core.workload import (TraceConfig, make_trace, make_trace_batch,
                                 paper_rate_for)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=15)
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--variants", default="eat,eat-a,eat-d,eat-da,ppo")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--num-envs", type=int, default=4)
    ap.add_argument("--eval-batch", type=int, default=16)
    ap.add_argument("--curriculum", action="store_true",
                    help="sample training traces from the scenario grid "
                         "(rate sweep, cold-start, bursty/flash arrivals) "
                         "instead of one fixed TraceConfig")
    ap.add_argument("--backend", default="fused", choices=BACKENDS,
                    help="repro.api execution backend for collection and "
                         "evaluation (sharded = device-mesh batch split)")
    ap.add_argument("--out", default="artifacts/training_curves.json")
    args = ap.parse_args()
    exec_spec = ExecSpec(backend=args.backend)

    ecfg = EnvConfig(num_servers=args.servers)
    rate = paper_rate_for(args.servers)
    tc = TraceConfig(arrival_rate=rate, max_servers=args.servers)
    trace_fn = lambda key: make_trace(key, tc)  # noqa: E731
    curriculum = None
    if args.curriculum:
        from repro.core.scenarios import training_curriculum
        curriculum = training_curriculum(ecfg)
        print("curriculum cells:", [sc.name for sc in curriculum])

    curves = {}
    # PolicySpec per evaluated policy: trained weights pass through params=
    eval_specs = {"random": PolicySpec("random"),
                  "greedy": PolicySpec("greedy")}
    for variant in args.variants.split(","):
        print(f"=== training {variant} ({args.episodes} episodes, "
              f"{args.servers} servers, rate {rate}, "
              f"{args.num_envs} parallel envs) ===")
        if variant == "ppo":
            st, hist = PPO.train_ppo(ecfg, PPO.PPOConfig(), trace_fn,
                                     args.episodes, seed=args.seed,
                                     log_every=5, num_envs=args.num_envs,
                                     curriculum=curriculum,
                                     exec_spec=exec_spec)
            eval_specs[variant] = PolicySpec("ppo", params=st.params)
        else:
            acfg = AG.AgentConfig(variant=variant)
            scfg = SAC.SACConfig(batch_size=128, warmup_steps=192,
                                 update_every=2)
            ts, hist = SAC.train(ecfg, acfg, scfg, trace_fn, args.episodes,
                                 seed=args.seed, log_every=5,
                                 num_envs=args.num_envs,
                                 curriculum=curriculum, exec_spec=exec_spec)
            eval_specs[variant] = PolicySpec("eat", params=ts.actor,
                                             options={"acfg": acfg})
        curves[variant] = hist

    # -- held-out evaluation: one batched program per policy, any backend --
    print(f"\n=== batched evaluation ({args.eval_batch} held-out traces) ===")
    eval_traces = make_trace_batch(jax.random.PRNGKey(10_000), tc,
                                   args.eval_batch)
    eval_keys = jax.random.split(jax.random.PRNGKey(777), args.eval_batch)
    evaluation = {}
    for name, spec in eval_specs.items():
        m = evaluate_batch(ecfg, eval_traces, spec, eval_keys,
                           exec_spec=exec_spec)
        evaluation[name] = {k: float(np.mean(v)) for k, v in m.items()}
    print(f"{'policy':8s} {'return':>8s} {'quality':>8s} {'resp':>8s} "
          f"{'reload':>7s}")
    for name, m in evaluation.items():
        print(f"{name:8s} {m['episode_return']:8.1f} {m['avg_quality']:8.3f} "
              f"{m['avg_response']:8.1f} {m['reload_rate']:7.2f}")
    curves = {"curves": curves, "evaluation": evaluation}

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(curves, f, indent=1)

    print(f"\ncurves -> {args.out}")
    print(f"{'variant':8s} {'first-3 R':>10s} {'last-3 R':>10s} "
          f"{'last-3 len':>10s} {'resp':>8s}")
    for v, hist in curves["curves"].items():
        f3 = sum(h["episode_return"] for h in hist[:3]) / min(3, len(hist))
        l3 = sum(h["episode_return"] for h in hist[-3:]) / min(3, len(hist))
        ln = sum(h["episode_len"] for h in hist[-3:]) / min(3, len(hist))
        rs = sum(h["avg_response"] for h in hist[-3:]) / min(3, len(hist))
        print(f"{v:8s} {f3:10.1f} {l3:10.1f} {ln:10.0f} {rs:8.1f}")


if __name__ == "__main__":
    main()
