"""Fig. 5 — training curves of the DRL schedulers (EAT vs ablations vs PPO).

    PYTHONPATH=src python examples/compare_agents.py --episodes 15 \
        --servers 8 --variants eat,eat-da,ppo

Trains each variant on the 8-server simulated cluster at the paper's
arrival rate and dumps reward / episode-length curves to
``artifacts/training_curves.json`` (paper Fig. 5a/5c: EAT trends above the
ablations; Fig. 5b: diffusion-policy variants converge to shorter episodes).
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import agent as AG
from repro.core import ppo as PPO
from repro.core import sac as SAC
from repro.core.env import EnvConfig
from repro.core.workload import TraceConfig, make_trace, paper_rate_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=15)
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--variants", default="eat,eat-a,eat-d,eat-da,ppo")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="artifacts/training_curves.json")
    args = ap.parse_args()

    ecfg = EnvConfig(num_servers=args.servers)
    rate = paper_rate_for(args.servers)
    tc = TraceConfig(arrival_rate=rate, max_servers=args.servers)
    trace_fn = lambda key: make_trace(key, tc)  # noqa: E731

    curves = {}
    for variant in args.variants.split(","):
        print(f"=== training {variant} ({args.episodes} episodes, "
              f"{args.servers} servers, rate {rate}) ===")
        if variant == "ppo":
            _, hist = PPO.train_ppo(ecfg, PPO.PPOConfig(), trace_fn,
                                    args.episodes, seed=args.seed,
                                    log_every=5)
        else:
            acfg = AG.AgentConfig(variant=variant)
            scfg = SAC.SACConfig(batch_size=128, warmup_steps=192,
                                 update_every=2)
            _, hist = SAC.train(ecfg, acfg, scfg, trace_fn, args.episodes,
                                seed=args.seed, log_every=5)
        curves[variant] = hist

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(curves, f, indent=1)

    print(f"\ncurves -> {args.out}")
    print(f"{'variant':8s} {'first-3 R':>10s} {'last-3 R':>10s} "
          f"{'last-3 len':>10s} {'resp':>8s}")
    for v, hist in curves.items():
        f3 = sum(h["episode_return"] for h in hist[:3]) / min(3, len(hist))
        l3 = sum(h["episode_return"] for h in hist[-3:]) / min(3, len(hist))
        ln = sum(h["episode_len"] for h in hist[-3:]) / min(3, len(hist))
        rs = sum(h["avg_response"] for h in hist[-3:]) / min(3, len(hist))
        print(f"{v:8s} {f3:10.1f} {l3:10.1f} {ln:10.0f} {rs:8.1f}")


if __name__ == "__main__":
    main()
