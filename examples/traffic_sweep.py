"""Streaming traffic sweep: unbounded-horizon QoS telemetry per policy.

    PYTHONPATH=src python examples/traffic_sweep.py            # default run
    PYTHONPATH=src python examples/traffic_sweep.py \
        --cells bursty,diurnal,flashcrowd --policies random,fifo,greedy \
        --streams 32 --window-tasks 64 --windows 50

The default invocation streams >= 100k tasks per policy through the
windowed engine (32 parallel streams x 64-task windows x 100 windows) on
CPU at O(window) memory, and reports p50/p95/p99 latency, QoS-violation
rate, server utilization, cold-start rate, and goodput per policy. Rows go
to --out as JSON (schema: traffic/sweep.py run_cell).

Named cells: poisson (paper rate), bursty (MMPP), diurnal, flashcrowd,
coldstart; or pass --rate to override the Poisson rate. Use --checkpoint to
evaluate trained EAT weights with --policies eat (without it, learned
policies run untrained and rows carry trained=false). --backend picks the
`repro.api` execution backend; `--backend sharded` splits the stream axis
over the local device mesh (bitwise-identical telemetry; on CPU force
devices with XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
from __future__ import annotations

import argparse

import jax

from repro.api import BACKENDS, ExecSpec
from repro.core import scenarios as SC
from repro.traffic.stream import StreamConfig
from repro.traffic.sweep import run_sweep


def named_cells(names, servers):
    grid = {
        "poisson": SC.poisson_scenario(servers),
        "bursty": SC.bursty_traffic(servers),
        "diurnal": SC.diurnal_traffic(servers),
        "flashcrowd": SC.flash_crowd(servers),
        "coldstart": SC.cold_start_heavy(servers),
    }
    unknown = [n for n in names if n not in grid]
    if unknown:
        raise SystemExit(f"unknown cells {unknown}; choose from {sorted(grid)}")
    return [grid[n] for n in names]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="poisson",
                    help="comma list: poisson,bursty,diurnal,flashcrowd,"
                         "coldstart")
    ap.add_argument("--policies", default="random,fifo,greedy")
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--streams", type=int, default=32,
                    help="parallel independent streams per run (batch axis)")
    ap.add_argument("--window-tasks", type=int, default=64,
                    help="tasks per window per stream (device memory bound)")
    ap.add_argument("--windows", type=int, default=100,
                    help="windows per run; 100 keeps >= 100k tasks per "
                         "policy even when overload caps injection at "
                         "window_tasks - max_carry per window")
    ap.add_argument("--max-steps-per-window", type=int, default=0)
    ap.add_argument("--resp-sla", type=float, default=120.0)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="override the Poisson cell's arrival rate")
    ap.add_argument("--checkpoint", default=None,
                    help="actor checkpoint dir for --policies eat/ppo")
    ap.add_argument("--backend", default="fused", choices=BACKENDS,
                    help="execution backend (sharded = device-mesh split "
                         "of the stream axis, bitwise-identical)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="artifacts/traffic_sweep.json")
    args = ap.parse_args()

    cells = named_cells(args.cells.split(","), args.servers)
    if args.rate:
        cells = [SC.poisson_scenario(args.servers, args.rate)
                 if c.name.startswith("poisson") else c for c in cells]
    stream = StreamConfig(
        num_windows=args.windows, num_streams=args.streams,
        max_steps_per_window=args.max_steps_per_window or None,
        resp_sla=args.resp_sla)
    total = args.streams * args.window_tasks * args.windows
    print(f"streaming <= {total} tasks per (cell, policy): "
          f"{args.streams} streams x {args.window_tasks}-task windows "
          f"x {args.windows} windows, {args.servers} servers")
    run_sweep(cells, args.policies.split(","), jax.random.PRNGKey(args.seed),
              stream=stream, window_tasks=args.window_tasks,
              checkpoint=args.checkpoint,
              exec_spec=ExecSpec(backend=args.backend), out=args.out)


if __name__ == "__main__":
    main()
