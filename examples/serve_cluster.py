"""End-to-end serving driver — the paper's Fig. 1 system, executable.

    PYTHONPATH=src python examples/serve_cluster.py [--policy eat|greedy|fifo]
        [--servers 4] [--tasks 12] [--archs qwen2-1.5b,tinyllama-1.1b]

Submits a batch of AIGC requests (prompts against real reduced models from
the assigned-architecture zoo), lets the chosen scheduler gang-allocate
logical edge servers, REALLY executes patch-parallel prefill + decode on the
loaded weights, and reports the Table-IX/X/XI metrics. Model loads and
reuses are real (weight materialisation vs pointer sharing), so the
cold-start economics the paper schedules around are visible in the metrics.

Virtual time (time_dilation=1) accounts busy-time with the calibrated
Table-VI latency model so the run is deterministic and completes in
seconds on CPU.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import agent as AG
from repro.core import env as EV
from repro.core import sac as SAC
from repro.serving.engine import Request, ServingEngine


def make_policy(name: str, num_servers: int, queue_window: int):
    if name == "fifo":
        # always execute the oldest task with mid steps
        def fifo(obs, key):
            a = np.zeros(2 + queue_window, np.float32)
            a[1] = 0.5
            a[2] = 1.0
            return a
        return fifo
    if name == "greedy":
        # prefer the task whose patch count matches an idle loaded gang
        def greedy(obs, key):
            a = np.zeros(2 + queue_window, np.float32)
            a[1] = 1.0                       # max steps (paper's Greedy)
            a[2:] = obs[0, -queue_window:]   # prefer longest-waiting
            return a
        return greedy
    # eat: the full attention+diffusion actor (untrained here: quickstart
    # scale; examples/compare_agents.py trains it properly)
    ecfg = EV.EnvConfig(num_servers=num_servers, queue_window=queue_window)
    acfg = AG.AgentConfig(variant="eat")
    actor = AG.init_actor(jax.random.PRNGKey(7), ecfg, acfg)

    def eat(obs, key):
        a = SAC.policy_act(actor, jax.numpy.asarray(obs), key,
                           ecfg=ecfg, acfg=acfg)
        return np.asarray(AG.to_env_action(a))
    return eat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="eat",
                    choices=["eat", "greedy", "fifo"])
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--tasks", type=int, default=12)
    ap.add_argument("--archs", default="qwen2-1.5b,tinyllama-1.1b")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    archs = args.archs.split(",")
    eng = ServingEngine(args.servers, archs, queue_window=8,
                        reduced=True, time_dilation=1.0, seed=args.seed)
    rng = np.random.default_rng(args.seed)

    # batched request arrivals (D_g exponential, D_c over {1,2,4})
    t = 0.0
    reqs = []
    for rid in range(args.tasks):
        t += rng.exponential(1.0 / 0.05)
        c = int(rng.choice([1, 2, 4], p=[0.4, 0.4, 0.2]))
        c = min(c, args.servers)
        reqs.append(Request(
            rid=rid, arch=archs[rid % len(archs)],
            prompt=rng.integers(1, 100, size=24).astype(np.int32),
            patches=c, arrive_t=t, max_new_tokens=8))

    policy = make_policy(args.policy, args.servers, eng.l)
    key = jax.random.PRNGKey(args.seed)
    pending = sorted(reqs, key=lambda r: r.arrive_t)
    decisions = 0
    while (pending or eng.queue) and decisions < 10 * args.tasks:
        now = eng.now()
        while pending and pending[0].arrive_t <= now:
            eng.submit(pending.pop(0))
        if not eng.queue:
            eng._advance(max(0.5, pending[0].arrive_t - now) if pending else 1.0)
            continue
        key, k = jax.random.split(key)
        done = eng.try_schedule(policy(eng.observe(), k))
        decisions += 1
        if done is not None:
            print(f"[{done.finish_t:8.1f}s] req {done.rid:2d} "
                  f"({done.arch}, c={done.patches}) steps={done.steps} "
                  f"reused={done.reused} resp={done.finish_t - done.arrive_t:7.1f}s "
                  f"tokens={done.tokens[:4]}...")

    m = eng.qos_summary()
    print(f"\npolicy={args.policy} servers={args.servers}: "
          f"scheduled {m['tasks_scheduled']}/{args.tasks}, "
          f"latency p50/p95 {m['latency_p50']:.1f}/{m['latency_p95']:.1f}s, "
          f"quality {m['avg_quality']:.3f}, "
          f"cold-start rate {m['cold_start_rate']:.2f} "
          f"({m['model_loads']} loads, {m['model_reuses']} reuses)")


if __name__ == "__main__":
    main()
