"""Train an LM end-to-end on the training substrate.

    PYTHONPATH=src python examples/train_lm.py                # CPU-sized demo
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

``--preset 100m`` builds a ~100M-parameter TinyLlama-family model (the
"train a ~100M model for a few hundred steps" deliverable — sized for a TPU
host; on this 1-core CPU container it runs but slowly, so the default demo
preset is smaller). Loss should drop well below ln(vocab) as the model
learns the synthetic Markov-chain data.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

from repro.common.config import get_config
from repro.training.data import DataConfig
from repro.training.train_loop import TrainConfig, train_lm


def build_cfg(preset: str):
    base = get_config("tinyllama-1.1b")
    if preset == "100m":
        # ~100M params: 12 x 640 TinyLlama-family, 8 heads (GQA kv=2)
        return dataclasses.replace(
            base, name="tinyllama-100m", num_layers=12, d_model=640,
            num_heads=8, num_kv_heads=2, head_dim=80, d_ff=1792,
            vocab_size=32000)
    if preset == "demo":
        return dataclasses.replace(
            base, name="tinyllama-demo", num_layers=4, d_model=256,
            num_heads=4, num_kv_heads=2, head_dim=64, d_ff=704,
            vocab_size=2048)
    raise SystemExit(f"unknown preset {preset}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=["demo", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    n_params = cfg.param_count()
    print(f"training {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x {args.seq}")

    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup=max(10, args.steps // 10),
                       ckpt_dir=args.ckpt_dir)
    dcfg = DataConfig(vocab_size=min(cfg.vocab_size, 2048),
                      seq_len=args.seq, batch_size=args.batch)
    params, history = train_lm(cfg, tcfg, dcfg)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    out = os.path.join("artifacts", f"train_{cfg.name}.json")
    os.makedirs("artifacts", exist_ok=True)
    with open(out, "w") as f:
        json.dump({"config": cfg.name, "params_m": n_params / 1e6,
                   "history": history}, f, indent=1)
    print(f"history -> {out}")


if __name__ == "__main__":
    main()
