"""Placement frontier: QoS with vs without the slow timescale.

    PYTHONPATH=src python benchmarks/bench_placement.py --streams 4 --windows 10

Runs the same streaming workload once per placement policy — none (the
reactive baseline), static (demand-blind prior), lfu (trailing window),
forecast (EWMA + trend) — on two non-stationary multi-model cells:

* ``modelskew-flashcrowd``: Zipf model popularity under periodic arrival
  spikes (`core.scenarios.model_skew_flashcrowd`) — reactive loading
  degenerates into cold-start storms at every spike;
* ``diurnal-skew``: Zipf popularity under sinusoidal day/night load.

Placement never perturbs demand, so all four runs of a cell see the
*identical* seeded arrival stream (asserted); the difference is purely the
layout the fast scheduler finds at each window start. Writes
BENCH_placement.json at the repo root (`make bench-placement`) and asserts
the acceptance gate for the two-timescale PR: on each cell, the best
demand-following policy (lfu or forecast) must beat placement-free on
cold-start rate AND p99 latency.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from common import write_bench_json
from repro.api import ExecSpec, PolicySpec, Simulator, WorkloadSpec
from repro.core.scenarios import model_skew_flashcrowd, zipf_probs
from repro.placement import PlacementSpec
from repro.traffic.arrivals import DiurnalArrivals

POLICIES = ("none", "static", "lfu", "forecast")


def _spec(policy: str, model_probs) -> PlacementSpec | None:
    if policy == "none":
        return None
    if policy == "static":
        return PlacementSpec(policy="static", model_probs=model_probs)
    return PlacementSpec(policy=policy)


def diurnal_skew(num_servers: int, num_models: int, zipf_a: float):
    """Zipf-skewed popularity under sinusoidal day/night arrivals."""
    sc = model_skew_flashcrowd(num_servers, num_models, zipf_a=zipf_a)
    base = sc.tcfg.arrival_rate
    return dataclasses.replace(
        sc, name=f"diurnal-skew-{num_models}x{num_servers}srv",
        arrival=DiurnalArrivals(base_rate=base, amplitude=0.6, period=800.0))


def run_point(wl: WorkloadSpec, backend: str, sched: str, policy: str,
              model_probs):
    sim = Simulator(wl, ExecSpec(backend=backend,
                                 placement=_spec(policy, model_probs)))
    res = sim.run(PolicySpec(sched), jax.random.PRNGKey(0))
    s = res.summary
    pc = res.raw.placement_counters
    return {
        "placement": policy,
        "scheduler": sched,
        "wall_s": res.wall_s,
        "tasks_injected": s["tasks_injected"],
        "tasks_scheduled": s["tasks_scheduled"],
        "cold_start_rate": s["cold_start_rate"],
        "reuse_rate": s["reuse_rate"],
        "latency_p50": s["latency_p50"],
        "latency_p99": s["latency_p99"],
        "qos_violation_rate": s["qos_violation_rate"],
        "goodput_rate": s["goodput_rate"],
        "utilization": s["utilization"],
        "placement_decisions": pc.get("placement_decisions", 0),
        "placement_prefetches": pc.get("placement_prefetches", 0),
        "placement_gangs_kept": pc.get("placement_gangs_kept", 0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--models", type=int, default=3)
    ap.add_argument("--zipf-a", type=float, default=1.5)
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--windows", type=int, default=10)
    ap.add_argument("--window-tasks", type=int, default=8)
    ap.add_argument("--backend", default="fused")
    ap.add_argument("--scheduler", default="greedy",
                    help="fast-timescale registry policy; the placement "
                         "sweep holds it fixed")
    ap.add_argument("--resp-sla", type=float, default=600.0)
    ap.add_argument("--json-out", default="",
                    help="BENCH json path ('' = repo-root default, "
                         "'none' = skip)")
    args = ap.parse_args()

    probs = zipf_probs(args.models, args.zipf_a)
    cells = [model_skew_flashcrowd(args.servers, args.models,
                                   zipf_a=args.zipf_a),
             diurnal_skew(args.servers, args.models, args.zipf_a)]

    rows = []
    for sc in cells:
        wl = WorkloadSpec.streaming(sc, streams=args.streams,
                                    num_windows=args.windows,
                                    window_tasks=args.window_tasks,
                                    resp_sla=args.resp_sla)
        cell_rows = {}
        for policy in POLICIES:
            pt = run_point(wl, args.backend, args.scheduler, policy, probs)
            pt["cell"] = sc.name
            cell_rows[policy] = pt
            rows.append(pt)
            print(json.dumps(pt))
        # identical arrivals: the slow timescale never perturbs demand
        injected = {p: r["tasks_injected"] for p, r in cell_rows.items()}
        assert len(set(injected.values())) == 1, \
            f"arrival streams diverged across placement policies: {injected}"
        # acceptance gate: the best demand-following policy beats reactive
        # loading on cold starts AND tail latency
        none_row = cell_rows["none"]
        best = min((cell_rows["lfu"], cell_rows["forecast"]),
                   key=lambda r: (r["cold_start_rate"], r["latency_p99"]))
        for gate, better in (("cold_start_rate", "lower"),
                             ("latency_p99", "lower")):
            assert best[gate] < none_row[gate], (
                f"{sc.name}: demand-following placement did not improve "
                f"{gate}: best({best['placement']})={best[gate]:.4f} vs "
                f"none={none_row[gate]:.4f}")
        print(f"# {sc.name}: {best['placement']} beats none — cold_start "
              f"{none_row['cold_start_rate']:.4f} -> "
              f"{best['cold_start_rate']:.4f}, p99 "
              f"{none_row['latency_p99']:.1f} -> {best['latency_p99']:.1f}")

    payload = {
        "workload": {"servers": args.servers, "models": args.models,
                     "zipf_a": args.zipf_a, "streams": args.streams,
                     "windows": args.windows,
                     "window_tasks": args.window_tasks,
                     "scheduler": args.scheduler,
                     "resp_sla": args.resp_sla},
        "frontier": rows,
        "gate": "per cell: min(lfu, forecast) beats none on "
                "cold_start_rate and latency_p99 on identical arrivals",
    }
    if args.json_out != "none":
        path = write_bench_json("placement", payload,
                                out=args.json_out or None,
                                exec_backend=args.backend)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
