"""Table VI / Fig. 7 — time-prediction model.

Validates that the latency model reproduces the paper's measurements:
init time ~constant in patch count, execution time linear in inference
steps with per-step cost shrinking with parallelism, and that the
predictor's error on noisy "measured" runs stays small (Fig. 7: predictions
adequately reflect node load even when loading times are unstable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import timemodel as TM

PAPER_TABLE_VI = {1: (33.5, 0.53), 2: (31.9, 0.29), 4: (35.0, 0.20)}


def run(verbose: bool = True) -> dict:
    rows = []
    for c, (init_ref, step_ref) in PAPER_TABLE_VI.items():
        c_arr = jnp.asarray(c)
        init = float(TM.init_time(c_arr))
        # per-step slope recovered from the linear model
        t20 = float(TM.exec_time(c_arr, jnp.asarray(20)))
        t40 = float(TM.exec_time(c_arr, jnp.asarray(40)))
        slope = (t40 - t20) / 20.0
        rows.append({"patches": c, "init_s": init, "init_paper": init_ref,
                     "step_s": round(slope, 3), "step_paper": step_ref})
    # linearity check (Fig. 7): exec time exactly linear in steps
    steps = jnp.arange(10, 51)
    t = np.asarray(TM.exec_time(jnp.asarray(2), steps))
    resid = np.max(np.abs(t - (t[0] + (np.asarray(steps) - 10) * (t[1] - t[0]))))
    # reuse-vs-reload prediction split (Fig. 7 right)
    pred_reload = float(TM.predict_remaining(jnp.asarray(2), jnp.asarray(20),
                                             jnp.asarray(False)))
    pred_reuse = float(TM.predict_remaining(jnp.asarray(2), jnp.asarray(20),
                                            jnp.asarray(True)))
    out = {"table": rows, "linearity_residual": float(resid),
           "pred_reload_2p20s": pred_reload, "pred_reuse_2p20s": pred_reuse}
    if verbose:
        print("Table VI — time prediction (model vs paper)")
        print("| patches | init (s) | paper | step (s) | paper |")
        print("|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['patches']} | {r['init_s']:.1f} | {r['init_paper']}"
                  f" | {r['step_s']:.3f} | {r['step_paper']} |")
        print(f"linearity residual: {resid:.2e}")
        print(f"2-patch 20-step predicted: reuse={pred_reuse:.1f}s "
              f"reload={pred_reload:.1f}s")
    return out


if __name__ == "__main__":
    run()
