"""Stream-training benchmark -> BENCH_stream_train.json.

    PYTHONPATH=src python benchmarks/bench_stream_train.py
    PYTHONPATH=src python benchmarks/bench_stream_train.py --devices 8

Runs the same streamed SAC training (>= 8 windows, sustained-overload
arrival rate — `StreamTrainConfig.rate_scale` > 1) on the "fused" and
"sharded" execution backends and records, per backend: windows/s,
transitions/s (collection + gradient updates included — this is end-to-end
training throughput), and the round-0 -> final episode return and
drop-inclusive QoS-violation rate. Every window's collected replay batch is
SHA-256 digested through the trainer's `transition_hook`; the bench asserts
the fused and sharded digests are bitwise-identical before writing the
record. `--devices N` forces N host CPU devices (re-exec with XLA_FLAGS
before jax initialises) so the sharded backend runs a real multi-device
mesh on a CPU container.
"""
from __future__ import annotations

import argparse
import hashlib
import os
import sys
import time


def _force_host_devices(n: int) -> None:
    flag = f"--xla_force_host_platform_device_count={n}"
    cur = os.environ.get("XLA_FLAGS", "")
    if flag in cur:
        return
    os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()
    os.execv(sys.executable, [sys.executable] + sys.argv)


def eval_policy_stream(ecfg, acfg, actor_params, backend, args,
                       windows: int = 8, seed: int = 12345):
    """Evaluate an actor on a fresh overload stream (empty cluster, same
    arrival seed for every policy) — the fair round-0 vs trained comparison:
    inside the *training* stream, later windows inherit the saturated
    backlog, so raw per-round telemetry confounds policy quality with
    backlog age."""
    import jax
    import numpy as np

    from repro.api import ExecSpec
    from repro.api.backends import rollout_fn_for
    from repro.core import sac as SAC
    from repro.training import stream_train as ST
    from repro.traffic.stream import (CurriculumTaskSource, StreamConfig,
                                      StreamRunner)

    cells = ST.resolve_cells(ecfg, None, None, args.rate_scale)
    k_src, k_stream = jax.random.split(jax.random.PRNGKey(seed))
    source = CurriculumTaskSource([(p, t) for _, p, t in cells], k_src,
                                  num_streams=args.streams)
    runner = StreamRunner(ecfg, SAC.actor_policy(ecfg, acfg), actor_params,
                          source, k_stream,
                          StreamConfig(num_windows=windows,
                                       num_streams=args.streams),
                          rollout_fn=rollout_fn_for(ExecSpec(backend=backend)))
    rets = [runner.run_window().record["episode_return_mean"]
            for _ in range(windows)]
    s = runner.result().summary
    return {"return_mean": float(np.mean(rets)),
            "violation_rate": s["qos_violation_rate"],
            "drop_rate": s["drop_rate"],
            "goodput_per_s": s["goodput_per_s"]}


def run_backend(backend: str, args):
    import jax

    from repro.api import ExecSpec
    from repro.core import agent as AG
    from repro.core import sac as SAC
    from repro.core.env import EnvConfig
    from repro.training import stream_train as ST

    ecfg = EnvConfig(num_servers=args.servers, max_tasks=args.window_tasks,
                     max_steps=4 * args.window_tasks)
    acfg = AG.AgentConfig(variant=args.variant, T=args.diffusion_steps)
    scfg = SAC.SACConfig(warmup_steps=args.warmup_steps,
                         batch_size=args.batch_size)
    stcfg = ST.StreamTrainConfig(
        rounds=args.rounds, streams=args.streams,
        rate_scale=args.rate_scale,
        max_updates_per_round=args.max_updates_per_round)

    digest = hashlib.sha256()
    counts = {"n": 0}

    def hook(r, flat):
        for a in flat:
            digest.update(a.tobytes())
        counts["n"] += len(flat[2])

    # warm the compiled programs (warmup + actor collection, update step) on
    # a throwaway short run so the timed run measures steady-state
    # windows/s, not compilation. Same scfg: SACConfig is a static jit arg.
    warm = ST.StreamTrainConfig(rounds=3, streams=args.streams,
                                rate_scale=args.rate_scale,
                                max_updates_per_round=1)
    ST.train_stream_sac(ecfg, acfg, scfg, warm, seed=args.seed,
                        exec_spec=ExecSpec(backend=backend))

    # the true round-0 policy: a zero-round run reproduces the trainer's
    # seed derivation exactly and returns the untouched initial actor
    # (capturing inside a callback would see round 0's post-update weights)
    round0_actor = ST.train_stream_sac(
        ecfg, acfg, scfg, ST.StreamTrainConfig(rounds=0, streams=args.streams),
        seed=args.seed, exec_spec=ExecSpec(backend=backend)).state.actor

    t0 = time.perf_counter()
    res = ST.train_stream_sac(ecfg, acfg, scfg, stcfg, seed=args.seed,
                              exec_spec=ExecSpec(backend=backend),
                              transition_hook=hook)
    wall = time.perf_counter() - t0
    first, last = res.history[0], res.history[-1]
    ev0 = eval_policy_stream(ecfg, acfg, round0_actor, backend, args)
    evT = eval_policy_stream(ecfg, acfg, res.state.actor, backend, args)
    rec = {
        "exec_backend": backend,
        "device_count": jax.local_device_count(),
        "wall_s": round(wall, 3),
        "windows": args.rounds,
        "windows_per_s": round(args.rounds / wall, 3),
        "transitions": counts["n"],
        "transitions_per_s": round(counts["n"] / wall, 1),
        "digest_sha256": digest.hexdigest(),
        "round0_return": first["episode_return_mean"],
        "final_return": last["episode_return_mean"],
        "round0_violation_rate": first["qos_violation_rate"],
        "final_violation_rate": last["qos_violation_rate"],
        "tasks_injected": res.stream.summary["tasks_injected"],
        "drop_rate": res.stream.summary["drop_rate"],
        # fresh-stream eval: round-0 actor vs trained actor on the SAME
        # arrival sequence from an empty cluster
        "eval_round0": ev0,
        "eval_trained": evT,
        "improved": bool(evT["return_mean"] > ev0["return_mean"]
                         or evT["violation_rate"] < ev0["violation_rate"]),
    }
    print(f"[{backend:8s}] {rec['windows_per_s']:6.2f} windows/s  "
          f"{rec['transitions_per_s']:8.1f} transitions/s")
    print(f"  eval (fresh stream): R {ev0['return_mean']:.2f} -> "
          f"{evT['return_mean']:.2f}  viol {ev0['violation_rate']:.3f} -> "
          f"{evT['violation_rate']:.3f}  improved={rec['improved']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host CPU devices for the sharded mesh")
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--window-tasks", type=int, default=32)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=16,
                    help=">= 8 windows per the acceptance criterion")
    ap.add_argument("--rate-scale", type=float, default=2.0,
                    help="sustained overload: offered load / paper rate")
    ap.add_argument("--variant", default="eat-da")
    ap.add_argument("--diffusion-steps", type=int, default=2)
    ap.add_argument("--warmup-steps", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--max-updates-per-round", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    if args.devices > 1:
        _force_host_devices(args.devices)

    sys.path.insert(0, os.path.dirname(__file__))
    from common import write_bench_json

    recs = {b: run_backend(b, args) for b in ("fused", "sharded")}
    assert recs["fused"]["digest_sha256"] == recs["sharded"]["digest_sha256"], \
        "fused and sharded backends collected different transitions"
    assert recs["fused"]["transitions"] == recs["sharded"]["transitions"]
    print(f"collection bitwise-identical across backends "
          f"({recs['fused']['transitions']} transitions, sha256 "
          f"{recs['fused']['digest_sha256'][:16]}...)")
    payload = {
        "config": {k: v for k, v in vars(args).items() if k != "json_out"},
        "backends": recs,
        "sharded_speedup": round(recs["sharded"]["windows_per_s"]
                                 / recs["fused"]["windows_per_s"], 3),
        "collection_bitwise_identical": True,
        "improved_on_both_backends": bool(recs["fused"]["improved"]
                                          and recs["sharded"]["improved"]),
    }
    write_bench_json("stream_train", payload, out=args.json_out,
                     exec_backend="sharded")


if __name__ == "__main__":
    main()
