"""Benchmark harness entry point — one benchmark per paper table.

    PYTHONPATH=src python -m benchmarks.run [--quick|--full] [--only NAME]

Light benchmarks (time model, patch acceleration, trace example, decision
latency, roofline report) always run live. The scheduling grid behind
Tables IX/X/XI is expensive (DRL training on one CPU core); by default it
REUSES the artifact cache under ``artifacts/scheduling/`` and only computes
missing cells with a reduced budget. ``--full`` recomputes the entire paper
grid at full budget.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback

from benchmarks import common as C

LIGHT = ("time_model", "patch_accel", "trace_example", "decision_latency",
         "roofline")


def run_light(name: str):
    mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
    return mod.run(verbose=True)


def run_scheduling(mode: str):
    if mode == "cache-only":
        grid, episodes, n_eval, algos = None, 0, 0, ()
        missing = False
    elif mode == "quick":
        # paper's headline cells only: one rate per cluster size
        grid = {4: (0.05,), 8: (0.10,), 12: (0.15,)}
        episodes, n_eval = 10, 3
        algos = C.ALL_ALGOS
        missing = True
    else:  # full
        grid, episodes, n_eval = C.PAPER_GRID, 20, 5
        algos = C.ALL_ALGOS
        missing = True
    if missing:
        C.run_grid(algos, grid, episodes=episodes, n_eval=n_eval)
    for t in ("quality", "latency", "reload", "efficiency"):
        print()
        run_light(t)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="compute missing scheduling cells at reduced budget")
    ap.add_argument("--full", action="store_true",
                    help="recompute the full paper grid (hours on 1 CPU)")
    ap.add_argument("--only", default=None,
                    help=f"run one benchmark: {LIGHT + ('scheduling',)}")
    args = ap.parse_args()

    t0 = time.time()
    failures = []
    names = [args.only] if args.only else list(LIGHT) + ["scheduling"]
    for name in names:
        print(f"\n=== bench: {name} " + "=" * max(0, 50 - len(name)))
        try:
            if name == "scheduling":
                mode = ("full" if args.full else
                        "quick" if args.quick else "cache-only")
                run_scheduling(mode)
            else:
                run_light(name)
        except Exception:  # noqa: BLE001 — report all failures at the end
            failures.append(name)
            traceback.print_exc()
    print(f"\nbenchmarks done in {time.time() - t0:.0f}s; "
          f"{len(failures)} failures{': ' + str(failures) if failures else ''}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
