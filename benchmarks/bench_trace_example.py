"""Tables II–IV — the motivating 4-task example.

Reproduces the paper's §II experiment: tasks 1-4 arrive 10 s apart on a
4-GPU cluster (tasks 1,2,4 need 2 patches; task 3 needs 4).  The
*traditional* policy runs a fixed 20 steps, schedules tasks in arrival
order onto the first free servers, and never reuses loaded models across
gang sizes — reproducing Table III's repeated inits.  The *EAT-style*
policy trades a few steps away on the queued tasks and reuses the 2-patch
gangs — reproducing Table II.  We report both event logs and the Table-IV
summary (quality / mean inference latency) from the same latency+quality
models used everywhere else.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import timemodel as TM
from repro.core.quality import quality_of

ARRIVALS = [0.0, 10.0, 20.0, 30.0]
PATCHES = [2, 2, 4, 2]


def _simulate(policy: str) -> Dict:
    """Event-driven simulation of the 4-task example on 4 servers."""
    free_at = np.zeros(4)
    gang_model = [None] * 4          # loaded gang signature per server
    log: List[Dict] = []
    responses, qualities = [], []

    if policy == "traditional":
        steps_for = {0: 20, 1: 20, 2: 20, 3: 20}
    else:  # eat: shave steps on queued tasks, reuse gangs
        steps_for = {0: 18, 1: 17, 2: 17, 3: 25}
        # proactive init (paper Table II: Init 1 + Init 2 both start at t=0,
        # before any task is scheduled — the agent warms two 2-patch gangs)
        for pair in ([0, 1], [2, 3]):
            init = float(TM.init_time(jnp.asarray(2)))
            for i in pair:
                free_at[i] = init
                gang_model[i] = ("gang", 2)
            log.append({"task": f"Init {len(log)+1}", "gpu": pair,
                        "time": round(init, 1)})

    order = [0, 1, 2, 3] if policy == "traditional" else [0, 1, 3, 2]
    for k in order:
        c = PATCHES[k]
        arr = ARRIVALS[k]
        # earliest time c servers are simultaneously free
        t_sorted = np.sort(free_at)
        start = max(arr, t_sorted[c - 1])
        sig = ("gang", c)
        # pick servers: prefer an idle gang with the same signature
        idle = [i for i in range(4) if free_at[i] <= start]
        reuse = (policy == "eat"
                 and sum(gang_model[i] == sig for i in idle) >= c)
        if reuse:
            sel = [i for i in idle if gang_model[i] == sig][:c]
            init = 0.0
        else:
            sel = sorted(idle, key=lambda i: free_at[i])[:c]
            init = float(TM.init_time(jnp.asarray(c)))
            log.append({"task": f"Init {len(log)+1}", "gpu": sel,
                        "time": round(init, 1)})
        s = steps_for[k]
        texe = float(TM.exec_time(jnp.asarray(c), jnp.asarray(s)))
        finish = start + init + texe
        for i in sel:
            free_at[i] = finish
            gang_model[i] = sig
        q = float(quality_of(jnp.asarray(s)))
        responses.append(finish - arr)
        qualities.append(q)
        log.append({"task": f"Task {k+1}", "patches": c, "gpu": sel,
                    "steps": s, "exec_s": round(texe, 1),
                    "inference_s": round(finish - arr, 1),
                    "quality": round(q, 2)})
    return {"log": log, "avg_quality": float(np.mean(qualities)),
            "avg_inference_latency": float(np.mean(responses))}


def run(verbose: bool = True) -> Dict:
    eat = _simulate("eat")
    trad = _simulate("traditional")
    out = {"eat": eat, "traditional": trad,
           "paper_table_iv": {"eat": {"quality": 2.4 / 10, "latency": 22.64},
                              "traditional": {"quality": 2.51 / 10,
                                              "latency": 52.00}}}
    if verbose:
        for name, res in (("EAT (Table II)", eat),
                          ("Traditional (Table III)", trad)):
            print(f"\n{name}:")
            for e in res["log"]:
                print("  ", e)
            print(f"  avg quality {res['avg_quality']:.3f}, "
                  f"avg inference latency {res['avg_inference_latency']:.2f} s")
        speedup = trad["avg_inference_latency"] / eat["avg_inference_latency"]
        print(f"\nTable IV: EAT latency {eat['avg_inference_latency']:.1f}s vs "
              f"traditional {trad['avg_inference_latency']:.1f}s "
              f"({speedup:.2f}x; paper: 22.6 vs 52.0 = 2.30x)")
    return out


if __name__ == "__main__":
    run()
