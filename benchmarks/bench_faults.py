"""Fault-tolerance frontier: QoS vs fault rate, retry+degrade vs naive drop.

    PYTHONPATH=src python benchmarks/bench_faults.py --streams 8 --windows 10

Sweeps the per-server outage rate (MTBF) over a deterministic seeded fault
timeline and runs the same streaming workload twice per point:

* ``retry``  — the fault-tolerant policy this repo ships: crashed gangs
  requeue with capped exponential backoff under a deadline-aware retry
  budget (`FaultSpec.max_retries > 0`);
* ``drop``   — the naive baseline: a crashed gang's task is lost
  (`max_retries=0` exhausts the budget on the first failure).

Both see the *same* outages (same FaultSpec seed => same timeline), so the
difference is purely the recovery policy. Each scheduling policy on the
grid (greedy + the fifo/random baselines) is swept with both strategies.
Writes BENCH_faults.json at the repo root (`make bench-faults`) and
asserts that under the shipped default policy (greedy) the retry
strategy's goodput is never below naive drop at any fault rate — the
acceptance gate for the fault-tolerance PR. Baselines are recorded
ungated: random placement can waste retry capacity, and the frontier
shows it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from common import write_bench_json
from repro.api import ExecSpec, PolicySpec, Simulator, WorkloadSpec
from repro.core.scenarios import poisson_scenario
from repro.core.workload import paper_rate_for
from repro.faults import FaultSpec

#: swept outage severities: mean seconds between per-server failures
#: (0 = faults off — the bitwise-identical baseline row)
MTBF_GRID = (0.0, 600.0, 300.0, 150.0, 75.0, 40.0)


def _spec(mtbf: float, retries: int, seed: int) -> FaultSpec | None:
    if mtbf <= 0.0:
        return None
    return FaultSpec(seed=seed, mtbf=mtbf, mttr=20.0, straggler_prob=0.05,
                     straggler_factor=3.0, max_retries=retries,
                     backoff_base=1.0, backoff_cap=5.0,
                     retry_deadline=900.0)


def run_point(wl: WorkloadSpec, backend: str, policy: str, mtbf: float,
              retries: int, seed: int):
    faults = _spec(mtbf, retries, seed)
    sim = Simulator(wl, ExecSpec(backend=backend, faults=faults))
    res = sim.run(PolicySpec(policy), jax.random.PRNGKey(0))
    s = res.summary
    return {
        "policy": policy,
        "mtbf": mtbf,
        "strategy": "off" if faults is None else (
            "retry" if retries > 0 else "drop"),
        "max_retries": 0 if faults is None else retries,
        "wall_s": res.wall_s,
        "tasks_injected": s["tasks_injected"],
        "tasks_scheduled": s["tasks_scheduled"],
        "tasks_failed": s.get("tasks_failed", 0),
        "tasks_retried": s.get("tasks_retried", 0),
        "tasks_dropped": s["tasks_dropped"],
        "tasks_dropped_retry_exhausted":
            s.get("tasks_dropped_retry_exhausted", 0),
        "tasks_pending_retry": s.get("tasks_failed_pending_retry", 0),
        "goodput_rate": s["goodput_rate"],
        "goodput_per_s": s["goodput_per_s"],
        "qos_violation_rate": s["qos_violation_rate"],
        "drop_rate": s["drop_rate"],
        "latency_p99": s["latency_p99"],
        "utilization": s["utilization"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--window-tasks", type=int, default=8,
                    help="small windows keep the retry re-admission "
                         "granularity (one window) well under the SLA")
    ap.add_argument("--windows", type=int, default=24)
    ap.add_argument("--backend", default="fused")
    ap.add_argument("--retries", type=int, default=3)
    ap.add_argument("--fault-seed", type=int, default=1)
    ap.add_argument("--policies", default="greedy,fifo,random",
                    help="comma-separated registry policies; each is swept "
                         "over the MTBF grid with both recovery strategies")
    ap.add_argument("--rate-scale", type=float, default=0.35,
                    help="offered load as a fraction of the paper rate: "
                         "the frontier needs headroom for recovered tasks "
                         "to finish inside the SLA (1.0 saturates the "
                         "cluster even fault-free)")
    ap.add_argument("--resp-sla", type=float, default=600.0)
    ap.add_argument("--json-out", default="",
                    help="BENCH json path ('' = repo-root default, "
                         "'none' = skip)")
    args = ap.parse_args()

    rate = paper_rate_for(args.servers) * args.rate_scale
    sc = poisson_scenario(args.servers, rate)
    wl = WorkloadSpec.streaming(sc, streams=args.streams,
                                num_windows=args.windows,
                                window_tasks=args.window_tasks,
                                resp_sla=args.resp_sla)

    rows = []
    for policy in args.policies.split(","):
        for mtbf in MTBF_GRID:
            pt_retry = run_point(wl, args.backend, policy, mtbf,
                                 args.retries, args.fault_seed)
            rows.append(pt_retry)
            print(json.dumps(pt_retry))
            if mtbf > 0.0:
                pt_drop = run_point(wl, args.backend, policy, mtbf, 0,
                                    args.fault_seed)
                rows.append(pt_drop)
                print(json.dumps(pt_drop))
                # the gate applies to the shipped default policy: under
                # greedy placement, retry+degrade must never lose to
                # naive drop. Baseline policies (fifo/random) are
                # recorded ungated — random placement can waste retry
                # capacity, which is exactly what the frontier shows.
                if policy != "greedy":
                    continue
                for gate in ("goodput_rate", "goodput_per_s"):
                    assert pt_retry[gate] >= pt_drop[gate], (
                        f"retry+degrade lost to naive drop for "
                        f"{policy} at mtbf={mtbf}: {gate} "
                        f"{pt_retry[gate]:.4f} < {pt_drop[gate]:.4f}")

    payload = {
        "workload": {"servers": args.servers, "streams": args.streams,
                     "window_tasks": args.window_tasks,
                     "windows": args.windows, "rate": rate,
                     "resp_sla": args.resp_sla},
        "fault_model": {"mttr": 20.0, "straggler_prob": 0.05,
                        "retries": args.retries, "seed": args.fault_seed},
        "frontier": rows,
        "gate": "greedy: retry goodput >= drop goodput at every MTBF "
                "(rate and per_s); baselines recorded ungated",
    }
    if args.json_out != "none":
        path = write_bench_json("faults", payload,
                                out=args.json_out or None,
                                exec_backend=args.backend)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
