"""Table IX — generation quality across algorithms / cluster sizes / rates.

Reads the shared scheduling-run cache (populated by ``benchmarks.common
.run_grid``; ``benchmarks.run`` orchestrates it) and prints the paper-style
table. Paper anchors: Greedy pins the 0.270 ceiling; SAC-family ~0.26;
PPO fixed 0.228; meta-heuristics ~0.18-0.22; Random lowest.
"""
from __future__ import annotations

from benchmarks import common as C


def run(verbose: bool = True):
    results = C.load_grid()
    if not results:
        print("no cached scheduling runs; run `python -m benchmarks.run` first")
        return None
    table = C.format_table(results, "avg_quality")
    if verbose:
        print("Table IX — quality (CLIP-proxy score)")
        print(table)
    return table


if __name__ == "__main__":
    run()
