"""Fig. 8 — generation efficiency (quality per second of response time).

Computed from the shared scheduling grid as quality / avg_response, the
paper's definition. The paper excludes Random and the meta-heuristics
(below the basic quality bar) and ranks EAT > EAT-A > EAT-DA > EAT-D >
PPO > Greedy on time utilization.
"""
from __future__ import annotations

from benchmarks import common as C

INCLUDED = ("eat", "eat-a", "eat-d", "eat-da", "ppo", "greedy")


def run(verbose: bool = True):
    results = [r for r in C.load_grid() if r["algo"] in INCLUDED]
    if not results:
        print("no cached scheduling runs; run `python -m benchmarks.run` first")
        return None
    for r in results:
        r["efficiency"] = r["avg_quality"] / max(r["avg_response"], 1e-9)
    table = C.format_table(results, "efficiency", fmt="{:.4f}")
    if verbose:
        print("Fig. 8 — generation efficiency (quality / response second)")
        print(table)
    return table


if __name__ == "__main__":
    run()
