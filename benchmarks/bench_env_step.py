"""Fused vs unfused environment decision step, measured end-to-end.

    PYTHONPATH=src python benchmarks/bench_env_step.py

Two measurements per (E servers, B envs) cell, both end-to-end through the
public engines so the numbers are what training/baselines/streaming
actually see:

* batched episodes/sec: `rollout.batch_rollout` with `fused=True` (one
  fused decision op advances all B envs per step) vs `fused=False` (the
  legacy vmap-of-scans engine on the compositional `env.step`);
* streaming tasks/sec: `traffic.run_stream` (open-loop Poisson arrivals at
  the paper rate) with `StreamConfig(fused=...)`.

Writes BENCH_env_step.json at the repo root (`make bench-env-step`). On
CPU the fused path runs the jnp reference; pass `--impl pallas` to time the
kernel itself (compiled on gpu/tpu, interpret-mode — slow, parity only —
on CPU). Both paths are bitwise-identical, so every speedup is free.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from common import write_bench_json
from repro.api import ExecSpec, rollout_fn_for
from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.workload import TraceConfig, make_trace_batch, paper_rate_for
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.stream import ProcessTaskSource, StreamConfig, run_stream

# fused/unfused measured through the api backends ("reference" is the legacy
# vmap-of-scans engine, "fused" the fused env-step op — bitwise-identical)
_ENGINES = (("unfused", "reference"), ("fused", "fused"))


def _policy(name, ecfg):
    return {"fifo": RO.fifo_policy, "random": RO.uniform_policy}[name](ecfg)


def bench_rollout_cell(E, B, *, policy, window_tasks, num_steps, impl,
                       min_s=2.0):
    ecfg = EV.EnvConfig(num_servers=E, max_tasks=window_tasks, queue_window=8,
                        max_steps=num_steps)
    tc = TraceConfig(num_tasks=window_tasks, arrival_rate=paper_rate_for(E),
                     max_servers=E)
    traces = make_trace_batch(jax.random.PRNGKey(0), tc, B)
    keys = jax.random.split(jax.random.PRNGKey(1), B)
    pol = _policy(policy, ecfg)
    out = {}
    for label, backend in _ENGINES:
        rollout = rollout_fn_for(ExecSpec(backend=backend, fused_impl=impl))

        def run():
            r = rollout(ecfg, traces, pol, {}, keys)
            jax.block_until_ready(r.metrics["episode_return"])
        t0 = time.perf_counter()
        run()                                  # compile
        compile_s = time.perf_counter() - t0
        n, t0 = 0, time.perf_counter()
        while time.perf_counter() - t0 < min_s:
            run()
            n += 1
        eps = B * n / (time.perf_counter() - t0)
        out[label] = {
            "eps_per_s": round(eps, 1), "compile_s": round(compile_s, 2)}
    out["speedup"] = round(out["fused"]["eps_per_s"]
                           / out["unfused"]["eps_per_s"], 2)
    return out


def bench_stream_cell(E, B, *, policy, window_tasks, windows, impl):
    ecfg = EV.EnvConfig(num_servers=E, max_tasks=window_tasks, queue_window=8)
    tc = TraceConfig(num_tasks=window_tasks, arrival_rate=paper_rate_for(E),
                     max_servers=E)
    pol = _policy(policy, ecfg)
    out = {}
    for label, backend in _ENGINES:
        rollout = rollout_fn_for(ExecSpec(backend=backend, fused_impl=impl))

        def run(num_windows):
            src = ProcessTaskSource(PoissonArrivals(tc.arrival_rate), tc,
                                    jax.random.PRNGKey(0), num_streams=B)
            cfg = StreamConfig(num_windows=num_windows, num_streams=B)
            t0 = time.perf_counter()
            res = run_stream(ecfg, pol, {}, src, jax.random.PRNGKey(1), cfg,
                             rollout_fn=rollout)
            return time.perf_counter() - t0, res
        run(1)                                 # compile + warm
        wall, res = run(windows)
        tasks = res.summary["tasks_injected"]
        out[label] = {
            "tasks": int(tasks), "wall_s": round(wall, 2),
            "tasks_per_s": round(tasks / wall, 1)}
    out["speedup"] = round(out["fused"]["tasks_per_s"]
                           / out["unfused"]["tasks_per_s"], 2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", default="8,16,32")
    ap.add_argument("--batches", default="32,256")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "random"])
    ap.add_argument("--window-tasks", type=int, default=32)
    ap.add_argument("--num-steps", type=int, default=256)
    ap.add_argument("--stream-windows", type=int, default=8)
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "ref", "pallas"],
                    help="fused implementation (auto: pallas on gpu/tpu, "
                         "jnp reference on cpu)")
    ap.add_argument("--json-out", default="",
                    help="BENCH json path ('' = repo-root default, "
                         "'none' = skip)")
    args = ap.parse_args()

    servers = [int(s) for s in args.servers.split(",")]
    batches = [int(b) for b in args.batches.split(",")]
    rollout_cells, stream_cells = [], []
    for E in servers:
        for B in batches:
            r = bench_rollout_cell(E, B, policy=args.policy,
                                   window_tasks=args.window_tasks,
                                   num_steps=args.num_steps, impl=args.impl)
            r.update(servers=E, batch=B)
            rollout_cells.append(r)
            print(f"rollout E={E:2d} B={B:3d}: "
                  f"unfused {r['unfused']['eps_per_s']:8.1f} eps/s  "
                  f"fused {r['fused']['eps_per_s']:8.1f} eps/s  "
                  f"({r['speedup']:.2f}x)", flush=True)
            s = bench_stream_cell(E, B, policy=args.policy,
                                  window_tasks=args.window_tasks,
                                  windows=args.stream_windows, impl=args.impl)
            s.update(servers=E, streams=B)
            stream_cells.append(s)
            print(f"stream  E={E:2d} B={B:3d}: "
                  f"unfused {s['unfused']['tasks_per_s']:8.1f} tasks/s  "
                  f"fused {s['fused']['tasks_per_s']:8.1f} tasks/s  "
                  f"({s['speedup']:.2f}x)", flush=True)

    payload = {
        "policy": args.policy,
        "window_tasks": args.window_tasks,
        "num_steps": args.num_steps,
        "impl": args.impl,
        "rollout": rollout_cells,
        "stream": stream_cells,
        "min_speedup_rollout": min(r["speedup"] for r in rollout_cells),
        "max_speedup_rollout": max(r["speedup"] for r in rollout_cells),
    }
    print(json.dumps(payload, indent=1))
    if args.json_out != "none":
        write_bench_json("env_step", payload, out=args.json_out or None,
                         fused=True, exec_backend="fused+reference")


if __name__ == "__main__":
    main()
