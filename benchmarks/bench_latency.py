"""Table X — response latency across algorithms / cluster sizes / rates.

Paper headline (4 servers, rate 0.05): EAT 39.7 s beats EAT-A by 28.7%,
EAT-DA by 58.2%, PPO by 68.8%, Greedy by 74.3%, Random by 30.0%.
We assert the *ordering* (EAT < ablations < Greedy) rather than absolute
seconds — see DESIGN.md §6 (calibrated latency model, smaller training
budget).
"""
from __future__ import annotations

from benchmarks import common as C


def run(verbose: bool = True):
    results = C.load_grid()
    if not results:
        print("no cached scheduling runs; run `python -m benchmarks.run` first")
        return None
    table = C.format_table(results, "avg_response", fmt="{:.1f}")
    if verbose:
        print("Table X — response latency (s)")
        print(table)
        # headline comparison at the paper's real-machine cell
        cell = {r["algo"]: r for r in results
                if r["servers"] == 4 and abs(r["rate"] - 0.05) < 1e-9}
        if "eat" in cell:
            eat = cell["eat"]["avg_response"]
            for other in ("eat-a", "eat-da", "ppo", "greedy", "random"):
                if other in cell:
                    o = cell[other]["avg_response"]
                    print(f"  EAT vs {other}: {eat:.1f} vs {o:.1f} "
                          f"({100 * (o - eat) / max(o, 1e-9):+.1f}%)")
    return table


if __name__ == "__main__":
    run()
