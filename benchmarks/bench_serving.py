"""Serving-backend QoS bench: stream-trained EAT vs baselines on the REAL
cluster -> BENCH_serving.json.

    PYTHONPATH=src python benchmarks/bench_serving.py
        [--servers 4] [--window-tasks 8] [--windows 3] [--rounds 6]
        [--rate 2.0] [--archs tinyllama-1.1b]

Three stages:
  1. train EAT in the stream (`train_stream_sac`, fused backend — the
     decision process is bitwise-identical to virtual-time serving, so the
     policy transfers exactly);
  2. evaluate the trained actor + baselines on `ExecSpec(backend="serving")`
     with real reduced-config models in virtual (Table-VI) time — the
     paper-comparable QoS numbers, plus real pool economics;
  3. re-evaluate everything under `serving_wall_clock=True` — measured
     prefill+decode seconds feed the latencies, the sim-to-real row.

Every row is the shared StreamAggregator schema (drop-inclusive p50/p95/p99,
violation, goodput, cold-start, utilization) + model_loads/model_reuses/
tasks_executed, so simulated and measured runs land in one table.
"""
from __future__ import annotations

import argparse
import warnings

import jax

from common import write_bench_json
from repro import api
from repro.core import agent as AG
from repro.core import env as EV
from repro.core import sac as SAC
from repro.core.scenarios import Scenario
from repro.core.workload import TraceConfig
from repro.training import stream_train as ST

BASELINES = ("greedy", "fifo", "random")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--window-tasks", type=int, default=8)
    ap.add_argument("--windows", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--archs", default="tinyllama-1.1b")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ecfg = EV.EnvConfig(num_servers=args.servers,
                        max_tasks=args.window_tasks)
    acfg = AG.AgentConfig(variant="eat-da", T=2)
    cell = Scenario(
        name=f"poisson-{args.servers}srv",
        ecfg=ecfg,
        tcfg=TraceConfig(num_tasks=args.window_tasks,
                         arrival_rate=args.rate,
                         max_servers=args.servers))

    # -- 1. train EAT in the stream (simulated, bitwise == virtual serving)
    print(f"[1/3] stream-training EAT ({args.rounds} rounds)...")
    tres = ST.train_stream_sac(
        ecfg, acfg, SAC.SACConfig(warmup_steps=64, batch_size=32),
        ST.StreamTrainConfig(rounds=args.rounds, streams=4,
                             max_steps_per_window=4 * args.window_tasks,
                             max_updates_per_round=16),
        scenario=cell, seed=args.seed,
        exec_spec=api.ExecSpec(backend="fused"))
    policies = {"eat": api.PolicySpec("eat", params=tres.state.actor,
                                      options={"acfg": acfg})}
    policies.update({b: api.PolicySpec(b) for b in BASELINES})

    # -- 2+3. evaluate on the real cluster, virtual then wall-clock -------
    wl = api.WorkloadSpec.streaming(
        cell, streams=1, num_windows=args.windows,
        window_tasks=args.window_tasks,
        max_steps_per_window=4 * args.window_tasks)
    keep = ("latency_p50", "latency_p95", "latency_p99", "latency_mean",
            "qos_violation_rate", "drop_rate", "goodput_per_s",
            "cold_start_rate", "reuse_rate", "utilization", "avg_quality",
            "tasks_injected", "tasks_scheduled", "tasks_executed",
            "tasks_dropped", "model_loads", "model_reuses", "wall_clock",
            "measured_busy_mean_s")
    rows = {}
    for stage, wall in (("virtual", False), ("wall_clock", True)):
        print(f"[{2 + int(wall)}/3] serving eval ({stage} time)...")
        spec = api.ExecSpec(backend="serving",
                            serving_archs=tuple(args.archs.split(",")),
                            serving_wall_clock=wall,
                            serving_prompt_len=8, serving_max_new_tokens=8,
                            serving_seed=args.seed)
        sim = api.Simulator(wl, spec)
        for name, pol in policies.items():
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", api.UntrainedPolicyWarning)
                r = sim.run(pol, jax.random.PRNGKey(args.seed))
            rows[f"{stage}/{name}"] = {
                **{k: r.summary[k] for k in keep if k in r.summary},
                "trained": r.trained, "wall_s": round(r.wall_s, 2)}
            print(f"    {stage:10s} {name:8s} p95="
                  f"{r.summary['latency_p95']:8.2f}s "
                  f"viol={r.summary['qos_violation_rate']:.3f} "
                  f"goodput={r.summary['goodput_per_s']:.4f}/s "
                  f"loads={r.summary['model_loads']}")

    write_bench_json("serving", {
        "servers": args.servers, "window_tasks": args.window_tasks,
        "windows": args.windows, "train_rounds": args.rounds,
        "arrival_rate": args.rate, "archs": args.archs.split(","),
        "final_train_return": tres.history[-1]["episode_return_mean"],
        "rows": rows,
    }, exec_backend="serving")


if __name__ == "__main__":
    main()
