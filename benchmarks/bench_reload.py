"""Table XI — model reload rate across algorithms / cluster sizes / rates.

Lower is better (fewer cold starts). Paper anchors at 4 servers / 0.05:
EAT 0.633 < EAT-A 0.667 < PPO 0.688 < EAT-DA 0.700 < Harmony 0.726 <
Random 0.800 < Genetic 0.850; Greedy's backlog artificially lowers its rate.
"""
from __future__ import annotations

from benchmarks import common as C


def run(verbose: bool = True):
    results = C.load_grid()
    if not results:
        print("no cached scheduling runs; run `python -m benchmarks.run` first")
        return None
    table = C.format_table(results, "reload_rate")
    if verbose:
        print("Table XI — model reload rate")
        print(table)
    return table


if __name__ == "__main__":
    run()
