"""Roofline report — assignment deliverable (g).

Reads the dry-run artifacts (``artifacts/dryrun/*.json``, produced by
``repro.launch.dryrun``) and prints, per (arch x shape) on the single-pod
mesh: the three roofline terms in seconds, the dominant bottleneck,
MODEL_FLOPS / HLO_FLOPs (useful-compute ratio), and per-device memory.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh: str = "single") -> List[Dict]:
    out = []
    if not os.path.isdir(ART):
        return out
    for fn in sorted(os.listdir(ART)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(ART, fn)) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            out.append(r)
    return out


def fmt_row(r: Dict) -> str:
    if r["status"] == "skipped":
        return f"| {r['arch']} | {r['shape']} | skipped |" + " - |" * 8
    if r["status"] != "ok":
        return f"| {r['arch']} | {r['shape']} | ERROR |" + " - |" * 8
    tc, tm, tl = (r.get("a_compute_s", 0), r.get("a_memory_s", 0),
                  r.get("a_collective_s", 0))
    dom = r.get("a_bottleneck", "?")
    mdom = r.get("bottleneck", "?")
    ratio = r.get("useful_flop_ratio", 0.0)
    peak_gb = r.get("peak_device_bytes", 0) / 1e9
    fits = "Y" if peak_gb < 15.2 else "N"   # v5e: 16 GB HBM, 5% headroom
    return (f"| {r['arch']} | {r['shape']} | {tc:.2e} | {tm:.2e} | {tl:.2e} "
            f"| {dom} | {mdom} | {ratio:.2f} | {peak_gb:.1f} | {fits} |")


def run(verbose: bool = True, mesh: str = "single") -> Optional[str]:
    rows = load(mesh)
    if not rows:
        print("no dry-run artifacts; run `python -m repro.launch.dryrun --all`")
        return None
    head = ("| arch | shape | compute_s | memory_s | collective_s | "
            "bottleneck | HLO-bneck | useful/HLO flops | HLO peak GB/dev "
            "| fits 16GB |")
    lines = [head, "|" + "---|" * 10]
    lines += [fmt_row(r) for r in rows]
    table = "\n".join(lines)
    if verbose:
        n_ok = sum(r["status"] == "ok" for r in rows)
        n_skip = sum(r["status"] == "skipped" for r in rows)
        print(f"Roofline ({mesh}-pod mesh): {n_ok} ok, {n_skip} skipped, "
              f"{len(rows) - n_ok - n_skip} errors")
        print("(compute/memory/collective = analytic model per device; "
              "HLO columns = measured, scan-body-once caveat — see "
              "EXPERIMENTS.md §Roofline)")
        print(table)
        census: Dict[str, int] = {}
        for r in rows:
            if r["status"] == "ok":
                b = r.get("a_bottleneck", "?")
                census[b] = census.get(b, 0) + 1
        print("analytic bottleneck census:", census)
    return table


if __name__ == "__main__":
    import sys
    run(mesh=sys.argv[1] if len(sys.argv) > 1 else "single")
