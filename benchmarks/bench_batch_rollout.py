"""Batched rollout engine benchmarks: host loop vs batched, fused vs sharded.

    PYTHONPATH=src python benchmarks/bench_batch_rollout.py --batch 32
    PYTHONPATH=src python benchmarks/bench_batch_rollout.py \
        --sharded --devices 8            # -> BENCH_sharded_rollout.json

Default mode rolls the same B (trace, key) pairs through (a)
`baselines.evaluate_policy` — the per-step host Python loop — and (b) the
`repro.api` "fused" backend — one jitted program — and reports warm
episodes/sec for both (identical metrics asserted; the engine is
bit-compatible with the host loop).

`--sharded` mode compares the "fused" backend (single device) against the
"sharded" backend (batch axis shard_map'd over the device mesh) at equal
batch sizes (default B in {256, 1024}) and writes BENCH_sharded_rollout.json.
`--devices N` forces N host CPU devices by re-execing with XLA_FLAGS before
jax initialises; results are bitwise-identical across backends, so the
speedup column is a free win.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _force_host_devices(n: int) -> None:
    """Re-exec with XLA_FLAGS forcing n host devices (must happen before
    jax backend init; safe here because main() runs before any jax call)."""
    flag = f"--xla_force_host_platform_device_count={n}"
    cur = os.environ.get("XLA_FLAGS", "")
    if flag in cur:
        return
    os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()
    os.execv(sys.executable, [sys.executable] + sys.argv)


def bench_host_vs_batched(args):
    import jax
    import numpy as np

    from repro.api import ExecSpec, rollout_fn_for
    from repro.core import baselines as BL
    from repro.core import env as EV
    from repro.core import rollout as RO
    from repro.core.workload import (TraceConfig, make_trace_batch,
                                     paper_rate_for)

    ecfg = EV.EnvConfig(num_servers=args.servers, max_tasks=args.tasks,
                        max_steps=args.max_steps)
    tc = TraceConfig(num_tasks=args.tasks,
                     arrival_rate=paper_rate_for(args.servers),
                     max_servers=args.servers)
    traces = make_trace_batch(jax.random.PRNGKey(1), tc, args.batch)
    keys = jax.random.split(jax.random.PRNGKey(2), args.batch)
    trace_list = [jax.tree_util.tree_map(lambda x, b=b: x[b], traces)
                  for b in range(args.batch)]
    if args.policy == "random":
        policy = RO.uniform_policy(ecfg)
        host_act = lambda tr: lambda k, s, o: BL.random_policy(k, ecfg)  # noqa: E731
    else:
        policy = RO.greedy_policy(ecfg)
        host_act = lambda tr: lambda k, s, o: BL.greedy_act(ecfg, tr, s)  # noqa: E731
    rollout = rollout_fn_for(ExecSpec(backend="fused"))

    # ---- host loop (warm its jitted step first) ----------------------
    BL.evaluate_policy(ecfg, trace_list[0], host_act(trace_list[0]), keys[0])
    t0 = time.perf_counter()
    host_metrics = [BL.evaluate_policy(ecfg, tr, host_act(tr), k)
                    for tr, k in zip(trace_list, keys)]
    host_s = time.perf_counter() - t0

    # ---- batched engine (api "fused" backend) ------------------------
    t0 = time.perf_counter()
    res = rollout(ecfg, traces, policy, {}, keys)
    jax.block_until_ready(res.metrics)
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(args.repeat):
        t0 = time.perf_counter()
        res = rollout(ecfg, traces, policy, {}, keys)
        jax.block_until_ready(res.metrics)
        times.append(time.perf_counter() - t0)
    batch_s = min(times)

    # metrics parity between the two paths: state-derived metrics are
    # bitwise; the return accumulation can differ by a float32 ulp when the
    # policy itself reduces over candidates (greedy under double-vmap).
    for k, rtol in (("num_scheduled", 0), ("avg_quality", 0),
                    ("avg_steps", 0), ("episode_return", 1e-6)):
        host_v = np.asarray([m[k] for m in host_metrics], np.float32)
        np.testing.assert_allclose(np.asarray(res.metrics[k], np.float32),
                                   host_v, rtol=rtol, atol=0)

    out = {
        "policy": args.policy, "batch": args.batch, "servers": args.servers,
        "max_steps": args.max_steps,
        "host_eps_per_s": args.batch / host_s,
        "batch_eps_per_s": args.batch / batch_s,
        "batch_tasks_per_s": args.batch * args.tasks / batch_s,
        "batch_compile_s": compile_s,
        "speedup": host_s / batch_s,
    }
    print(json.dumps(out, indent=1))
    print(f"\n{args.policy}: host {out['host_eps_per_s']:8.2f} eps/s | "
          f"batched {out['batch_eps_per_s']:8.2f} eps/s | "
          f"speedup x{out['speedup']:.1f} (compile {compile_s:.1f}s)")
    if args.json_out != "none":
        from common import write_bench_json
        write_bench_json(f"batch_rollout_{args.policy}", out,
                         out=args.json_out or None, fused=None,
                         exec_backend="fused")
    return out


def bench_sharded_vs_fused(args):
    """Equal-batch eps/s: "fused" on one device vs "sharded" over the mesh.
    Both are bitwise-identical programs, so speedup is pure scaling."""
    import jax
    import numpy as np

    from repro.api import ExecSpec, resolve_shards, rollout_fn_for
    from repro.core import env as EV
    from repro.core import rollout as RO
    from repro.core.workload import (TraceConfig, make_trace_batch,
                                     paper_rate_for)

    ecfg = EV.EnvConfig(num_servers=args.servers, max_tasks=args.tasks,
                        max_steps=args.max_steps)
    tc = TraceConfig(num_tasks=args.tasks,
                     arrival_rate=paper_rate_for(args.servers),
                     max_servers=args.servers)
    policy = RO.fifo_policy(ecfg)
    cells = []
    for B in [int(b) for b in args.sharded_batches.split(",")]:
        traces = make_trace_batch(jax.random.PRNGKey(1), tc, B)
        keys = jax.random.split(jax.random.PRNGKey(2), B)
        cell = {"batch": B, "servers": args.servers,
                "shards": resolve_shards(B, ExecSpec(backend="sharded"))}
        ref = None
        for backend in ("fused", "sharded"):
            rollout = rollout_fn_for(ExecSpec(backend=backend))

            def run():
                r = rollout(ecfg, traces, policy, {}, keys,
                            num_steps=args.max_steps)
                jax.block_until_ready(r.metrics["episode_return"])
                return r
            t0 = time.perf_counter()
            r = run()                              # compile
            compile_s = time.perf_counter() - t0
            if ref is None:
                ref = np.asarray(r.metrics["episode_return"])
            else:                                  # bitwise across backends
                np.testing.assert_array_equal(
                    ref, np.asarray(r.metrics["episode_return"]))
            n, t0 = 0, time.perf_counter()
            while time.perf_counter() - t0 < args.min_s:
                run()
                n += 1
            eps = B * n / (time.perf_counter() - t0)
            cell[backend] = {"eps_per_s": round(eps, 1),
                             "compile_s": round(compile_s, 2)}
        cell["speedup"] = round(cell["sharded"]["eps_per_s"]
                                / cell["fused"]["eps_per_s"], 2)
        cell["bitwise_identical"] = True
        cells.append(cell)
        print(f"B={B:5d} shards={cell['shards']}: "
              f"fused {cell['fused']['eps_per_s']:9.1f} eps/s  "
              f"sharded {cell['sharded']['eps_per_s']:9.1f} eps/s  "
              f"({cell['speedup']:.2f}x)", flush=True)

    payload = {"policy": "fifo", "tasks": args.tasks,
               "max_steps": args.max_steps, "cells": cells,
               "min_speedup": min(c["speedup"] for c in cells)}
    print(json.dumps(payload, indent=1))
    if args.json_out != "none":
        from common import write_bench_json
        write_bench_json("sharded_rollout", payload,
                         out=args.json_out or None, fused=True,
                         exec_backend="sharded")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--tasks", type=int, default=32)
    ap.add_argument("--max-steps", type=int, default=256)
    ap.add_argument("--policy", choices=("random", "greedy"), default="random")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--sharded", action="store_true",
                    help="bench the sharded vs fused api backends instead "
                         "of host-loop vs batched")
    ap.add_argument("--sharded-batches", default="256,1024")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host CPU devices (re-execs with "
                         "XLA_FLAGS before jax initialises)")
    ap.add_argument("--min-s", type=float, default=2.0)
    ap.add_argument("--json-out", default="",
                    help="BENCH json path ('' = repo-root default, "
                         "'none' = skip)")
    a = ap.parse_args()
    if a.devices:
        _force_host_devices(a.devices)
    sys.path.insert(0, os.path.dirname(__file__))
    if a.sharded:
        bench_sharded_vs_fused(a)
    else:
        bench_host_vs_batched(a)
