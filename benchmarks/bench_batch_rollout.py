"""Batched rollout engine vs host-loop evaluator (episodes/sec).

    PYTHONPATH=src python benchmarks/bench_batch_rollout.py --batch 32

Rolls the same B (trace, key) pairs through (a) `baselines.evaluate_policy`
— the per-step host Python loop — and (b) `rollout.batch_rollout` — one
jitted vmap+scan program — and reports warm episodes/sec for both. The
tier criterion is a >= 5x speedup at B=32 on CPU; identical metrics are
asserted (the engine is bit-compatible with the host loop).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import baselines as BL
from repro.core import env as EV
from repro.core import rollout as RO
from repro.core.workload import TraceConfig, make_trace_batch, paper_rate_for


def bench(args):
    ecfg = EV.EnvConfig(num_servers=args.servers, max_tasks=args.tasks,
                        max_steps=args.max_steps)
    tc = TraceConfig(num_tasks=args.tasks,
                     arrival_rate=paper_rate_for(args.servers),
                     max_servers=args.servers)
    traces = make_trace_batch(jax.random.PRNGKey(1), tc, args.batch)
    keys = jax.random.split(jax.random.PRNGKey(2), args.batch)
    trace_list = [jax.tree_util.tree_map(lambda x, b=b: x[b], traces)
                  for b in range(args.batch)]
    if args.policy == "random":
        policy = RO.uniform_policy(ecfg)
        host_act = lambda tr: lambda k, s, o: BL.random_policy(k, ecfg)  # noqa: E731
    else:
        policy = RO.greedy_policy(ecfg)
        host_act = lambda tr: lambda k, s, o: BL.greedy_act(ecfg, tr, s)  # noqa: E731

    # ---- host loop (warm its jitted step first) ----------------------
    BL.evaluate_policy(ecfg, trace_list[0], host_act(trace_list[0]), keys[0])
    t0 = time.perf_counter()
    host_metrics = [BL.evaluate_policy(ecfg, tr, host_act(tr), k)
                    for tr, k in zip(trace_list, keys)]
    host_s = time.perf_counter() - t0

    # ---- batched engine ----------------------------------------------
    t0 = time.perf_counter()
    res = RO.batch_rollout(ecfg, traces, policy, {}, keys)
    jax.block_until_ready(res.metrics)
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(args.repeat):
        t0 = time.perf_counter()
        res = RO.batch_rollout(ecfg, traces, policy, {}, keys)
        jax.block_until_ready(res.metrics)
        times.append(time.perf_counter() - t0)
    batch_s = min(times)

    # metrics parity between the two paths: state-derived metrics are
    # bitwise; the return accumulation can differ by a float32 ulp when the
    # policy itself reduces over candidates (greedy under double-vmap).
    for k, rtol in (("num_scheduled", 0), ("avg_quality", 0),
                    ("avg_steps", 0), ("episode_return", 1e-6)):
        host_v = np.asarray([m[k] for m in host_metrics], np.float32)
        np.testing.assert_allclose(np.asarray(res.metrics[k], np.float32),
                                   host_v, rtol=rtol, atol=0)

    out = {
        "policy": args.policy, "batch": args.batch, "servers": args.servers,
        "max_steps": args.max_steps,
        "host_eps_per_s": args.batch / host_s,
        "batch_eps_per_s": args.batch / batch_s,
        "batch_tasks_per_s": args.batch * args.tasks / batch_s,
        "batch_compile_s": compile_s,
        "speedup": host_s / batch_s,
    }
    print(json.dumps(out, indent=1))
    print(f"\n{args.policy}: host {out['host_eps_per_s']:8.2f} eps/s | "
          f"batched {out['batch_eps_per_s']:8.2f} eps/s | "
          f"speedup x{out['speedup']:.1f} (compile {compile_s:.1f}s)")
    if args.json_out != "none":
        from common import write_bench_json
        write_bench_json(f"batch_rollout_{args.policy}", out,
                         out=args.json_out or None, fused=None)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--tasks", type=int, default=32)
    ap.add_argument("--max-steps", type=int, default=256)
    ap.add_argument("--policy", choices=("random", "greedy"), default="random")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--json-out", default="",
                    help="BENCH json path ('' = repo-root default, "
                         "'none' = skip)")
    bench(ap.parse_args())
