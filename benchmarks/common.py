"""Shared infrastructure for the paper-table benchmarks.

Trains/evaluates every scheduling algorithm (EAT + ablations, PPO,
meta-heuristics, Random, Greedy) on the simulated edge cluster and caches
per-(algo, servers, rate) metrics under ``artifacts/scheduling/`` so the
table benchmarks (IX quality, X latency, XI reload) share one set of runs.
"""
from __future__ import annotations

import functools
import json
import os
import platform
import subprocess
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExecSpec, PolicySpec, evaluate_batch
from repro.core import agent as AG
from repro.core import baselines as BL
from repro.core import env as EV
from repro.core import ppo as PPO
from repro.core import sac as SAC
from repro.core.scenarios import PAPER_RATE_GRID as PAPER_GRID
from repro.core.workload import (TraceConfig, make_trace, paper_rate_for,
                                 stack_traces)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
SCHED_DIR = os.path.join(ART, "scheduling")
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

#: bench-record schema: 1 = pre-provenance records (no git_sha/platform);
#: 2 adds schema_version, git_sha, platform, python_version, cpu_count.
#: Every checked-in BENCH_*.json carries schema_version >= 2.
BENCH_SCHEMA_VERSION = 2


@functools.lru_cache(maxsize=1)
def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT, timeout=10,
            capture_output=True, text=True, check=True).stdout.strip()
    except Exception:
        return "unknown"


def write_bench_json(name: str, payload: Dict, out: Optional[str] = None,
                     fused: Optional[bool] = None,
                     exec_backend: Optional[str] = None) -> str:
    """Machine-readable perf record: BENCH_<name>.json at the repo root so
    the numbers are tracked across PRs. Adds a timestamp, jax version, the
    fused env-step flag (`fused=None` records the engine default), the
    `repro.api` execution backend, the local device count, and provenance
    (schema version, git SHA, platform, Python, CPU count), so perf
    trajectories across PRs state exactly which code + engine + machine
    produced them."""
    path = out or os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    payload = dict(payload)
    payload.setdefault("bench", name)
    payload.setdefault("schema_version", BENCH_SCHEMA_VERSION)
    payload.setdefault("timestamp", time.strftime("%Y-%m-%dT%H:%M:%S"))
    payload.setdefault("git_sha", _git_sha())
    payload.setdefault("jax_version", jax.__version__)
    payload.setdefault("backend", jax.default_backend())
    payload.setdefault("platform", platform.platform())
    payload.setdefault("python_version", platform.python_version())
    payload.setdefault("cpu_count", os.cpu_count())
    # batch_rollout defaults to the fused engine; None = "ran on default"
    payload.setdefault("env_step_fused", True if fused is None else bool(fused))
    if exec_backend is None:
        exec_backend = ("fused" if fused in (None, True) else "reference")
    payload.setdefault("exec_backend", exec_backend)
    payload.setdefault("device_count", jax.local_device_count())
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"bench json -> {path}")
    return path

DRL_ALGOS = ("eat", "eat-a", "eat-d", "eat-da", "ppo")
ALL_ALGOS = DRL_ALGOS + ("greedy", "random", "genetic", "harmony")

def make_env_cfg(num_servers: int) -> EV.EnvConfig:
    return EV.EnvConfig(num_servers=num_servers, queue_window=8,
                        s_min=10, s_max=50, max_tasks=32,
                        time_limit=1024.0, max_steps=1024)


def make_trace_cfg(num_servers: int, rate: float) -> TraceConfig:
    return TraceConfig(num_tasks=32, arrival_rate=rate,
                       max_servers=num_servers)


def trace_fn_for(num_servers: int, rate: float) -> Callable:
    tc = make_trace_cfg(num_servers, rate)
    return lambda key: make_trace(key, tc)


def eval_traces(num_servers: int, rate: float, n: int = 5, seed0: int = 10_000):
    fn = trace_fn_for(num_servers, rate)
    return [fn(jax.random.PRNGKey(seed0 + i)) for i in range(n)]


# ----------------------------------------------------------------------
# training (cached in-process; trained once per (algo, servers))
_TRAINED: Dict = {}


def train_drl(algo: str, num_servers: int, episodes: int, seed: int = 0,
              log_every: int = 0):
    """Train a DRL variant at the paper's per-cluster rate. Returns
    (rollout policy, policy params, history) for the batched evaluator."""
    cache_key = (algo, num_servers, episodes, seed)
    if cache_key in _TRAINED:
        return _TRAINED[cache_key]
    ecfg = make_env_cfg(num_servers)
    rate = paper_rate_for(num_servers)
    tfn = trace_fn_for(num_servers, rate)
    if algo == "ppo":
        st, hist = PPO.train_ppo(ecfg, PPO.PPOConfig(), tfn, episodes,
                                 seed=seed, log_every=log_every)
        policy, params = PPO.ppo_policy(ecfg), st.params
    else:
        acfg = AG.AgentConfig(variant=algo)
        scfg = SAC.SACConfig(batch_size=128, warmup_steps=192, update_every=2)
        ts, hist = SAC.train(ecfg, acfg, scfg, tfn, episodes, seed=seed,
                             log_every=log_every)
        policy, params = SAC.actor_policy(ecfg, acfg, deterministic=True), \
            ts.actor
    _TRAINED[cache_key] = (policy, params, hist)
    return policy, params, hist


# ----------------------------------------------------------------------
def evaluate_algo(algo: str, num_servers: int, rate: float, *,
                  episodes: int, n_eval: int = 5, seed: int = 0) -> Dict:
    """Average episode metrics for one algorithm at one (servers, rate).
    Policy algorithms evaluate all n_eval traces in one jitted batched
    rollout (bit-compatible with the old per-trace host loop)."""
    ecfg = make_env_cfg(num_servers)
    traces = eval_traces(num_servers, rate, n_eval)
    batched = stack_traces(traces)
    keys = jnp.stack([jax.random.PRNGKey(777 + i) for i in range(n_eval)])
    per_ep: List[Dict] = []

    if algo in ("eat", "eat-a", "eat-d", "eat-da", "ppo", "random", "greedy"):
        if algo in ("random", "greedy"):
            m = evaluate_batch(ecfg, batched, PolicySpec(algo), keys,
                               exec_spec=ExecSpec())
        else:
            policy, params, _ = train_drl(algo, num_servers, episodes,
                                          seed=seed)
            m = evaluate_batch(ecfg, batched, policy, keys, params=params,
                               exec_spec=ExecSpec())
        per_ep = [{k: float(v[i]) for k, v in m.items()}
                  for i in range(n_eval)]
    elif algo in ("genetic", "harmony"):
        # meta-heuristics optimise a fixed sequence on a *training* trace
        # (no run-time feedback, as the paper describes), then replay it on
        # the evaluation traces.
        opt_trace = trace_fn_for(num_servers, rate)(jax.random.PRNGKey(3))
        if algo == "genetic":
            gcfg = BL.GeneticConfig(seq_len=512, generations=12, population=32)
            seq, _ = BL.genetic_schedule(jax.random.PRNGKey(seed), ecfg,
                                         opt_trace, gcfg)
        else:
            hcfg = BL.HarmonyConfig(seq_len=512, improvisations=32,
                                    memory_size=32)
            seq, _ = BL.harmony_schedule(jax.random.PRNGKey(seed), ecfg,
                                         opt_trace, hcfg)
        rets, fstates = jax.vmap(
            lambda tr: BL.rollout_sequence(ecfg, tr, seq))(batched)
        ms = jax.vmap(
            lambda tr, s: EV.episode_metrics(ecfg, tr, s))(batched, fstates)
        for i in range(n_eval):
            m = {k: float(v[i]) for k, v in ms.items()}
            m.update(episode_return=float(rets[i]), episode_len=len(seq))
            per_ep.append(m)
    else:
        raise ValueError(f"unknown algo {algo!r}")

    keys = per_ep[0].keys()
    out = {k: float(np.mean([m[k] for m in per_ep])) for k in keys}
    out.update(algo=algo, servers=num_servers, rate=rate, n_eval=n_eval)
    return out


# ----------------------------------------------------------------------
def cache_path(algo: str, servers: int, rate: float) -> str:
    return os.path.join(SCHED_DIR, f"{algo}__{servers}__{rate:.2f}.json")


def run_grid(algos=ALL_ALGOS, grid: Optional[Dict] = None, *,
             episodes: int = 40, n_eval: int = 5, force: bool = False,
             verbose: bool = True) -> List[Dict]:
    """Populate the artifact cache for every (algo, servers, rate) cell."""
    os.makedirs(SCHED_DIR, exist_ok=True)
    grid = grid or PAPER_GRID
    results = []
    for servers, rates in grid.items():
        for algo in algos:
            for rate in rates:
                p = cache_path(algo, servers, rate)
                if os.path.exists(p) and not force:
                    with open(p) as f:
                        results.append(json.load(f))
                    continue
                t0 = time.time()
                m = evaluate_algo(algo, servers, rate, episodes=episodes,
                                  n_eval=n_eval)
                m["wall_s"] = round(time.time() - t0, 1)
                with open(p, "w") as f:
                    json.dump(m, f, indent=1)
                results.append(m)
                if verbose:
                    print(f"[{algo:8s} E={servers:2d} rate={rate:.2f}] "
                          f"q={m['avg_quality']:.3f} "
                          f"resp={m['avg_response']:7.1f} "
                          f"reload={m['reload_rate']:.3f} "
                          f"({m['wall_s']}s)", flush=True)
    return results


def load_grid() -> List[Dict]:
    out = []
    if not os.path.isdir(SCHED_DIR):
        return out
    for fn in sorted(os.listdir(SCHED_DIR)):
        if fn.endswith(".json"):
            with open(os.path.join(SCHED_DIR, fn)) as f:
                out.append(json.load(f))
    return out


def format_table(results: List[Dict], metric: str, fmt: str = "{:.3f}") -> str:
    """Paper-style table: rows = algos, columns = (servers, rate)."""
    cells = {}
    cols = []
    for r in results:
        col = (r["servers"], r["rate"])
        if col not in cols:
            cols.append(col)
        cells[(r["algo"], col)] = r.get(metric)
    cols.sort()
    algos = [a for a in ALL_ALGOS
             if any((a, c) in cells for c in cols)]
    head = "| Algorithm | " + " | ".join(f"{s}N@{r:.2f}" for s, r in cols) + " |"
    sep = "|" + "---|" * (len(cols) + 1)
    lines = [head, sep]
    for a in algos:
        row = [f"| {a:8s} "]
        for c in cols:
            v = cells.get((a, c))
            row.append("| " + (fmt.format(v) if v is not None else "-") + " ")
        lines.append("".join(row) + "|")
    return "\n".join(lines)
