"""Table I — task acceleration with different numbers of patches.

Two measurements:
  1. the calibrated latency model's speedup curve (paper Table I anchors:
     x1.8 @ 2 patches, x3.1 @ 4, x4.9 @ 8 for a 45-step generation);
  2. a REAL patch-parallel measurement on this host: a reduced LM service
     prefills a prompt split into c patches (the TPU mapping of
     DistriFusion's spatial patches — each patch is a sequence chunk on one
     mesh slice; here they run as a batched call), wall-clocked vs the
     single-patch run. On one CPU device the batched call has no real
     parallelism, so we report the *work-per-patch* scaling which on a
     c-wide mesh slice converts to the Table-I speedup.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import get_config
from repro.core import timemodel as TM
from repro.models.zoo import build_model

PAPER_TABLE_I = {1: 1.0, 2: 1.8, 4: 3.1, 8: 4.9}


def model_speedups(steps: int = 45) -> dict:
    t1 = float(TM.exec_time(jnp.asarray(1), jnp.asarray(steps)))
    out = {}
    for c in (1, 2, 4, 8):
        tc = float(TM.exec_time(jnp.asarray(c), jnp.asarray(steps)))
        out[c] = t1 / tc
    return out


def real_patch_prefill(arch: str = "tinyllama-1.1b", seq: int = 512,
                       iters: int = 3) -> dict:
    """Prefill a seq-token prompt as c patches of seq/c; time per patch-chunk."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    results = {}
    for c in (1, 2, 4):
        chunk = seq // c
        toks = jnp.zeros((c, chunk), jnp.int32)
        cache = model.make_cache(c, chunk, jnp.float32)

        fn = jax.jit(lambda p, b, ca: model.prefill(p, b, ca))
        out = fn(params, {"tokens": toks}, cache)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(params, {"tokens": toks}, cache)
            jax.block_until_ready(out)
        results[c] = (time.perf_counter() - t0) / iters
    return results


def run(verbose: bool = True, with_real: bool = True) -> dict:
    speedups = model_speedups()
    out = {"model_speedup": speedups, "paper": PAPER_TABLE_I}
    if with_real:
        real = real_patch_prefill()
        # on-a-real-mesh speedup = t(1 patch of S) / t(1 chunk of S/c):
        out["real_chunk_times_s"] = real
        out["real_projected_speedup"] = {c: real[1] / real[c] for c in real}
    if verbose:
        print("Table I — patch acceleration")
        print("| patches | model x | paper x |", "projected x |" if with_real else "")
        for c in (1, 2, 4, 8):
            line = f"| {c} | {speedups[c]:.1f} | {PAPER_TABLE_I[c]} |"
            if with_real and c in out.get("real_projected_speedup", {}):
                line += f" {out['real_projected_speedup'][c]:.1f} |"
            print(line)
    return out


if __name__ == "__main__":
    run()
