"""Streaming traffic engine throughput (wall-clock tasks/sec + sim QoS).

    PYTHONPATH=src python benchmarks/bench_traffic.py --streams 32 \
        --window-tasks 64 --windows 20

Streams one window-chained run per policy through `traffic.run_stream`
(ProcessTaskSource + Poisson at the paper rate) and records wall-clock
tasks/sec, per-window latency, and the simulated p50/p95/p99 / QoS numbers.
Writes BENCH_traffic.json at the repo root so the perf trajectory is
tracked across PRs (`make bench-traffic`).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax

from common import write_bench_json
from repro.core import env as EV
from repro.core.workload import TraceConfig, paper_rate_for
from repro.traffic.arrivals import PoissonArrivals
from repro.traffic.policies import make_policy
from repro.traffic.stream import ProcessTaskSource, StreamConfig, run_stream


def bench_policy(name: str, ecfg, tcfg, scfg, *, warm_windows: int = 2):
    policy, params = make_policy(name, ecfg)
    proc = PoissonArrivals(tcfg.arrival_rate)

    def one(num_windows, key_seed):
        src = ProcessTaskSource(proc, tcfg, jax.random.PRNGKey(key_seed),
                                num_streams=scfg.num_streams)
        cfg = dataclasses.replace(scfg, num_windows=num_windows)
        t0 = time.perf_counter()
        res = run_stream(ecfg, policy, params, src, jax.random.PRNGKey(1), cfg)
        return time.perf_counter() - t0, res

    warm_s, _ = one(warm_windows, 0)              # compile + warm windows
    wall_s, res = one(scfg.num_windows, 0)
    s = res.summary
    tasks = s["tasks_injected"]
    return {
        "policy": name,
        "tasks": tasks,
        "wall_s": wall_s,
        "warm_s": warm_s,
        "tasks_per_s": tasks / wall_s,
        "windows_per_s": scfg.num_windows / wall_s,
        "latency_p50": s["latency_p50"],
        "latency_p99": s["latency_p99"],
        "qos_violation_rate": s["qos_violation_rate"],
        "utilization": s["utilization"],
        "goodput_per_s": s["goodput_per_s"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--streams", type=int, default=32)
    ap.add_argument("--window-tasks", type=int, default=64)
    ap.add_argument("--windows", type=int, default=20)
    ap.add_argument("--policies", default="random,fifo,greedy")
    ap.add_argument("--fused", type=int, default=1,
                    help="1 = fused env-step engine (default), 0 = legacy "
                         "path (bitwise-identical QoS, slower)")
    ap.add_argument("--json-out", default="",
                    help="BENCH json path ('' = repo-root default, "
                         "'none' = skip)")
    args = ap.parse_args()

    ecfg = EV.EnvConfig(num_servers=args.servers, max_tasks=args.window_tasks)
    tcfg = TraceConfig(num_tasks=args.window_tasks,
                       arrival_rate=paper_rate_for(args.servers),
                       max_servers=args.servers)
    scfg = StreamConfig(num_windows=args.windows, num_streams=args.streams,
                        fused=bool(args.fused))

    rows = []
    for name in args.policies.split(","):
        row = bench_policy(name, ecfg, tcfg, scfg)
        rows.append(row)
        print(f"{name:>8s}: {row['tasks']:7d} tasks in {row['wall_s']:6.1f}s "
              f"= {row['tasks_per_s']:8.0f} tasks/s | "
              f"p99 {row['latency_p99']:8.1f}s "
              f"viol {row['qos_violation_rate']:.3f} "
              f"util {row['utilization']:.2f}")

    payload = {"servers": args.servers, "streams": args.streams,
               "window_tasks": args.window_tasks, "windows": args.windows,
               "comparability_note":
                   "absolute tasks/s depend on machine load at record time "
                   "and are NOT comparable across records; for engine "
                   "comparisons use BENCH_env_step.json, which measures "
                   "fused vs unfused side-by-side in one run",
               "policies": rows}
    print(json.dumps(payload, indent=1))
    if args.json_out != "none":
        write_bench_json("traffic", payload, out=args.json_out or None,
                         fused=bool(args.fused))


if __name__ == "__main__":
    main()
