"""Streaming traffic engine throughput (wall-clock tasks/sec + sim QoS).

    PYTHONPATH=src python benchmarks/bench_traffic.py --streams 32 \
        --window-tasks 64 --windows 20

Streams one window-chained run per policy through the `repro.api` facade
(`Simulator` with a streaming WorkloadSpec, Poisson at the paper rate) and
records wall-clock tasks/sec, per-window latency, and the simulated
p50/p95/p99 / QoS numbers. `--backend` picks the execution backend
(reference / fused / sharded — bitwise-identical QoS); writes
BENCH_traffic.json at the repo root so the perf trajectory is tracked
across PRs (`make bench-traffic`).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from common import write_bench_json
from repro.api import BACKENDS, ExecSpec, PolicySpec, Simulator, WorkloadSpec
from repro.core.scenarios import poisson_scenario
from repro.core.workload import paper_rate_for


def bench_policy(name: str, wl: WorkloadSpec, exec_spec: ExecSpec, *,
                 warm_windows: int = 2):
    spec = PolicySpec(name)

    def one(num_windows, key_seed):
        w = dataclasses.replace(wl, num_windows=num_windows)
        return Simulator(w, exec_spec).run(spec, jax.random.PRNGKey(key_seed))

    warm = one(warm_windows, 0)                   # compile + warm windows
    res = one(wl.num_windows, 0)
    s = res.summary
    tasks = s["tasks_injected"]
    return {
        "policy": name,
        "trained": res.trained,
        "tasks": tasks,
        "wall_s": res.wall_s,
        "warm_s": warm.wall_s,
        "tasks_per_s": tasks / res.wall_s,
        "windows_per_s": wl.num_windows / res.wall_s,
        "latency_p50": s["latency_p50"],
        "latency_p99": s["latency_p99"],
        "qos_violation_rate": s["qos_violation_rate"],
        "utilization": s["utilization"],
        "goodput_per_s": s["goodput_per_s"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--streams", type=int, default=32)
    ap.add_argument("--window-tasks", type=int, default=64)
    ap.add_argument("--windows", type=int, default=20)
    ap.add_argument("--policies", default="random,fifo,greedy")
    ap.add_argument("--backend", default="fused", choices=BACKENDS,
                    help="api execution backend (bitwise-identical QoS; "
                         "sharded splits streams over the device mesh)")
    ap.add_argument("--fused", type=int, default=None,
                    help="legacy alias: 1 = --backend fused, 0 = "
                         "--backend reference")
    ap.add_argument("--json-out", default="",
                    help="BENCH json path ('' = repo-root default, "
                         "'none' = skip)")
    args = ap.parse_args()
    backend = args.backend
    if args.fused is not None:
        backend = "fused" if args.fused else "reference"
    exec_spec = ExecSpec(backend=backend)

    sc = poisson_scenario(args.servers, paper_rate_for(args.servers))
    wl = WorkloadSpec.streaming(sc, streams=args.streams,
                                num_windows=args.windows,
                                window_tasks=args.window_tasks)

    rows = []
    for name in args.policies.split(","):
        row = bench_policy(name, wl, exec_spec)
        rows.append(row)
        print(f"{name:>8s}: {row['tasks']:7d} tasks in {row['wall_s']:6.1f}s "
              f"= {row['tasks_per_s']:8.0f} tasks/s | "
              f"p99 {row['latency_p99']:8.1f}s "
              f"viol {row['qos_violation_rate']:.3f} "
              f"util {row['utilization']:.2f}")

    payload = {"servers": args.servers, "streams": args.streams,
               "window_tasks": args.window_tasks, "windows": args.windows,
               "comparability_note":
                   "absolute tasks/s depend on machine load at record time "
                   "and are NOT comparable across records; for engine "
                   "comparisons use BENCH_env_step.json / "
                   "BENCH_sharded_rollout.json, which measure side-by-side "
                   "in one run",
               "policies": rows}
    print(json.dumps(payload, indent=1))
    if args.json_out != "none":
        write_bench_json("traffic", payload, out=args.json_out or None,
                         fused=backend != "reference", exec_backend=backend)


if __name__ == "__main__":
    main()
