"""Table XII — per-decision inference latency of each scheduler.

Wall-clocks one scheduling decision (state -> action) per algorithm on this
host. The paper's ordering (Greedy > EAT > EAT-A > EAT-DA ~ PPO > Random ~
meta-heuristics ~ 0) comes from: Greedy enumerates candidate futures, the
diffusion policies run the T=10 denoise chain, the attention encoder adds a
little on top of the MLP encoder, and the precomputed-sequence methods do no
inference at all.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import agent as AG
from repro.core import baselines as BL
from repro.core import env as EV
from repro.core import ppo as PPO
from repro.core import sac as SAC
from repro.core.workload import TraceConfig, make_trace


def _time_fn(fn, iters: int = 50) -> float:
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run(verbose: bool = True, num_servers: int = 4) -> Dict[str, float]:
    ecfg = EV.EnvConfig(num_servers=num_servers)
    trace = make_trace(jax.random.PRNGKey(0),
                       TraceConfig(max_servers=num_servers))
    state = EV.reset(ecfg)
    obs = EV.observe(ecfg, trace, state)
    key = jax.random.PRNGKey(1)
    out: Dict[str, float] = {}

    for variant in ("eat", "eat-a", "eat-d", "eat-da"):
        acfg = AG.AgentConfig(variant=variant)
        params = AG.init_actor(jax.random.PRNGKey(2), ecfg, acfg)
        out[variant] = _time_fn(lambda: jax.block_until_ready(
            SAC.policy_act(params, obs, key, ecfg=ecfg, acfg=acfg)))

    st = PPO.init_ppo(jax.random.PRNGKey(3), ecfg)
    out["ppo"] = _time_fn(lambda: jax.block_until_ready(
        PPO.ppo_act(st.params, obs, key, ecfg=ecfg)[0]))

    out["greedy"] = _time_fn(lambda: jax.block_until_ready(
        BL.greedy_act(ecfg, trace, state)))
    out["random"] = _time_fn(lambda: jax.block_until_ready(
        BL.random_policy(key, ecfg)))
    out["genetic"] = 0.0   # precomputed sequence: no run-time inference
    out["harmony"] = 0.0

    if verbose:
        print("Table XII — scheduler decision latency (s/decision)")
        for k in ("greedy", "eat", "eat-a", "eat-d", "eat-da", "ppo",
                  "random", "genetic", "harmony"):
            print(f"| {k:8s} | {out[k]:.2e} |")
    return out


if __name__ == "__main__":
    run()
