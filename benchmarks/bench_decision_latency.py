"""Table XII — per-decision inference latency of each scheduler.

Wall-clocks one scheduling decision (state -> action) per registered policy
on this host via the unified probe (`telemetry.profile.profile_policy`):
every policy resolves through `api.registry` to the rollout protocol, so
the measured program is exactly the inference the serving backend's
`_policy_prog` jit boundary pays per arriving task. Reports p50/p95/p99 and
mean seconds per decision and writes `BENCH_decision_latency.json`.

The paper's ordering (Greedy > EAT > EAT-A > EAT-DA ~ PPO > Random ~
meta-heuristics) comes from: Greedy enumerates candidate futures, the
diffusion policies run the T=10 denoise chain, the attention encoder adds a
little on top of the MLP encoder, and the precomputed-sequence methods only
index a replay buffer.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence

import jax

try:        # `python benchmarks/bench_decision_latency.py` (script dir)
    from common import make_env_cfg, make_trace_cfg, write_bench_json
except ImportError:     # `python -m benchmarks...` (package)
    from benchmarks.common import (make_env_cfg, make_trace_cfg,
                                   write_bench_json)
from repro.api import registry as REG
from repro.core.workload import make_trace
from repro.telemetry.profile import profile_policy

#: eat ablation variants ride along with the registered names — same
#: builder, different AgentConfig.variant
EAT_VARIANTS = ("eat", "eat-a", "eat-d", "eat-da")


def _specs(policies: Optional[Sequence] = None) -> List:
    if policies is not None:
        return list(policies)
    from repro.api import PolicySpec
    # offline meta-heuristics: tiny resolve-time optimisation budget — the
    # measured program (sequence_policy indexing) is identical regardless
    small = {"genetic": {"seq_len": 64, "generations": 2, "population": 8},
             "harmony": {"seq_len": 64, "improvisations": 4,
                         "memory_size": 8}}
    specs = []
    for name in REG.available_policies():
        if name == "eat":
            specs.extend(PolicySpec("eat", options={"variant": v})
                         for v in EAT_VARIANTS)
        else:
            specs.append(PolicySpec(name, options=small.get(name, {})))
    return specs


def run(verbose: bool = True, num_servers: int = 4, iters: int = 50,
        policies: Optional[Sequence] = None) -> Dict[str, Dict[str, float]]:
    ecfg = make_env_cfg(num_servers)
    tcfg = make_trace_cfg(num_servers, 0.75)
    trace = make_trace(jax.random.PRNGKey(0), tcfg)
    trace_fn = lambda key: make_trace(key, tcfg)  # noqa: E731

    out: Dict[str, Dict[str, float]] = {}
    for spec in _specs(policies):
        label = spec if isinstance(spec, str) else (
            spec.options.get("variant", spec.name))
        with warnings.catch_warnings():
            # untrained weights are fine: latency depends on architecture,
            # not on weight values
            warnings.simplefilter("ignore", REG.UntrainedPolicyWarning)
            rp = REG.resolve(spec, ecfg, trace_fn=trace_fn)
        out[label] = profile_policy(ecfg, rp.policy, rp.params,
                                    jax.random.PRNGKey(1), trace=trace,
                                    iters=iters)
        out[label]["kind"] = rp.kind

    if verbose:
        print("Table XII — scheduler decision latency (s/decision)")
        print("| policy   |     mean |      p50 |      p99 |")
        print("|----------|----------|----------|----------|")
        for k, m in sorted(out.items(),
                           key=lambda kv: -kv[1]["decision_latency_mean_s"]):
            print(f"| {k:8s} | {m['decision_latency_mean_s']:.2e} "
                  f"| {m['decision_latency_p50_s']:.2e} "
                  f"| {m['decision_latency_p99_s']:.2e} |")
    return out


if __name__ == "__main__":
    res = run()
    write_bench_json("decision_latency",
                     {"policies": res, "iters": 50, "num_servers": 4})
